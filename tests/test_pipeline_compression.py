"""Pipeline parallelism + compressed cross-pod sync + async checkpoints."""
import numpy as np
import pytest

from _subproc import run_devices


def test_pipeline_equals_sequential():
    """GPipe schedule over 4 stages == running the 4 blocks in sequence."""
    out = run_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.pipeline import run_pipeline

S, M, MB, D = 4, 6, 2, 16
mesh = jax.make_mesh((S,), ("stage",))
rng = np.random.default_rng(0)
w = jnp.asarray(rng.standard_normal((S, D, D)) * 0.2, jnp.float32)
x = jnp.asarray(rng.standard_normal((M, MB, 3, D)), jnp.float32)

def stage_fn(wi, xi):
    return jnp.tanh(xi @ wi)

def pipe(w_all, x_mb):
    return run_pipeline(stage_fn, w_all[0], x_mb, "stage", S)[None]

f = jax.jit(jax.shard_map(pipe, mesh=mesh, in_specs=(P("stage"), P()),
                          out_specs=P("stage"), check_vma=False))
outs = np.asarray(f(w, x))[-1]          # last stage's banked outputs

ref = x
for s in range(S):
    ref = jnp.tanh(ref @ w[s])
assert np.allclose(outs, np.asarray(ref), rtol=1e-5, atol=1e-5), \\
    np.abs(outs - np.asarray(ref)).max()
print("OK")
""", n=4)
    assert "OK" in out


def test_compressed_proxy_psum_bounded_error():
    out = run_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C

mesh = jax.make_mesh((2, 4), ("pod", "data"))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((8, 16, 8)), jnp.float32)

def f(xl):
    return C.compressed_proxy_psum(xl[0], "data", "pod")

r = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=(P(("pod", "data")),),
                          out_specs=P(), check_vma=False))(x)
exact = np.asarray(jnp.sum(x, axis=0))
err = np.abs(np.asarray(r) - exact)
# int8 rounding of per-pod regional sums: <= n_pods * scale/2
scale = np.abs(exact).max() / 127.0
assert err.max() <= 2 * scale + 1e-5, (err.max(), scale)
rel = err.max() / np.abs(exact).max()
assert rel < 0.02, rel
print("OK", float(rel))
""", n=8)
    assert "OK" in out


def test_async_checkpointer(tmp_path):
    import jax
    import jax.numpy as jnp
    from repro.checkpoint.ckpt import AsyncCheckpointer, restore_checkpoint

    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(1, tree)
    # mutate the live tree immediately — the snapshot must be unaffected
    tree["a"] = tree["a"] * 0
    ck.save(2, {"a": jnp.arange(10, dtype=jnp.float32) * 2,
                "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}})
    ck.wait()
    r1 = restore_checkpoint(str(tmp_path),
                            {"a": jnp.zeros(10, jnp.float32),
                             "b": {"c": jnp.zeros((3, 3), jnp.bfloat16)}},
                            step=1)
    np.testing.assert_array_equal(np.asarray(r1["a"]), np.arange(10))
    r2 = restore_checkpoint(str(tmp_path),
                            {"a": jnp.zeros(10, jnp.float32),
                             "b": {"c": jnp.zeros((3, 3), jnp.bfloat16)}},
                            step=2)
    np.testing.assert_array_equal(np.asarray(r2["a"]), np.arange(10) * 2)


def test_bubble_fraction():
    from repro.core.pipeline import pipeline_bubble_fraction
    assert pipeline_bubble_fraction(4, 12) == pytest.approx(3 / 15)
    assert pipeline_bubble_fraction(1, 8) == 0.0
