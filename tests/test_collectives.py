"""Proxy collective schedules on an 8-device fake mesh (subprocess: the
device count must be pinned before jax initialises, and the main test
process must keep seeing 1 device)."""
import numpy as np
import pytest

from _subproc import run_devices


def test_proxy_psum_equals_flat():
    out = run_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C
mesh = jax.make_mesh((2, 4), ("pod", "data"))
rng = np.random.default_rng(0)
for shape in [(8, 16, 4), (8, 5, 3), (8, 64)]:
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    r = C.hierarchical_psum(x, mesh, "data", "pod")
    assert np.allclose(r, jnp.sum(x, 0), rtol=1e-5, atol=1e-5), shape
print("OK")
""")
    assert "OK" in out


def test_two_hop_equals_one_hop_and_manual():
    out = run_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C
mesh = jax.make_mesh((2, 4), ("pod", "data"))
rng = np.random.default_rng(0)
buf = jnp.asarray(rng.standard_normal((8, 2, 4, 3, 5)), jnp.float32)
def run(fn):
    f = jax.shard_map(lambda b: fn(b[0], "data", "pod")[None],
                      mesh=mesh, in_specs=(P(("pod","data")),),
                      out_specs=P(("pod","data")), check_vma=False)
    return np.asarray(jax.jit(f)(buf))
a = run(C.two_hop_all_to_all)
b = run(C.one_hop_all_to_all)
assert np.allclose(a, b)
bufr = np.asarray(buf).reshape(2,4,2,4,3,5)
expect = np.transpose(bufr, (2,3,0,1,4,5)).reshape(8,2,4,3,5)
assert np.allclose(a, expect)
print("OK")
""")
    assert "OK" in out


def test_proxy_embedding_grad():
    out = run_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C
mesh = jax.make_mesh((2, 4), ("pod", "data"))
rng = np.random.default_rng(0)
V, D = 32, 4
ids = jnp.asarray(rng.integers(0, V, (8, 6)), jnp.int32)
gv = jnp.asarray(rng.standard_normal((8, 6, D)), jnp.float32)
def f(i, g):
    return C.proxy_embedding_grad(i[0], g[0], V, "data", "pod")
out = jax.jit(jax.shard_map(f, mesh=mesh,
    in_specs=(P(("pod","data")), P(("pod","data"))),
    out_specs=P("data", None), check_vma=False))(ids, gv)
dense = np.zeros((V, D), np.float32)
np.add.at(dense, np.asarray(ids).reshape(-1), np.asarray(gv).reshape(-1, D))
assert np.allclose(np.asarray(out), dense, rtol=1e-5, atol=1e-5)
print("OK")
""")
    assert "OK" in out


def test_sharded_train_step_runs():
    """A reduced arch trains on a 2x2 mesh with the rule-based shardings
    (integration: shardings.py x train_step x GSPMD)."""
    out = run_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import registry
from repro.training.optimizer import adamw
from repro.training.train_step import TrainState, make_train_step
from repro.launch.shardings import (batch_spec, opt_spec, param_spec,
                                    tree_shardings)
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((2, 2), ("data", "model"))
cfg, fam = registry.get("deepseek-7b", smoke=True)
opt = adamw(lr=1e-3)
params = fam["init"](cfg, jax.random.PRNGKey(0))
state = TrainState.create(params, opt)
sshard = TrainState(
    params=tree_shardings(params, param_spec, mesh, fsdp=True),
    opt_state=tree_shardings(state.opt_state, opt_spec, mesh, fsdp=True),
    step=NamedSharding(mesh, P()))
rng = np.random.default_rng(0)
batch = dict(tokens=jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32),
             labels=jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32))
bshard = tree_shardings(batch, batch_spec, mesh)
step = jax.jit(make_train_step(cfg, fam, opt),
               in_shardings=(sshard, bshard), out_shardings=(sshard, None))
with mesh:
    state2, m = step(state, batch)
    state3, m2 = step(state2, batch)
assert np.isfinite(float(m["loss"])) and np.isfinite(float(m2["loss"]))
# params actually moved by step 2 (step 1 has lr=0 from warmup; the
# loss itself may round equal in bf16)
d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(state2.params),
                        jax.tree.leaves(state3.params)))
assert d > 0, d
assert int(state3.step) == 2
print("OK", float(m["loss"]), float(m2["loss"]), d)
""", n=4, timeout=500)
    assert "OK" in out


def test_sharded_equals_single_device():
    """The sharded train step computes the same loss as unsharded."""
    out = run_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import registry
from repro.training.optimizer import adamw
from repro.training.train_step import TrainState, make_train_step
from repro.launch.shardings import batch_spec, opt_spec, param_spec, tree_shardings
from jax.sharding import NamedSharding, PartitionSpec as P
cfg, fam = registry.get("granite-moe-1b-a400m", smoke=True)
opt = adamw(lr=1e-3)
params = fam["init"](cfg, jax.random.PRNGKey(0))
state = TrainState.create(params, opt)
rng = np.random.default_rng(0)
batch = dict(tokens=jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32),
             labels=jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32))
# single device
_, m0 = jax.jit(make_train_step(cfg, fam, opt))(state, batch)
# 4-device mesh
mesh = jax.make_mesh((2, 2), ("data", "model"))
sshard = TrainState(
    params=tree_shardings(params, param_spec, mesh, fsdp=False),
    opt_state=tree_shardings(state.opt_state, opt_spec, mesh, fsdp=False),
    step=NamedSharding(mesh, P()))
bshard = tree_shardings(batch, batch_spec, mesh)
step = jax.jit(make_train_step(cfg, fam, opt),
               in_shardings=(sshard, bshard), out_shardings=(sshard, None))
with mesh:
    _, m1 = step(state, batch)
d = abs(float(m0["loss"]) - float(m1["loss"]))
assert d < 1e-2, d
print("OK", d)
""", n=4, timeout=500)
    assert "OK" in out
