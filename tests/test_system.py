"""End-to-end behaviour tests: graph pipeline, LM pipeline, dry-run
machinery (parser + sharding rules as pure functions)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest


def test_graph_end_to_end(small_graph, grid8):
    """dataset -> engine -> traffic counters -> priced system report."""
    from repro.core.costmodel import DCRA_SRAM, price
    from repro.core.proxy import ProxyConfig
    from repro.graph import apps, oracles
    g = small_graph
    root = int(np.argmax(g.out_degree()))
    r = apps.bfs(g, root, grid8, proxy=ProxyConfig(4, 4, slots=256),
                 oq_cap=32)
    assert np.array_equal(r.values, oracles.bfs_oracle(g, root))
    rep = price(DCRA_SRAM, grid8, r.run.counters,
                mem_bits_sram=float(g.footprint_bytes() * 8),
                per_superstep_peak=dict(time_s=r.run.time_s))
    assert rep.time_s > 0 and rep.energy_j > 0 and rep.cost_usd > 0
    assert r.gteps > 0


def test_lm_end_to_end_train_drop():
    """~0.5M-param model, 25 steps: loss demonstrably decreases."""
    from repro.launch.train import main
    losses = main(["--arch", "deepseek-7b", "--smoke", "--steps", "25",
                   "--batch", "8", "--seq", "32", "--lr", "3e-3",
                   "--log-every", "100"])
    assert losses[-1] < losses[0]


def test_generate_roundtrip():
    from repro.serving.decode import generate
    import jax
    from repro.models import registry
    cfg, fam = registry.get("h2o-danube-3-4b", smoke=True)
    params = fam["init"](cfg, jax.random.PRNGKey(0))
    import jax.numpy as jnp
    toks = jnp.zeros((2, 8), jnp.int32)
    out = generate(cfg, fam, params, dict(tokens=toks), steps=4)
    assert out.shape == (2, 4)
    assert int(out.max()) < cfg.vocab


# ------------------------------------------------------- dry-run machinery
def test_collective_parser():
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x)
  %all-gather.2 = bf16[64]{0} all-gather(bf16[32]{0} %y)
  %add.3 = f32[2]{0} add(f32[2]{0} %a, f32[2]{0} %b)
  ROOT %all-to-all.4 = (f32[16,16]{1,0}) all-to-all(f32[16,16]{1,0} %z)
"""
    r = parse_collectives(hlo)
    assert r["bytes"]["all-reduce"] == 128 * 256 * 4
    assert r["bytes"]["all-gather"] == 64 * 2
    assert r["bytes"]["all-to-all"] == 16 * 16 * 4
    assert r["counts"]["all-reduce"] == 1
    assert r["total_bytes"] == 128 * 256 * 4 + 128 + 1024


def test_sharding_rules_divisibility():
    """Rules never assign an axis that does not divide (subprocess with a
    4-device mesh; checks every leaf of a stacked param tree)."""
    from _subproc import run_devices
    out = run_devices("""
import jax, numpy as np
import jax.tree_util as jtu
from repro.models import registry
from repro.launch.shardings import param_spec
mesh = jax.make_mesh((2, 2), ("data", "model"))
sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
for arch in registry.ARCHS:
    cfg, fam = registry.get(arch, smoke=True)
    abs_p = jax.eval_shape(lambda: fam["init"](cfg, jax.random.PRNGKey(0)))
    for path, leaf in jtu.tree_flatten_with_path(abs_p)[0]:
        ps = jtu.keystr(path)
        spec = param_spec(ps, tuple(leaf.shape), mesh, fsdp=True)
        for ax, name in zip(range(len(leaf.shape)), list(spec) + [None]*9):
            if name is None: continue
            names = name if isinstance(name, tuple) else (name,)
            n = int(np.prod([sizes[a] for a in names]))
            assert leaf.shape[ax] % n == 0, (arch, ps, leaf.shape, spec)
print("OK")
""", n=4, timeout=400)
    assert "OK" in out


def test_dryrun_smoke_cell(tmp_path):
    """One tiny-arch dry-run cell end-to-end in a subprocess (512 fake
    devices, full machinery: shardings, lower, compile, artifact)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "decode_32k", "--mesh", "single",
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=560, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    art = json.load(open(os.path.join(
        str(tmp_path), "whisper-tiny_decode_32k_single.json")))
    assert art["status"] == "ok"
    assert art["n_devices"] == 256
    assert art["cost"]["flops_per_device"] > 0
    assert art["dominant"] in ("compute_s", "memory_s", "collective_s")
