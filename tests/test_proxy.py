"""Proxy-region mapping properties (paper Fig. 2 semantics)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
pytestmark = pytest.mark.property
from hypothesis import given, settings, strategies as st

from repro.core.proxy import ProxyConfig, pcache_slot, proxy_tile, region_id
from repro.core.tilegrid import TileGrid


@given(st.integers(0, 4095), st.integers(0, 4095))
@settings(max_examples=150, deadline=None)
def test_proxy_in_senders_region(owner, src):
    g = TileGrid(64, 64)
    cfg = ProxyConfig(region_ny=16, region_nx=16)
    p = int(proxy_tile(g, cfg, owner, src))
    assert region_id(g, cfg, p) == region_id(g, cfg, src)


@given(st.integers(0, 4095), st.integers(0, 4095), st.integers(0, 4095))
@settings(max_examples=100, deadline=None)
def test_proxy_deterministic_per_region(owner, s1, s2):
    """Two senders in the same region proxy a given owner to the SAME
    tile (that's what makes coalescing possible)."""
    g = TileGrid(64, 64)
    cfg = ProxyConfig(region_ny=16, region_nx=16)
    if region_id(g, cfg, s1) == region_id(g, cfg, s2):
        assert int(proxy_tile(g, cfg, owner, s1)) == \
            int(proxy_tile(g, cfg, owner, s2))


@given(st.integers(0, 4095), st.integers(0, 4095))
@settings(max_examples=100, deadline=None)
def test_proxy_distinct_owners_spread(o1, o2):
    """Owners with different in-region coordinates map to different proxy
    tiles (P_DIST distributes proxy ownership across the region)."""
    g = TileGrid(64, 64)
    cfg = ProxyConfig(region_ny=16, region_nx=16)
    src = 0
    oy1, ox1 = divmod(o1, 64)
    oy2, ox2 = divmod(o2, 64)
    if (oy1 % 16, ox1 % 16) != (oy2 % 16, ox2 % 16):
        assert int(proxy_tile(g, cfg, o1, src)) != \
            int(proxy_tile(g, cfg, o2, src))


def test_proxy_reduces_hops_on_average():
    """The point of the technique: average src->proxy distance is smaller
    than src->owner distance for uniformly random traffic."""
    g = TileGrid(64, 64)
    cfg = ProxyConfig(region_ny=16, region_nx=16)
    rng = np.random.default_rng(0)
    src = rng.integers(0, 4096, 4000)
    owner = rng.integers(0, 4096, 4000)
    p = proxy_tile(g, cfg, owner, src)
    d_direct = np.asarray(g.hops(src, owner)).mean()
    d_proxy = np.asarray(g.hops(src, np.asarray(p))).mean()
    assert d_proxy < d_direct * 0.55          # 16x16 region in 64x64 grid


@given(st.integers(0, 10_000_000))
@settings(max_examples=50, deadline=None)
def test_pcache_slot_in_range(idx):
    cfg = ProxyConfig(4, 4, slots=256)
    assert 0 <= int(pcache_slot(cfg, idx)) < 256
