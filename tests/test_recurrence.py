"""Chunkwise-parallel == recurrent for the sequence-mixing blocks (the
training path and the decode path must be the same function)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
pytestmark = pytest.mark.property
from hypothesis import given, settings, strategies as st

import repro.models.ssm as ssm
import repro.models.xlstm as xl


@dataclasses.dataclass(frozen=True)
class Cfg:
    d_model: int = 32
    n_heads: int = 2
    norm_bias: bool = False
    xlstm_proj: int = 2
    ssm_expand: int = 2
    ssm_heads: int = 2
    ssm_head_dim: int = 32
    ssm_state: int = 8


CFG = Cfg()


def _x(seq, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (2, seq, CFG.d_model)).astype(jnp.bfloat16)


@given(st.integers(3, 40))
@settings(max_examples=8, deadline=None)
def test_mlstm_chunk_equals_recurrent(seq):
    old = xl.MCHUNK
    xl.MCHUNK = 8
    try:
        p = xl.mlstm_init(jax.random.PRNGKey(0), CFG)
        x = _x(seq)
        y_chunk, st_chunk = xl.mlstm_forward(p, x, CFG)
        di = CFG.xlstm_proj * CFG.d_model
        pp = di // CFG.n_heads
        state = (jnp.zeros((2, CFG.n_heads, pp, pp)),
                 jnp.zeros((2, CFG.n_heads, pp)),
                 jnp.full((2, CFG.n_heads), -1e30))
        ys = []
        for t in range(seq):
            yt, state = xl.mlstm_decode(p, x[:, t:t + 1], state, CFG)
            ys.append(yt)
        y_rec = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(
            np.asarray(y_chunk, np.float32), np.asarray(y_rec, np.float32),
            rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(np.asarray(st_chunk[0]),
                                   np.asarray(state[0]), rtol=1e-2,
                                   atol=1e-2)
    finally:
        xl.MCHUNK = old


@given(st.integers(3, 40))
@settings(max_examples=8, deadline=None)
def test_ssd_chunk_equals_recurrent(seq):
    old = ssm.CHUNK
    ssm.CHUNK = 8
    try:
        p = ssm.ssd_init(jax.random.PRNGKey(2), CFG)
        x = _x(seq, seed=3)
        y1, st1 = ssm.ssd_forward(p, x, CFG)
        state = (jnp.zeros((2, CFG.ssm_heads, CFG.ssm_head_dim,
                            CFG.ssm_state)),
                 jnp.zeros((2, ssm.CONV_W - 1,
                            CFG.ssm_expand * CFG.d_model), x.dtype))
        ys = []
        for t in range(seq):
            yt, state = ssm.ssd_decode(p, x[:, t:t + 1], state, CFG)
            ys.append(yt)
        y2 = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(
            np.asarray(y1, np.float32), np.asarray(y2, np.float32),
            rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(np.asarray(st1[0]), np.asarray(state[0]),
                                   rtol=1e-2, atol=1e-2)
    finally:
        ssm.CHUNK = old


def test_slstm_state_carry():
    """sLSTM forward from state == concatenated forward."""
    p = xl.slstm_init(jax.random.PRNGKey(4), CFG)
    x = _x(16, seed=5)
    y_full, st_full = xl.slstm_forward(p, x, CFG)
    y1, st1 = xl.slstm_forward(p, x[:, :8], CFG)
    y2, st2 = xl.slstm_forward(p, x[:, 8:], CFG, state=st1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1), np.float32),
        np.asarray(y_full, np.float32), rtol=2e-2, atol=2e-2)
