"""Telemetry/observability stack (``repro.obs``).

Acceptance properties:
  * attaching an observer (with ``telemetry=True``) is **bit-identical**
    to a bare run — same final values, TrafficCounters, SuperstepTrace
    and superstep count — for all six apps, monolithic and 4-chip, and
    the measured host-sync count (``engine.host_syncs``) is unchanged;
  * the Chrome trace-event export is valid JSON with the documented
    shape and a span for every chunk on every wall track;
  * the imbalance metrics match an O(n²) NumPy oracle on hand-built
    matrices;
  * cascading improves measured load balance: cascade-on total Gini ≤
    cascade-off on the RMAT test graph (8x8 tiles, 4 chips), with
    positive cascade efficacy vs the no-proxy baseline;
  * the metrics registry is deterministic and survives snapshot/reset.
"""
import json

import numpy as np
import pytest

from repro import obs
from repro.core.tilegrid import square_grid
from repro.graph import apps, rmat_edges
from repro.graph.rmat import histogram_input
from repro.obs import export as obs_export
from repro.obs import imbalance as obs_imbalance
from repro.obs import report as obs_report
from repro.obs.metrics import Histogram, MetricsRegistry, default_registry

GRID = square_grid(16)
CHUNK = 8
ALL_APPS = ("bfs", "sssp", "wcc", "pagerank", "spmv", "histo")


@pytest.fixture(scope="module")
def g():
    return rmat_edges(8, edge_factor=8, seed=1)


@pytest.fixture(scope="module")
def root(g):
    return int(np.argmax(g.out_degree()))


def _run(name, g, root, chips=0, **extra):
    """One chunked run per app, Table-II proxy policy (as test_chunked)."""
    if chips:
        extra["chips"] = chips
    if name == "bfs":
        return apps.bfs(g, root, GRID, oq_cap=16, run_chunk=CHUNK, **extra)
    if name == "sssp":
        px = apps.table2_proxy(GRID, "sssp")
        return apps.sssp(g, root, GRID, proxy=px, oq_cap=16,
                         run_chunk=CHUNK, **extra)
    if name == "wcc":
        px = apps.table2_proxy(GRID, "wcc")
        return apps.wcc(g, GRID, proxy=px, oq_cap=16, run_chunk=CHUNK,
                        **extra)
    if name == "pagerank":
        px = apps.table2_proxy(GRID, "pagerank")
        return apps.pagerank(g, GRID, proxy=px, epochs=2, oq_cap=16,
                             run_chunk=CHUNK, **extra)
    if name == "spmv":
        x = np.random.default_rng(3).random(g.n_cols).astype(np.float32)
        px = apps.table2_proxy(GRID, "spmv", cascade_levels=1)
        return apps.spmv(g, x, GRID, proxy=px, oq_cap=16, run_chunk=CHUNK,
                         **extra)
    if name == "histo":
        bins = g.n_rows // 8
        hv = histogram_input(g, bins)
        px = apps.table2_proxy(GRID, "histo")
        return apps.histogram(hv, bins, GRID, proxy=px, oq_cap=8,
                              run_chunk=CHUNK, **extra)
    raise ValueError(name)


def _syncs() -> float:
    return default_registry().counter("engine.host_syncs").value


# -------------------------------------------------- observer bit-identity
def _assert_observer_inert(name, g, root, chips):
    s0 = _syncs()
    base = _run(name, g, root, chips=chips)
    syncs_off = _syncs() - s0
    rec = obs.TimelineRecorder()
    s1 = _syncs()
    r = _run(name, g, root, chips=chips, telemetry=True, observer=rec)
    syncs_on = _syncs() - s1
    assert np.array_equal(base.values, r.values)
    db, dr = base.run.counters.as_dict(), r.run.counters.as_dict()
    assert db == dr, {k: (db[k], dr[k]) for k in db if db[k] != dr[k]}
    assert base.run.trace.to_dict() == r.run.trace.to_dict()
    assert base.run.supersteps == r.run.supersteps
    assert syncs_on == syncs_off, "observer added host syncs"
    assert rec.spans, "observer saw no chunks"
    assert rec.meta is not None and rec.result is not None
    assert rec.meta.telemetry and rec.meta.chunk == CHUNK
    if name != "pagerank":            # pagerank: one span set per epoch
        assert rec.supersteps == r.run.supersteps
    assert rec.vec_keys(), "telemetry recorded no load vectors"
    return rec, r


@pytest.mark.parametrize("name", ALL_APPS)
def test_observer_bit_identical_monolithic(name, g, root):
    rec, _ = _assert_observer_inert(name, g, root, chips=0)
    assert "tv_delivered" in rec.vec_keys()
    load = obs.run_load_matrix(rec)
    assert load.shape[1] == GRID.ny * GRID.nx


@pytest.mark.parametrize("name", ALL_APPS)
def test_observer_bit_identical_4chip(name, g, root):
    rec, _ = _assert_observer_inert(name, g, root, chips=4)
    assert "pc_delivered" in rec.vec_keys()
    load = obs.run_load_matrix(rec)
    assert load.shape[1] == 4


def test_legacy_loop_emits_per_step_spans(g, root):
    rec = obs.TimelineRecorder()
    r = apps.bfs(g, root, GRID, oq_cap=16, run_chunk=0, telemetry=True,
                 observer=rec)
    assert len(rec.spans) == r.run.supersteps
    assert all(s.n_steps == 1 for s in rec.spans)
    assert rec.supersteps == r.run.supersteps


# ------------------------------------------------------ trace-event export
@pytest.fixture(scope="module")
def bfs4_rec(g, root):
    rec = obs.TimelineRecorder()
    r = _run("bfs", g, root, chips=4, telemetry=True, observer=rec)
    return rec, r


def test_trace_event_schema(bfs4_rec, tmp_path):
    rec, _ = bfs4_rec
    path = str(tmp_path / "trace.json")
    obs.write_trace(rec, path)
    with open(path) as f:
        d = json.load(f)
    assert set(d) == {"traceEvents", "displayTimeUnit", "otherData"}
    assert d["otherData"]["n_chips"] == 4
    evs = d["traceEvents"]
    assert evs and all(e["ph"] in ("M", "X", "C") for e in evs)
    for e in evs:
        assert {"ph", "pid", "tid", "name"} <= set(e)
        if e["ph"] == "X":
            assert e["ts"] >= 0.0 and e["dur"] >= 0.0


def test_trace_has_span_per_chunk_per_track(bfs4_rec):
    rec, _ = bfs4_rec
    evs = obs.to_trace_events(rec)
    host_x = [e for e in evs
              if e["ph"] == "X" and e["pid"] == obs_export.PID_HOST]
    # one complete span per chunk on each of dispatch/fetch/account
    assert len(host_x) == 3 * len(rec.spans)
    for s in rec.spans:
        label = f"chunk {s.index} [{s.step_lo}:{s.step_hi})"
        assert sum(e["name"] == label for e in host_x) == 3
    sim_x = [e for e in evs
             if e["ph"] == "X" and e["pid"] == obs_export.PID_SIM]
    assert sim_x, "no simulated BSP spans"
    counters = [e for e in evs if e["ph"] == "C"]
    pids = {e["pid"] for e in counters}
    assert pids == {obs_export.PID_CHIP0 + c for c in range(4)}


# --------------------------------------------- compaction telemetry track
def test_compaction_track_schema_and_metrics(g, root, bfs4_rec):
    """Compacted runs emit the active-set counter track (one
    active_fraction + bucket_cap sample per superstep, on the sim
    process) plus the engine.active_fraction gauge and per-capacity
    bucket-occupancy counters — all riding the existing chunk stat
    fetch.  Dense runs emit none of it."""
    reg = default_registry()
    before = dict(reg.snapshot()["counters"])
    rec = obs.TimelineRecorder()
    r = _run("bfs", g, root, telemetry=True, observer=rec, compaction=2)
    evs = obs.to_trace_events(rec)
    comp = [e for e in evs if e["ph"] == "C"
            and e["pid"] == obs_export.PID_SIM
            and e["tid"] == obs_export._TID_COMPACTION]
    fracs = [e for e in comp if e["name"] == "active_fraction"]
    caps = [e for e in comp if e["name"] == "bucket_cap"]
    assert len(fracs) == r.run.supersteps
    assert len(caps) == r.run.supersteps
    assert all(0.0 <= e["args"]["active_fraction"] <= 1.0 for e in fracs)
    from repro.core.engine import capacity_ladder
    ladder = set(map(float, capacity_ladder(GRID.ny * GRID.nx, 2)))
    assert {e["args"]["bucket_cap"] for e in caps} <= ladder
    for e in comp:                       # schema: counter-track events
        assert {"ph", "pid", "tid", "name", "ts", "args"} <= set(e)
        assert e["ts"] >= 0.0
    snap = reg.snapshot()
    assert 0.0 <= snap["gauges"]["engine.active_fraction"] <= 1.0
    occ = {k: v - before.get(k, 0.0)
           for k, v in snap["counters"].items()
           if k.startswith("engine.bucket_occupancy.")}
    occ = {k: v for k, v in occ.items() if v}
    assert occ, "no bucket-occupancy counters incremented"
    assert {float(k.rsplit(".", 1)[1]) for k in occ} <= ladder
    assert sum(occ.values()) == r.run.supersteps
    # dense run (module fixture): no compaction track at all
    dense_rec, _ = bfs4_rec
    dense = [e for e in obs.to_trace_events(dense_rec)
             if e.get("tid") == obs_export._TID_COMPACTION]
    assert dense == []


# ------------------------------------------------------- imbalance metrics
def _gini_oracle(x):
    """O(n²) mean-absolute-difference definition."""
    x = np.asarray(x, np.float64)
    n, s = x.size, float(x.sum())
    if n == 0 or s <= 0:
        return 0.0
    return float(np.abs(x[:, None] - x[None, :]).sum() / (2.0 * n * s))


def test_gini_matches_oracle(rng):
    for n in (1, 2, 3, 7, 32):
        x = rng.random(n) * 10.0
        assert obs.gini(x) == pytest.approx(_gini_oracle(x), abs=1e-12)
    ints = rng.integers(0, 50, 16).astype(float)
    assert obs.gini(ints) == pytest.approx(_gini_oracle(ints), abs=1e-12)
    assert obs.gini(np.array([])) == 0.0
    assert obs.gini(np.zeros(5)) == 0.0
    assert obs.gini(np.full(9, 3.0)) == pytest.approx(0.0, abs=1e-12)
    # one worker holds everything: (n-1)/n
    assert obs.gini(np.array([0.0, 0.0, 0.0, 7.0])) == pytest.approx(0.75)


def test_summarize_hand_built():
    load = np.array([[1.0, 1.0, 1.0, 1.0],
                     [0.0, 0.0, 0.0, 8.0],
                     [0.0, 0.0, 0.0, 0.0]])
    s = obs_imbalance.summarize(load, top=2)
    assert s["supersteps"] == 3 and s["workers"] == 4
    # totals per worker: [1, 1, 1, 9]
    assert s["total_gini"] == pytest.approx(_gini_oracle([1, 1, 1, 9]))
    assert s["total_max_over_mean"] == pytest.approx(9.0 / 3.0)
    # idle step 2 excluded from per-step means
    assert s["mean_step_gini"] == pytest.approx((0.0 + 0.75) / 2.0)
    assert s["max_step_gini"] == pytest.approx(0.75)
    assert s["mean_step_max_over_mean"] == pytest.approx((1.0 + 4.0) / 2.0)
    assert [t["step"] for t in s["top_steps"]] == [1, 0]
    assert s["top_steps"][0]["load"] == pytest.approx(8.0)


def test_max_over_mean():
    assert obs.max_over_mean([2.0, 2.0]) == pytest.approx(1.0)
    assert obs.max_over_mean([0.0, 4.0]) == pytest.approx(2.0)
    assert obs.max_over_mean([]) == 0.0
    assert obs.max_over_mean([0.0, 0.0]) == 0.0


def test_cascade_efficacy_formula():
    assert obs.cascade_efficacy(50.0, 100.0) == pytest.approx(0.5)
    assert obs.cascade_efficacy(100.0, 100.0) == pytest.approx(0.0)
    assert obs.cascade_efficacy(150.0, 100.0) == pytest.approx(-0.5)
    assert obs.cascade_efficacy(10.0, 0.0) == 0.0


def test_cascade_improves_measured_balance(g, root):
    """The paper's load-balance claim, measured: on the 8x8-tile 4-chip
    partition, BFS with a 2-level cascade tree has lower whole-run Gini
    than the same proxy without cascading, and positive cascade efficacy
    vs the no-proxy baseline."""
    grid = square_grid(64)
    base = apps.bfs(g, root, grid, oq_cap=16, run_chunk=CHUNK, chips=4)
    recs = {}
    for levels in (0, 2):
        rec = obs.TimelineRecorder()
        px = apps.table2_proxy(grid, "bfs", cascade_levels=levels,
                               selective=False)
        apps.bfs(g, root, grid, proxy=px, oq_cap=16, run_chunk=CHUNK,
                 chips=4, telemetry=True, observer=rec)
        recs[levels] = rec
    rep_on = obs.imbalance_report(recs[2], base.run.counters)
    rep_off = obs.imbalance_report(recs[0], base.run.counters)
    assert rep_on["total_gini"] <= rep_off["total_gini"]
    assert rep_on["cascade_efficacy"] > 0.0
    assert rep_on["owner_msgs"] < rep_on["baseline_owner_msgs"]


# ----------------------------------------------------------- run report
def test_run_report_and_markdown(bfs4_rec, tmp_path):
    rec, r = bfs4_rec
    rep = obs_report.run_report(rec, teps_edges=r.teps_edges)
    assert rep["app"] == "bfs" and rep["n_chips"] == 4
    assert rep["supersteps"] == r.run.supersteps
    assert rep["sim_time_s"] == pytest.approx(float(r.run.time_s))
    assert rep["gteps"] == pytest.approx(r.gteps)
    assert rep["counters"] == r.run.counters.as_dict()
    assert sum(rep["superstep_histogram"]["counts"]) == r.run.supersteps
    assert rep["sanitizer"]["status"] == "off"
    assert rep["imbalance"]["supersteps"] == r.run.supersteps
    paths = obs.write_report(rep, str(tmp_path / "rep"))
    with open(paths["json"]) as f:
        assert json.load(f)["app"] == "bfs"
    md = open(paths["markdown"]).read()
    assert md.startswith("# Run report: bfs")
    assert "Load imbalance" in md


# ------------------------------------------------------- metrics registry
def test_metrics_registry_basics():
    reg = MetricsRegistry()
    c = reg.counter("a.b")
    c.inc()
    c.inc(2.0)
    assert c.value == 3.0
    assert reg.counter("a.b") is c
    reg.gauge("g").set(7)
    h = reg.histogram("h")
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100 and h.min == 0.0 and h.max == 99.0
    assert h.mean == pytest.approx(49.5)
    snap = reg.snapshot()
    assert snap["counters"]["a.b"] == 3.0
    assert snap["gauges"]["g"] == 7.0
    assert snap["histograms"]["h"]["count"] == 100
    assert json.dumps(snap)          # JSON-serializable
    reg.reset()
    assert reg.snapshot() == dict(counters={}, gauges={}, histograms={})


def test_histogram_reservoir_deterministic():
    h1, h2 = Histogram("x", sample_cap=32), Histogram("x", sample_cap=32)
    for v in range(5000):
        h1.observe(float(v))
        h2.observe(float(v))
    assert h1.summary() == h2.summary()
    assert h1.percentile(50) == h2.percentile(50)
    # the systematic sample still spans the stream
    assert h1.percentile(0) <= h1.percentile(50) <= h1.percentile(100)
    assert h1.summary()["p95"] > h1.summary()["p50"]


def test_progress_reporter_emits_metrics(g, root, capsys):
    reg = default_registry()
    before = reg.snapshot()["counters"].get("progress.bfs.reports", 0.0)
    from repro.core.engine import DataLocalEngine, EngineConfig
    cfg = EngineConfig(grid=GRID, n_src=g.n_rows, n_dst=g.n_cols, oq_cap=8)
    eng = DataLocalEngine(apps.BFS_SPEC, cfg, g.row_lo, g.row_hi,
                          g.col_idx, g.weights)
    eng.run(eng.init_state(seed_idx=root, seed_val=0.0),
            progress_every=5, chunk=4)
    out = capsys.readouterr().out
    lines = [ln for ln in out.splitlines() if "step " in ln]
    assert lines
    snap = reg.snapshot()
    assert snap["counters"]["progress.bfs.reports"] - before == len(lines)
    assert snap["gauges"]["progress.bfs.steps"] > 0


def test_sanitize_progress_line_reports_violations(g, root, capsys):
    from repro.core.engine import DataLocalEngine, EngineConfig
    cfg = EngineConfig(grid=GRID, n_src=g.n_rows, n_dst=g.n_cols,
                       oq_cap=8, sanitize=True)
    eng = DataLocalEngine(apps.BFS_SPEC, cfg, g.row_lo, g.row_hi,
                          g.col_idx, g.weights)
    eng.run(eng.init_state(seed_idx=root, seed_val=0.0),
            progress_every=5, chunk=4)
    lines = [ln for ln in capsys.readouterr().out.splitlines()
             if "step " in ln]
    assert lines
    assert all("sanity_violations=0" in ln for ln in lines)
