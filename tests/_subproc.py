"""Run a python snippet in a subprocess with N fake XLA devices."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

HEADER = """\
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import warnings
warnings.filterwarnings("ignore")
import sys
sys.path.insert(0, {src!r})
"""


def run_devices(snippet: str, n: int = 8, timeout: int = 360) -> str:
    code = HEADER.format(n=n, src=os.path.abspath(SRC)) + snippet
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, \
        f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout
