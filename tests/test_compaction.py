"""Active-set compaction (``EngineConfig.compaction``): the shape-
bucketed sparse-superstep path through the engine hot loop.

Acceptance properties:
  * the capacity ladder and on-device bucket selector honor the exact
    boundaries — an active count *at* a capacity picks that rung, one
    over spills to the next larger one;
  * ``_compact_window`` is a stable (tile-order-preserving) compaction
    whose scatter-back rows drop exactly the invalid lanes;
  * compacted runs are **bit-identical** to dense — same final values,
    TrafficCounters, SuperstepTrace and superstep count — for all six
    apps, monolithic and 4-chip, per-step (chunk=0) and chunked
    (chunk=8) loops, with and without the double-buffered exchange, on
    the Pallas delivery backend, and under reactivation churn (SSSP at
    ``oq_cap=1``, where tiles re-enter the active set every superstep);
  * the dense oracle stays the default: ``compaction=0`` runs carry no
    bucket telemetry stats.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import _compact_window, bucket_index, capacity_ladder
from repro.core.tilegrid import square_grid
from repro.graph import apps, rmat_edges
from repro.graph.rmat import histogram_input

GRID = square_grid(16)
ALL_APPS = ("bfs", "sssp", "wcc", "pagerank", "spmv", "histo")


@pytest.fixture(scope="module")
def g():
    return rmat_edges(8, edge_factor=8, seed=1)


@pytest.fixture(scope="module")
def root(g):
    return int(np.argmax(g.out_degree()))


def _run(name, g, root, chunk, chips=0, **extra):
    """One full run per app (Table-II proxy policy, as test_obs)."""
    if chips:
        extra["chips"] = chips
    if name == "bfs":
        return apps.bfs(g, root, GRID, oq_cap=8, run_chunk=chunk, **extra)
    if name == "sssp":
        px = apps.table2_proxy(GRID, "sssp")
        return apps.sssp(g, root, GRID, proxy=px, oq_cap=8,
                         run_chunk=chunk, **extra)
    if name == "wcc":
        px = apps.table2_proxy(GRID, "wcc")
        return apps.wcc(g, GRID, proxy=px, oq_cap=8, run_chunk=chunk,
                        **extra)
    if name == "pagerank":
        px = apps.table2_proxy(GRID, "pagerank")
        return apps.pagerank(g, GRID, proxy=px, epochs=2, oq_cap=8,
                             run_chunk=chunk, **extra)
    if name == "spmv":
        x = np.random.default_rng(3).random(g.n_cols).astype(np.float32)
        px = apps.table2_proxy(GRID, "spmv", cascade_levels=1)
        return apps.spmv(g, x, GRID, proxy=px, oq_cap=8, run_chunk=chunk,
                         **extra)
    if name == "histo":
        bins = g.n_rows // 8
        hv = histogram_input(g, bins)
        px = apps.table2_proxy(GRID, "histo")
        return apps.histogram(hv, bins, GRID, proxy=px, oq_cap=8,
                              run_chunk=chunk, **extra)
    raise ValueError(name)


def _assert_bit_identical(dense, comp, label):
    assert np.array_equal(dense.values, comp.values), f"{label}: values"
    dd, dc = dense.run.counters.as_dict(), comp.run.counters.as_dict()
    assert dd == dc, {k: (dd[k], dc[k]) for k in dd if dd[k] != dc[k]}
    assert dense.run.trace.to_dict() == comp.run.trace.to_dict(), \
        f"{label}: trace"
    assert dense.run.supersteps == comp.run.supersteps, f"{label}: steps"


# ----------------------------------------------------- ladder boundaries
def test_capacity_ladder_shape():
    assert capacity_ladder(1024, 3) == (1024, 256, 64, 16)
    assert capacity_ladder(16, 2) == (16, 4, 1)
    # rungs floor at 1 and non-shrinking rungs are dropped
    assert capacity_ladder(4, 5) == (4, 1)
    assert capacity_ladder(1, 3) == (1,)
    # levels <= 0: dense only
    assert capacity_ladder(256, 0) == (256,)


def test_bucket_index_exact_boundaries():
    """An active count exactly at a capacity picks that rung; one over
    spills to the next larger rung — for every rung of the ladder."""
    ladder = capacity_ladder(1024, 3)          # (1024, 256, 64, 16)
    for j, cap in enumerate(ladder):
        assert int(bucket_index(jnp.int32(cap), ladder)) == j, cap
        if j > 0:
            assert int(bucket_index(jnp.int32(cap + 1), ladder)) == j - 1
    # empty active set sits in the smallest window
    assert int(bucket_index(jnp.int32(0), ladder)) == len(ladder) - 1


def test_compact_window_stable_roundtrip():
    T, W = 64, 16
    rng = np.random.default_rng(7)
    for n in (0, 1, W - 1, W, 5, 11):
        act = np.zeros(T, bool)
        act[np.sort(rng.choice(T, n, replace=False))] = True
        w_valid, w_rows, rows_drop = (np.asarray(a) for a in
                                      _compact_window(jnp.asarray(act),
                                                      W, T))
        assert int(w_valid.sum()) == n
        # stable: window slots enumerate active tiles in tile order
        assert w_rows[w_valid].tolist() == np.flatnonzero(act).tolist()
        # invalid lanes clamp the gather row and drop the scatter row
        assert np.all(w_rows[~w_valid] == T - 1)
        assert np.all(rows_drop[~w_valid] == T)
        # scatter-back via rows_drop touches exactly the active rows
        hit = np.zeros(T, np.int32)
        np.add.at(hit, rows_drop[w_valid], 1)
        assert np.array_equal(hit.astype(bool), act)


def test_compact_window_overfull_truncates():
    """More active tiles than slots: the window takes the first W in
    tile order (the engine never selects such a bucket — bucket_index
    spills to a larger rung — but the primitive must stay sane)."""
    T, W = 32, 4
    act = np.ones(T, bool)
    w_valid, w_rows, _ = (np.asarray(a) for a in
                          _compact_window(jnp.asarray(act), W, T))
    assert w_valid.all()
    assert w_rows.tolist() == [0, 1, 2, 3]


# ------------------------------------------------- whole-run bit-identity
@pytest.mark.parametrize("chunk", (0, 8))
@pytest.mark.parametrize("name", ALL_APPS)
def test_mono_bit_identical(name, chunk, g, root):
    dense = _run(name, g, root, chunk)
    comp = _run(name, g, root, chunk, compaction=2)
    _assert_bit_identical(dense, comp, f"{name}/mono/chunk{chunk}")


@pytest.mark.parametrize("chunk,db", ((0, False), (8, False), (8, True)))
@pytest.mark.parametrize("name", ALL_APPS)
def test_4chip_bit_identical(name, chunk, db, g, root):
    dense = _run(name, g, root, chunk, chips=4, double_buffer=db)
    comp = _run(name, g, root, chunk, chips=4, double_buffer=db,
                compaction=2)
    _assert_bit_identical(dense, comp,
                          f"{name}/4chip/chunk{chunk}/db{int(db)}")


def test_4chip_db_chunk0_bit_identical(g, root):
    """The remaining (chunk=0, double_buffer) corner on one min and one
    add app — the per-step loop drives the deferred exchange directly."""
    for name in ("sssp", "histo"):
        dense = _run(name, g, root, 0, chips=4, double_buffer=True)
        comp = _run(name, g, root, 0, chips=4, double_buffer=True,
                    compaction=2)
        _assert_bit_identical(dense, comp, f"{name}/4chip/chunk0/db1")


def test_reactivation_churn_bit_identical(g, root):
    """SSSP at oq_cap=1: cursors reopen and tiles re-enter the active
    set every superstep (maximum bucket churn — the selector crosses
    rung boundaries many times per run), deepest ladder."""
    px = apps.table2_proxy(GRID, "sssp")
    dense = apps.sssp(g, root, GRID, proxy=px, oq_cap=1, run_chunk=8)
    comp = apps.sssp(g, root, GRID, proxy=px, oq_cap=1, run_chunk=8,
                     compaction=3)
    _assert_bit_identical(dense, comp, "sssp/churn/c3")


@pytest.mark.parametrize("name", ("bfs", "sssp"))
def test_pallas_backend_bit_identical(name, g, root):
    dense = _run(name, g, root, 8, backend="pallas")
    comp = _run(name, g, root, 8, backend="pallas", compaction=2)
    _assert_bit_identical(dense, comp, f"{name}/pallas")


def test_dense_default_has_no_bucket_stats(g, root):
    """compaction=0 (the default) must stay the dense oracle: no bucket
    switch, no active-set telemetry stats in the chunk rows."""
    from repro import obs
    rec = obs.TimelineRecorder()
    _run("bfs", g, root, 8, observer=rec)
    keys = {k for s in rec.spans for k in s.stats}
    assert "active_tiles" not in keys and "bucket_cap" not in keys
    rec2 = obs.TimelineRecorder()
    _run("bfs", g, root, 8, observer=rec2, compaction=2)
    keys2 = {k for s in rec2.spans for k in s.stats}
    assert {"active_tiles", "bucket_cap"} <= keys2
