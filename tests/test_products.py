"""Product-search subsystem: measure-once / price-many over the package
design space (trace fidelity, counter cache, Pareto selection)."""
import numpy as np
import pytest

from repro.core.costmodel import DCRA_SRAM, price
from repro.core.netstats import SuperstepTrace
from repro.core.proxy import max_cascade_levels
from repro.core.tilegrid import square_grid
from repro.products import (MeasureSpec, ProductSearch, pareto_front,
                            product_space, select_products)

SSSP = MeasureSpec(app="sssp", scale=8, tiles=64)
HISTO = MeasureSpec(app="histo", scale=8, tiles=64, cascade_levels=1)


@pytest.fixture(scope="module")
def search(tmp_path_factory):
    return ProductSearch(cache_dir=str(tmp_path_factory.mktemp("products")))


@pytest.fixture(scope="module")
def rows(search):
    return search.sweep([SSSP, HISTO], product_space())


def test_sweep_measures_once_prices_many(search, rows):
    """2 specs x 12 configs -> 24 priced rows from exactly 2 engine runs."""
    assert len(rows) == 2 * 12
    assert search.engine_runs == 2
    assert len({r["product"] for r in rows}) == 12


def test_cache_round_trip_identical_pricing(search, rows):
    """Reloading a measurement from its JSON cache entry reproduces the
    live measurement's pricing bit-for-bit, without an engine run."""
    runs_before = search.engine_runs
    rows2 = search.sweep([SSSP, HISTO], product_space())
    assert search.engine_runs == runs_before
    assert all(r["from_cache"] for r in rows2)
    for r1, r2 in zip(rows, rows2):
        assert r1["product"] == r2["product"]
        assert r1["time_s"] == r2["time_s"]
        assert r1["energy_j"] == r2["energy_j"]
        assert r1["cost_usd"] == r2["cost_usd"]


def test_trace_json_round_trip(search):
    m = search.measure(SSSP)
    t2 = SuperstepTrace.from_dict(m.trace.to_dict())
    assert len(t2) == len(m.trace) == m.supersteps
    assert t2.to_dict() == m.trace.to_dict()


def test_reprice_under_own_config_matches_measured_time(search):
    """The re-pricing contract closes the loop: pricing a run's trace
    under the config it was measured with reproduces the run loop's own
    BSP time (monolithic and distributed)."""
    m = search.measure(SSSP)       # measured under the default DCRA_SRAM
    rep = price(DCRA_SRAM, m.grid, m.counters, per_superstep_peak=m.trace)
    assert rep.time_s == pytest.approx(m.time_s, rel=1e-9)


def test_reprice_distributed_trace_matches_measured_time(search):
    spec = MeasureSpec(app="sssp", scale=8, tiles=64, chips=4)
    m = search.measure(spec)
    assert m.trace.board_links > 1
    assert m.counters.off_chip_msgs > 0
    rep = price(DCRA_SRAM, m.grid, m.counters, per_superstep_peak=m.trace)
    assert rep.time_s == pytest.approx(m.time_s, rel=1e-9)


def test_pareto_front_no_selected_product_dominated(rows):
    for meas in {r["measurement"] for r in rows}:
        group = [r for r in rows if r["measurement"] == meas]
        front = pareto_front(group)
        assert front
        for f in front:
            for r in group:
                dominates = (r["thr_per_usd"] >= f["thr_per_usd"]
                             and r["eff_per_usd"] >= f["eff_per_usd"]
                             and (r["thr_per_usd"] > f["thr_per_usd"]
                                  or r["eff_per_usd"] > f["eff_per_usd"]))
                assert not dominates, (f, r)


def test_select_products_optimal_per_objective(rows):
    group = [r for r in rows if r["measurement"] == SSSP.label]
    sel = select_products(group)
    assert sel["time"]["time_s"] == min(r["time_s"] for r in group)
    assert sel["energy"]["energy_j"] == min(r["energy_j"] for r in group)
    assert sel["cost"]["cost_usd"] == min(r["cost_usd"] for r in group)
    assert sel["throughput_per_dollar"]["thr_per_usd"] == \
        max(r["thr_per_usd"] for r in group)
    assert sel["efficiency_per_dollar"]["eff_per_usd"] == \
        max(r["eff_per_usd"] for r in group)


def test_cascade_legs_priced_into_products(search, rows):
    """The cascade measurement's combine events reach the priced rows
    (tag-energy leg), closing the ROADMAP's fold-into-Fig.9/10 item."""
    casc = [r for r in rows if r["measurement"] == HISTO.label]
    assert all(r["cascade_combined"] > 0 for r in casc)
    m = search.measure(HISTO)
    no_casc = search.measure(MeasureSpec(app="histo", scale=8, tiles=64))
    assert m.counters.cascade_combined > 0
    assert no_casc.counters.cascade_combined == 0


def test_corrupt_cache_entry_is_a_miss(tmp_path):
    ps = ProductSearch(cache_dir=str(tmp_path))
    spec = MeasureSpec(app="histo", scale=7, tiles=16)
    m = ps.measure(spec)
    assert not m.from_cache
    path = ps.cache.path(spec.key())
    with open(path, "w") as f:
        f.write("{not json")
    m2 = ps.measure(spec)
    assert not m2.from_cache          # re-measured, not crashed
    assert ps.engine_runs == 2
    assert ps.measure(spec).from_cache


def test_max_cascade_levels():
    # 8x8 window, 2x2 base regions, 2x2 grouping: level 1 = 4x4 fits;
    # level 2 = 8x8 is the degenerate whole-window root -> depth 1
    assert max_cascade_levels(8, 8, 2, 2) == 1
    assert max_cascade_levels(16, 16, 2, 2) == 2
    assert max_cascade_levels(16, 16, 2, 2, 4, 4) == 1
    assert max_cascade_levels(8, 8, 3, 3) == 0    # regions don't divide
    assert max_cascade_levels(8, 8, 2, 2, 8, 8) == 0


def test_histogram_measurement_values_sane(search):
    m = search.measure(HISTO)
    assert m.supersteps == len(m.trace)
    assert m.counters.edges_processed > 0
    assert m.touched_bits > 0 and m.dataset_bits > 0
    assert np.isfinite(m.time_s) and m.time_s > 0
