"""Product-search subsystem: measure-once / price-many over the package
design space (trace fidelity, counter cache, Pareto selection, and the
chip-partitioning packaging axis)."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core.costmodel import DCRA_SRAM, price
from repro.core.netstats import SuperstepTrace
from repro.core.proxy import max_cascade_levels
from repro.core.tilegrid import square_grid
from repro.products import (MeasureSpec, ProductSearch, pareto_front,
                            product_space, select_products)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:          # property tests below degrade to skips
    given = None

SSSP = MeasureSpec(app="sssp", scale=8, tiles=64)
HISTO = MeasureSpec(app="histo", scale=8, tiles=64, cascade_levels=1)


@pytest.fixture(scope="module")
def search(tmp_path_factory):
    return ProductSearch(cache_dir=str(tmp_path_factory.mktemp("products")))


@pytest.fixture(scope="module")
def rows(search):
    return search.sweep([SSSP, HISTO], product_space())


def test_sweep_measures_once_prices_many(search, rows):
    """2 specs x 12 configs -> 24 priced rows from exactly 2 engine runs."""
    assert len(rows) == 2 * 12
    assert search.engine_runs == 2
    assert len({r["product"] for r in rows}) == 12


def test_cache_round_trip_identical_pricing(search, rows):
    """Reloading a measurement from its JSON cache entry reproduces the
    live measurement's pricing bit-for-bit, without an engine run."""
    runs_before = search.engine_runs
    rows2 = search.sweep([SSSP, HISTO], product_space())
    assert search.engine_runs == runs_before
    assert all(r["from_cache"] for r in rows2)
    for r1, r2 in zip(rows, rows2):
        assert r1["product"] == r2["product"]
        assert r1["time_s"] == r2["time_s"]
        assert r1["energy_j"] == r2["energy_j"]
        assert r1["cost_usd"] == r2["cost_usd"]


def test_trace_json_round_trip(search):
    m = search.measure(SSSP)
    t2 = SuperstepTrace.from_dict(m.trace.to_dict())
    assert len(t2) == len(m.trace) == m.supersteps
    assert t2.to_dict() == m.trace.to_dict()


def test_reprice_under_own_config_matches_measured_time(search):
    """The re-pricing contract closes the loop: pricing a run's trace
    under the config it was measured with reproduces the run loop's own
    BSP time (monolithic and distributed)."""
    m = search.measure(SSSP)       # measured under the default DCRA_SRAM
    rep = price(DCRA_SRAM, m.grid, m.counters, per_superstep_peak=m.trace)
    assert rep.time_s == pytest.approx(m.time_s, rel=1e-9)


def test_reprice_distributed_trace_matches_measured_time(search):
    spec = MeasureSpec(app="sssp", scale=8, tiles=64, chips=4)
    m = search.measure(spec)
    assert m.trace.board_links > 1
    assert m.counters.off_chip_msgs > 0
    rep = price(DCRA_SRAM, m.grid, m.counters, per_superstep_peak=m.trace)
    assert rep.time_s == pytest.approx(m.time_s, rel=1e-9)


def test_pareto_front_no_selected_product_dominated(rows):
    for meas in {r["measurement"] for r in rows}:
        group = [r for r in rows if r["measurement"] == meas]
        front = pareto_front(group)
        assert front
        for f in front:
            for r in group:
                dominates = (r["thr_per_usd"] >= f["thr_per_usd"]
                             and r["eff_per_usd"] >= f["eff_per_usd"]
                             and (r["thr_per_usd"] > f["thr_per_usd"]
                                  or r["eff_per_usd"] > f["eff_per_usd"]))
                assert not dominates, (f, r)


def test_select_products_optimal_per_objective(rows):
    group = [r for r in rows if r["measurement"] == SSSP.label]
    sel = select_products(group)
    assert sel["time"]["time_s"] == min(r["time_s"] for r in group)
    assert sel["energy"]["energy_j"] == min(r["energy_j"] for r in group)
    assert sel["cost"]["cost_usd"] == min(r["cost_usd"] for r in group)
    assert sel["throughput_per_dollar"]["thr_per_usd"] == \
        max(r["thr_per_usd"] for r in group)
    assert sel["efficiency_per_dollar"]["eff_per_usd"] == \
        max(r["eff_per_usd"] for r in group)


def test_cascade_legs_priced_into_products(search, rows):
    """The cascade measurement's combine events reach the priced rows
    (tag-energy leg), closing the ROADMAP's fold-into-Fig.9/10 item."""
    casc = [r for r in rows if r["measurement"] == HISTO.label]
    assert all(r["cascade_combined"] > 0 for r in casc)
    m = search.measure(HISTO)
    no_casc = search.measure(MeasureSpec(app="histo", scale=8, tiles=64))
    assert m.counters.cascade_combined > 0
    assert no_casc.counters.cascade_combined == 0


def test_corrupt_cache_entry_is_a_miss(tmp_path):
    ps = ProductSearch(cache_dir=str(tmp_path))
    spec = MeasureSpec(app="histo", scale=7, tiles=16)
    m = ps.measure(spec)
    assert not m.from_cache
    path = ps.cache.path(spec.key())
    with open(path, "w") as f:
        f.write("{not json")
    m2 = ps.measure(spec)
    assert not m2.from_cache          # re-measured, not crashed
    assert ps.engine_runs == 2
    assert ps.measure(spec).from_cache


# ------------------------------------------------------- chips packaging axis
def test_sweep_chips_axis_measures_per_chip_count(tmp_path):
    """Configs with chips=N re-base the measurement onto the distributed
    runtime at N chips: one engine run per chip count, every same-count
    config re-priced from the one cached board-level trace."""
    ps = ProductSearch(cache_dir=str(tmp_path))
    spec = MeasureSpec(app="sssp", scale=8, tiles=64)
    cfgs = product_space(memory=("sram",), network=("d_32+64_od64",),
                        chips=(1, 4), board_links=(1, 2))
    rows = ps.sweep([spec], cfgs)
    assert ps.engine_runs == 2                  # once per chip count
    assert sorted({r["chips"] for r in rows}) == [1, 4]
    # chips=4 rows price the distributed measurement (board leg exists)
    by_chips = {}
    for r in rows:
        by_chips.setdefault(r["chips"], []).append(r)
    assert all(r["measurement"].endswith("4chips")
               for r in by_chips[4])
    # board-link provisioning is live: fewer links can never be faster,
    # and the board hardware they pay for is monotone in $
    t = {r["product"]: r for r in by_chips[4]}
    assert t["sram/net-d/sram1.5/c4/bl1"]["time_s"] >= \
        t["sram/net-d/sram1.5/c4"]["time_s"]
    assert t["sram/net-d/sram1.5/c4/bl1"]["cost_usd"] < \
        t["sram/net-d/sram1.5/c4"]["cost_usd"]


def test_reprice_cached_4chip_trace_exact(tmp_path):
    """Acceptance: re-pricing a cached 4-chip trace under its measured
    PackageConfig reproduces the directly measured run.time_s."""
    ps = ProductSearch(cache_dir=str(tmp_path))
    spec = MeasureSpec(app="sssp", scale=8, tiles=64, chips=4)
    live = ps.measure(spec)
    cached = ps.measure(spec)
    assert cached.from_cache and not live.from_cache
    assert cached.trace.chips_y * cached.trace.chips_x == 4
    for m in (live, cached):
        rep = ps.price_product(m, dataclasses.replace(DCRA_SRAM, chips=4))
        assert rep.time_s == m.time_s == live.time_s


def test_price_product_rejects_chip_count_mismatch(search):
    m = search.measure(SSSP)                    # monolithic measurement
    with pytest.raises(ValueError, match="chips=4"):
        search.price_product(m, dataclasses.replace(DCRA_SRAM, chips=4))


def test_measure_validates_spec():
    ps = ProductSearch(cache_dir="/nonexistent-never-written")
    with pytest.raises(ValueError, match="unknown app"):
        ps.measure(MeasureSpec(app="bfsx", scale=8, tiles=64))
    with pytest.raises(ValueError, match="cannot block-partition"):
        ps.measure(MeasureSpec(app="sssp", scale=8, tiles=64, chips=5))
    assert ps.engine_runs == 0                  # rejected before running


# ------------------------------------------------------- cache correctness
def test_spec_hash_sensitive_to_every_field():
    """Any MeasureSpec field change (including the new chips axis) must
    change the cache key — a stale hit would re-price the wrong trace."""
    base = MeasureSpec(app="sssp", scale=8, tiles=64)
    perturbed = dict(app="histo", scale=9, tiles=256, edge_factor=16,
                     seed=2, oq_cap=16, slots=256, region_div=2,
                     cascade_levels=1, cascade_group=4, selective=False,
                     chips=4, epochs=5)
    assert set(perturbed) == {f.name for f in dataclasses.fields(base)}
    keys = {base.key()}
    for field, value in perturbed.items():
        assert getattr(base, field) != value, field
        k = dataclasses.replace(base, **{field: value}).key()
        assert k not in keys, f"key collision perturbing {field!r}"
        keys.add(k)


def test_stale_schema_cache_entry_rejected(tmp_path):
    """A cache entry from an older schema is a miss (re-measured), never
    silently re-priced without its partition geometry."""
    ps = ProductSearch(cache_dir=str(tmp_path))
    spec = MeasureSpec(app="histo", scale=7, tiles=16)
    ps.measure(spec)
    path = ps.cache.path(spec.key())
    with open(path) as f:
        payload = json.load(f)
    payload["schema"] = 1                       # pre-chips-axis schema
    with open(path, "w") as f:
        json.dump(payload, f)
    m = ps.measure(spec)
    assert not m.from_cache and ps.engine_runs == 2
    assert ps.measure(spec).from_cache          # rewritten at current schema


def test_concurrent_writer_round_trip(tmp_path):
    """Two searches sharing one cache dir: whoever measures first
    publishes atomically; the other reads it back identically.  A torn
    write (interrupted tmp file) neither corrupts the entry nor breaks
    later reads."""
    spec = MeasureSpec(app="histo", scale=7, tiles=16)
    a = ProductSearch(cache_dir=str(tmp_path))
    b = ProductSearch(cache_dir=str(tmp_path))
    ma = a.measure(spec)
    mb = b.measure(spec)
    assert a.engine_runs == 1 and b.engine_runs == 0
    assert mb.from_cache
    assert mb.trace.to_dict() == ma.trace.to_dict()
    assert mb.counters.as_dict() == ma.counters.as_dict()
    # torn write: a leftover half-written tmp never shadows the entry,
    # and a torn final file is a miss, not a crash
    (tmp_path / "junk.tmp").write_text('{"schema": 2, "trunc')
    assert b.measure(spec).from_cache
    path = a.cache.path(spec.key())
    with open(path, "w") as f:
        f.write('{"schema": 2, "spec": {"app": "hist')   # torn mid-write
    m = b.measure(spec)
    assert not m.from_cache and b.engine_runs == 1       # re-measured
    assert b.measure(spec).from_cache                    # healed


# ---------------------------------------------------- pricing-contract property
@pytest.mark.property
@pytest.mark.slow
@pytest.mark.skipif(given is None, reason="hypothesis not installed")
def test_pricing_contract_random_configs(tmp_path_factory):
    """Property: for random cascade/chunk/chip measurement configs, the
    measured trace priced under its own PackageConfig reproduces the run
    loop's time — the contract every product row stands on."""
    cache = str(tmp_path_factory.mktemp("contract"))
    ps = ProductSearch(cache_dir=cache)

    @settings(max_examples=6, deadline=None)
    @given(app=st.sampled_from(("sssp", "histo")),
           cascade_levels=st.integers(0, 1),
           chips=st.sampled_from((0, 4)),
           run_chunk=st.sampled_from((0, 3)),
           seed=st.integers(1, 2))
    def check(app, cascade_levels, chips, run_chunk, seed):
        spec = MeasureSpec(app=app, scale=7, tiles=64, seed=seed,
                           cascade_levels=cascade_levels, chips=chips)
        m = ps.measure(spec, run_chunk=run_chunk)
        cfg = dataclasses.replace(DCRA_SRAM, chips=max(chips, 1))
        rep = ps.price_product(m, cfg)
        assert rep.time_s == pytest.approx(m.time_s, rel=1e-9)
        # and the chips=0 (inherit-partition) rendering agrees
        rep0 = ps.price_product(m, DCRA_SRAM)
        assert rep0.time_s == rep.time_s

    check()


def test_max_cascade_levels():
    # 8x8 window, 2x2 base regions, 2x2 grouping: level 1 = 4x4 fits;
    # level 2 = 8x8 is the degenerate whole-window root -> depth 1
    assert max_cascade_levels(8, 8, 2, 2) == 1
    assert max_cascade_levels(16, 16, 2, 2) == 2
    assert max_cascade_levels(16, 16, 2, 2, 4, 4) == 1
    assert max_cascade_levels(8, 8, 3, 3) == 0    # regions don't divide
    assert max_cascade_levels(8, 8, 2, 2, 8, 8) == 0


def test_histogram_measurement_values_sane(search):
    m = search.measure(HISTO)
    assert m.supersteps == len(m.trace)
    assert m.counters.edges_processed > 0
    assert m.touched_bits > 0 and m.dataset_bits > 0
    assert np.isfinite(m.time_s) and m.time_s > 0
