"""The six paper applications vs pure-numpy oracles, with and without
proxy regions and both coherence policies — plus engine invariants."""
import numpy as np
import pytest

from repro.core.proxy import ProxyConfig
from repro.core.tilegrid import square_grid
from repro.graph import apps, oracles, rmat_edges, wikipedia_like
from repro.graph.rmat import histogram_input

GRID = square_grid(64)
WT = ProxyConfig(4, 4, slots=256)
WB = ProxyConfig(4, 4, slots=256, write_back=True)


@pytest.fixture(scope="module")
def g():
    return rmat_edges(9, edge_factor=8, seed=1)


@pytest.fixture(scope="module")
def root(g):
    return int(np.argmax(g.out_degree()))


@pytest.mark.parametrize("proxy", [None, WT], ids=["direct", "proxy-wt"])
def test_bfs(g, root, proxy):
    r = apps.bfs(g, root, GRID, proxy=proxy, oq_cap=32)
    assert np.array_equal(r.values, oracles.bfs_oracle(g, root))
    assert r.run.counters.messages > 0
    assert r.gteps > 0


@pytest.mark.parametrize("proxy", [None, WT], ids=["direct", "proxy-wt"])
def test_sssp(g, root, proxy):
    r = apps.sssp(g, root, GRID, proxy=proxy, oq_cap=32)
    assert np.allclose(r.values, oracles.sssp_oracle(g, root))


@pytest.mark.parametrize("proxy", [None, WT], ids=["direct", "proxy-wt"])
def test_wcc(g, proxy):
    r = apps.wcc(g, GRID, proxy=proxy, oq_cap=32)
    assert np.array_equal(r.values, oracles.wcc_oracle(g))


@pytest.mark.parametrize("proxy", [None, WB], ids=["direct", "proxy-wb"])
def test_pagerank(g, proxy):
    r = apps.pagerank(g, GRID, proxy=proxy, epochs=3, oq_cap=32)
    o = oracles.pagerank_oracle(g, epochs=3)
    assert np.allclose(r.values, o, rtol=1e-4, atol=1e-7)


@pytest.mark.parametrize("proxy", [None, WB], ids=["direct", "proxy-wb"])
def test_spmv(g, proxy, rng):
    x = rng.random(g.n_cols).astype(np.float32)
    r = apps.spmv(g, x, GRID, proxy=proxy, oq_cap=32)
    assert np.allclose(r.values, oracles.spmv_oracle(g, x),
                       rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("proxy", [None, WB], ids=["direct", "proxy-wb"])
def test_histogram(g, proxy):
    bins = g.n_rows // 8
    hv = histogram_input(g, bins)
    r = apps.histogram(hv, bins, GRID, proxy=proxy, oq_cap=32)
    assert np.array_equal(r.values, oracles.histogram_oracle(hv, bins))


def test_wikipedia_like_bfs():
    g = wikipedia_like(n=512, avg_deg=12)
    root = int(np.argmax(g.out_degree()))
    r = apps.bfs(g, root, GRID, oq_cap=32)
    assert np.array_equal(r.values, oracles.bfs_oracle(g, root))


# ------------------------------------------------------------- invariants
def test_backpressure_changes_schedule_not_result(g, root):
    """Shrinking the OQ budget can only change scheduling (more
    supersteps), never the fixed point."""
    o = oracles.bfs_oracle(g, root)
    r_small = apps.bfs(g, root, GRID, oq_cap=4)
    r_big = apps.bfs(g, root, GRID, oq_cap=256)
    assert np.array_equal(r_small.values, o)
    assert np.array_equal(r_big.values, o)
    assert r_small.run.supersteps >= r_big.run.supersteps


def test_proxy_filters_traffic(g, root):
    """Write-through proxy absorbs non-improving updates: the owner-side
    delivered message count drops vs direct routing."""
    r_d = apps.sssp(g, root, GRID, oq_cap=32)
    r_p = apps.sssp(g, root, GRID, proxy=WT, oq_cap=32)
    assert r_p.run.counters.filtered_at_proxy > 0
    # records consumed at owners shrink (filter + coalesce)
    assert (r_p.run.counters.records_consumed
            <= r_d.run.counters.records_consumed)


def test_iq_ratio_goldilocks_measurable(g):
    """Different IQ:OQ ratios give different superstep counts (the knob
    the paper tunes in Fig. 7 is live)."""
    x = np.random.default_rng(1).random(g.n_cols).astype(np.float32)
    steps = {r: apps.spmv(g, x, GRID, oq_cap=16, iq_ratio=r).run.supersteps
             for r in (1, 8)}
    assert steps[8] <= steps[1]


def test_histogram_conservation(g):
    """Every input element lands in exactly one bin (no loss under
    backpressure + proxy + flush)."""
    bins = g.n_rows // 8
    hv = histogram_input(g, bins)
    r = apps.histogram(hv, bins, GRID, proxy=WB, oq_cap=8)
    assert int(r.values.sum()) == hv.shape[0]
