"""Distributed multi-chip runtime vs the monolithic engine.

Acceptance properties:
  * distributed runs at 2, 4 and 16 emulated chips are numerically
    identical to the monolithic engine on all six apps (bitwise for the
    min-combine propagators and integer-count histogram; up to f32
    re-association — the delivery order across the exchange — for the
    floating add-combine apps);
  * the 1 -> 256-chip weak-scaling sweep emits a monotone measured GTEPS
    curve, with off-chip traffic counted in the energy/$ report;
  * chip partition index maps round-trip; chip-local proxy adaptation
    truncates cascades at the chip boundary;
  * the shard_map backend (real devices, collective exchange) matches
    the vmapped emulation (subprocess with fake XLA devices).
"""
import numpy as np
import pytest

from _subproc import run_devices

from repro.core.proxy import chip_local_proxy
from repro.core.tilegrid import ChipPartition, partition_grid, square_grid
from repro.distrib import harness, partition
from repro.graph import apps, oracles, rmat_edges
from repro.graph.rmat import histogram_input

GRID = square_grid(64)                                  # 8x8 tiles
CHIP_COUNTS = (2, 4, 16)


@pytest.fixture(scope="module")
def g():
    return rmat_edges(9, edge_factor=8, seed=1)


@pytest.fixture(scope="module")
def root(g):
    return int(np.argmax(g.out_degree()))


# ---------------------------------------------------------- partition maps
def test_partition_round_trip():
    part = ChipPartition(square_grid(256), 4, 4)
    tids = np.arange(part.grid.num_tiles)
    chip = np.asarray(part.chip_of_tile(tids))
    local = np.asarray(part.local_tile(tids))
    back = np.asarray(part.global_tile(chip, local))
    assert np.array_equal(back, tids)
    # every chip holds exactly tiles_per_chip tiles
    assert np.array_equal(np.bincount(chip),
                          np.full(part.num_chips, part.tiles_per_chip))


def test_partition_grid_squarish():
    part = partition_grid(square_grid(1024), 16)
    assert (part.chips_y, part.chips_x) == (4, 4)
    assert partition(square_grid(64), 2).num_chips == 2
    with pytest.raises(ValueError):
        partition_grid(square_grid(64), 5)              # cannot divide 8x8


def test_chip_hops_torus():
    part = ChipPartition(square_grid(256), 4, 4)       # 4x4 chips of 4x4
    # opposite corners: 2 hops each axis direct, 1+1 via torus wrap
    assert int(part.chip_hops(0, 255)) == 2


def test_chip_local_proxy_truncates_at_boundary():
    px = apps.table2_proxy(square_grid(1024), "histo", cascade_levels=3)
    # chip subgrid 8x8: base 8x8 regions gcd to 8x8 -> no combining level
    # fits inside the chip, the cascade roots at the chip boundary
    adapted = chip_local_proxy(px, 8, 8)
    assert adapted.cascade is None
    # chip subgrid 32x32: base regions fit, 2 of 3 levels fit
    adapted = chip_local_proxy(px, 32, 32)
    assert adapted.region_ny == 8 and adapted.cascade.levels == 2


# -------------------------------------------------- six-app numerical identity
def _match(mono, dist, exact):
    if exact:
        assert np.array_equal(mono.values, dist.values)
    else:
        assert np.allclose(mono.values, dist.values, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("chips", CHIP_COUNTS)
def test_bfs_identical(g, root, chips):
    m = apps.bfs(g, root, GRID, oq_cap=32)
    d = apps.bfs(g, root, GRID, oq_cap=32, chips=chips)
    _match(m, d, exact=True)
    assert np.array_equal(d.values, oracles.bfs_oracle(g, root))
    assert d.run.counters.off_chip_msgs > 0
    # without proxies the schedule is per-tile local: same superstep count
    assert d.run.supersteps == m.run.supersteps


@pytest.mark.parametrize("chips", CHIP_COUNTS)
def test_sssp_identical(g, root, chips):
    px = apps.table2_proxy(GRID, "sssp")
    m = apps.sssp(g, root, GRID, proxy=px, oq_cap=32)
    d = apps.sssp(g, root, GRID, proxy=px, oq_cap=32, chips=chips)
    _match(m, d, exact=True)
    assert np.allclose(d.values, oracles.sssp_oracle(g, root))


@pytest.mark.parametrize("chips", CHIP_COUNTS)
def test_wcc_identical(g, chips):
    px = apps.table2_proxy(GRID, "wcc")
    m = apps.wcc(g, GRID, proxy=px, oq_cap=32)
    d = apps.wcc(g, GRID, proxy=px, oq_cap=32, chips=chips)
    _match(m, d, exact=True)
    assert np.array_equal(d.values, oracles.wcc_oracle(g))


@pytest.mark.parametrize("chips", CHIP_COUNTS)
def test_pagerank_identical(g, chips):
    px = apps.table2_proxy(GRID, "pagerank")
    m = apps.pagerank(g, GRID, proxy=px, epochs=3, oq_cap=32)
    d = apps.pagerank(g, GRID, proxy=px, epochs=3, oq_cap=32, chips=chips)
    _match(m, d, exact=False)
    assert np.allclose(d.values, oracles.pagerank_oracle(g, epochs=3),
                       rtol=1e-4, atol=1e-7)


@pytest.mark.parametrize("chips", CHIP_COUNTS)
def test_spmv_identical(g, rng, chips):
    x = rng.random(g.n_cols).astype(np.float32)
    px = apps.table2_proxy(GRID, "spmv", cascade_levels=2)
    m = apps.spmv(g, x, GRID, proxy=px, oq_cap=32)
    d = apps.spmv(g, x, GRID, proxy=px, oq_cap=32, chips=chips)
    _match(m, d, exact=False)
    assert np.allclose(d.values, oracles.spmv_oracle(g, x),
                       rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("chips", CHIP_COUNTS)
def test_histogram_identical(g, chips):
    bins = g.n_rows // 8
    hv = histogram_input(g, bins)
    px = apps.table2_proxy(GRID, "histo")
    m = apps.histogram(hv, bins, GRID, proxy=px, oq_cap=32)
    d = apps.histogram(hv, bins, GRID, proxy=px, oq_cap=32, chips=chips)
    _match(m, d, exact=True)                       # integer counts: exact
    assert int(d.values.sum()) == hv.shape[0]      # conservation across chips


def test_chain_graph_survives_boundary_crossings(g):
    """Regression: termination must be decided on the *post-exchange*
    state.  On a path graph the frontier is repeatedly a single record
    that crosses the chip boundary — every chip's pre-exchange queues
    look empty exactly when the exchanged record is the only live work,
    and an early break would silently truncate the traversal."""
    from repro.graph.csr import csr_from_edges
    n = 64
    chain = csr_from_edges(np.arange(n - 1), np.arange(1, n), n)
    grid = square_grid(4)
    m = apps.bfs(chain, 0, grid, oq_cap=8)
    assert np.isfinite(m.values).all()             # whole chain reached
    for chips in (2, 4):
        d = apps.bfs(chain, 0, grid, oq_cap=8, chips=chips)
        assert np.array_equal(m.values, d.values)
        assert d.run.supersteps == m.run.supersteps


def test_distributed_engine_single_chip(g, root):
    """chips=1 through the DistributedEngine itself (not the apps-level
    fallback) is the degenerate partition: runs, matches, no off-chip."""
    from repro.core.engine import EngineConfig
    from repro.distrib import run_distributed
    cfg = EngineConfig(grid=GRID, n_src=g.n_rows, n_dst=g.n_cols,
                       proxy=None, oq_cap=32)
    vals, run = run_distributed(apps.BFS_SPEC, cfg, g.row_lo, g.row_hi,
                                g.col_idx, g.weights, chips=1,
                                seed_idx=root, seed_val=0.0)
    assert np.array_equal(vals[: g.n_rows], oracles.bfs_oracle(g, root))
    assert run.counters.off_chip_msgs == 0


# --------------------------------------------------------- traffic accounting
def test_off_chip_only_when_partitioned(g, root):
    m = apps.bfs(g, root, GRID, oq_cap=32)
    assert m.run.counters.off_chip_msgs == 0
    d = apps.bfs(g, root, GRID, oq_cap=32, chips=4)
    c = d.run.counters
    assert c.off_chip_msgs > 0
    assert c.off_chip_hop_msgs >= c.off_chip_msgs   # >= 1 board hop each
    # off-chip records are a subset of the owner-bound messages
    assert c.off_chip_msgs <= c.owner_msgs


def test_more_chips_more_off_chip_traffic(g, root):
    offs = [apps.bfs(g, root, GRID, oq_cap=32,
                     chips=c).run.counters.off_chip_msgs
            for c in (2, 4, 16)]
    assert offs[0] < offs[1] < offs[2]


# ------------------------------------------- cross-runtime trace equivalence
# Per-superstep trace fields that must be *identical* between the
# monolithic engine and the distributed runtime on a proxy-free run: the
# schedule is per-tile local and hop charging keeps global tile ids, so
# splitting the grid into chips adds only the board leg (off_chip_*).
# endpoint_bits is excluded by design: the distributed runtime accounts
# exchange receive contention as max(local-delivery max, exchange max)
# rather than re-deriving a fused per-tile total.
EQUIV_TRACE_FIELDS = ("compute_ops", "intra_bits", "die_bits", "pkg_bits",
                      "touched_bits", "pending")


def _trace_run(name, g, root, chips=0, run_chunk=0):
    """Proxy-free run of one app (proxies are chip-locally adapted, which
    legitimately changes the schedule — equivalence needs them off)."""
    kw = dict(oq_cap=16, run_chunk=run_chunk)
    if chips:
        kw["chips"] = chips
    if name == "bfs":
        return apps.bfs(g, root, GRID, **kw)
    if name == "sssp":
        return apps.sssp(g, root, GRID, **kw)
    if name == "wcc":
        return apps.wcc(g, GRID, **kw)
    if name == "pagerank":
        return apps.pagerank(g, GRID, epochs=2, **kw)
    if name == "spmv":
        x = np.random.default_rng(3).random(g.n_cols).astype(np.float32)
        return apps.spmv(g, x, GRID, **kw)
    if name == "histo":
        bins = g.n_rows // 8
        return apps.histogram(histogram_input(g, bins), bins, GRID, **kw)
    raise ValueError(name)


@pytest.mark.parametrize(
    "name", ("bfs", "sssp", "wcc", "pagerank", "spmv", "histo"))
def test_trace_equivalence_minus_board_leg(name, g, root):
    """The distributed trace at chips=4 (aggregated over chips by the run
    loop) equals the monolithic trace on every shared level-traffic
    vector; only the board leg (off_chip_*) is new — under both the
    legacy per-step loop (chunk=0) and the scan-chunked loop (chunk>0)."""
    from repro.core.costmodel import DCRA_SRAM, board_link_provisioning
    mono = _trace_run(name, g, root).run.trace.to_dict()
    assert mono["chips_y"] == mono["chips_x"] == 1
    assert sum(mono["off_chip_msgs"]) == 0
    for chunk in (0, 8):
        dist = _trace_run(name, g, root, chips=4,
                          run_chunk=chunk).run.trace.to_dict()
        for f in EQUIV_TRACE_FIELDS:
            assert dist[f] == mono[f], (name, chunk, f)
        # the board leg exists only once the grid is physically split
        assert sum(dist["off_chip_msgs"]) > 0, (name, chunk)
        assert sum(dist["off_chip_bits"]) > 0, (name, chunk)
        # the trace records its partition geometry + the provisioning the
        # run's own package config implies (what re-pricing rescales)
        assert dist["chips_y"] * dist["chips_x"] == 4
        assert dist["board_links"] == board_link_provisioning(
            DCRA_SRAM, dist["chips_y"], dist["chips_x"])


# ------------------------------------------------------ 1 -> 256 weak scaling
def test_weak_scaling_monotone_gteps_and_energy_report():
    rows = harness.weak_scaling(chip_counts=(1, 4, 16, 64, 256))
    curve = [r["gteps"] for r in rows]
    # measured GTEPS grows monotonically with the chip count (weak
    # scaling: constant per-chip work, growing dataset)
    assert all(b > a for a, b in zip(curve, curve[1:])), curve
    assert rows[-1]["chips"] == 256 and rows[-1]["tiles"] == 4096
    # off-chip traffic is measured and counted in the energy/$ report
    for r in rows[1:]:
        assert r["off_chip_msgs"] > 0
        assert 0 < r["off_chip_j"] < r["energy_j"]
        assert r["cost_usd"] > 0
    assert rows[0]["off_chip_msgs"] == 0           # single chip: no boundary
    # re-pricing cross-check: the analytic board-level pricing of each
    # measured trace reproduces the directly measured N-chip time
    for r in rows:
        assert abs(r["reprice_ratio"] - 1.0) < 1e-9, r


# ------------------------------------------------------- shard_map backend
def test_shard_map_backend_matches_emulation():
    out = run_devices("""
import numpy as np, jax
from repro.core.tilegrid import square_grid
from repro.graph import apps, rmat_edges
assert jax.device_count() == 8
g = rmat_edges(9, edge_factor=8, seed=1)
grid = square_grid(64)
root = int(np.argmax(g.out_degree()))
m = apps.bfs(g, root, grid, oq_cap=32)
for chips in (8, 16):   # 1 and 2 chips per device
    d = apps.bfs(g, root, grid, oq_cap=32, chips=chips, backend="shard_map")
    assert np.array_equal(m.values, d.values), chips
    assert d.run.counters.off_chip_msgs > 0
px = apps.table2_proxy(grid, "histo")
from repro.graph.rmat import histogram_input
bins = g.n_rows // 8
hv = histogram_input(g, bins)
hm = apps.histogram(hv, bins, grid, proxy=px, oq_cap=32)
hd = apps.histogram(hv, bins, grid, proxy=px, oq_cap=32, chips=8,
                    backend="shard_map")
assert np.array_equal(hm.values, hd.values)
print("OK")
""", n=8)
    assert "OK" in out
