"""Trip-count-aware HLO analyzer vs closed forms on synthetic scans
(the roofline's data source must itself be verified)."""
import numpy as np
import pytest

from _subproc import run_devices


def test_scan_flops_scale_with_trip_count():
    out = run_devices("""
import jax, jax.numpy as jnp
from repro.launch.hloanalysis import analyze_hlo

def make(n):
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y
    return jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((n, 128, 128), jnp.float32)).compile()

for n in (2, 8):
    a = analyze_hlo(make(n).as_text())
    expect = n * 2 * 128**3          # n matmuls
    ratio = a["flops"] / expect
    assert 0.95 < ratio < 1.15, (n, ratio)   # + tanh elementwise
    # stacked w streams through HBM once, not per trip
    assert a["hbm_bytes"] < 3 * (n * 128 * 128 * 4 + 10 * 128 * 128 * 4), \\
        (n, a["hbm_bytes"])
print("OK")
""", n=4)
    assert "OK" in out


def test_collectives_inside_scan_multiply():
    out = run_devices("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hloanalysis import analyze_hlo

mesh = jax.make_mesh((4,), ("model",))
def g(x, w):
    def body(c, wi):
        return jnp.tanh(c @ wi), None
    y, _ = jax.lax.scan(body, x, w)
    return y
c = jax.jit(g, in_shardings=(
    NamedSharding(mesh, P(None, "model")),
    NamedSharding(mesh, P(None, "model", None)))).lower(
    jax.ShapeDtypeStruct((128, 128), jnp.float32),
    jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)).compile()
a = analyze_hlo(c.as_text())
counts = a["collective_counts"]
total = sum(counts.values())
assert total >= 8, counts            # one AR per scan step, x8 trips
print("OK", counts)
""", n=4)
    assert "OK" in out


def test_parser_handles_tuples_and_dus():
    from repro.launch.hloanalysis import analyze_hlo
    hlo = """
ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  %c = f32[8,64]{1,0} constant({...})
  %dus = f32[64,64]{1,0} dynamic-update-slice(%p0, %c, %i, %i)
  ROOT %ar = f32[64,64]{1,0} all-reduce(%dus), to_apply=%add
}
"""
    a = analyze_hlo(hlo)
    assert a["collective_bytes"]["all-reduce"] == 64 * 64 * 4
    # DUS charged as slice traffic (small operand), not 2x the buffer
    assert a["hbm_bytes"] <= 8 * 64 * 4 + 1
