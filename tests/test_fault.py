"""Fault-tolerant distributed runtime (``repro.runtime`` + driver).

Acceptance properties:
  * a chip loss injected at a seeded random superstep recovers
    **bit-identically** to an unfailed run — same final values,
    TrafficCounters, superstep count and SuperstepTrace vectors — for
    all six apps on 4 chips, across chunked/legacy dispatch,
    double-buffered exchange on/off and active-set compaction on/off
    (and on a real 4-device ``shard_map`` mesh via subprocess);
  * re-pricing a faulted run's trace under its own config reproduces
    its measured time **exactly** (``reprice_ratio == 1.0``): the
    recovery overhead legs (checkpoint writes, discarded replay window,
    re-shard restore) are priced from ``trace.recovery_events`` with
    the same shared helpers the run loop used;
  * ``FaultTolerantLoop.run`` rolls its metrics history back with the
    state (no double-counted replay steps) and budgets retries
    **per step** (a flaky step cannot eat another step's budget; a
    persistently failing step still gives up);
  * ``straggler.rebalance_chunks`` returns monotone boundaries whose
    sizes sum exactly to ``n_items`` and stay inside the clip window —
    including when the post-clip drift exceeds the tile count.
"""
import zlib

import numpy as np
import pytest

from repro.core.costmodel import trace_time_s
from repro.core.netstats import SuperstepTrace
from repro.core.tilegrid import square_grid
from repro.graph import rmat_edges
from repro.graph.apps import engine_and_state
from repro.graph.rmat import histogram_input
from repro.runtime import (FaultInjector, FaultTolerantLoop,
                           SimulatedFailure, detect_stragglers,
                           rebalance_chunks)

from _subproc import run_devices

GRID = square_grid(16)
ALL_APPS = ("bfs", "sssp", "wcc", "pagerank", "spmv", "histo")
CHIPS = 4


@pytest.fixture(scope="module")
def g():
    return rmat_edges(8, edge_factor=8, seed=1)


def _engine(name, g, **kw):
    kw.setdefault("chips", CHIPS)
    kw.setdefault("oq_cap", 16)
    if name in ("bfs", "sssp"):
        # root 0 can be isolated in an RMAT sample; seed from the hub
        kw.setdefault("root", int(np.argmax(g.out_degree())))
    if name == "histo":
        bins = g.n_rows // 8
        return engine_and_state(name, g, GRID,
                                histo_values=histogram_input(g, bins),
                                bins=bins, **kw)
    return engine_and_state(name, g, GRID, **kw)


def _assert_bit_identical(base_state, base, f_state, f):
    assert np.array_equal(base_state["values"], f_state["values"])
    assert base.counters.as_dict() == f.counters.as_dict()
    assert base.supersteps == f.supersteps
    for k in SuperstepTrace._VECTOR_FIELDS:
        assert getattr(base.trace, k) == getattr(f.trace, k), k
    assert base.trace.board_links == f.trace.board_links
    assert base.trace.double_buffer == f.trace.double_buffer


def _fault_pair(name, g, *, chunk, seed=None, at=None, chip=1,
                ckpt_dir=None, **cfg_kw):
    """(unfailed run, chip-loss run) of the same app+config."""
    cfg_kw.setdefault("ckpt_every_supersteps", 3)
    eng, state, _ = _engine(name, g, **cfg_kw)
    base_state, base = eng.run(dict(state), chunk=chunk)
    eng2, state2, _ = _engine(name, g, **cfg_kw)
    if seed is not None:
        inj = FaultInjector.seeded(seed, max_superstep=base.supersteps,
                                   num_chips=CHIPS)
    else:
        inj = FaultInjector(at_superstep=at, chip=chip)
    f_state, f = eng2.run(dict(state2), chunk=chunk, fault_injector=inj,
                          ckpt_dir=ckpt_dir)
    assert inj.fired, "injector never fired: loss point past drain"
    return base_state, base, f_state, f, eng2


# ---------------------------------------------------- chip-loss bit-identity
@pytest.mark.parametrize("name", ALL_APPS)
def test_chip_loss_recovers_bit_identical(name, g, tmp_path):
    """Seeded random loss point/chip, all six apps, chunked dispatch."""
    seed = zlib.crc32(name.encode())       # stable across interpreters
    base_state, base, f_state, f, _ = _fault_pair(
        name, g, chunk=8, seed=seed, ckpt_dir=str(tmp_path / name))
    _assert_bit_identical(base_state, base, f_state, f)
    # the unfailed run checkpoints on the same cadence, nothing more
    assert all(ev["kind"] == "checkpoint"
               for ev in base.trace.recovery_events)
    kinds = [ev["kind"] for ev in f.trace.recovery_events]
    assert "rollback" in kinds and "reshard" in kinds
    assert kinds[0] == "checkpoint"          # the step-0 baseline


@pytest.mark.parametrize("chunk", [0, 8])
@pytest.mark.parametrize("double_buffer", [False, True])
@pytest.mark.parametrize("compaction", [0, 2])
def test_chip_loss_matrix(g, chunk, double_buffer, compaction):
    """Dispatch-mode matrix: legacy/chunked x double-buffer x
    compaction, loss pinned mid-run."""
    base_state, base, f_state, f, eng = _fault_pair(
        "bfs", g, chunk=chunk, at=5, chip=2,
        double_buffer=double_buffer, compaction=compaction)
    _assert_bit_identical(base_state, base, f_state, f)
    # the faulted run costs strictly more — overhead is priced, not lost
    assert f.cycles > base.cycles


@pytest.mark.parametrize("double_buffer", [False, True])
def test_faulted_run_reprices_exactly(g, double_buffer):
    """reprice_ratio == 1.0 *exactly* on a faulted run: the trace
    replay re-derives base + overhead with bit-identical floats."""
    _, base, _, f, eng = _fault_pair("bfs", g, chunk=8, at=5,
                                     double_buffer=double_buffer)
    t = trace_time_s(eng.cfg.pkg, GRID, f.trace)
    assert t == f.time_s
    assert t / f.time_s == 1.0
    # and the unfailed run's contract still holds
    assert trace_time_s(eng.cfg.pkg, GRID, base.trace) == base.time_s


def test_checkpoint_cadence_alone_is_inert(g):
    """A checkpoint cadence without a failure changes nothing but the
    event log (checkpoint legs are priced overhead, exactly repriced)."""
    eng, state, _ = _engine("bfs", g)
    base_state, base = eng.run(dict(state), chunk=8)
    eng2, state2, _ = _engine("bfs", g, ckpt_every_supersteps=2)
    c_state, c = eng2.run(dict(state2), chunk=8)
    _assert_bit_identical(base_state, base, c_state, c)
    assert all(ev["kind"] == "checkpoint"
               for ev in c.trace.recovery_events)
    assert len(c.trace.recovery_events) > 1
    assert c.cycles > base.cycles
    assert trace_time_s(eng2.cfg.pkg, GRID, c.trace) == c.time_s


def test_chip_loss_on_4_device_mesh(g):
    """Real multi-device recovery: 4 forced host devices, shard_map
    backend.  After the loss the mesh rebuilds on the surviving 3
    devices — the largest subset dividing 4 chips is 2 devices (2 chips
    per device), so the lost chip's block lands on a survivor."""
    out = run_devices("""
import numpy as np
from repro.core.tilegrid import square_grid
from repro.graph import rmat_edges
from repro.graph.apps import engine_and_state
from repro.runtime import FaultInjector

g = rmat_edges(8, edge_factor=8, seed=1)
grid = square_grid(16)
kw = dict(chips=4, oq_cap=16, backend="shard_map",
          ckpt_every_supersteps=3, root=int(np.argmax(g.out_degree())))
eng, state, _ = engine_and_state("bfs", g, grid, **kw)
assert eng.mesh.ndev == 4, eng.mesh
base_state, base = eng.run(dict(state), chunk=8)
eng2, state2, _ = engine_and_state("bfs", g, grid, **kw)
inj = FaultInjector(at_superstep=5, chip=3)
f_state, f = eng2.run(dict(state2), chunk=8, fault_injector=inj)
assert inj.fired
assert eng2.mesh.ndev == 2, f"mesh not rebuilt on survivors: {eng2.mesh}"
ev = f.trace.recovery_events
assert any(e["kind"] == "reshard" and e["devices"] == 2 for e in ev), ev
assert np.array_equal(base_state["values"], f_state["values"])
assert base.counters.as_dict() == f.counters.as_dict()
assert base.supersteps == f.supersteps
print("MESH_RECOVERY_OK")
""", n=4)
    assert "MESH_RECOVERY_OK" in out


# ------------------------------------------------------ recovery event log
def _mk_trace(n):
    t = SuperstepTrace()
    for i in range(n):
        for f in SuperstepTrace._VECTOR_FIELDS:
            getattr(t, f).append(float(i))
    return t


def test_recovery_events_roundtrip_and_extend():
    t = _mk_trace(6)
    t.recovery_events.append(dict(kind="checkpoint", step=0, bits=8.0))
    t.recovery_events.append(dict(kind="rollback", chip=1, from_step=0,
                                  at_step=4))
    d = t.to_dict()
    assert d["recovery_events"] == t.recovery_events
    rt = SuperstepTrace.from_dict(d)
    assert rt.recovery_events == t.recovery_events
    # an event-free trace keeps its legacy dict shape
    assert "recovery_events" not in SuperstepTrace().to_dict()
    # extend() shifts the appended trace's event step anchors
    other = _mk_trace(3)
    other.recovery_events.append(dict(kind="checkpoint", step=1, bits=2.0))
    t.extend(other)
    assert t.recovery_events[-1]["step"] == 6 + 1


def test_trace_truncate():
    t = _mk_trace(5)
    t.truncate(2)
    assert len(t) == 2
    assert t.compute_ops == [0.0, 1.0]
    assert all(len(getattr(t, f)) == 2 for f in t._VECTOR_FIELDS)
    t.truncate(0)
    assert len(t) == 0


# ------------------------------------------------- FaultTolerantLoop fixes
def _loop(tmp_path, hook=None, **kw):
    def train_step(state, batch):
        s = state + batch
        return s, {"loss": float(s)}

    return FaultTolerantLoop(train_step=train_step,
                             batch_at=lambda step: float(step + 1),
                             ckpt_dir=str(tmp_path), failure_hook=hook,
                             **kw)


def test_loop_history_rolls_back_with_state(tmp_path):
    """A rollback replays steps; their metrics must not double-count."""
    fails = {5: 1}

    def hook(step):
        if fails.get(step, 0) > 0:
            fails[step] -= 1
            raise SimulatedFailure(f"step {step}")

    loop = _loop(tmp_path / "a", hook, ckpt_every=2)
    state, history = loop.run(np.float64(0.0), 8)
    ref_state, ref_history = _loop(tmp_path / "b", ckpt_every=2).run(
        np.float64(0.0), 8)
    assert state == ref_state
    assert history == ref_history          # exactly one entry per step
    assert len(history) == 8


def test_loop_retry_budget_is_per_step(tmp_path):
    """Two different flaky steps each get the full budget."""
    fails = {2: 2, 5: 2}

    def hook(step):
        if fails.get(step, 0) > 0:
            fails[step] -= 1
            raise SimulatedFailure(f"step {step}")

    loop = _loop(tmp_path / "c", hook, ckpt_every=2,
                 max_retries_per_step=2)
    state, history = loop.run(np.float64(0.0), 8)
    assert len(history) == 8
    assert state == float(sum(range(1, 9)))


def test_loop_gives_up_on_persistent_step(tmp_path):
    """A step that always fails exhausts its budget even though the
    rollback replays earlier (succeeding) steps in between."""
    calls = [0]

    def hook(step):
        if step == 3:
            calls[0] += 1
            raise SimulatedFailure("always")

    loop = _loop(tmp_path / "d", hook, ckpt_every=2,
                 max_retries_per_step=3)
    with pytest.raises(SimulatedFailure):
        loop.run(np.float64(0.0), 8)
    assert calls[0] == 4                   # initial try + 3 retries


# ------------------------------------------------------ straggler rebalance
def _assert_valid_boundaries(b, t, n_items):
    assert b.shape == (t + 1,)
    assert b[0] == 0 and b[-1] == n_items
    sizes = np.diff(b)
    assert (sizes >= 0).all(), "non-monotone boundaries"
    assert sizes.sum() == n_items


def test_rebalance_exact_total_random():
    rng = np.random.default_rng(0)
    for _ in range(300):
        t = int(rng.integers(2, 65))
        n_items = int(rng.integers(t, 5000))
        load = rng.random(t) * 10 ** rng.integers(0, 6)
        max_ratio = float(rng.uniform(1.05, 4.0))
        b = rebalance_chunks(load, n_items, max_ratio=max_ratio)
        _assert_valid_boundaries(b, t, n_items)


def test_rebalance_large_drift():
    """One molten-hot chunk: the clip's drift exceeds the tile count,
    which the seed's single +-1 repair pass (and its final-boundary
    overwrite) silently corrupted."""
    t = 16
    load = np.ones(t)
    load[0] = 1e9
    b = rebalance_chunks(load, 160, max_ratio=1.5)
    _assert_valid_boundaries(b, t, 160)
    sizes = np.diff(b)
    # every chunk stays inside the clip window after the full repair
    assert sizes.min() >= min(int(160 / t / 1.5), 160 // t)
    assert sizes.max() <= max(int(np.ceil(160 / t * 1.5)),
                              int(np.ceil(160 / t)))
    # the hot chunk never ends up above the equal share
    assert sizes[0] <= 160 // t


def test_rebalance_balanced_is_noop():
    b = rebalance_chunks(np.ones(8), 800)
    assert (np.diff(b) == 100).all()


def test_detect_stragglers():
    load = np.array([1.0, 1.0, 1.0, 9.0])
    mask, ratio = detect_stragglers(load, threshold=2.0)
    assert mask.tolist() == [False, False, False, True]
    assert ratio == pytest.approx(3.0)


def test_rebalance_plan_from_telemetry(g):
    """End-to-end: telemetry run -> straggler verdict -> advisory
    boundaries for the next wave."""
    eng, state, _ = _engine("bfs", g, telemetry=True)
    eng.run(dict(state), chunk=8)
    plan = eng.rebalance_plan()
    assert plan["load"].shape == (CHIPS,)
    _assert_valid_boundaries(plan["boundaries"], CHIPS,
                             GRID.num_tiles * eng.Cd)
    assert plan["imbalance"] >= 1.0
    assert plan["predicted_imbalance"] <= plan["imbalance"] + 1e-9
    eng2, state2, _ = _engine("bfs", g)       # telemetry off
    eng2.run(dict(state2), chunk=8)
    with pytest.raises(ValueError):
        eng2.rebalance_plan()
