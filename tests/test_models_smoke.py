"""Per-architecture smoke tests (deliverable f): reduced config of the
same family, one forward/train step on CPU, asserting output shapes and
no NaNs — plus prefill/decode consistency for the attention families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.models.lm import lm_loss

ARCHS = list(registry.ARCHS)


def _batch(cfg, rng, b=2, s=16):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    if cfg.family == "encdec":
        return dict(embeds=jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.bfloat16),
            tokens=tokens, labels=labels)
    if cfg.input_embeds:
        return dict(embeds=jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.bfloat16),
            labels=labels)
    return dict(tokens=tokens, labels=labels)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch, rng):
    cfg, fam = registry.get(arch, smoke=True)
    params = fam["init"](cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits, aux = fam["forward"](params, batch, cfg)
    main = logits[0] if isinstance(logits, tuple) else logits
    b, s = batch["labels"].shape
    assert main.shape == (b, s, cfg.vocab_pad)
    assert bool(jnp.all(jnp.isfinite(main.astype(jnp.float32))))

    def loss_fn(p):
        lg, aux = fam["forward"](p, batch, cfg)
        lg = lg[0] if isinstance(lg, tuple) else lg
        return lm_loss(lg, batch["labels"], cfg, aux)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch, rng):
    cfg, fam = registry.get(arch, smoke=True)
    params = fam["init"](cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)
    logits, cache = fam["prefill"](params, batch, cfg)
    assert logits.shape[0] == b and logits.shape[-1] == cfg.vocab_pad
    tok = jnp.zeros((b, 1), jnp.int32)
    lg, cache2 = fam["decode"](params, cache, tok, jnp.int32(s - 1), cfg)
    assert lg.shape == (b, cfg.vocab_pad)
    assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
    # cache structure is stable across steps (required by jitted loops)
    assert (jax.tree.structure(cache) == jax.tree.structure(cache2))


@pytest.mark.parametrize("arch", ["deepseek-7b", "xlstm-1.3b",
                                  "zamba2-1.2b"])
def test_decode_matches_forward(arch, rng):
    """Teacher-forcing consistency: decoding token-by-token reproduces the
    full-sequence forward logits (the decode path is not an
    approximation)."""
    from repro.serving.kvcache import pad_cache
    cfg, fam = registry.get(arch, smoke=True)
    params = fam["init"](cfg, jax.random.PRNGKey(0))
    b, s = 1, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    full, _ = fam["forward"](params, dict(tokens=toks), cfg)
    # prefill on the first s-1 tokens, then decode the last one (the
    # cache needs one slot of decode headroom)
    logits_p, cache = fam["prefill"](params, dict(tokens=toks[:, :-1]), cfg)
    cache = pad_cache(cfg, cache, 1)
    lg, _ = fam["decode"](params, cache, toks[:, -1:], jnp.int32(s - 1), cfg)
    want = full[:, -1].astype(np.float32)
    got = lg.astype(np.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-2, atol=5e-2)
    # prefill's own logits must equal the forward logits at that position
    np.testing.assert_allclose(np.asarray(logits_p[:, 0], np.float32),
                               np.asarray(full[:, -2], np.float32),
                               rtol=5e-2, atol=5e-2)


def test_swa_ring_cache(rng):
    """h2o-danube's sliding window: decode cache is window-sized and the
    step accepts positions beyond the window (ring addressing)."""
    cfg, fam = registry.get("h2o-danube-3-4b", smoke=True)
    assert cfg.swa_window == 8
    cache = fam["init_cache"](cfg, 2, 32)
    assert cache["k"].shape[2] == cfg.swa_window
    params = fam["init"](cfg, jax.random.PRNGKey(0))
    lg, cache = fam["decode"](params, cache, jnp.zeros((2, 1), jnp.int32),
                              jnp.int32(20), cfg)
    assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))


def test_param_counts_full_configs():
    """Full-config analytic param counts are in the right ballpark."""
    approx = {"starcoder2-3b": (2.5e9, 4.5e9),
              "starcoder2-15b": (13e9, 18e9),
              "deepseek-7b": (6e9, 8e9),
              "deepseek-v3-671b": (5.5e11, 7.5e11),
              "granite-moe-1b-a400m": (0.7e9, 1.7e9),
              "xlstm-1.3b": (0.9e9, 2.2e9),   # ours carries sLSTM FFNs
              "zamba2-1.2b": (0.8e9, 1.8e9)}
    for arch, (lo, hi) in approx.items():
        n = registry.ARCHS[arch].param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo},{hi}]"
