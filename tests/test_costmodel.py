"""Cost/energy model sanity (paper §IV-B constants and Fig. 9 structure)."""
import dataclasses

import numpy as np
import pytest

from repro.core.costmodel import (DALOREX, DCRA_HBM_HORIZ, DCRA_HBM_VERT,
                                  DCRA_SRAM, NETWORK_OPTIONS,
                                  board_link_provisioning, dcra_die_area_mm2,
                                  die_cost, dies_per_wafer, murphy_yield,
                                  price, system_cost_usd, tile_area_mm2)
from repro.core.netstats import TrafficCounters
from repro.core.tilegrid import TileGrid, square_grid

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:          # property tests below degrade to skips
    given = None


def test_murphy_yield_monotone():
    areas = [10, 50, 100, 400, 800]
    ys = [murphy_yield(a) for a in areas]
    assert all(0 < y <= 1 for y in ys)
    assert all(a >= b for a, b in zip(ys, ys[1:]))


def test_murphy_yield_bounds_dense_sweep():
    """murphy_yield stays in (0, 1] and strictly decreases with area
    across the whole plausible die-size range (0 -> perfect yield)."""
    assert murphy_yield(0.0) == 1.0
    areas = np.linspace(1.0, 2000.0, 200)
    ys = np.array([murphy_yield(a) for a in areas])
    assert np.all((ys > 0) & (ys <= 1))
    assert np.all(np.diff(ys) < 0)


def test_dies_per_wafer_and_die_cost_monotone():
    """Bigger dies: strictly fewer candidates per wafer, strictly higher
    unit cost (yield superlinearity on top of area)."""
    areas = [25, 50, 100, 200, 400, 800]
    dpw = [dies_per_wafer(a) for a in areas]
    assert all(d >= 1.0 for d in dpw)
    assert all(a > b for a, b in zip(dpw, dpw[1:]))
    costs = [die_cost(a) for a in areas]
    assert all(c > 0 for c in costs)
    assert all(a < b for a, b in zip(costs, costs[1:]))


def test_price_deterministic_across_calls():
    """price() is pure: identical inputs give bit-identical reports on
    repeated calls (the benchmarks diff runs across commits)."""
    g = square_grid(1024)
    c = _counters()
    reps = [price(DCRA_HBM_HORIZ, g, c, mem_bits_sram=1e9,
                  mem_bits_hbm=1e10) for _ in range(3)]
    for r in reps[1:]:
        assert r.time_s == reps[0].time_s
        assert r.energy_j == reps[0].energy_j
        assert r.cost_usd == reps[0].cost_usd
        assert r.breakdown == reps[0].breakdown


def test_paper_die_size_yield_claim():
    """Paper §V-A: a 32x32-tile die (~27x25mm) yields far fewer good dies
    per wafer than 16x16 dies (paper: "62% less")."""
    a16 = dcra_die_area_mm2(DCRA_SRAM, TileGrid(16, 16))
    a32 = 4 * a16
    good16 = dies_per_wafer(a16) * murphy_yield(a16)
    good32 = dies_per_wafer(a32) * murphy_yield(a32)
    # raw good dies per wafer collapse (>=60% fewer, the paper's claim)
    assert good32 / good16 < 0.4
    # per-tile silicon efficiency also degrades, but less than 2x
    assert 0.4 < (good32 * 4) / good16 < 0.9


def test_die_cost_increases_with_area():
    assert die_cost(400.0) > 4 * die_cost(100.0)   # superlinear via yield


def test_sram_dominates_tile_area():
    a = tile_area_mm2(1.5)
    logic = (1.5 / 3.5) / 7.0
    assert a > 7 * logic                # §V-A: SRAM ~7x logic


def test_hbm_package_costs_more():
    g = square_grid(1024)               # 2x2 dies
    assert system_cost_usd(DCRA_HBM_HORIZ, g) > system_cost_usd(DCRA_SRAM, g)
    assert system_cost_usd(DCRA_HBM_VERT, g) > \
        system_cost_usd(DCRA_HBM_HORIZ, g)


def test_network_option_c_area_overhead():
    """Fig. 6 text: option (c) grows die area ~4.5% over option (a)."""
    g = TileGrid(16, 16)
    a = dcra_die_area_mm2(NETWORK_OPTIONS["a_2x32_od32"], g)
    c = dcra_die_area_mm2(NETWORK_OPTIONS["c_32+64_od2x32"], g)
    assert 1.005 < c / a < 1.06


def _counters(msgs=1e6, hops=4e6):
    c = TrafficCounters()
    c.messages = msgs
    c.hop_msgs = hops
    c.intra_die_hops = hops * 0.8
    c.inter_die_crossings = hops * 0.15
    c.inter_pkg_crossings = hops * 0.05
    c.edges_processed = msgs
    c.records_consumed = msgs / 2
    return c


def test_price_components_positive():
    g = square_grid(4096)
    rep = price(DCRA_SRAM, g, _counters(), mem_bits_sram=1e9)
    assert rep.energy_j > 0 and rep.cost_usd > 0 and rep.time_s > 0
    assert rep.breakdown["wire_j"] > 0
    assert rep.power_w == pytest.approx(rep.energy_j / rep.time_s)


def test_vertical_hbm_saves_wire_energy():
    g = square_grid(1024)
    c = _counters()
    horiz = price(DCRA_HBM_HORIZ, g, c, mem_bits_hbm=1e10)
    vert = price(DCRA_HBM_VERT, g, c, mem_bits_hbm=1e10)
    assert vert.energy_j < horiz.energy_j      # paper §V-C conclusion


def test_dalorex_narrower_links_slower():
    g = square_grid(4096)
    c = _counters()
    t_dal = price(DALOREX, g, c).time_s
    t_dcra = price(DCRA_SRAM, g, c).time_s
    assert t_dal >= t_dcra


# --------------------------------------------------------------------------
# per-superstep re-pricing contract (the measure-once / price-many fix)
# --------------------------------------------------------------------------
def _net_trace(steps=3):
    """Synthetic per-superstep level traffic where the network dominates
    compute, so link provisioning decides the BSP time."""
    return dict(compute_ops=[1e3] * steps,
                intra_bits=[4e8] * steps,
                die_bits=[5e8] * steps,
                pkg_bits=[0.0] * steps)


def test_reprice_network_options_different_and_ordered():
    """Regression for the broken contract: re-pricing the *same* counters
    under option (a) vs (d) must give different — and correctly ordered —
    times (the old code silently reused one time for every config)."""
    g = square_grid(1024)
    c = _counters()
    t = {k: price(NETWORK_OPTIONS[k], g, c,
                  per_superstep_peak=_net_trace()).time_s
         for k in NETWORK_OPTIONS}
    assert all(v > 0 for v in t.values())
    # (a) halves both link widths vs (d): strictly slower, not equal
    assert t["a_2x32_od32"] > t["d_32+64_od64"]
    # wider intra-die links ((b) vs (a)) can never hurt
    assert t["b_32+64_od32"] <= t["a_2x32_od32"]
    # doubling inter-die links ((c) vs (b)) can never hurt
    assert t["c_32+64_od2x32"] <= t["b_32+64_od32"]


def test_reprice_noc_count_and_hbm_channels_live():
    """The documented knobs beyond link widths: NoC count scales intra-die
    capacity; an hbm_bits vector adds the HBM drain leg for HBM configs."""
    import dataclasses
    g = square_grid(1024)
    c = _counters()
    tr = dict(compute_ops=[0.0], intra_bits=[1e9], die_bits=[0.0],
              pkg_bits=[0.0])
    base = price(DCRA_SRAM, g, c, per_superstep_peak=tr).time_s
    single_noc = dataclasses.replace(DCRA_SRAM, noc_count=1)
    t1 = price(single_noc, g, c, per_superstep_peak=tr).time_s
    # serialization doubles; the constant pipeline-fill term does not
    assert 1.9 * base < t1 < 2.0 * base
    hbm_tr = dict(tr, hbm_bits=[1e13])
    t_hbm = price(DCRA_HBM_HORIZ, g, c, per_superstep_peak=hbm_tr).time_s
    assert t_hbm > price(DCRA_HBM_HORIZ, g, c,
                         per_superstep_peak=tr).time_s
    # hbm_bits on a SRAM-only product has no HBM channels to drain into
    assert price(DCRA_SRAM, g, c, per_superstep_peak=hbm_tr).time_s == \
        pytest.approx(base)


def test_reprice_legacy_time_s_still_honored():
    g = square_grid(1024)
    rep = price(DCRA_SRAM, g, _counters(),
                per_superstep_peak=dict(time_s=1.25e-3))
    assert rep.time_s == 1.25e-3


def test_reprice_empty_trace_falls_back_to_roofline():
    """A zero-superstep trace must not crash: it prices like no trace."""
    from repro.core.netstats import SuperstepTrace
    g = square_grid(1024)
    c = _counters()
    base = price(DCRA_SRAM, g, c).time_s
    assert price(DCRA_SRAM, g, c,
                 per_superstep_peak=SuperstepTrace()).time_s == base
    assert price(DCRA_SRAM, g, c,
                 per_superstep_peak=dict(compute_ops=[])).time_s == base


# --------------------------------------------------------------------------
# chip partitioning as a packaging axis (board leg + board-level $)
# --------------------------------------------------------------------------
def _board_trace(steps=4, chips=(2, 2)):
    """Synthetic distributed trace where the board leg dominates, so
    board-link provisioning decides the BSP time."""
    cy, cx = chips
    return dict(compute_ops=[1e3] * steps, intra_bits=[1e6] * steps,
                die_bits=[0.0] * steps, pkg_bits=[0.0] * steps,
                off_chip_bits=[5e9] * steps, off_chip_msgs=[100.0] * steps,
                chips_y=cy, chips_x=cx,
                board_links=board_link_provisioning(DCRA_SRAM, cy, cx))


def test_board_link_provisioning_formula():
    # 2x2 chip grid, default 2 links/adjacent pair/axis: 2*(2-1)*2 * 2axes
    assert board_link_provisioning(DCRA_SRAM, 2, 2) == 8
    assert board_link_provisioning(DCRA_SRAM, 1, 1) == 1     # floor
    wide = dataclasses.replace(DCRA_SRAM, board_links_y=4, board_links_x=1)
    # per-axis: 4 vertical-pair links * chips_x + 1 horizontal * chips_y
    assert board_link_provisioning(wide, 2, 2) == 2 * 1 + 2 * 4


def test_board_leg_rescaled_by_per_axis_provisioning():
    """Re-pricing a distributed trace under different board-link knobs
    rescales the board serialization leg — fewer links, strictly slower
    when the board dominates; wider provisioning can never hurt."""
    g = square_grid(1024)
    c = _counters()
    tr = _board_trace()
    t2 = price(DCRA_SRAM, g, c, per_superstep_peak=tr).time_s
    t1 = price(dataclasses.replace(DCRA_SRAM, board_links_y=1,
                                   board_links_x=1), g, c,
               per_superstep_peak=tr).time_s
    t4 = price(dataclasses.replace(DCRA_SRAM, board_links_y=4,
                                   board_links_x=4), g, c,
               per_superstep_peak=tr).time_s
    assert t1 > t2 > t4
    # board-dominated: halving provisioning ~doubles the serialization
    assert t1 / t2 == pytest.approx(2.0, rel=0.05)


def test_reprice_rejects_chip_count_mismatch():
    """A trace measured on one partition cannot be re-priced as a product
    with a different chip count — its off-chip traffic is a property of
    the measured partition."""
    g = square_grid(1024)
    c = _counters()
    tr = _board_trace(chips=(2, 2))
    ok = dataclasses.replace(DCRA_SRAM, chips=4)
    assert price(ok, g, c, per_superstep_peak=tr).time_s > 0
    for chips in (1, 2, 16):
        with pytest.raises(ValueError, match="chip"):
            price(dataclasses.replace(DCRA_SRAM, chips=chips), g, c,
                  per_superstep_peak=tr)
    # monolithic trace, multi-chip product: also a measurement mismatch
    with pytest.raises(ValueError, match="chip"):
        price(ok, g, c, per_superstep_peak=_net_trace())


def test_chip_partitioned_cost_model():
    """chips>=1 prices board-level packaging: per-chip IO dies and board
    sites, per-link board cost, and assembly yield per bonded die."""
    g = square_grid(4096)                       # 4x4 dies
    mono = system_cost_usd(DCRA_SRAM, g)        # chips=0: legacy model
    c1 = system_cost_usd(dataclasses.replace(DCRA_SRAM, chips=1), g)
    c4 = system_cost_usd(dataclasses.replace(DCRA_SRAM, chips=4), g)
    c16 = system_cost_usd(dataclasses.replace(DCRA_SRAM, chips=16), g)
    assert mono > 0 and c1 > 0
    # more chips: more IO dies + board sites/links on the same silicon
    assert c4 > c1 and c16 > c4
    # board links are priced hardware: wider provisioning costs more
    wide = dataclasses.replace(DCRA_SRAM, chips=16, board_links_y=8,
                               board_links_x=8)
    assert system_cost_usd(wide, g) > c16
    # a chip count that cannot partition the grid is rejected
    with pytest.raises(ValueError):
        system_cost_usd(dataclasses.replace(DCRA_SRAM, chips=5), g)


def test_assembly_yield_favors_splitting_large_builds():
    """The partitioning tradeoff the $ model encodes: bonding all dies of
    a very large grid into one package pays an assembly-yield penalty
    that eventually exceeds the extra IO-die/board cost of splitting."""
    g = square_grid(65536)                      # 16x16 = 256 dies
    c1 = system_cost_usd(dataclasses.replace(DCRA_SRAM, chips=1), g)
    c16 = system_cost_usd(dataclasses.replace(DCRA_SRAM, chips=16), g)
    assert c16 < c1


@pytest.mark.property
@pytest.mark.slow
@pytest.mark.skipif(given is None, reason="hypothesis not installed")
def test_price_monotonicity_properties():
    """Property: on random board-dominated traces, time is monotone
    non-increasing in board-link width and in NoC count, and board
    hardware $ is non-decreasing in board-link width."""
    g = square_grid(1024)
    c = _counters()

    @settings(max_examples=25, deadline=None)
    @given(off_bits=st.floats(1e6, 1e12), intra_bits=st.floats(1e6, 1e12),
           steps=st.integers(1, 6), links_lo=st.integers(1, 8),
           links_hi=st.integers(1, 8), noc_lo=st.integers(1, 4),
           noc_hi=st.integers(1, 4))
    def check(off_bits, intra_bits, steps, links_lo, links_hi, noc_lo,
              noc_hi):
        links_lo, links_hi = sorted((links_lo, links_hi))
        noc_lo, noc_hi = sorted((noc_lo, noc_hi))
        tr = dict(_board_trace(steps=steps),
                  off_chip_bits=[off_bits] * steps,
                  intra_bits=[intra_bits] * steps)
        lo = dataclasses.replace(DCRA_SRAM, board_links_y=links_lo,
                                 board_links_x=links_lo, noc_count=noc_lo)
        hi = dataclasses.replace(DCRA_SRAM, board_links_y=links_hi,
                                 board_links_x=links_hi, noc_count=noc_lo)
        assert price(hi, g, c, per_superstep_peak=tr).time_s <= \
            price(lo, g, c, per_superstep_peak=tr).time_s
        more_noc = dataclasses.replace(lo, noc_count=noc_hi)
        assert price(more_noc, g, c, per_superstep_peak=tr).time_s <= \
            price(lo, g, c, per_superstep_peak=tr).time_s
        cost_lo = system_cost_usd(
            dataclasses.replace(lo, chips=4), g)
        cost_hi = system_cost_usd(
            dataclasses.replace(hi, chips=4), g)
        assert cost_hi >= cost_lo

    check()


def test_reprice_energy_legs_package_invariant():
    """For fixed counters, energy legs that don't depend on the package
    (wire, PU, tag) are identical across every product config; the HBM
    refresh and interposer terms appear only for has_hbm configs."""
    from repro.products import product_space
    g = square_grid(1024)
    c = _counters()
    c.cascade_combined = 1e4
    reps = {cfg.name: (cfg, price(cfg, g, c, mem_bits_sram=1e9,
                                  per_superstep_peak=_net_trace()))
            for cfg in product_space()}
    base = next(iter(reps.values()))[1]
    for cfg, rep in reps.values():
        assert rep.breakdown["wire_j"] == base.breakdown["wire_j"]
        assert rep.breakdown["pu_j"] == base.breakdown["pu_j"]
        assert rep.breakdown["tags_j"] == base.breakdown["tags_j"]
        assert rep.breakdown["ops"] == base.breakdown["ops"]
    # same mem traffic, no HBM bits: only has_hbm configs pay refresh
    # energy and interposer dollars
    for name, (cfg, rep) in reps.items():
        twin = next(r for n, (c2, r) in reps.items()
                    if not c2.has_hbm
                    and c2.intra_die_link_bits == cfg.intra_die_link_bits
                    and c2.inter_die_link_bits == cfg.inter_die_link_bits
                    and c2.inter_die_links == cfg.inter_die_links
                    and c2.sram_per_tile_mib == cfg.sram_per_tile_mib)
        if cfg.has_hbm:
            assert rep.energy_j > twin.energy_j     # refresh
            assert rep.cost_usd > twin.cost_usd     # HBM + interposer
        else:
            assert rep.energy_j == twin.energy_j
            assert rep.cost_usd == twin.cost_usd
