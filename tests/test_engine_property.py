"""Property-based engine validation: on ARBITRARY random digraphs and
grid/proxy geometries, the data-local engine must agree with the
oracles — proxies and queue budgets may only change the schedule."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
pytestmark = pytest.mark.property
from hypothesis import given, settings, strategies as st

from repro.core.proxy import ProxyConfig
from repro.core.tilegrid import square_grid
from repro.graph import apps, oracles
from repro.graph.csr import csr_from_edges


def random_graph(draw):
    n = draw(st.integers(8, 48))
    m = draw(st.integers(n, 4 * n))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.integers(1, 16, m).astype(np.float32)
    return csr_from_edges(src, dst, n, weights=w), seed


graphs = st.composite(random_graph)


@given(graphs(), st.sampled_from([16, 64]),
       st.sampled_from([None, (2, 2), (4, 4)]),
       st.sampled_from([4, 32]))
@settings(max_examples=12, deadline=None)
def test_bfs_any_graph_any_grid(gs, tiles, region, oq):
    g, seed = gs
    grid = square_grid(tiles)
    if region and (grid.ny % region[0] or grid.nx % region[1]):
        return
    px = ProxyConfig(*region, slots=64) if region else None
    root = seed % g.n_rows
    r = apps.bfs(g, root, grid, proxy=px, oq_cap=oq)
    assert np.array_equal(r.values, oracles.bfs_oracle(g, root))


@given(graphs(), st.sampled_from([None, (2, 2)]), st.booleans())
@settings(max_examples=8, deadline=None)
def test_sssp_any_graph(gs, region, small_q):
    g, seed = gs
    grid = square_grid(16)
    px = ProxyConfig(*region, slots=64) if region else None
    root = seed % g.n_rows
    r = apps.sssp(g, root, grid, proxy=px, oq_cap=4 if small_q else 64)
    assert np.allclose(r.values, oracles.sssp_oracle(g, root))


@given(graphs(), st.booleans())
@settings(max_examples=8, deadline=None)
def test_histogram_conservation_property(gs, write_back):
    g, seed = gs
    grid = square_grid(16)
    bins = max(2, g.n_rows // 4)
    vals = (np.asarray(g.col_idx) % bins).astype(np.int32)
    px = ProxyConfig(2, 2, slots=32, write_back=True) if write_back else None
    r = apps.histogram(vals, bins, grid, proxy=px, oq_cap=8)
    assert int(r.values.sum()) == vals.shape[0]
    assert np.array_equal(r.values, oracles.histogram_oracle(vals, bins))


@given(graphs())
@settings(max_examples=6, deadline=None)
def test_spmv_linearity(gs):
    """Engine SPMV is linear: A(ax + by) == a Ax + b Ay."""
    g, seed = gs
    grid = square_grid(16)
    rng = np.random.default_rng(seed)
    x = rng.random(g.n_cols).astype(np.float32)
    y = rng.random(g.n_cols).astype(np.float32)
    rx = apps.spmv(g, x, grid, oq_cap=32).values
    ry = apps.spmv(g, y, grid, oq_cap=32).values
    rxy = apps.spmv(g, 2.0 * x + 3.0 * y, grid, oq_cap=32).values
    assert np.allclose(rxy, 2.0 * rx + 3.0 * ry, rtol=1e-3, atol=1e-3)
