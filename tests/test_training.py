"""Training substrate: optimizers, gradient accumulation, loss descent."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import SyntheticLM
from repro.models import registry
from repro.training.optimizer import adafactor, adamw
from repro.training.train_step import (TrainState, clip_by_global_norm,
                                       make_train_step)


@pytest.fixture(scope="module")
def setup():
    import dataclasses
    cfg, fam = registry.get("deepseek-7b", smoke=True)
    cfg = dataclasses.replace(cfg, vocab=128)   # learnable in ~40 steps
    params = fam["init"](cfg, jax.random.PRNGKey(0))
    src = SyntheticLM(vocab=cfg.vocab, seq_len=64, batch=16, noise=0.0)
    return cfg, fam, params, src


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_loss_decreases(setup, opt_name):
    cfg, fam, params, src = setup
    opt = adamw(lr=1e-2, warmup=3) if opt_name == "adamw" \
        else adafactor(lr=5e-2, warmup=3)
    state = TrainState.create(params, opt)
    step = jax.jit(make_train_step(cfg, fam, opt))
    losses = []
    for i in range(40):
        state, m = step(state, jax.tree.map(jnp.asarray, src.batch_at(i)))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_grad_accum_equivalence(setup):
    """microbatches=2 produces (nearly) the same update as one batch."""
    cfg, fam, params, src = setup
    opt = adamw(lr=1e-3)
    state = TrainState.create(params, opt)
    batch = jax.tree.map(jnp.asarray, src.batch_at(0))
    s1, m1 = jax.jit(make_train_step(cfg, fam, opt, microbatches=1))(
        state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, fam, opt, microbatches=2))(
        state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 5e-2


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 10.0, "b": jnp.ones((3,)) * -10.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    from repro.training.train_step import global_norm
    assert float(norm) > 1.0
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_adafactor_state_is_factored(setup):
    cfg, fam, params, _ = setup
    opt = adafactor()
    st = opt.init(params)
    p_bytes = sum(x.size * x.dtype.itemsize
                  for x in jax.tree.leaves(params))
    s_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(st))
    assert s_bytes < 0.35 * p_bytes    # far sub-linear vs adamw's 4x


def test_mtp_loss_path():
    cfg, fam = registry.get("deepseek-v3-671b", smoke=True)
    assert cfg.mtp
    params = fam["init"](cfg, jax.random.PRNGKey(0))
    opt = adafactor(lr=1e-3)
    state = TrainState.create(params, opt)
    rng = np.random.default_rng(0)
    batch = dict(
        tokens=jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32),
        labels=jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32))
    state, m = jax.jit(make_train_step(cfg, fam, opt))(state, batch)
    assert np.isfinite(float(m["loss"]))
