"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
pytestmark = pytest.mark.property
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,bins", [(100, 37), (5000, 1000), (1024, 512),
                                    (3000, 2048), (1, 5)])
def test_histogram_shapes(n, bins, rng):
    idx = rng.integers(0, bins, n).astype(np.int32)
    a = ops.histogram(jnp.asarray(idx), bins)
    b = ref.histogram_ref(idx, bins)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_histogram_ignores_padding(rng):
    idx = np.array([-1, 0, 1, -1, 1], np.int32)
    a = ops.histogram(jnp.asarray(idx), 4)
    np.testing.assert_allclose(np.asarray(a), [1, 2, 0, 0])


@pytest.mark.parametrize("combine", ["min", "add"])
@pytest.mark.parametrize("n", [17, 2048, 5000])
def test_relax(combine, n, rng):
    v = rng.random(n).astype(np.float32)
    m = rng.random(n).astype(np.float32)
    f = rng.random(n) < 0.5
    a1, a2 = ops.relax(jnp.asarray(v), jnp.asarray(m), jnp.asarray(f),
                       combine=combine)
    b1, b2 = ref.relax_ref(v, m, f, combine=combine)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(b1), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(a2), np.asarray(b2))


@pytest.mark.parametrize("combine", ["min", "add"])
@pytest.mark.parametrize("n,segs", [(100, 7), (4000, 700), (2048, 513)])
def test_segment_combine(combine, n, segs, rng):
    seg = rng.integers(0, segs, n).astype(np.int32)
    val = rng.random(n).astype(np.float32)
    a = ops.segment_combine(jnp.asarray(seg), jnp.asarray(val), segs,
                            combine=combine)
    b = ref.segment_combine_ref(seg, val, segs, combine=combine)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bm,bk", [(32, 32), (64, 128)])
def test_spmv_blocks(bm, bk, rng):
    from repro.graph import rmat_edges
    g = rmat_edges(7, edge_factor=6, seed=2)
    mat = ops.bcsr_from_csr(g.row_ptr, g.col_idx, g.weights,
                            (g.n_rows, g.n_cols), bm=bm, bk=bk)
    x = rng.random(g.n_cols).astype(np.float32)
    a = ops.spmv(mat, x)
    b = ref.spmv_ref_csr(g.row_ptr, g.col_idx, g.weights, x)
    np.testing.assert_allclose(np.asarray(a), b, rtol=1e-4, atol=1e-4)


def test_spmv_dense_equivalence(rng):
    """BCSR conversion is lossless: y == dense A @ x."""
    n = 96
    dense = (rng.random((n, n)) < 0.05) * rng.random((n, n))
    rp = np.concatenate([[0], np.cumsum((dense != 0).sum(1))]).astype(np.int64)
    ci = np.nonzero(dense)[1].astype(np.int32)
    w = dense[dense != 0].astype(np.float32)
    mat = ops.bcsr_from_csr(rp, ci, w, (n, n), bm=32, bk=32)
    x = rng.random(n).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ops.spmv(mat, x)),
                               dense.astype(np.float32) @ x,
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,s,d,block", [
    (2, 8, 2, 300, 64, 128), (1, 4, 4, 64, 32, 64), (3, 6, 3, 1000, 128, 256)])
def test_decode_attention(dtype, b, h, hkv, s, d, block, rng):
    q = rng.standard_normal((b, h, d)).astype(np.float32)
    k = rng.standard_normal((b, hkv, s, d)).astype(np.float32)
    v = rng.standard_normal((b, hkv, s, d)).astype(np.float32)
    lens = rng.integers(1, s + 1, b).astype(np.int32)
    out = ops.decode_attention(jnp.asarray(q, dtype), jnp.asarray(k, dtype),
                               jnp.asarray(v, dtype), jnp.asarray(lens),
                               block_s=block)
    want = ref.decode_attention_ref(q, k, v, lens)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), rtol=tol, atol=tol)


@given(st.integers(1, 300), st.integers(2, 64))
@settings(max_examples=20, deadline=None)
def test_histogram_property(n, bins):
    rng = np.random.default_rng(n * 31 + bins)
    idx = rng.integers(0, bins, n).astype(np.int32)
    a = np.asarray(ops.histogram(jnp.asarray(idx), bins))
    assert a.sum() == n                         # conservation
    np.testing.assert_allclose(a, np.bincount(idx, minlength=bins))
