"""Straggler rebalance, synthetic data, pipeline, serve scheduler."""
import numpy as np
import pytest

from repro.data.synthetic import SyntheticLM, zipf_tokens
from repro.runtime.straggler import (detect_stragglers, rebalance_chunks,
                                     rebalance_experts)


def test_detect_stragglers():
    load = np.ones(16)
    load[3] = 10.0
    mask, ratio = detect_stragglers(load)
    assert mask[3] and mask.sum() == 1
    assert ratio > 5


def test_rebalance_chunks_properties():
    rng = np.random.default_rng(0)
    load = rng.pareto(1.5, 32) + 0.1
    n = 10_000
    b = rebalance_chunks(load, n)
    assert b[0] == 0 and b[-1] == n
    assert (np.diff(b) > 0).all()                  # monotone, non-empty
    # the hottest tile gets a smaller-than-equal chunk
    hot = int(np.argmax(load))
    assert np.diff(b)[hot] <= n / 32


def test_rebalance_chunks_uniform_noop_ish():
    b = rebalance_chunks(np.ones(8), 800)
    np.testing.assert_allclose(np.diff(b), 100, atol=1)


def test_rebalance_experts_preserves_capacity():
    load = np.array([1, 1, 1, 20.0])
    cap = rebalance_experts(load, 64)
    assert cap.sum() == 64 * 4
    assert cap[3] == cap.max()


def test_synthetic_deterministic_and_learnable():
    src = SyntheticLM(vocab=64, seq_len=32, batch=4, noise=0.0)
    a, b = src.batch_at(5), src.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # order-2 determinism: same (t-1, t-2) => same t
    toks = np.concatenate([a["tokens"], a["labels"][:, -1:]], axis=1)
    seen = {}
    for row in toks:
        for t in range(2, len(row)):
            key = (row[t - 1], row[t - 2])
            if key in seen:
                assert seen[key] == row[t]
            seen[key] = row[t]


def test_zipf_skew():
    rng = np.random.default_rng(0)
    t = zipf_tokens(rng, 1000, (20000,))
    counts = np.bincount(t, minlength=1000)
    assert counts[:10].sum() > 5 * counts[500:510].sum()


def test_pipeline_prefetch_order():
    from repro.data.pipeline import DataPipeline
    src = SyntheticLM(vocab=32, seq_len=8, batch=2)
    pipe = DataPipeline(src, mesh=None, prefetch=2)
    b0 = next(pipe)
    b1 = next(pipe)
    pipe.close()
    np.testing.assert_array_equal(b0["tokens"], src.batch_at(0)["tokens"])
    np.testing.assert_array_equal(b1["tokens"], src.batch_at(1)["tokens"])


def test_serve_scheduler_completes():
    import jax
    from repro.models import registry
    from repro.serving.scheduler import Request, ServeScheduler
    cfg, fam = registry.get("deepseek-7b", smoke=True)
    params = fam["init"](cfg, jax.random.PRNGKey(0))
    sched = ServeScheduler(cfg, fam, params, batch_slots=2, max_len=32)
    rng = np.random.default_rng(0)
    for rid in range(3):
        sched.submit(Request(rid=rid,
                             prompt=rng.integers(0, cfg.vocab, 4)
                             .astype(np.int32), max_new=4))
    done = sched.run()
    assert len(done) == 3
    assert all(len(r.out) == 4 for r in done)


def test_cache_plan():
    import jax
    from repro.models import registry
    from repro.serving.kvcache import plan_cache
    cfg, fam = registry.get("deepseek-7b", smoke=True)
    plan = plan_cache(cfg, fam, batch=4, cache_len=128, n_devices=4)
    assert plan.bytes_total > 0
    assert plan.bytes_per_device == plan.bytes_total // 4
