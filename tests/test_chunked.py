"""Device-resident (scan-chunked) run loop vs the legacy per-step loop.

Acceptance properties of the chunked execution path:
  * identical ``TrafficCounters``, ``SuperstepTrace``, BSP cycles, final
    values and superstep counts vs the per-step loop for all six apps,
    monolithic and 4-chip distributed, write-back flush included;
  * the superstep budget (``max_supersteps``) truncates both loops at
    the same step;
  * trace assembly from stacked chunk arrays (``append_chunk`` /
    ``chunk_counters``) is bit-identical to per-step appends;
  * ``progress_every`` reports true executed superstep counts at chunk
    granularity;
  * the Pallas kernel backend (``EngineConfig.backend='pallas'``,
    interpret mode on CPU) matches the jnp oracle path.
"""
import numpy as np
import pytest

from repro.core.engine import chunk_counters, superstep_counters
from repro.core.netstats import SuperstepTrace
from repro.core.tilegrid import square_grid
from repro.graph import apps, oracles, rmat_edges
from repro.graph.rmat import histogram_input

GRID = square_grid(16)
CHUNK = 8


@pytest.fixture(scope="module")
def g():
    return rmat_edges(8, edge_factor=8, seed=1)


@pytest.fixture(scope="module")
def root(g):
    return int(np.argmax(g.out_degree()))


def _assert_identical(r_legacy, r_chunked, exact_values=True):
    if exact_values:
        assert np.array_equal(r_legacy.values, r_chunked.values)
    else:
        assert np.allclose(r_legacy.values, r_chunked.values,
                           rtol=1e-5, atol=1e-6)
    dl = r_legacy.run.counters.as_dict()
    dc = r_chunked.run.counters.as_dict()
    assert dl == dc, {k: (dl[k], dc[k]) for k in dl if dl[k] != dc[k]}
    assert r_legacy.run.trace.to_dict() == r_chunked.run.trace.to_dict()
    assert r_legacy.run.cycles == r_chunked.run.cycles
    assert r_legacy.run.supersteps == r_chunked.run.supersteps


def _run_pair(fn, *args, chips=0, **kw):
    if chips:
        kw["chips"] = chips
    rl = fn(*args, run_chunk=0, **kw)
    rc = fn(*args, run_chunk=CHUNK, **kw)
    return rl, rc


def _app_runs(name, g, root, chips=0):
    """One (legacy, chunked) pair per app, Table-II proxy policy."""
    if name == "bfs":      # direct routing (no proxy leg)
        return _run_pair(apps.bfs, g, root, GRID, oq_cap=16, chips=chips)
    px = apps.table2_proxy(GRID, name)
    if name == "sssp":
        return _run_pair(apps.sssp, g, root, GRID, proxy=px, oq_cap=16,
                         chips=chips)
    if name == "wcc":
        return _run_pair(apps.wcc, g, GRID, proxy=px, oq_cap=16,
                         chips=chips)
    if name == "pagerank":
        return _run_pair(apps.pagerank, g, GRID, proxy=px, epochs=2,
                         oq_cap=16, chips=chips)
    if name == "spmv":
        x = np.random.default_rng(3).random(g.n_cols).astype(np.float32)
        px = apps.table2_proxy(GRID, "spmv", cascade_levels=1)
        return _run_pair(apps.spmv, g, x, GRID, proxy=px, oq_cap=16,
                         chips=chips)
    if name == "histo":
        bins = g.n_rows // 8
        hv = histogram_input(g, bins)
        return _run_pair(apps.histogram, hv, bins, GRID, proxy=px,
                         oq_cap=8, chips=chips)
    raise ValueError(name)


ALL_APPS = ("bfs", "sssp", "wcc", "pagerank", "spmv", "histo")


@pytest.mark.parametrize("name", ALL_APPS)
def test_chunked_identical_monolithic(name, g, root):
    rl, rc = _app_runs(name, g, root)
    _assert_identical(rl, rc)


@pytest.mark.parametrize("name", ("bfs", "sssp", "histo", "spmv"))
def test_chunked_identical_4chip(name, g, root):
    rl, rc = _app_runs(name, g, root, chips=4)
    _assert_identical(rl, rc)


def test_chunked_respects_superstep_budget(g, root):
    """max_supersteps truncates the chunked loop at the same step as the
    legacy loop, even when the budget is not a chunk multiple."""
    from repro.core.engine import DataLocalEngine, EngineConfig
    cfg = EngineConfig(grid=GRID, n_src=g.n_rows, n_dst=g.n_cols, oq_cap=8)
    eng = DataLocalEngine(apps.BFS_SPEC, cfg, g.row_lo, g.row_hi,
                          g.col_idx, g.weights)
    _, rl = eng.run(eng.init_state(seed_idx=root, seed_val=0.0),
                    max_supersteps=7, chunk=0)
    _, rc = eng.run(eng.init_state(seed_idx=root, seed_val=0.0),
                    max_supersteps=7, chunk=4)
    assert rl.supersteps == rc.supersteps == 7
    assert rl.counters.as_dict() == rc.counters.as_dict()
    assert rl.trace.to_dict() == rc.trace.to_dict()


def test_chunk_of_one_equals_legacy(g, root):
    rl = apps.bfs(g, root, GRID, oq_cap=16, run_chunk=0)
    r1 = apps.bfs(g, root, GRID, oq_cap=16, run_chunk=1)
    _assert_identical(rl, r1)


# ----------------------------------------------------- chunk-array assembly
def _fake_stacked(n, rng):
    keys = ("messages", "hop_msgs", "owner_msgs", "owner_hop_msgs",
            "intra_die_hops", "inter_die_crossings", "inter_pkg_crossings",
            "filtered_at_proxy", "coalesced_at_proxy", "cascade_combined",
            "cross_region_msgs", "edges_processed", "records_consumed",
            "compute_per_tile_max", "delivered_max_per_tile", "pending",
            "p_resident")
    return {k: rng.integers(0, 1000, n).astype(np.float32) for k in keys}


def test_append_chunk_matches_per_step(rng):
    stacked = _fake_stacked(12, rng)
    t_chunk = SuperstepTrace()
    t_chunk.append_chunk(stacked, 9, element_bits=64)
    t_step = SuperstepTrace()
    for i in range(9):
        t_step.append_step({k: v[i] for k, v in stacked.items()},
                           element_bits=64)
    assert t_chunk.to_dict() == t_step.to_dict()
    assert len(t_chunk) == 9


def test_chunk_counters_match_per_step(rng):
    stacked = _fake_stacked(16, rng)
    via_chunk = chunk_counters(stacked, 11)
    from repro.core.netstats import TrafficCounters
    via_steps = TrafficCounters()
    for i in range(11):
        via_steps.add(superstep_counters(
            {k: v[i] for k, v in stacked.items()}))
    assert via_chunk.as_dict() == via_steps.as_dict()


# --------------------------------------------------------------- progress
def test_progress_reports_true_step_counts(g, root, capsys):
    apps.bfs(g, root, GRID, oq_cap=8, run_chunk=4)
    capsys.readouterr()
    from repro.core.engine import DataLocalEngine, EngineConfig
    cfg = EngineConfig(grid=GRID, n_src=g.n_rows, n_dst=g.n_cols, oq_cap=8)
    eng = DataLocalEngine(apps.BFS_SPEC, cfg, g.row_lo, g.row_hi,
                          g.col_idx, g.weights)
    _, r = eng.run(eng.init_state(seed_idx=root, seed_val=0.0),
                   progress_every=5, chunk=4)
    lines = [ln for ln in capsys.readouterr().out.splitlines()
             if "step " in ln]
    assert lines, "progress_every printed nothing"
    steps = [int(ln.split("step ")[1].split()[0]) for ln in lines]
    # true executed counts: chunk multiples, strictly increasing, within
    # the run, and every progress_every window hit at most once per chunk
    assert steps == sorted(set(steps))
    assert all(0 < s <= r.supersteps for s in steps)
    assert all(s % 4 == 0 or s == r.supersteps for s in steps)


# ------------------------------------------------------------ pallas paths
@pytest.mark.parametrize("name", ("bfs", "sssp", "histo"))
def test_pallas_backend_matches_jnp_oracle(name):
    g = rmat_edges(7, edge_factor=6, seed=1)
    root = int(np.argmax(g.out_degree()))
    if name == "bfs":
        rj = apps.bfs(g, root, GRID, oq_cap=16)
        rp = apps.bfs(g, root, GRID, oq_cap=16, backend="pallas")
        assert np.array_equal(rj.values, rp.values)
        assert np.array_equal(rj.values, oracles.bfs_oracle(g, root))
    elif name == "sssp":
        px = apps.table2_proxy(GRID, "sssp")
        rj = apps.sssp(g, root, GRID, proxy=px, oq_cap=16)
        rp = apps.sssp(g, root, GRID, proxy=px, oq_cap=16,
                       backend="pallas")
        assert np.array_equal(rj.values, rp.values)
    else:
        bins = g.n_rows // 8
        hv = histogram_input(g, bins)
        px = apps.table2_proxy(GRID, "histo")
        rj = apps.histogram(hv, bins, GRID, proxy=px, oq_cap=8)
        rp = apps.histogram(hv, bins, GRID, proxy=px, oq_cap=8,
                            backend="pallas")
        # integer counts: exact even under add re-association
        assert np.array_equal(rj.values, rp.values)
    # network accounting is shared by both backends — whole-run counters
    # and the per-superstep re-pricing trace (so a pallas-measured run
    # prices identically to the jnp oracle across the product space)
    assert (rj.run.counters.as_dict() == rp.run.counters.as_dict())
    assert rj.run.trace.to_dict() == rp.run.trace.to_dict()


def test_pallas_backend_rejected_distributed(g, root):
    with pytest.raises(ValueError, match="monolithic-only"):
        apps.bfs(g, root, GRID, oq_cap=16, chips=4, backend="pallas")


def test_unknown_backend_rejected(g):
    from repro.core.engine import DataLocalEngine, EngineConfig
    cfg = EngineConfig(grid=GRID, n_src=g.n_rows, n_dst=g.n_cols,
                       backend="tpu")
    with pytest.raises(ValueError, match="unknown engine backend"):
        DataLocalEngine(apps.BFS_SPEC, cfg, g.row_lo, g.row_hi, g.col_idx)
