"""Grid geometry: exact invariants + hypothesis properties."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
pytestmark = pytest.mark.property
from hypothesis import given, settings, strategies as st

from repro.core.tilegrid import TileGrid, square_grid


def test_basic_counts():
    g = TileGrid(64, 64)
    assert g.num_tiles == 4096
    assert g.dies == (4, 4)
    assert g.packages == (1, 1)
    g2 = TileGrid(128, 128)
    assert g2.num_packages == 4


def test_owner_equal_chunks():
    g = square_grid(16)
    n = 103
    owners = np.asarray(g.owner(np.arange(n), n))
    # equal chunks of ceil(103/16)=7
    assert owners[0] == 0 and owners[-1] == g.num_tiles - 1 or owners[-1] < g.num_tiles
    sizes = np.bincount(owners, minlength=16)
    assert sizes.max() <= 7


@given(st.integers(0, 63), st.integers(0, 63), st.integers(0, 63),
       st.integers(0, 63))
@settings(max_examples=100, deadline=None)
def test_hops_symmetric_torus(y1, x1, y2, x2):
    g = TileGrid(8, 8)
    a, b = g.tid(y1 % 8, x1 % 8), g.tid(y2 % 8, x2 % 8)
    assert int(g.hops(a, b)) == int(g.hops(b, a))
    assert int(g.hops(a, a)) == 0
    # torus diameter = ny/2 + nx/2
    assert int(g.hops(a, b)) <= 8


@given(st.integers(0, 255), st.integers(0, 255))
@settings(max_examples=100, deadline=None)
def test_mesh_hops_ge_torus(a, b):
    gt = TileGrid(16, 16, torus=True)
    gm = TileGrid(16, 16, torus=False)
    assert int(gt.hops(a, b)) <= int(gm.hops(a, b))


@given(st.integers(0, 4095), st.integers(0, 4095))
@settings(max_examples=100, deadline=None)
def test_link_levels_decompose(a, b):
    g = TileGrid(64, 64)                      # 4x4 dies, single package
    intra, die, pkg = g.link_levels(a, b)
    total = int(g.hops(a, b))
    # every hop is exactly one level; pkg crossings are 0 on one package
    assert int(pkg) == 0
    assert int(intra) + int(die) == total
    assert int(intra) >= 0 and int(die) >= 0


@given(st.integers(0, 16383), st.integers(0, 16383))
@settings(max_examples=60, deadline=None)
def test_link_levels_multi_package(a, b):
    g = TileGrid(128, 128)                    # 2x2 packages
    intra, die, pkg = g.link_levels(a, b)
    assert int(intra) + int(die) + int(pkg) == int(g.hops(a, b))


def test_square_grid_rejects_nonsquare():
    with pytest.raises(ValueError):
        square_grid(48)
