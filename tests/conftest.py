# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (tests/_subproc.py).
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    # The five hypothesis-based modules carry `pytestmark =
    # pytest.mark.property` and guard their import with
    # pytest.importorskip("hypothesis"), so environments without
    # hypothesis still collect and run the rest of the suite, and
    # `pytest -m property` selects exactly the property suites.
    config.addinivalue_line(
        "markers", "property: hypothesis property-based tests "
                   "(skipped when hypothesis is not installed)")
    # The slow marker splits nightly-style suites out of the per-PR lane:
    # scripts/tier1.sh runs `-m "not slow"` by default and everything
    # under `--full` (the CI workflow's per-PR job uses the default).
    config.addinivalue_line(
        "markers", "slow: nightly-style tests (property sweeps that run "
                   "the engine repeatedly); excluded by scripts/tier1.sh "
                   "unless invoked with --full")


@pytest.fixture(scope="session")
def small_graph():
    from repro.graph import rmat_edges
    return rmat_edges(9, edge_factor=8, seed=1)      # 512 vertices


@pytest.fixture(scope="session")
def grid8():
    from repro.core.tilegrid import square_grid
    return square_grid(64)                           # 8x8 tiles


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
