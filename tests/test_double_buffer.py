"""Double-buffered boundary exchange vs the synchronous oracle.

``EngineConfig.double_buffer`` defers each superstep's exchanged
mailbox-*value* scatter by one superstep (the mailbox bank rides the
scan carry), so the boundary exchange of superstep k overlaps superstep
k+1's compute in the BSP time model.  The deferral must be *purely* a
scheduling change:

  * values, counters and the physical per-superstep trace are
    bit-identical to the synchronous exchange on all six apps, at 4
    chips, for both the legacy per-step loop (chunk=0) and the chunked
    scan (chunk=8);
  * the priced BSP time is never worse — and strictly better whenever
    the run has charged off-chip exchanges;
  * on a monolithic engine the flag is inert: time bitwise unchanged;
  * re-pricing a double-buffered trace reproduces the measured time
    exactly (the costmodel replays the overlap-aware rule).
"""
import numpy as np
import pytest

from repro.core.costmodel import DCRA_SRAM, price
from repro.core.tilegrid import square_grid
from repro.graph import apps, rmat_edges
from repro.graph.rmat import histogram_input

GRID = square_grid(16)
APPS = ("bfs", "sssp", "wcc", "pagerank", "spmv", "histo")


@pytest.fixture(scope="module")
def g():
    return rmat_edges(8, edge_factor=8, seed=1)


def _run(name, g, **kw):
    kw.setdefault("oq_cap", 32)
    root = int(np.argmax(g.out_degree()))
    if name == "bfs":
        return apps.bfs(g, root, GRID, **kw)
    if name == "sssp":
        return apps.sssp(g, root, GRID,
                         proxy=apps.table2_proxy(GRID, "sssp"), **kw)
    if name == "wcc":
        return apps.wcc(g, GRID, proxy=apps.table2_proxy(GRID, "wcc"),
                        **kw)
    if name == "pagerank":
        return apps.pagerank(g, GRID,
                             proxy=apps.table2_proxy(GRID, "pagerank"),
                             epochs=2, **kw)
    if name == "spmv":
        x = np.random.default_rng(3).random(g.n_cols).astype(np.float32)
        return apps.spmv(g, x, GRID,
                         proxy=apps.table2_proxy(GRID, "spmv",
                                                 cascade_levels=1), **kw)
    if name == "histo":
        bins = max(g.n_rows // 8, 1)
        hv = histogram_input(g, bins)
        return apps.histogram(hv, bins, GRID,
                              proxy=apps.table2_proxy(GRID, "histo"), **kw)
    raise ValueError(name)


def _assert_same_physics(a, b, label):
    """Everything but the priced overlap must match bitwise."""
    assert np.array_equal(np.asarray(a.values), np.asarray(b.values)), label
    assert a.run.counters.as_dict() == b.run.counters.as_dict(), label
    ta, tb = a.run.trace.to_dict(), b.run.trace.to_dict()
    ta.pop("double_buffer"), tb.pop("double_buffer")
    assert ta == tb, label
    assert a.run.supersteps == b.run.supersteps, label


@pytest.mark.parametrize("name", APPS)
def test_db_bit_identity_4chip(name, g):
    sync = _run(name, g, chips=4, run_chunk=8)
    assert not sync.run.trace.double_buffer
    for chunk in (0, 8):
        db = _run(name, g, chips=4, run_chunk=chunk, double_buffer=True)
        assert db.run.trace.double_buffer
        _assert_same_physics(sync, db, f"{name}/chunk={chunk}")
        # overlap can only help: every charged step pays
        # max(core, prev exchange) instead of core + exchange
        assert db.run.time_s <= sync.run.time_s, f"{name}/chunk={chunk}"


@pytest.mark.parametrize("name", ("bfs", "pagerank"))
def test_db_flag_inert_on_monolithic(name, g):
    sync = _run(name, g)
    db = _run(name, g, double_buffer=True)
    _assert_same_physics(sync, db, name)
    # no boundary exchange exists to overlap: time bitwise unchanged
    assert db.run.time_s == sync.run.time_s, name


def test_db_overlap_actually_charged(g):
    """At 4 chips the min-propagators do cross chip boundaries, so the
    overlap must buy a strictly lower BSP time."""
    sync = _run("sssp", g, chips=4, run_chunk=8)
    db = _run("sssp", g, chips=4, run_chunk=8, double_buffer=True)
    assert sync.run.counters.off_chip_msgs > 0
    assert db.run.time_s < sync.run.time_s


@pytest.mark.parametrize("chunk", (0, 8))
def test_db_reprice_ratio_is_one(g, chunk):
    db = _run("sssp", g, chips=4, run_chunk=chunk, double_buffer=True)
    rep = price(DCRA_SRAM, GRID, db.run.counters,
                per_superstep_peak=db.run.trace)
    assert rep.time_s == pytest.approx(db.run.time_s, rel=1e-12)
