"""ExecMesh placement + real multi-device execution.

Acceptance properties:
  * ``ExecMesh.build`` picks the largest dividing device subset and
    *warns* (never raises) when the host's device count does not divide
    the chip count — the old driver's hard ``ValueError`` is gone;
  * single-device meshes degenerate every collective helper to the
    identity, so the unified step function is traceable outside
    shard_map;
  * one 4-chip run produces bit-identical counters, physical trace and
    values on 1, 2 and 4 *real* XLA host devices, with the synchronous
    and the double-buffered exchange alike (subprocesses with forced
    CPU device counts);
  * a 3-device host runs a 4-chip engine on the 2-device subset,
    bit-identical to the monolithic oracle.
"""
import numpy as np
import pytest

from _subproc import run_devices

from repro.distrib.mesh import ExecMesh, largest_dividing_devices


# --------------------------------------------------------------- placement
def test_largest_dividing_devices():
    assert largest_dividing_devices(4, 3) == 2
    assert largest_dividing_devices(4, 8) == 4
    assert largest_dividing_devices(6, 4) == 3
    assert largest_dividing_devices(5, 4) == 1
    assert largest_dividing_devices(1, 16) == 1


def test_build_fallback_warns_and_subsets():
    with pytest.warns(RuntimeWarning, match="largest dividing subset"):
        m = ExecMesh.build(4, "shard_map", device_count=3)
    assert (m.ndev, m.per, m.backend_name) == (2, 2, "shard_map")


def test_build_modes():
    m = ExecMesh.build(4, "vmap", device_count=8)
    assert (m.ndev, m.per, m.is_sharded) == (1, 4, False)
    assert m.backend_name == "vmap"
    # shard_map on a single-device host: 1 divides everything -> no warn
    m = ExecMesh.build(4, "shard_map", device_count=1)
    assert (m.ndev, m.backend_name) == (1, "vmap")
    # auto on one device stays the vmapped emulation
    assert ExecMesh.build(4, "auto", device_count=1).ndev == 1
    # dividing counts are taken as-is, silently
    assert ExecMesh.build(4, "shard_map", device_count=2).ndev == 2
    with pytest.raises(ValueError, match="unknown distributed backend"):
        ExecMesh.build(4, "bogus")


def test_mesh_rejects_non_dividing_placement():
    with pytest.raises(ValueError, match="do not divide"):
        ExecMesh(4, 3)


def test_single_device_mesh_identity_helpers():
    import jax.numpy as jnp
    m = ExecMesh(4, 1)
    assert np.array_equal(np.asarray(m.chip_ids()), [0, 1, 2, 3])
    assert int(m.axis_index()) == 0
    x = jnp.arange(3.0)
    assert m.psum(x) is x and m.pmax(x) is x and m.all_gather(x) is x
    parts = {"dst": x}
    assert m.gather_records(parts) is parts


# ------------------------------------------------- real multi-device runs
_RUN_SNIPPET = """
import json
import numpy as np
from repro.core.tilegrid import square_grid
from repro.graph import apps, rmat_edges
g = rmat_edges(8, edge_factor=8, seed=1)
grid = square_grid(16)
root = int(np.argmax(g.out_degree()))
for db in (False, True):
    r = apps.sssp(g, root, grid, oq_cap=32, chips=4, backend="shard_map",
                  double_buffer=db)
    tr = r.run.trace.to_dict()
    tr.pop("double_buffer")
    vals = np.asarray(r.values, np.float32)
    print("COUNTERS", db, json.dumps(r.run.counters.as_dict(),
                                     sort_keys=True))
    print("TRACE", db, json.dumps(tr, sort_keys=True))
    print("TIME", db, repr(r.run.time_s))
    print("VALS", db, vals.tobytes().hex())
"""


def _result_lines(out: str):
    keep = ("COUNTERS", "TRACE", "TIME", "VALS")
    return [ln for ln in out.splitlines() if ln.startswith(keep)]


def test_counters_trace_equal_across_device_counts():
    """The same 4-chip run on 1, 2 and 4 real XLA devices: counters,
    physical trace, BSP time and values all bit-identical, for the sync
    and the double-buffered exchange alike."""
    outs = {n: _result_lines(run_devices(_RUN_SNIPPET, n=n))
            for n in (1, 2, 4)}
    assert outs[1], "subprocess produced no result lines"
    assert outs[2] == outs[1]
    assert outs[4] == outs[1]


def test_engine_fallback_on_non_dividing_host():
    """4 chips on a 3-device host: the engine warns, runs on the 2-device
    subset, and still matches the monolithic oracle bitwise."""
    out = run_devices("""
import warnings
import jax
import numpy as np
from repro.core.tilegrid import square_grid
from repro.graph import apps, rmat_edges
assert jax.device_count() == 3
g = rmat_edges(8, edge_factor=8, seed=1)
grid = square_grid(16)
root = int(np.argmax(g.out_degree()))
m = apps.bfs(g, root, grid, oq_cap=32)
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    d = apps.bfs(g, root, grid, oq_cap=32, chips=4, backend="shard_map")
assert any("largest dividing subset" in str(x.message) for x in w), \\
    [str(x.message) for x in w]
assert np.array_equal(m.values, d.values)
print("OK", bool(d.run.counters.off_chip_msgs > 0))
""", n=3)
    assert "OK True" in out
