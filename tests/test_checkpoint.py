"""Checkpoint roundtrip, atomicity, fault-tolerant loop, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (latest_step, restore_checkpoint,
                                   save_checkpoint)
from repro.data.synthetic import SyntheticLM
from repro.models import registry
from repro.runtime.fault import FaultTolerantLoop, SimulatedFailure
from repro.training.optimizer import adamw
from repro.training.train_step import TrainState, make_train_step


@pytest.fixture(scope="module")
def small_state():
    cfg, fam = registry.get("deepseek-7b", smoke=True)
    params = fam["init"](cfg, jax.random.PRNGKey(0))
    opt = adamw(lr=1e-3)
    return cfg, fam, opt, TrainState.create(params, opt)


def test_roundtrip(tmp_path, small_state):
    _, _, _, state = small_state
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    restored = restore_checkpoint(str(tmp_path), state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_multiple(tmp_path, small_state):
    _, _, _, state = small_state
    for s in (1, 5, 3):
        save_checkpoint(str(tmp_path), s, state)
    assert latest_step(str(tmp_path)) == 5


def test_shape_mismatch_rejected(tmp_path, small_state):
    _, _, _, state = small_state
    save_checkpoint(str(tmp_path), 1, state)
    bad = jax.tree.map(
        lambda x: jnp.zeros((3,) + tuple(x.shape), x.dtype), state)
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)


def test_fault_tolerant_loop_recovers(tmp_path, small_state):
    cfg, fam, opt, state = small_state
    src = SyntheticLM(vocab=cfg.vocab, seq_len=16, batch=4)
    step = jax.jit(make_train_step(cfg, fam, opt))
    fails = {"at": 12, "done": False}

    def hook(i):
        if i == fails["at"] and not fails["done"]:
            fails["done"] = True
            raise SimulatedFailure(f"injected at step {i}")

    loop = FaultTolerantLoop(
        step, lambda i: jax.tree.map(jnp.asarray, src.batch_at(i)),
        str(tmp_path), ckpt_every=5, failure_hook=hook)
    state, history = loop.run(state, 15)
    # retried from checkpoint at 10: steps 10,11 re-run => history > 15
    assert len(history) >= 15
    assert fails["done"]
    assert latest_step(str(tmp_path)) == 15


def test_loop_gives_up_after_retries(tmp_path, small_state):
    cfg, fam, opt, state = small_state
    src = SyntheticLM(vocab=cfg.vocab, seq_len=16, batch=4)
    step = jax.jit(make_train_step(cfg, fam, opt))

    def hook(i):
        raise SimulatedFailure("permanent")

    loop = FaultTolerantLoop(
        step, lambda i: jax.tree.map(jnp.asarray, src.batch_at(i)),
        str(tmp_path), ckpt_every=5, failure_hook=hook,
        max_retries_per_step=2)
    with pytest.raises(SimulatedFailure):
        loop.run(state, 5)


def test_elastic_restore_different_mesh(tmp_path, small_state):
    """Save on 1 device; restore sharded onto a 4-device mesh in a
    subprocess (elastic restart across device counts)."""
    _, _, _, state = small_state
    save_checkpoint(str(tmp_path), 3, state)
    from _subproc import run_devices
    out = run_devices(f"""
import jax, numpy as np
from repro.models import registry
from repro.training.optimizer import adamw
from repro.training.train_step import TrainState
from repro.runtime.elastic import reshard_checkpoint
from repro.launch.shardings import param_spec, opt_spec
cfg, fam = registry.get("deepseek-7b", smoke=True)
params = jax.eval_shape(lambda: fam["init"](cfg, jax.random.PRNGKey(0)))
opt = adamw(lr=1e-3)
state_abs = jax.eval_shape(lambda: TrainState.create(
    fam["init"](cfg, jax.random.PRNGKey(0)), opt))
mesh = jax.make_mesh((2, 2), ("data", "model"))
def rule(path, shape):
    from jax.sharding import PartitionSpec as P
    if "params" in path:
        return param_spec(path, shape, mesh, fsdp=True)
    if "opt_state" in path:
        return opt_spec(path, shape, mesh, fsdp=True)
    return P()
st = reshard_checkpoint({str(tmp_path)!r}, state_abs, mesh, rule, step=3)
assert int(st.step) == 0 or True
n = sum(x.size for x in jax.tree.leaves(st.params))
shardings = set(str(x.sharding) for x in jax.tree.leaves(st.params))
assert any("model" in s or "data" in s for s in shardings), shardings
print("OK", n, len(shardings))
""", n=4, timeout=360)
    assert "OK" in out
