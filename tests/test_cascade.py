"""Selective cascading: the region reduction tree that drains proxy
output level-by-level (region proxy -> parent-region proxy -> owner).

Invariants under test:
  * cascading is a schedule change only — final state identical to the
    non-cascaded engine for min- and add-combine apps;
  * on a far-traffic reduction workload it strictly reduces cross-region
    traffic at >= 2 cascade levels while merging records in the tree;
  * config validation rejects non-divisible region groupings;
  * the selective criterion gates unprofitable apps out of the tree.
"""
import numpy as np
import pytest

from repro.core.engine import AppSpec, DataLocalEngine, EngineConfig
from repro.core.proxy import CascadeConfig, ProxyConfig, cascade_proxy_tile
from repro.core.tilegrid import TileGrid, square_grid
from repro.graph import apps, oracles, rmat_edges
from repro.graph.rmat import histogram_input

GRID = square_grid(64)                                  # 8x8 tiles


@pytest.fixture(scope="module")
def g():
    return rmat_edges(9, edge_factor=8, seed=1)


@pytest.fixture(scope="module")
def root(g):
    return int(np.argmax(g.out_degree()))


# ------------------------------------------------- (a) numerical equality
def test_cascade_equals_direct_min_combine(g, root):
    """SSSP (min-combine, write-through): forcing the full forward set
    through a 2-level tree (selective=False) must not change the fixed
    point, and must match the oracle."""
    px0 = apps.table2_proxy(GRID, "sssp")
    px2 = apps.table2_proxy(GRID, "sssp", cascade_levels=2,
                            selective=False)
    r0 = apps.sssp(g, root, GRID, proxy=px0, oq_cap=32)
    r2 = apps.sssp(g, root, GRID, proxy=px2, oq_cap=32)
    # min is idempotent: hierarchical combining is bitwise exact
    assert np.array_equal(r0.values, r2.values)
    assert np.allclose(r2.values, oracles.sssp_oracle(g, root))
    assert r2.run.counters.cascade_combined > 0


def test_cascade_equals_direct_add_combine(g):
    """Histogram (add-combine, write-back): cascaded flush drain equals
    the direct flush, exactly (integer counts)."""
    bins = g.n_rows // 8
    hv = histogram_input(g, bins)
    px0 = apps.table2_proxy(GRID, "histo")
    px2 = apps.table2_proxy(GRID, "histo", cascade_levels=2)
    r0 = apps.histogram(hv, bins, GRID, proxy=px0, oq_cap=32)
    r2 = apps.histogram(hv, bins, GRID, proxy=px2, oq_cap=32)
    assert np.array_equal(r0.values, r2.values)
    assert np.array_equal(r2.values, oracles.histogram_oracle(hv, bins))
    assert r2.run.counters.cascade_combined > 0


def test_cascade_equals_direct_spmv(g, rng):
    """SPMV float accumulation: reassociation by the tree stays allclose."""
    x = rng.random(g.n_cols).astype(np.float32)
    r0 = apps.spmv(g, x, GRID, proxy=apps.table2_proxy(GRID, "spmv"),
                   oq_cap=32)
    r2 = apps.spmv(g, x, GRID,
                   proxy=apps.table2_proxy(GRID, "spmv", cascade_levels=2),
                   oq_cap=32)
    assert np.allclose(r0.values, r2.values, rtol=1e-4, atol=1e-5)
    assert np.allclose(r2.values, oracles.spmv_oracle(g, x),
                       rtol=1e-3, atol=1e-3)


# --------------------------------------- (b) cross-region traffic shrinks
def test_cascade_reduces_cross_region_traffic_far_workload():
    """Far-traffic reduction drain: every tile funnels counts into 8 hot
    bins owned far away.  At 2 genuinely sub-grid cascade levels (16x16
    grid, 2x2 regions -> 4x4 -> 8x8) the tree must strictly cut
    cross-region traffic AND owner-bound messages, by merging records."""
    grid = square_grid(256)
    far = (np.arange(20000) % 8).astype(np.int32)
    px0 = apps.table2_proxy(grid, "histo", slots=64, region_div=8)
    px2 = apps.table2_proxy(grid, "histo", slots=64, region_div=8,
                            cascade_levels=2)
    r0 = apps.histogram(far, 64, grid, proxy=px0, oq_cap=16)
    r2 = apps.histogram(far, 64, grid, proxy=px2, oq_cap=16)
    assert np.array_equal(r0.values, r2.values)
    c0, c2 = r0.run.counters, r2.run.counters
    assert c2.cascade_combined > 0
    assert c2.cross_region_msgs < c0.cross_region_msgs
    assert c2.owner_msgs < c0.owner_msgs


def test_cascade_reduces_inter_die_crossings_at_scale(g, rng):
    """On a 32x32 grid (2x2 dies of 16x16) the write-back flush drain
    crosses chips; the reduction tree (4x4 regions -> 8x8 -> 16x16, both
    levels genuinely sub-grid) must cut inter-die crossings."""
    grid = square_grid(1024)
    x = rng.random(g.n_cols).astype(np.float32)
    r0 = apps.spmv(g, x, grid,
                   proxy=apps.table2_proxy(grid, "spmv", region_div=8),
                   oq_cap=32)
    r2 = apps.spmv(g, x, grid,
                   proxy=apps.table2_proxy(grid, "spmv", region_div=8,
                                           cascade_levels=2),
                   oq_cap=32)
    assert np.allclose(r0.values, r2.values, rtol=1e-4, atol=1e-5)
    c0, c2 = r0.run.counters, r2.run.counters
    assert c2.inter_die_crossings < c0.inter_die_crossings
    assert c2.cross_region_msgs < c0.cross_region_msgs


# ------------------------------------------------- (c) config validation
def test_cascade_config_validation_params():
    with pytest.raises(ValueError):
        CascadeConfig(levels=0)
    with pytest.raises(ValueError):
        CascadeConfig(group_ny=0)
    with pytest.raises(ValueError):
        CascadeConfig(group_ny=1, group_nx=1)    # merges nothing


def test_cascade_validation_non_divisible_grouping():
    grid = square_grid(64)                       # 8x8
    # level-1 regions would be 6x6 on an 8x8 grid: non-divisible
    bad = ProxyConfig(3, 3, cascade=CascadeConfig(levels=1))
    with pytest.raises(ValueError, match="divide"):
        bad.validate(grid)
    # base regions fine, level-2 regions exceed the grid non-divisibly
    bad2 = ProxyConfig(2, 2, cascade=CascadeConfig(levels=2, group_ny=3,
                                                   group_nx=3))
    with pytest.raises(ValueError, match="non-divisible"):
        bad2.validate(grid)
    # engine construction performs the same check
    spec = AppSpec("histo", combine="add", edge_value="one",
                   reactivate=False)
    cfg = EngineConfig(grid=grid, n_src=64, n_dst=64, proxy=bad)
    with pytest.raises(ValueError, match="divide"):
        DataLocalEngine(spec, cfg, np.zeros(64, np.int32),
                        np.zeros(64, np.int32), np.zeros(1, np.int32))
    # a divisible grouping passes
    ProxyConfig(2, 2, cascade=CascadeConfig(levels=2)).validate(grid)


# ------------------------------------------------- selective criterion
def test_selective_criterion_gates_unprofitable_apps(g, root):
    """BFS is marked cascade-unprofitable: under selective=True the tree
    is bypassed entirely — traffic identical to the non-cascaded run."""
    px0 = apps.table2_proxy(GRID, "bfs")
    px2 = apps.table2_proxy(GRID, "bfs", cascade_levels=2)  # selective
    r0 = apps.bfs(g, root, GRID, proxy=px0, oq_cap=32)
    r2 = apps.bfs(g, root, GRID, proxy=px2, oq_cap=32)
    assert np.array_equal(r0.values, r2.values)
    c0, c2 = r0.run.counters, r2.run.counters
    assert c2.cascade_combined == 0
    assert c2.hop_msgs == c0.hop_msgs
    assert c2.messages == c0.messages


# ------------------------------------------------- tree geometry helpers
def test_cascade_proxy_tile_stays_in_senders_super_region():
    grid = TileGrid(16, 16)
    rng = np.random.default_rng(0)
    for rny, rnx in ((4, 4), (8, 8)):
        src = rng.integers(0, 256, 200)
        owner = rng.integers(0, 256, 200)
        p = np.asarray(cascade_proxy_tile(grid, rny, rnx, owner, src))
        assert np.array_equal(
            np.asarray(grid.region_id(p, rny, rnx)),
            np.asarray(grid.region_id(src, rny, rnx)))


def test_region_crossings_zero_within_region():
    grid = TileGrid(8, 8)
    # both endpoints inside the same 4x4 region: no crossings
    assert int(grid.region_crossings(grid.tid(0, 0), grid.tid(3, 3),
                                     4, 4)) == 0
    # one region boundary per axis
    assert int(grid.region_crossings(grid.tid(3, 3), grid.tid(4, 4),
                                     4, 4)) == 2
