"""repro.analysis: each pass must *detect its hazard class*, not just run.

Every pass gets a mutation test — introduce the hazard (a host callback
in a traced step, an aliased overwrite window, a dropped delivery, a
corrupted counter/trace, a dead module) and require the finding; remove
it and require silence.  Plus the regression tests for the real findings
the passes surfaced on this tree (``p_resident`` riding the f32 stat row
uncovered — rule ``int-stat-f32-row``), and the ``EngineConfig.sanitize``
contract: bit-identical results, and a raised ``SanitizerError`` on a
corrupted engine state.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import deadcode, invariants, jaxprlint, pallas_races
from repro.analysis.findings import Finding, Report, load_baseline
from repro.core import engine as eng_mod
from repro.core.costmodel import DCRA_SRAM
from repro.core.netstats import MSG_BITS, SuperstepTrace, TrafficCounters
from repro.core.tilegrid import square_grid
from repro.graph import apps, rmat_edges

GRID = square_grid(16)


@pytest.fixture(scope="module")
def g():
    return rmat_edges(6, edge_factor=4, seed=3)


@pytest.fixture(scope="module")
def root(g):
    return int(np.argmax(g.out_degree()))


@pytest.fixture(scope="module")
def bfs_res(g, root):
    return apps.bfs(g, root, GRID, oq_cap=16)


def _rules(findings):
    return [f.rule for f in findings]


# ------------------------------------------------------------- jaxprlint
class TestJaxprLint:
    def test_host_sync_mutation(self):
        def clean(x):
            return jnp.sum(x * 2)

        def dirty(x):
            jax.debug.print("x={x}", x=x)
            return jnp.sum(x)

        x = jnp.ones((4,))
        assert jaxprlint.lint_step_fn(clean, (x,), "t") == []
        fs = jaxprlint.lint_step_fn(dirty, (x,), "t")
        assert "host-sync" in _rules(fs)

    def test_host_sync_inside_scan_body(self):
        # the walker must recurse into scan bodies — that is where the
        # chunked run loop would hide a per-iteration host round trip
        def dirty(x):
            def body(c, _):
                jax.debug.print("c={c}", c=c)
                return c + 1, c
            return jax.lax.scan(body, x, None, length=3)

        fs = jaxprlint.lint_step_fn(dirty, (jnp.float32(0),), "t")
        assert "host-sync" in _rules(fs)

    def test_scatter_mode_mutation(self):
        idx = jnp.array([0, 1, 1], jnp.int32)
        v = jnp.ones((3,))

        def drop(x):
            return x.at[idx].set(v, mode="drop")

        def clip(x):
            return x.at[idx].set(v, mode="clip")

        def clip_add(x):          # commutative: safe under duplicates
            return x.at[idx].add(v, mode="clip")

        x = jnp.zeros((4,))
        assert jaxprlint.lint_step_fn(drop, (x,), "t") == []
        assert jaxprlint.lint_step_fn(clip_add, (x,), "t") == []
        fs = jaxprlint.lint_step_fn(clip, (x,), "t")
        assert "scatter-mode" in _rules(fs)

    def test_engine_steps_are_clean(self, g, root):
        eng, state, _ = apps.engine_and_state("bfs", g, GRID, root=root,
                                              oq_cap=16)
        fs = jaxprlint.lint_step_fn(eng._chunk_step_one,
                                    (state, jnp.zeros((), jnp.bool_)), "t")
        assert fs == []

    def test_int_stat_regression_p_resident(self, g, root):
        # the real finding this pass surfaced: 'p_resident' (int32,
        # bounded by T*slots — past 2**24 at million-PU scale) rode the
        # packed f32 stat row uncovered.  It is covered now; removing it
        # from the side channel must re-fire the rule.
        assert "p_resident" in eng_mod._EXACT_INT_STATS
        # the scan body's drained test reads int row 0: order is load-bearing
        assert eng_mod._EXACT_INT_STATS[0] == "pending"
        eng, state, _ = apps.engine_and_state("bfs", g, GRID, root=root,
                                              oq_cap=16)
        shapes = jaxprlint.stats_shapes_of(eng._chunk_step_one, state,
                                           jnp.zeros((), jnp.bool_))
        assert jaxprlint.lint_int_stats(shapes, eng_mod._EXACT_INT_STATS,
                                        "t") == []
        uncovered = [k for k in eng_mod._EXACT_INT_STATS
                     if k != "p_resident"]
        fs = jaxprlint.lint_int_stats(shapes, uncovered, "t")
        assert any(f.rule == "int-stat-f32-row"
                   and f.where.endswith("p_resident") for f in fs)

    def test_backend_drift_mutation(self):
        a = {"x": jax.ShapeDtypeStruct((4,), jnp.float32)}
        assert jaxprlint.lint_backend_drift(a, dict(a), "t") == []
        b = {"x": jax.ShapeDtypeStruct((4,), jnp.int32)}
        fs = jaxprlint.lint_backend_drift(a, b, "t")
        assert _rules(fs) == ["backend-dtype-drift"]
        fs = jaxprlint.lint_backend_drift(a, {}, "t")
        assert _rules(fs) == ["backend-dtype-drift"]


# ---------------------------------------------------------- pallas_races
class _Spec:
    def __init__(self, block_shape, index_map):
        self.block_shape = block_shape
        self.index_map = index_map


def _call(index_map, grid=(4,), block=(8,)):
    return pallas_races.CapturedCall(
        kernel_name="k", grid=grid, out_specs=[_Spec(block, index_map)],
        out_shapes=[(8,)])


class TestPallasRaces:
    def test_aliased_overwrite_mutation(self):
        aliased = _call(lambda i: 0)          # every program, one window
        fs = pallas_races.check_call(aliased, "overwrite", "t")
        assert "aliased-overwrite" in _rules(fs)
        # same geometry under a commutative combine: the standard
        # revisit-accumulate reduction pattern — clean
        assert pallas_races.check_call(aliased, "add", "t") == []
        # disjoint windows: clean under any combine
        disjoint = _call(lambda i: i)
        assert pallas_races.check_call(disjoint, "overwrite", "t") == []

    def test_no_pallas_call_is_vacuous(self):
        fs = pallas_races.check_fn(lambda: None, "add", "t")
        assert _rules(fs) == ["no-pallas-call"]

    def test_kernel_suite_only_documented_exception(self):
        # the repo's kernels must prove disjoint (or commutative-aliased)
        # — except decode_attention's online-softmax carry, whose output
        # window is deliberately revisited across KV blocks and is safe
        # only because the Pallas grid executes sequentially.  That one
        # lives in the committed baseline.
        keys = {f.key for f in pallas_races.check_kernels()}
        assert keys == {"pallas_races:aliased-overwrite:"
                        "kernels/decode_attention:_kernel[out0]"}


# ------------------------------------------------------------ invariants
def _counters(**over):
    base = dict(messages=10.0, hop_msgs=12.0, owner_msgs=8.0,
                owner_hop_msgs=10.0, intra_die_hops=6.0,
                inter_die_crossings=4.0, inter_pkg_crossings=2.0,
                filtered_at_proxy=1.0, coalesced_at_proxy=1.0,
                cascade_combined=0.0, edges_processed=10.0,
                records_consumed=8.0, supersteps=3)
    base.update(over)
    return TrafficCounters(**base)


class TestInvariants:
    def test_clean_counters(self):
        assert invariants.check_counters(_counters(), where="t") == []

    def test_dropped_delivery_breaks_conservation(self):
        fs = invariants.check_counters(_counters(owner_msgs=7.0), where="t")
        assert "owner-conservation" in _rules(fs)
        # write-back P$ absorbs without a counter: <= is allowed there...
        fs = invariants.check_counters(
            _counters(owner_msgs=7.0, records_consumed=7.0), where="t",
            write_back=True)
        assert fs == []
        # ...but over-delivery is a bug in either mode
        fs = invariants.check_counters(_counters(owner_msgs=11.0,
                                                 owner_hop_msgs=13.0),
                                       where="t", write_back=True)
        assert "owner-conservation" in _rules(fs)

    def test_corrupted_counter(self):
        fs = invariants.check_counters(_counters(messages=-1.0), where="t")
        assert "counter-negative" in _rules(fs)
        fs = invariants.check_counters(_counters(edges_processed=10.5),
                                       where="t")
        assert "counter-nonint" in _rules(fs)
        fs = invariants.check_counters(_counters(intra_die_hops=7.0),
                                       where="t")
        assert "hop-decomposition" in _rules(fs)
        fs = invariants.check_counters(_counters(records_consumed=9.0),
                                       where="t")
        assert "consumed-bound" in _rules(fs)
        assert invariants.check_counters(_counters(records_consumed=9.0),
                                         where="t", seeds=1) == []

    def _trace(self):
        tr = SuperstepTrace()
        for pend in (3.0, 0.0):
            tr.append_step(dict(compute_per_tile_max=2.0, intra_die_hops=3,
                                inter_die_crossings=1,
                                inter_pkg_crossings=0,
                                delivered_max_per_tile=2,
                                edges_processed=4, records_consumed=2,
                                pending=pend))
        return tr

    def test_trace_mutations(self):
        assert invariants.check_trace(self._trace(), where="t") == []
        tr = self._trace()
        tr.pending[-1] = 5.0
        assert "trace-not-drained" in _rules(
            invariants.check_trace(tr, where="t"))
        # an undrained final step is fine when the budget was declared
        assert invariants.check_trace(tr, where="t", drained=False) == []
        tr = self._trace()
        tr.intra_bits[0] += 1.0
        assert "trace-bit-quantum" in _rules(
            invariants.check_trace(tr, where="t"))
        tr = self._trace()
        tr.die_bits[0] = -float(MSG_BITS)
        assert "trace-negative" in _rules(
            invariants.check_trace(tr, where="t"))
        tr = self._trace()
        tr.pending.append(0.0)
        assert "trace-length" in _rules(
            invariants.check_trace(tr, where="t"))

    def test_monotone_frontier_mutation(self):
        assert invariants.check_values([2.0, 3.0], [1.0, 3.0], "min",
                                       where="t") == []
        fs = invariants.check_values([2.0, 3.0], [2.0, 4.0], "min",
                                     where="t")
        assert _rules(fs) == ["monotone-frontier"]
        # add-combine apps accumulate: growth is not a violation
        assert invariants.check_values([2.0], [4.0], "add", where="t") == []

    def test_reprice_mutation(self, bfs_res):
        run = bfs_res.run
        assert invariants.check_reprice(run, DCRA_SRAM, GRID,
                                        where="t") == []
        bad = copy.deepcopy(run)
        bad.trace.compute_ops[0] += 1e6
        fs = invariants.check_reprice(bad, DCRA_SRAM, GRID, where="t")
        assert _rules(fs) == ["reprice-ratio"]

    def test_check_run_composes_clean(self, bfs_res):
        fs = invariants.check_run(bfs_res.run, pkg=DCRA_SRAM, grid=GRID,
                                  where="t", seeds=1)
        assert fs == []

    def test_assert_clean_raises(self):
        invariants.assert_clean([])
        with pytest.raises(invariants.SanitizerError):
            invariants.assert_clean(
                [Finding("invariants", "counter-negative", "t", "boom")])


# -------------------------------------------------------------- sanitize
class TestSanitize:
    def test_bit_identical_fast(self, g, root):
        r0 = apps.bfs(g, root, GRID, oq_cap=16)
        r1 = apps.bfs(g, root, GRID, oq_cap=16, sanitize=True)
        assert np.array_equal(r0.values, r1.values)
        assert r0.run.cycles == r1.run.cycles
        assert r0.run.counters.as_dict() == r1.run.counters.as_dict()

    @pytest.mark.slow
    def test_bit_identical_all_apps(self, g, root):
        # the acceptance contract: sanitize=True runs every app
        # bit-identically to sanitize=False (checks observe, never branch)
        bins = max(g.n_rows // 8, 1)
        from repro.graph.rmat import histogram_input
        hv = histogram_input(g, bins)
        x = np.random.default_rng(5).random(g.n_cols).astype(np.float32)

        def runs(**kw):
            pr = apps.table2_proxy(GRID, "pagerank")
            sp = apps.table2_proxy(GRID, "spmv", cascade_levels=1)
            hp = apps.table2_proxy(GRID, "histo")
            wp = apps.table2_proxy(GRID, "wcc")
            return [
                apps.bfs(g, root, GRID, oq_cap=16, **kw),
                apps.sssp(g, root, GRID,
                          proxy=apps.table2_proxy(GRID, "sssp"),
                          oq_cap=16, **kw),
                apps.wcc(g, GRID, proxy=wp, oq_cap=16, **kw),
                apps.pagerank(g, GRID, proxy=pr, epochs=2, oq_cap=16, **kw),
                apps.spmv(g, x, GRID, proxy=sp, oq_cap=16, **kw),
                apps.histogram(hv, bins, GRID, proxy=hp, oq_cap=8, **kw),
            ]

        for r0, r1 in zip(runs(), runs(sanitize=True)):
            assert np.array_equal(r0.values, r1.values)
            assert r0.run.cycles == r1.run.cycles
            assert r0.run.counters.as_dict() == r1.run.counters.as_dict()

    @pytest.mark.parametrize("chunk", [0, 8])
    def test_corrupted_state_raises(self, g, root, chunk):
        # a NaN planted in the value array is unrepairable (min-combine
        # comparisons against NaN are False, so it survives every step):
        # the on-device check must count it and the run loop must raise —
        # through both the legacy and the chunked accounting paths
        eng, state, _ = apps.engine_and_state("bfs", g, GRID, root=root,
                                              oq_cap=16, sanitize=True)
        victim = (root + 1) % g.n_rows
        state["values"] = state["values"].at[victim].set(jnp.nan)
        with pytest.raises(invariants.SanitizerError):
            eng.run(state, chunk=chunk)

    def test_distributed_sanitize_runs(self, g, root):
        r0 = apps.bfs(g, root, GRID, oq_cap=16, chips=4)
        r1 = apps.bfs(g, root, GRID, oq_cap=16, chips=4, sanitize=True)
        assert np.array_equal(r0.values, r1.values)
        assert r0.run.cycles == r1.run.cycles


# -------------------------------------------------------------- deadcode
class TestDeadcode:
    def test_dead_and_quarantined(self, tmp_path):
        src = tmp_path / "src" / "pkg"
        src.mkdir(parents=True)
        (src / "__init__.py").write_text("")
        (src / "used.py").write_text("X = 1\n")
        (src / "dead.py").write_text("Y = 2\n")
        (src / "quar.py").write_text(
            f"{deadcode.MARKER} — kept for reference\nZ = 3\n")
        t = tmp_path / "tests"
        t.mkdir()
        (t / "test_x.py").write_text("from pkg import used\n")
        fs, meta = deadcode.check_repo(tmp_path)
        assert meta["dead"] == ["pkg.dead"]
        assert meta["quarantined"] == ["pkg.quar"]
        assert _rules(fs) == ["dead-module"]

    def test_repo_has_no_unmarked_dead_modules(self):
        import pathlib
        repo = pathlib.Path(__file__).resolve().parent.parent
        fs, meta = deadcode.check_repo(repo)
        assert fs == [], meta["dead"]


# ------------------------------------------------------ findings/baseline
class TestFindings:
    def test_report_round_trip(self):
        rep = Report(passes=["jaxprlint"], matrix=["bfs/jnp/mono"])
        rep.extend([Finding("jaxprlint", "host-sync", "bfs/jnp/mono",
                            "msg")])
        back = Report.from_json(rep.to_json())
        assert back.keys() == rep.keys()
        assert back.matrix == rep.matrix

    def test_baseline_gate(self, tmp_path):
        f1 = Finding("p", "r", "w1", "m")
        f2 = Finding("p", "r", "w2", "different message, same site kind")
        rep = Report(findings=[f1, f2])
        path = tmp_path / "base.json"
        path.write_text(Report(findings=[f1]).baseline_json())
        base = load_baseline(path)
        assert [f.key for f in rep.new_vs_baseline(base)] == [f2.key]
        # message changes do not churn the key
        f1b = Finding("p", "r", "w1", "reworded")
        assert Report(findings=[f1b]).new_vs_baseline(base) == []
        assert load_baseline(tmp_path / "missing.json") == []


# ----------------------------------------------------------------- runner
@pytest.mark.slow
def test_runner_static_cell_clean():
    import pathlib
    from repro.analysis import runner
    repo = pathlib.Path(__file__).resolve().parent.parent
    rep = runner.run_all(repo, app_names=["bfs"], passes=["jaxprlint"])
    assert rep.findings == []
    assert "bfs/jnp/mono" in rep.matrix
