#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md): the one command every PR must keep green.
#   scripts/tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
