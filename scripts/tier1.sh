#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md): the one command every PR must keep green.
#   scripts/tier1.sh [extra pytest args]   # per-PR lane: -m "not slow"
#   scripts/tier1.sh --full [args]         # nightly lane: whole suite,
#                                          # including slow property sweeps
# (--full must be the first argument; pytest keeps only the last -m, so
# passing your own -m in the per-PR lane replaces the "not slow" filter)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--full" ]]; then
  shift
  exec python -m pytest -x -q "$@"
fi
exec python -m pytest -x -q -m "not slow" "$@"
