#!/usr/bin/env python
"""CI lint gate: run the ``repro.analysis`` passes over the app matrix.

Runs the static passes (jaxpr lint, Pallas write-race proof, dead-code
report) and the executed invariant checks (counter conservation, trace
sanity, reprice contract) over six apps x {jnp, pallas} x {monolithic,
4-chip} and compares the findings against the committed baseline
(``analysis_baseline.json`` at the repo root).  A finding whose key is
not baselined fails the run — the baseline exists for *documented*
exceptions (e.g. decode_attention's order-dependent softmax carry, safe
only because the Pallas grid executes sequentially), not as a dumping
ground; update it deliberately with ``--update-baseline``.

  scripts/lint_engine.py                 # full matrix, human output
  scripts/lint_engine.py --ci            # + write JSON report, exit 1 on
                                         #   non-baselined findings
  scripts/lint_engine.py --apps bfs,sssp --passes jaxprlint
  scripts/lint_engine.py --update-baseline   # rewrite the baseline from
                                             # this run's findings
"""
import argparse
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis import load_baseline  # noqa: E402
from repro.analysis.findings import summarize  # noqa: E402
from repro.analysis.runner import APP_NAMES, run_all  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--apps", default=None,
                    help=f"comma-separated subset of {','.join(APP_NAMES)}")
    ap.add_argument("--passes", default=None,
                    help="comma-separated subset of "
                         "jaxprlint,invariants,pallas_races,deadcode")
    ap.add_argument("--baseline", default=str(REPO / "analysis_baseline.json"),
                    help="committed baseline of accepted finding keys")
    ap.add_argument("--out", default=None,
                    help="write the full JSON report here")
    ap.add_argument("--ci", action="store_true",
                    help="CI mode: write --out (default lint_report.json), "
                         "exit 1 on non-baselined findings")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline from this run's finding keys")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-cell progress lines")
    args = ap.parse_args(argv)

    apps = args.apps.split(",") if args.apps else None
    passes = args.passes.split(",") if args.passes else None
    say = (lambda _m: None) if args.quiet else \
        (lambda m: print(f"  [lint] {m}", flush=True))

    report = run_all(REPO, app_names=apps, passes=passes, progress=say)
    baseline = load_baseline(args.baseline)

    out = args.out or ("lint_report.json" if args.ci else None)
    if out:
        pathlib.Path(out).write_text(report.to_json())
        print(f"report: {out} ({len(report.findings)} finding(s), "
              f"{len(report.matrix)} matrix cell(s))")

    if args.update_baseline:
        pathlib.Path(args.baseline).write_text(report.baseline_json())
        print(f"baseline updated: {args.baseline} "
              f"({len(set(report.keys()))} key(s))")
        return 0

    print(summarize(report.findings, baseline))
    new = report.new_vs_baseline(baseline)
    if new:
        print(f"\nFAIL: {len(new)} non-baselined finding(s) "
              f"(baseline: {args.baseline})")
        return 1
    print(f"\nOK: {len(report.findings)} finding(s), all baselined; "
          f"{len(report.matrix)} matrix cell(s) analyzed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
