#!/usr/bin/env python
"""Bench-regression gate: fresh BENCH_engine.json vs the committed one.

Re-runs the engine-throughput benchmark (or takes a pre-generated file
via ``--fresh``) and compares it row-by-row against the committed
baseline with per-field tolerances:

  * **exact**: ``supersteps``, ``host_syncs_legacy``,
    ``host_syncs_chunked`` — these are deterministic properties of the
    run loop (same graph seed, same configs); any drift is a real
    behaviour change.
  * **bit-identity flags**: ``counters_equal`` / ``trace_equal`` must be
    true in the fresh run — the chunked loop's core guarantee.
  * **sim_time_s** (and, on devices-axis rows, ``sim_time_s_db``):
    relative tolerance 1e-6 — the BSP time is integer count arithmetic
    in f64, reproducible to rounding.  The double-buffering sim win is
    therefore gated implicitly: both operands are exact.
  * **speedup**: fresh must stay above ``min_frac`` (default 0.25) of
    the committed speedup — wall-clock is noisy in CI, so this only
    catches collapses, not jitter.  Devices-axis rows (``devices`` in
    the key) run real multi-process XLA host devices, which is noisier
    still: their ``speedup`` is the sync/db wall ratio and gets a
    per-device-count fraction (x0.6 at 2 devices, x0.4 at 4+).

  * **speedup_compaction** (sparse-regime rows): the dense-chunked /
    compacted wall ratio of the active-set compaction path — gated
    collapse-only with the same ``min_frac`` policy as ``speedup``
    (wall-clock noise must not fail CI; a collapse means the compacted
    fast path stopped being fast).  Its bit-identity flag
    (``compaction_equal``) and measured sync count
    (``host_syncs_compacted``) are gated exactly.

Rows are matched on (app, tiles, scale, oq_cap, proxy, chunk, chips,
devices, compaction, ckpt_every) — trailing fields are absent from rows
that predate their axes; a baseline row missing from the fresh run is a
regression.  Exits nonzero
on any regression and writes a markdown report for the CI artifact.

BENCH_recovery.json (the fault-tolerance benchmark) is gated with the
same machinery when the committed baseline exists:

  * ``recovery_equal`` (bit-identical recovered run) must stay true and
    ``reprice_ratio`` must stay **exactly** equal (1.0 in the committed
    baseline: the trace replay re-derives the faulted run's time to the
    bit) — plus exact ``supersteps`` / ``n_checkpoints`` /
    ``n_rollbacks`` and 1e-6-relative ``overhead_cycles``;
  * ``recovery_wall_s`` (host wall clock of the loss: mesh rebuild +
    recompile + replay) is gated ratio-only — fresh must stay under
    ``--max-wall-ratio`` (default 4x) of the committed value.

Usage:
  python scripts/bench_check.py                  # re-run + compare
  python scripts/bench_check.py --fresh f.json   # compare existing file
  python scripts/bench_check.py --report out.md  # also write report
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO, "BENCH_engine.json")
RECOVERY_BASELINE = os.path.join(REPO, "BENCH_recovery.json")

EXACT_FIELDS = ("supersteps", "host_syncs_legacy", "host_syncs_chunked",
                "host_syncs_compacted", "mesh_devices", "reprice_ratio",
                "n_checkpoints", "n_rollbacks")
TRUE_FLAGS = ("counters_equal", "trace_equal", "values_equal",
              "compaction_equal", "recovery_equal")
SIM_FIELDS = ("sim_time_s", "sim_time_s_db", "overhead_cycles")
# wall-clock fields gated ratio-only (fresh <= base * max_wall_ratio)
WALL_RATIO_FIELDS = ("recovery_wall_s",)
KEY_FIELDS = ("app", "tiles", "scale", "oq_cap", "proxy", "chunk",
              "chips", "devices", "compaction", "ckpt_every")
# wall-clock speedup collapse fraction, scaled per forced device count
# (multi-device CPU runs are the noisiest rows)
_DEVICE_FRAC = {2: 0.6, 4: 0.4}


def _min_frac_for(row: dict, base: float) -> float:
    dev = row.get("devices")
    if dev is None:
        return base
    return base * _DEVICE_FRAC.get(int(dev), 0.4 if int(dev) > 1 else 1.0)


def _key(row: dict) -> tuple:
    return tuple(row.get(k) for k in KEY_FIELDS)


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _generate(out_path: str) -> None:
    sys.path.insert(0, os.path.join(REPO, "benchmarks"))
    sys.path.insert(0, os.path.join(REPO, "src"))
    import engine_throughput
    engine_throughput.run(small=True, out_path=out_path)


def _generate_recovery(out_path: str) -> None:
    sys.path.insert(0, os.path.join(REPO, "benchmarks"))
    sys.path.insert(0, os.path.join(REPO, "src"))
    import recovery
    recovery.smoke(out_path)


def compare(baseline: dict, fresh: dict, *, min_frac: float = 0.25,
            sim_rel_tol: float = 1e-6, max_wall_ratio: float = 4.0,
            allow_missing: bool = False):
    """Returns (regressions, notes): lists of human-readable strings.

    ``allow_missing=True`` downgrades baseline rows absent from the
    fresh run to notes — used for the recovery gate, where CI
    regenerates only the smoke subset of the committed rows — but at
    least one baseline row must still match, else the gate is vacuous
    and that itself is a regression.
    """
    regressions, notes = [], []
    matched = 0
    fresh_rows = {_key(r): r for r in fresh.get("rows", [])}
    for brow in baseline.get("rows", []):
        k = _key(brow)
        label = "/".join(str(v) for v in k)
        frow = fresh_rows.pop(k, None)
        if frow is None:
            if allow_missing:
                notes.append(f"{label}: not re-run (baseline-only row)")
            else:
                regressions.append(f"{label}: row missing from fresh run")
            continue
        matched += 1
        for f in EXACT_FIELDS:
            if f in brow and frow.get(f) != brow.get(f):
                regressions.append(
                    f"{label}: {f} changed {brow.get(f)} -> {frow.get(f)}")
        for f in TRUE_FLAGS:
            if f in brow and not frow.get(f, False):
                regressions.append(f"{label}: {f} is no longer true")
        for f in SIM_FIELDS:
            if f not in brow:
                continue
            b_sim, f_sim = brow.get(f, 0.0), frow.get(f, 0.0)
            if abs(f_sim - b_sim) > sim_rel_tol * max(abs(b_sim), 1e-300):
                regressions.append(
                    f"{label}: {f} drifted {b_sim:g} -> {f_sim:g} "
                    f"(rel tol {sim_rel_tol:g})")
        for f in WALL_RATIO_FIELDS:
            if f not in brow:
                continue
            b_w, f_w = float(brow.get(f, 0.0)), float(frow.get(f, 0.0))
            # 1s floor so near-zero baselines don't make the gate flaky
            if f_w > max(b_w, 1.0) * max_wall_ratio:
                regressions.append(
                    f"{label}: {f} blew up {b_w:.2f}s -> {f_w:.2f}s "
                    f"(> {max_wall_ratio:g}x baseline)")
            elif f_w > b_w:
                notes.append(f"{label}: {f} {b_w:.2f}s -> {f_w:.2f}s "
                             f"(within {max_wall_ratio:g}x wall ratio)")
        frac = _min_frac_for(brow, min_frac)
        for sp in ("speedup", "speedup_compaction"):
            if sp not in brow:
                continue
            b_sp, f_sp = brow.get(sp, 0.0), frow.get(sp, 0.0)
            if f_sp < b_sp * frac:
                regressions.append(
                    f"{label}: {sp} collapsed {b_sp:.2f}x -> {f_sp:.2f}x "
                    f"(< {frac:.2f} of baseline)")
            elif f_sp < b_sp:
                notes.append(f"{label}: {sp} {b_sp:.2f}x -> {f_sp:.2f}x "
                             f"(within wall-clock tolerance)")
    for k in fresh_rows:
        notes.append("/".join(str(v) for v in k)
                     + ": new row not in baseline")
    if allow_missing and matched == 0:
        regressions.append(
            "no baseline rows matched the fresh run (vacuous gate — "
            "row keys drifted?)")
    return regressions, notes


def to_markdown(regressions, notes, baseline_path, fresh_path) -> str:
    lines = ["# Bench regression check", "",
             f"- baseline: `{baseline_path}`",
             f"- fresh: `{fresh_path}`",
             f"- regressions: **{len(regressions)}**, "
             f"notes: {len(notes)}", ""]
    if regressions:
        lines += ["## Regressions", ""] + [f"- {r}" for r in regressions] \
            + [""]
    if notes:
        lines += ["## Notes", ""] + [f"- {n}" for n in notes] + [""]
    if not regressions:
        lines.append("All rows within tolerance.")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--fresh", default=None,
                    help="pre-generated fresh BENCH_engine.json "
                         "(default: re-run the benchmark)")
    ap.add_argument("--recovery-baseline", default=RECOVERY_BASELINE)
    ap.add_argument("--fresh-recovery", default=None,
                    help="pre-generated fresh BENCH_recovery.json "
                         "(default: re-run the recovery smoke)")
    ap.add_argument("--recovery-only", action="store_true",
                    help="gate only BENCH_recovery.json (skip the "
                         "engine-throughput re-run)")
    ap.add_argument("--min-speedup-frac", type=float, default=0.25)
    ap.add_argument("--sim-rel-tol", type=float, default=1e-6)
    ap.add_argument("--max-wall-ratio", type=float, default=4.0,
                    help="recovery_wall_s collapse factor (wall clock; "
                         "ratio-only gate)")
    ap.add_argument("--report", default=None,
                    help="write a markdown report here")
    ap.add_argument("--ci", action="store_true",
                    help="CI alias: re-run + compare, exit nonzero on any "
                         "regression (the default behavior, named so the "
                         "workflow invocation is self-describing)")
    args = ap.parse_args(argv)

    regressions, notes = [], []
    sections = []
    if not args.recovery_only:
        fresh_path = args.fresh
        if fresh_path is None:
            fresh_path = os.path.join(
                tempfile.mkdtemp(prefix="bench_check_"),
                "BENCH_engine.json")
            _generate(fresh_path)
        r, n = compare(
            _load(args.baseline), _load(fresh_path),
            min_frac=args.min_speedup_frac, sim_rel_tol=args.sim_rel_tol)
        regressions += r
        notes += n
        sections.append((args.baseline, fresh_path))
    if os.path.exists(args.recovery_baseline):
        fresh_rec = args.fresh_recovery
        if fresh_rec is None:
            fresh_rec = os.path.join(
                tempfile.mkdtemp(prefix="bench_check_rec_"),
                "BENCH_recovery.json")
            _generate_recovery(fresh_rec)
        r, n = compare(
            _load(args.recovery_baseline), _load(fresh_rec),
            min_frac=args.min_speedup_frac, sim_rel_tol=args.sim_rel_tol,
            max_wall_ratio=args.max_wall_ratio, allow_missing=True)
        regressions += r
        notes += n
        sections.append((args.recovery_baseline, fresh_rec))
    elif args.recovery_only:
        regressions.append(
            f"--recovery-only but no baseline at {args.recovery_baseline}")
    report = to_markdown(
        regressions, notes,
        "; ".join(b for b, _ in sections) or args.recovery_baseline,
        "; ".join(f for _, f in sections) or "(none)")
    print(report)
    if args.report:
        os.makedirs(os.path.dirname(os.path.abspath(args.report)),
                    exist_ok=True)
        with open(args.report, "w") as f:
            f.write(report)
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
