"""Quickstart: the paper's technique in 30 lines.

Runs SSSP on an RMAT graph twice — direct owner-routing (Dalorex) vs
proxy regions (DCRA) — and prints the traffic reduction, then prices the
run under two chip packages.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.costmodel import DCRA_HBM_HORIZ, DCRA_SRAM, price
from repro.core.proxy import ProxyConfig
from repro.core.tilegrid import square_grid
from repro.graph import apps, oracles, rmat_edges

graph = rmat_edges(scale=11, edge_factor=8)       # 2048 vertices
grid = square_grid(256)                           # 16x16 tiles
root = int(np.argmax(graph.out_degree()))

direct = apps.sssp(graph, root, grid, oq_cap=32)
proxy = apps.sssp(graph, root, grid, oq_cap=32,
                  proxy=ProxyConfig(region_ny=4, region_nx=4, slots=512))

assert np.allclose(direct.values, oracles.sssp_oracle(graph, root))
assert np.allclose(proxy.values, direct.values)

print(f"direct: {direct.run.counters.hop_msgs:.3g} hop-messages, "
      f"avg {direct.run.counters.avg_hops:.2f} hops")
print(f"proxy:  {proxy.run.counters.hop_msgs:.3g} hop-messages, "
      f"avg {proxy.run.counters.avg_hops:.2f} hops "
      f"({proxy.run.counters.filtered_at_proxy:.0f} filtered, "
      f"{proxy.run.counters.coalesced_at_proxy:.0f} coalesced at P$)")
print(f"traffic reduction: "
      f"{direct.run.counters.hop_msgs / proxy.run.counters.hop_msgs:.2f}x")

for pkg in (DCRA_SRAM, DCRA_HBM_HORIZ):
    # per-superstep trace: BSP time is recomputed under *each* package
    rep = price(pkg, grid, proxy.run.counters,
                mem_bits_sram=graph.footprint_bytes() * 8,
                per_superstep_peak=proxy.run.trace)
    print(f"{pkg.name:16s} time={rep.time_s*1e6:8.1f}us "
          f"energy={rep.energy_j*1e3:7.3f}mJ cost=${rep.cost_usd:8.0f}")
