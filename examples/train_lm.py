"""End-to-end driver: train a ~100M-param deepseek-style LM for a few
hundred steps on the synthetic order-2 language, with checkpointing and
the fault-tolerant loop.

Defaults are CPU-sized (~30 min); pass --full for the true ~100M x 300
steps run on capable hardware.

    PYTHONPATH=src python examples/train_lm.py [--full]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true",
                help="~100M params, 300 steps (hours on CPU)")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

if args.full:
    # deepseek-style dense: 12 x d512 x ffn(1408-ish scaled) ~ 100M with
    # the 102k vocab embedding
    argv = ["--arch", "deepseek-7b", "--d-model", "512", "--n-layers",
            "12", "--steps", "300", "--batch", "16", "--seq", "512",
            "--lr", "1e-3", "--microbatches", "2",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50"]
else:
    argv = ["--arch", "deepseek-7b", "--d-model", "128", "--n-layers",
            "4", "--vocab", "2048", "--steps", "60", "--batch", "8",
            "--seq", "128", "--lr", "2e-3",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "20"]

losses = train_main(argv)
assert losses[-1] < losses[0], "loss should decrease"
print("OK: loss decreased; checkpoints in", args.ckpt_dir)
