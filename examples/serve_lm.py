"""Serve a small model with batched requests through the
continuous-batching scheduler (slots refill as requests finish).

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main

serve_main(["--arch", "h2o-danube-3-4b", "--smoke", "--requests", "6",
            "--slots", "3", "--max-new", "8", "--max-len", "48"])
