"""Strong scaling on one chart: BFS across grid sizes, proxy on/off
(the shape of the paper's Fig. 8/11 at laptop scale).

    PYTHONPATH=src python examples/graph_scaling.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.proxy import ProxyConfig
from repro.core.tilegrid import square_grid
from repro.graph import apps, rmat_edges

graph = rmat_edges(scale=12, edge_factor=8)
root = int(np.argmax(graph.out_degree()))

print(f"{'tiles':>7} {'mode':>7} {'GTEPS':>8} {'avg hops':>9} "
      f"{'supersteps':>10}")
for n_tiles in (64, 256, 1024):
    grid = square_grid(n_tiles)
    for mode in ("direct", "proxy"):
        px = None if mode == "direct" else ProxyConfig(
            max(grid.ny // 4, 2), max(grid.nx // 4, 2), slots=512)
        r = apps.bfs(graph, root, grid, proxy=px, oq_cap=32)
        print(f"{n_tiles:>7} {mode:>7} {r.gteps:8.3f} "
              f"{r.run.counters.avg_hops:9.2f} {r.run.supersteps:>10}")
