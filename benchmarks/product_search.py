"""Package-time product search: Fig. 9/10 tradeoff curves from a swept
design space (measure-once / price-many).

Each (app, cascade level/grouping) combination runs the engine once; the
per-superstep counter vectors are cached on disk (JSON keyed by a spec
hash), then re-priced analytically across the packaging cross-product
(SRAM / HBM-horiz / HBM-vert x network options a-d x SRAM-per-tile
sizes).  Cascade legs (``cross_region_msgs``, ``cascade_combined``) are
part of the measured traffic, so their energy and time land in every
priced product.  The output is the Fig. 9-style product table plus the
Pareto front and the per-objective product selection — the paper's
claim that one silicon design yields differently-optimal chip products
post-silicon.

    --small (default)  2 apps (sssp, spmv +-cascade) at 4096 tiles
    --full             sssp/spmv/histo at 4096 & 16384 tiles, cascade
                       level/grouping sweep, 3 SRAM sizes
    --chips 1,4,16     chip partitioning as a packaging axis: each chip
                       count is measured once on the distributed runtime
                       (board-level trace cached), priced across the
                       board-link provisioning sweep, and Pareto-ranked
                       against the other counts — Fig. 9/10 curves with
                       chip count on the front
    --smoke            tiny grid, 2 package configs, cached-counter
                       round-trip assertion (CI); with --chips N it
                       additionally asserts the re-pricing contract on a
                       measured N-chip trace

Counters are cached under ``--cache-dir`` (default
``benchmarks/.cache/products``); delete the directory to force
re-measurement.
"""
from __future__ import annotations

import dataclasses
import os

from common import row

from repro.core.costmodel import DCRA_SRAM
from repro.core.proxy import max_cascade_levels
from repro.core.tilegrid import square_grid
from repro.products import (FULL_SRAM_MIB, MeasureSpec, ProductSearch,
                            chip_counts_for, pareto_front, product_space,
                            select_products)

DEFAULT_CACHE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             ".cache", "products")


def _cascade_sweep(app: str, tiles: int, levels, groups,
                   region_div: int = 4):
    """Specs for one app's cascade level/grouping sweep on ``tiles``,
    keeping only well-formed reduction trees (plus the no-cascade base).
    ``region_div`` must match the MeasureSpec the depths are used with
    (same base-region formula as ``apps.table2_proxy``)."""
    grid = square_grid(tiles)
    region_ny = max(grid.ny // region_div, 2)
    region_nx = max(grid.nx // region_div, 2)
    specs = []
    for group in groups:
        for lv in levels:
            if lv == 0:
                continue
            if lv <= max_cascade_levels(grid.ny, grid.nx, region_ny,
                                        region_nx, group, group):
                specs.append((lv, group))
    return specs


def _specs(small: bool, scale_bump: int = 0):
    if small:
        scale = 13 + scale_bump
        return [
            MeasureSpec(app="sssp", scale=scale, tiles=4096),
            MeasureSpec(app="spmv", scale=scale, tiles=4096),
            MeasureSpec(app="spmv", scale=scale, tiles=4096,
                        cascade_levels=2),
        ]
    specs = []
    for tiles in (4096, 16384):
        scale = (15 if tiles == 4096 else 16) + scale_bump
        for app in ("sssp", "spmv", "histo"):
            specs.append(MeasureSpec(app=app, scale=scale, tiles=tiles))
            if app in ("spmv", "histo"):     # write-back: cascade profits
                for lv, group in _cascade_sweep(app, tiles, (1, 2), (2, 4)):
                    specs.append(MeasureSpec(app=app, scale=scale,
                                             tiles=tiles, cascade_levels=lv,
                                             cascade_group=group))
    return specs


def _emit(rows, search: ProductSearch):
    for r in rows:
        row(f"product/{r['measurement']}/{r['product']}",
            r["time_s"] * 1e6,
            f"energy_j={r['energy_j']:.3e};cost=${r['cost_usd']:.0f};"
            f"thr_per_$={r['thr_per_usd']:.3g};"
            f"eff_per_$={r['eff_per_usd']:.3g};"
            f"cascade_combined={r['cascade_combined']:.0f};"
            f"cached={int(r['from_cache'])}")
    by_meas = {}
    for r in rows:
        by_meas.setdefault(r["measurement"], []).append(r)
    for meas, group in by_meas.items():
        front = pareto_front(group)
        names = "|".join(sorted(r["product"] for r in front))
        row(f"product/pareto/{meas}", len(front), f"front={names}")
        sel = select_products(group)
        picks = ";".join(f"{obj}={r['product']}"
                         for obj, r in sel.items())
        row(f"product/select/{meas}", len(group), picks)
    print(f"# product_search: {len(rows)} priced rows from "
          f"{search.engine_runs} engine runs "
          f"({len(by_meas)} measurements)", flush=True)


def run(small: bool = True, cache_dir: str = DEFAULT_CACHE):
    search = ProductSearch(cache_dir=cache_dir)
    sram = (1.5,) if small else FULL_SRAM_MIB
    configs = product_space(sram_mib=sram)
    rows = search.sweep(_specs(small), configs)
    _emit(rows, search)
    return rows


def run_chips(chip_counts, small: bool = True,
              cache_dir: str = DEFAULT_CACHE):
    """Chip partitioning as a packaging axis: measure each (app, chips)
    once on the distributed runtime, price across the board-link
    provisioning sweep, and put chip count on the Pareto front."""
    search = ProductSearch(cache_dir=cache_dir)
    tiles = 1024 if small else 4096
    scale = 11 if small else 13
    counts = chip_counts_for(tiles, chip_counts)
    for n in chip_counts:
        if max(n, 1) not in counts:
            print(f"# product_search: skipped chips={n} (cannot "
                  f"block-partition the {tiles}-tile grid)", flush=True)
    if not counts:
        raise SystemExit(
            f"--chips {','.join(map(str, chip_counts))}: no requested "
            f"count can partition the {tiles}-tile grid")
    specs = [MeasureSpec(app="sssp", scale=scale, tiles=tiles),
             MeasureSpec(app="histo", scale=scale, tiles=tiles)]
    rows = []
    for n in counts:
        configs = product_space(
            memory=("sram", "hbm-horiz"),
            network=("a_2x32_od32", "d_32+64_od64"),
            chips=(n,), board_links=(1, 2, 4) if n > 1 else (2,))
        rows.extend(search.sweep(specs, configs))
    _emit(rows, search)
    # chip count on the Pareto front: rank every chip count's products
    # together, per app, and name the per-objective winner at each scale
    for app in sorted({r["app"] for r in rows}):
        group = [r for r in rows if r["app"] == app]
        front = pareto_front(group)
        chips_on_front = sorted({r["chips"] for r in front})
        row(f"product/chips-pareto/{app}", len(front),
            "front_chips=" + ",".join(str(c) for c in chips_on_front))
        for n in counts:
            sub = [r for r in group if r["chips"] == n]
            sel = select_products(sub, ("time", "energy", "cost"))
            picks = ";".join(f"{obj}={r['product']}"
                             for obj, r in sel.items())
            row(f"product/chips-select/{app}/{n}chips", len(sub), picks)
    return rows


def smoke(cache_dir: str = DEFAULT_CACHE) -> None:
    """CI smoke: tiny grid, 2 package configs, cache round-trip."""
    search = ProductSearch(cache_dir=cache_dir)
    specs = [MeasureSpec(app="sssp", scale=8, tiles=64),
             MeasureSpec(app="histo", scale=8, tiles=64,
                         cascade_levels=1)]
    configs = product_space(memory=("sram",),
                            network=("a_2x32_od32", "d_32+64_od64"))
    rows1 = search.sweep(specs, configs)
    runs_after_first = search.engine_runs
    rows2 = search.sweep(specs, configs)    # must be pure cache hits
    assert search.engine_runs == runs_after_first, \
        "second sweep re-ran the engine despite cached counters"
    assert all(r["from_cache"] for r in rows2), "cache round-trip failed"
    for r1, r2 in zip(rows1, rows2):
        assert r1["time_s"] == r2["time_s"], (r1, r2)
        assert r1["energy_j"] == r2["energy_j"], (r1, r2)
    # the re-pricing contract: option (a)'s narrower links can never beat
    # option (d) on the same measured traffic
    for meas in {r["measurement"] for r in rows2}:
        t = {r["product"]: r["time_s"] for r in rows2
             if r["measurement"] == meas}
        assert t["sram/net-a/sram1.5"] >= t["sram/net-d/sram1.5"], t
    _emit(rows2, search)
    print("# product_search smoke: OK", flush=True)


def smoke_chips(chips: int, cache_dir: str = DEFAULT_CACHE) -> None:
    """CI smoke for the chips axis: a tiny N-chip measurement on the
    distributed runtime round-trips through the cache, and re-pricing the
    cached board-level trace under its measured PackageConfig reproduces
    the directly measured ``run.time_s`` (the acceptance contract)."""
    if chips <= 1:
        raise SystemExit(
            "--smoke --chips needs a chip count > 1: the contract under "
            "test is the board leg, which only exists on a real partition")
    search = ProductSearch(cache_dir=cache_dir)
    spec = MeasureSpec(app="sssp", scale=8, tiles=64)
    configs = product_space(memory=("sram",),
                            network=("a_2x32_od32", "d_32+64_od64"),
                            chips=(chips,), board_links=(1, 2))
    rows1 = search.sweep([spec], configs)
    runs_after_first = search.engine_runs
    rows2 = search.sweep([spec], configs)   # must be pure cache hits
    assert search.engine_runs == runs_after_first, \
        "second sweep re-ran the engine despite a cached N-chip trace"
    assert all(r["from_cache"] and r["chips"] == chips for r in rows2)
    for r1, r2 in zip(rows1, rows2):
        assert r1["time_s"] == r2["time_s"], (r1, r2)
        assert r1["cost_usd"] == r2["cost_usd"], (r1, r2)
    # the re-pricing contract on the measured partition: the cached
    # N-chip trace priced under its measured config reproduces the
    # directly measured run time
    m = search.measure(dataclasses.replace(spec, chips=chips))
    assert m.from_cache and m.trace.chips_y * m.trace.chips_x == chips
    rep = search.price_product(m, dataclasses.replace(DCRA_SRAM,
                                                      chips=chips))
    assert abs(rep.time_s - m.time_s) <= 1e-12 * m.time_s, \
        (rep.time_s, m.time_s)
    # board-link provisioning is live and monotone: halving the links
    # can never make the same measured traffic faster
    for meas in {r["measurement"] for r in rows2}:
        t = {r["product"]: r["time_s"] for r in rows2
             if r["measurement"] == meas}
        for netname in ("a", "d"):
            assert t[f"sram/net-{netname}/sram1.5/c{chips}/bl1"] >= \
                t[f"sram/net-{netname}/sram1.5/c{chips}"], t
    _emit(rows2, search)
    print(f"# product_search smoke --chips {chips}: OK "
          f"(reprice == measured at {m.time_s:.3e}s)", flush=True)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--chips", type=str, default=None,
                    help="comma-separated chip counts for the chip-"
                         "partitioning axis (e.g. 1,4,16); with --smoke, "
                         "a single count > 1 for the CI contract check")
    ap.add_argument("--cache-dir", default=DEFAULT_CACHE)
    a = ap.parse_args()
    if a.chips:
        try:
            counts = tuple(int(c) for c in a.chips.split(","))
        except ValueError:
            raise SystemExit(f"--chips {a.chips!r}: expected an integer "
                             f"or comma-separated integers")
    if a.smoke and a.chips:
        if len(counts) != 1:
            raise SystemExit(f"--smoke --chips {a.chips!r}: the CI "
                             f"contract check takes a single count > 1")
        smoke_chips(counts[0], cache_dir=a.cache_dir)
    elif a.smoke:
        smoke(cache_dir=a.cache_dir)
    elif a.chips:
        run_chips(counts, small=not a.full, cache_dir=a.cache_dir)
    else:
        run(small=not a.full, cache_dir=a.cache_dir)
