"""Graph500-style BFS accounting (§IV-D) + measured multi-chip scaling.

Runs BFS per Graph500 guidelines (time traversal only; TEPS = traversed
edges / time) on the largest CPU-feasible RMAT, then runs the
*distributed* runtime's weak-scaling sweep (``repro.distrib``) so the
multi-chip GTEPS curve is measured, not projected: each chip count
executes per-chip engine supersteps with a boundary exchange and
off-chip charging.  The old linear-scaling projection of the paper's
RMAT-26 headline is still printed alongside, clearly labelled, for
comparison with the measured curve.

  --chips 1,4,16,64   override the measured chip counts
"""
from __future__ import annotations

import numpy as np

from common import SCALE, dataset, row

from repro.core.proxy import ProxyConfig
from repro.core.tilegrid import square_grid
from repro.distrib import harness
from repro.graph import apps


def run(small: bool = True, chips=None):
    g = dataset(12 if small else 16)
    root = int(np.argmax(g.out_degree()))
    out = {}
    for n_tiles in ((256, 1024) if small else (1024, 4096)):
        grid = square_grid(n_tiles)
        px = ProxyConfig(max(grid.ny // 4, 2), max(grid.nx // 4, 2),
                         slots=512)
        r = apps.bfs(g, root, grid, proxy=px, oq_cap=32)
        out[n_tiles] = r.gteps
        row(f"graph500/bfs/{n_tiles}tiles", r.run.time_s * 1e6,
            f"gteps={r.gteps:.3f};edges={r.teps_edges:.0f};"
            f"supersteps={r.run.supersteps}")

    # measured multi-chip path: weak-scaling sweep on the distributed
    # runtime (per-chip supersteps + boundary exchange + off-chip leg).
    # The small default measures only the endpoints bracketing the
    # projection — the full curve lives in benchmarks/multichip_scaling.py
    # (which run.py executes alongside this module).
    counts = tuple(chips) if chips else ((1, 64) if small
                                         else (1, 4, 16, 64, 256))
    mc = harness.weak_scaling(chip_counts=counts,
                              tiles_per_chip=16 if small else 64,
                              base_scale=6 if small else 8)
    for m in mc:
        out[f"{m['chips']}chips"] = m["gteps"]
        row(f"graph500/bfs/{m['chips']}chips_measured",
            m["time_s"] * 1e6,
            f"gteps={m['gteps']:.3f};tiles={m['tiles']};"
            f"supersteps={m['supersteps']};"
            f"off_chip_msgs={m['off_chip_msgs']:.0f};"
            f"off_chip_j={m['off_chip_j']:.3e};"
            f"gteps_per_usd={m['gteps_per_usd']:.3g}")

    # projection: TEPS scales with tile count at constant per-tile
    # utilization until per-tile work thins out (paper Fig. 11); scale
    # linearly from the largest measured grid to 2^20 tiles with the
    # paper's own observed ~60% efficiency decay at extreme scale.
    # Kept only as a sanity bracket around the measured curve above.
    biggest = max(k for k in out if isinstance(k, int))
    proj = out[biggest] * (2**20 / biggest) * 0.6
    row("graph500/bfs/projected_2^20tiles_rmat26", 0.0,
        f"gteps_projection={proj:.0f};paper_claim=3323;"
        "method=linear_tile_scaling_x0.6_utilization")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=str, default=None,
                    help="comma-separated chip counts for the measured "
                         "multi-chip sweep (e.g. 1,4,16,64,256)")
    ap.add_argument("--full", action="store_true")
    a = ap.parse_args()
    counts = tuple(int(c) for c in a.chips.split(",")) if a.chips else None
    run(small=not a.full, chips=counts)
