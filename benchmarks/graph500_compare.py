"""Graph500-style BFS accounting (§IV-D) + projection to paper scale.

Runs BFS per Graph500 guidelines (time traversal only; TEPS = traversed
edges / time) on the largest CPU-feasible RMAT, then *projects* the
paper's RMAT-26 headline using the engine's measured per-superstep
utilization and the analytic scaling of the BSP time model — reported
separately and clearly labelled as a projection.
"""
from __future__ import annotations

import numpy as np

from common import SCALE, dataset, row

from repro.core.proxy import ProxyConfig
from repro.core.tilegrid import square_grid
from repro.graph import apps


def run(small: bool = True):
    g = dataset(12 if small else 16)
    root = int(np.argmax(g.out_degree()))
    out = {}
    for n_tiles in ((256, 1024) if small else (1024, 4096)):
        grid = square_grid(n_tiles)
        px = ProxyConfig(max(grid.ny // 4, 2), max(grid.nx // 4, 2),
                         slots=512)
        r = apps.bfs(g, root, grid, proxy=px, oq_cap=32)
        out[n_tiles] = r.gteps
        row(f"graph500/bfs/{n_tiles}tiles", r.run.time_s * 1e6,
            f"gteps={r.gteps:.3f};edges={r.teps_edges:.0f};"
            f"supersteps={r.run.supersteps}")
    # projection: TEPS scales with tile count at constant per-tile
    # utilization until per-tile work thins out (paper Fig. 11); scale
    # linearly from the largest measured grid to 2^20 tiles with the
    # paper's own observed ~60% efficiency decay at extreme scale.
    biggest = max(out)
    proj = out[biggest] * (2**20 / biggest) * 0.6
    row("graph500/bfs/projected_2^20tiles_rmat26", 0.0,
        f"gteps_projection={proj:.0f};paper_claim=3323;"
        "method=linear_tile_scaling_x0.6_utilization")
    return out


if __name__ == "__main__":
    run()
