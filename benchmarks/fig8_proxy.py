"""Fig. 8: proxies vs Dalorex — vertex-update hop distance + throughput —
plus the selective-cascading check: cascaded == non-cascaded final state
on all six apps while cross-region traffic drops at >= 2 cascade levels.

The paper's headline: proxy regions cut vertex-update network traffic
1.8x vs Dalorex (same engine, proxies off) and keep scaling past the
grid sizes where Dalorex plateaus; cascading then combines owner-bound
updates region-to-region in a reduction tree so the scheme keeps scaling
across chips.
"""
from __future__ import annotations

import numpy as np

from common import dataset, row

from repro.core.costmodel import DALOREX, DCRA_SRAM
from repro.core.proxy import ProxyConfig
from repro.core.tilegrid import square_grid
from repro.graph import apps
from repro.graph.rmat import histogram_input


def run(small: bool = True):
    sizes = (64, 256, 1024) if small else (256, 1024, 4096, 16384)
    g = dataset(11)
    root = int(np.argmax(g.out_degree()))
    base_thr = None
    results = {}
    for n_tiles in sizes:
        grid = square_grid(n_tiles)
        px = ProxyConfig(max(grid.ny // 4, 2), max(grid.nx // 4, 2),
                         slots=512)
        dal = apps.sssp(g, root, grid, proxy=None, oq_cap=32, pkg=DALOREX)
        dcra = apps.sssp(g, root, grid, proxy=px, oq_cap=32, pkg=DCRA_SRAM)
        # Fig. 8 (top): avg hops of the vertex-update *invocation* — for
        # DCRA that's the (short, in-region) src->proxy leg; for Dalorex
        # the direct src->owner trip.  The 1.8x traffic claim is the
        # owner-bound (post-filter/coalesce) hop-weighted traffic.
        cd, cp = dal.run.counters, dcra.run.counters
        hops_dal = cd.avg_hops
        hops_dcra = ((cp.hop_msgs - cp.owner_hop_msgs)
                     / max(cp.messages - cp.owner_msgs, 1.0))
        update_ratio = cd.owner_hop_msgs / max(cp.owner_hop_msgs, 1.0)
        wire_ratio = (dal.run.counters.hop_msgs
                      / max(dcra.run.counters.hop_msgs, 1.0))
        thr_dal = dal.teps_edges / dal.run.time_s
        thr_dcra = dcra.teps_edges / dcra.run.time_s
        if base_thr is None:
            base_thr = thr_dal
        results[n_tiles] = dict(update_ratio=update_ratio,
                                wire_ratio=wire_ratio,
                                hops_dal=hops_dal, hops_dcra=hops_dcra)
        row(f"fig8/hops/{n_tiles}tiles", dcra.run.time_s * 1e6,
            f"dalorex_hops={hops_dal:.2f};dcra_hops={hops_dcra:.2f};"
            f"update_traffic_reduction={update_ratio:.2f}x;"
            f"total_wire_reduction={wire_ratio:.2f}x")
        row(f"fig8/throughput/{n_tiles}tiles", 0.0,
            f"dalorex_x={thr_dal/base_thr:.2f};dcra_x={thr_dcra/base_thr:.2f}")
    results.update(run_cascade(small))
    return results


def run_cascade(small: bool = True):
    """Selective cascading: numerical equivalence on all six apps and the
    cross-region traffic reduction on the write-back reduction drains."""
    g = dataset(9 if small else 11)
    root = int(np.argmax(g.out_degree()))
    x = np.random.default_rng(0).random(g.n_cols).astype(np.float32)
    bins = g.n_rows // 8
    hv = histogram_input(g, bins)
    grid = square_grid(64 if small else 1024)
    levels = 2

    def runner(name):
        return {
            "bfs": lambda px: apps.bfs(g, root, grid, proxy=px, oq_cap=32),
            "sssp": lambda px: apps.sssp(g, root, grid, proxy=px, oq_cap=32),
            "wcc": lambda px: apps.wcc(g, grid, proxy=px, oq_cap=32),
            "pagerank": lambda px: apps.pagerank(g, grid, proxy=px,
                                                 epochs=3, oq_cap=32),
            "spmv": lambda px: apps.spmv(g, x, grid, proxy=px, oq_cap=32),
            "histo": lambda px: apps.histogram(hv, bins, grid, proxy=px,
                                               oq_cap=32),
        }[name]

    results = {}
    for name in ("bfs", "sssp", "wcc", "pagerank", "spmv", "histo"):
        fn = runner(name)
        # For the write-through min apps the selective criterion would
        # bypass the tree (their sparse improvement streams merge too
        # rarely); force them through it (selective=False) so the
        # equivalence claim covers every app's combine.
        selective = name in apps.WRITE_BACK_APPS
        r0 = fn(apps.table2_proxy(grid, name))
        r2 = fn(apps.table2_proxy(grid, name, cascade_levels=levels,
                                  selective=selective))
        equal = bool(np.allclose(r0.values, r2.values,
                                 rtol=1e-4, atol=1e-6))
        c0, c2 = r0.run.counters, r2.run.counters
        xr = c0.cross_region_msgs / max(c2.cross_region_msgs, 1.0)
        ow = c0.owner_msgs / max(c2.owner_msgs, 1.0)
        results[("cascade", name)] = dict(equal=equal, xregion_ratio=xr,
                                          owner_ratio=ow)
        row(f"fig8/cascade/{name}", r2.run.time_s * 1e6,
            f"equal={equal};levels={levels};"
            f"xregion_reduction={xr:.2f}x;owner_msg_reduction={ow:.2f}x;"
            f"combined={c2.cascade_combined:.0f}")
    # far-traffic drain: everything funnels into a handful of hot bins —
    # the regime the reduction tree exists for.  Small regions (2x2 on a
    # 16x16 grid) leave both cascade levels genuinely below the grid.
    fgrid = square_grid(256 if small else 4096)
    far = (np.arange(20000) % 8).astype(np.int32)
    f0 = apps.histogram(far, 64, fgrid,
                        proxy=apps.table2_proxy(fgrid, "histo", slots=64,
                                                region_div=8),
                        oq_cap=16)
    f2 = apps.histogram(far, 64, fgrid,
                        proxy=apps.table2_proxy(fgrid, "histo", slots=64,
                                                region_div=8,
                                                cascade_levels=levels),
                        oq_cap=16)
    xr = (f0.run.counters.cross_region_msgs
          / max(f2.run.counters.cross_region_msgs, 1.0))
    results[("cascade", "far_histo")] = dict(
        equal=bool(np.array_equal(f0.values, f2.values)), xregion_ratio=xr)
    row("fig8/cascade/far_histo", f2.run.time_s * 1e6,
        f"equal={np.array_equal(f0.values, f2.values)};levels={levels};"
        f"xregion_reduction={xr:.2f}x")
    return results


if __name__ == "__main__":
    run()
