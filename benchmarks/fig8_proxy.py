"""Fig. 8: proxies vs Dalorex — vertex-update hop distance + throughput.

The paper's headline: proxy regions cut vertex-update network traffic
1.8x vs Dalorex (same engine, proxies off) and keep scaling past the
grid sizes where Dalorex plateaus.
"""
from __future__ import annotations

import numpy as np

from common import dataset, row

from repro.core.costmodel import DALOREX, DCRA_SRAM
from repro.core.proxy import ProxyConfig
from repro.core.tilegrid import square_grid
from repro.graph import apps


def run(small: bool = True):
    sizes = (64, 256, 1024) if small else (256, 1024, 4096, 16384)
    g = dataset(11)
    root = int(np.argmax(g.out_degree()))
    base_thr = None
    results = {}
    for n_tiles in sizes:
        grid = square_grid(n_tiles)
        px = ProxyConfig(max(grid.ny // 4, 2), max(grid.nx // 4, 2),
                         slots=512)
        dal = apps.sssp(g, root, grid, proxy=None, oq_cap=32, pkg=DALOREX)
        dcra = apps.sssp(g, root, grid, proxy=px, oq_cap=32, pkg=DCRA_SRAM)
        # Fig. 8 (top): avg hops of the vertex-update *invocation* — for
        # DCRA that's the (short, in-region) src->proxy leg; for Dalorex
        # the direct src->owner trip.  The 1.8x traffic claim is the
        # owner-bound (post-filter/coalesce) hop-weighted traffic.
        cd, cp = dal.run.counters, dcra.run.counters
        hops_dal = cd.avg_hops
        hops_dcra = ((cp.hop_msgs - cp.owner_hop_msgs)
                     / max(cp.messages - cp.owner_msgs, 1.0))
        update_ratio = cd.owner_hop_msgs / max(cp.owner_hop_msgs, 1.0)
        wire_ratio = (dal.run.counters.hop_msgs
                      / max(dcra.run.counters.hop_msgs, 1.0))
        thr_dal = dal.teps_edges / dal.run.time_s
        thr_dcra = dcra.teps_edges / dcra.run.time_s
        if base_thr is None:
            base_thr = thr_dal
        results[n_tiles] = dict(update_ratio=update_ratio,
                                wire_ratio=wire_ratio,
                                hops_dal=hops_dal, hops_dcra=hops_dcra)
        row(f"fig8/hops/{n_tiles}tiles", dcra.run.time_s * 1e6,
            f"dalorex_hops={hops_dal:.2f};dcra_hops={hops_dcra:.2f};"
            f"update_traffic_reduction={update_ratio:.2f}x;"
            f"total_wire_reduction={wire_ratio:.2f}x")
        row(f"fig8/throughput/{n_tiles}tiles", 0.0,
            f"dalorex_x={thr_dal/base_thr:.2f};dcra_x={thr_dcra/base_thr:.2f}")
    return results


if __name__ == "__main__":
    run()
