"""Engine run-loop throughput: device-resident (chunked) vs legacy loop.

Measures wall-clock supersteps/sec and simulated-GTEPS-per-wall-second
for BFS/SSSP/PageRank at 1024 (and, with --full, 4096) tiles, comparing
the legacy per-superstep dispatch loop (``run(chunk=0)``: one jitted
step + one host sync per superstep — the seed engine's behavior) against
the scan-chunked device-resident loop (``run(chunk=K)``: K supersteps
per dispatch, one host sync per chunk).  Both loops produce bit-identical
``TrafficCounters`` and ``SuperstepTrace`` — asserted on every row — so
the comparison is pure wall-clock.

Rows sweep ``oq_cap``: small OQ budgets mean many cheap supersteps (the
dispatch/sync-bound regime the chunked loop exists for — the paper's
runs take hundreds of thousands of such steps); large budgets mean fewer,
compute-heavy steps where the loop overhead is already amortized.  On a
CPU-only container the XLA superstep itself executes synchronously, so
the measured speedup is bounded by the step's own execution time; on an
async-dispatch accelerator backend the per-step host round-trip the
chunked loop eliminates is the dominant term.  ``host_syncs`` records
the exactly-measured O(supersteps) -> O(supersteps/K) sync reduction.

Emits BENCH_engine.json (list of per-config rows) for the perf
trajectory; --smoke runs one tiny config, asserts counter/trace
equality, and still writes the JSON (CI uploads it as an artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from common import row, timed  # noqa: F401  (path bootstrap)

import numpy as np

from repro.core.engine import DataLocalEngine, EngineConfig
from repro.core.tilegrid import square_grid
from repro.graph import apps, rmat_edges

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_engine.json")


def _mk_engine(app_name: str, g, grid, oq_cap: int, use_proxy: bool):
    spec = {"bfs": apps.BFS_SPEC, "sssp": apps.SSSP_SPEC,
            "pagerank": apps.PAGERANK_SPEC}[app_name]
    proxy = apps.table2_proxy(grid, app_name) if use_proxy else None
    cfg = EngineConfig(grid=grid, n_src=g.n_rows, n_dst=g.n_cols,
                       oq_cap=oq_cap, proxy=proxy)
    return spec, DataLocalEngine(spec, cfg, g.row_lo, g.row_hi, g.col_idx,
                                 g.weights)


def _init(app_name: str, eng, g, root):
    if app_name == "pagerank":
        deg = np.maximum(g.out_degree(), 1).astype(np.float32)
        contrib = 0.85 / g.n_rows / deg
        state = eng.init_state()
        return eng.activate_all(state, contrib)
    return eng.init_state(seed_idx=root, seed_val=0.0)


def _run_mode(app_name, eng, g, root, chunk, repeats: int):
    """Best-of-N wall clock of a full drained run (compile excluded:
    the first run warms the jit cache)."""
    eng.run(_init(app_name, eng, g, root), chunk=chunk)      # warm/compile
    best, result = float("inf"), None
    for _ in range(repeats):
        state = _init(app_name, eng, g, root)
        t0 = time.time()
        _, r = eng.run(state, chunk=chunk)
        best = min(best, time.time() - t0)
        result = r
    return best, result


def bench_config(app_name: str, tiles: int, scale: int, oq_cap: int,
                 chunk: int, use_proxy: bool = False,
                 repeats: int = 3) -> dict:
    """One benchmark row: legacy (chunk=0) vs chunked loop on the same
    engine, with bit-identity of counters/trace asserted."""
    g = rmat_edges(scale, edge_factor=8, seed=1)
    grid = square_grid(tiles)
    root = int(np.argmax(g.out_degree()))
    _, eng = _mk_engine(app_name, g, grid, oq_cap, use_proxy)
    t_legacy, r_legacy = _run_mode(app_name, eng, g, root, 0, repeats)
    t_chunk, r_chunk = _run_mode(app_name, eng, g, root, chunk, repeats)

    counters_equal = (r_legacy.counters.as_dict()
                      == r_chunk.counters.as_dict())
    trace_equal = r_legacy.trace.to_dict() == r_chunk.trace.to_dict()
    assert counters_equal, f"{app_name}: chunked counters diverged"
    assert trace_equal, f"{app_name}: chunked trace diverged"
    steps = r_chunk.supersteps
    teps = float(g.nnz)          # simulated edges traversed (upper bound)
    out = dict(
        app=app_name, tiles=tiles, scale=scale, oq_cap=oq_cap,
        proxy=use_proxy, chunk=chunk, supersteps=steps,
        wall_s_legacy=t_legacy, wall_s_chunked=t_chunk,
        steps_per_s_legacy=steps / t_legacy,
        steps_per_s_chunked=steps / t_chunk,
        speedup=t_legacy / t_chunk,
        host_syncs_legacy=steps,
        host_syncs_chunked=-(-steps // chunk),
        sim_time_s=r_chunk.time_s,
        sim_gteps_per_wall_s_legacy=teps / r_chunk.time_s / 1e9 / t_legacy,
        sim_gteps_per_wall_s_chunked=teps / r_chunk.time_s / 1e9 / t_chunk,
        counters_equal=counters_equal, trace_equal=trace_equal,
    )
    row(f"engine_throughput/{app_name}-{tiles}t-oq{oq_cap}"
        f"{'-proxy' if use_proxy else ''}",
        t_chunk * 1e6,
        f"speedup={out['speedup']:.2f}x "
        f"steps/s {out['steps_per_s_legacy']:.0f}->"
        f"{out['steps_per_s_chunked']:.0f} "
        f"syncs {steps}->{out['host_syncs_chunked']}")
    return out


# (app, oq_cap, chunk, use_proxy): the dispatch-bound small-OQ regimes the
# chunked loop targets plus one compute-heavy point per app for contrast.
CONFIGS_1024 = [
    ("bfs", 1, 128, False),
    ("bfs", 8, 32, False),
    ("bfs", 1, 128, True),
    ("sssp", 1, 128, False),
    ("sssp", 8, 32, True),
    ("pagerank", 4, 64, True),
]
CONFIGS_4096 = [
    ("bfs", 1, 128, False),
    ("sssp", 4, 64, True),
    ("pagerank", 4, 64, True),
]


def run(small: bool = True, out_path: str = DEFAULT_OUT) -> list:
    rows = []
    for app_name, oq, chunk, px in CONFIGS_1024:
        rows.append(bench_config(app_name, 1024, 11, oq, chunk, px))
    if not small:
        for app_name, oq, chunk, px in CONFIGS_4096:
            rows.append(bench_config(app_name, 4096, 13, oq, chunk, px))
    _write(rows, out_path)
    return rows


def smoke(out_path: str = DEFAULT_OUT) -> None:
    """CI gate: tiny grid, asserts chunked == legacy counters/trace for a
    write-through and a write-back app, writes the JSON artifact."""
    rows = [bench_config("bfs", 64, 9, 4, 16, False, repeats=1),
            bench_config("pagerank", 64, 9, 8, 16, True, repeats=1)]
    for r in rows:
        assert r["counters_equal"] and r["trace_equal"]
        assert r["host_syncs_chunked"] < r["host_syncs_legacy"]
    _write(rows, out_path)
    print(f"# smoke OK -> {out_path}")


def _write(rows: list, out_path: str) -> None:
    payload = dict(
        benchmark="engine_throughput",
        description="device-resident (scan-chunked) run loop vs legacy "
                    "per-superstep dispatch; bit-identical counters/trace",
        rows=rows,
        best_speedup=max((r["speedup"] for r in rows), default=0.0),
        note="CPU-only container: speedup bounded by the XLA superstep's "
             "own synchronous execution time; on async-dispatch "
             "accelerator backends the eliminated per-step host sync is "
             "the dominant term. host_syncs_* records the exact "
             "O(supersteps) -> O(supersteps/K) reduction.",
    )
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {out_path} (best speedup "
          f"{payload['best_speedup']:.2f}x)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config, asserts bit-identity")
    ap.add_argument("--full", action="store_true",
                    help="include the 4096-tile grids")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="output JSON path")
    args = ap.parse_args()
    if args.smoke:
        smoke(args.out)
    else:
        run(small=not args.full, out_path=args.out)
