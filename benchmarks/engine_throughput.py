"""Engine run-loop throughput: device-resident (chunked) vs legacy loop.

Measures wall-clock supersteps/sec and simulated-GTEPS-per-wall-second
for BFS/SSSP/PageRank at 1024 (and, with --full, 4096) tiles, comparing
the legacy per-superstep dispatch loop (``run(chunk=0)``: one jitted
step + one host sync per superstep — the seed engine's behavior) against
the scan-chunked device-resident loop (``run(chunk=K)``: K supersteps
per dispatch, one host sync per chunk).  Both loops produce bit-identical
``TrafficCounters`` and ``SuperstepTrace`` — asserted on every row — so
the comparison is pure wall-clock.

Rows sweep ``oq_cap``: small OQ budgets mean many cheap supersteps (the
dispatch/sync-bound regime the chunked loop exists for — the paper's
runs take hundreds of thousands of such steps); large budgets mean fewer,
compute-heavy steps where the loop overhead is already amortized.  On a
CPU-only container the XLA superstep itself executes synchronously, so
the measured speedup is bounded by the step's own execution time; on an
async-dispatch accelerator backend the per-step host round-trip the
chunked loop eliminates is the dominant term.  ``host_syncs`` records
the exactly-measured O(supersteps) -> O(supersteps/K) sync reduction.

A third *compaction* leg rides the sparse-regime rows: the same chunked
run through the engine's shape-bucketed active-set path
(``EngineConfig.compaction``), asserted bit-identical (values, counters,
trace, superstep count) to the dense chunked run and asserted to pay the
exact same measured host-sync count (bucket selection is on-device,
inside the scan).  ``speedup_compaction`` is the dense-chunked /
compacted wall ratio and ``mean_active_fraction`` records how sparse the
run actually was (from the ``active_tiles`` telemetry stat, fetched with
the chunk stats — no extra syncs).

A second axis sweeps *devices*: each ``DEVICE_CONFIGS`` row re-executes
this script in a subprocess with ``XLA_FLAGS=
--xla_force_host_platform_device_count=N`` (N = 1/2/4 forced CPU
devices) and runs the 4-chip distributed engine on the resulting
ExecMesh, once with the synchronous boundary exchange and once
double-buffered (``EngineConfig.double_buffer``).  Counters, values and
the physical trace are asserted identical between the two modes (the
double-buffer flag itself is excluded — it is priced, not measured);
``db_sim_win`` records the simulated-time win the overlapped exchange
buys, and ``speedup`` here is the sync/db *wall* ratio (noisy on CPU —
the sim win is the deterministic signal).

Emits BENCH_engine.json (list of per-config rows) for the perf
trajectory; --smoke runs one tiny config, asserts counter/trace
equality, and still writes the JSON (CI uploads it as an artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from common import row, timed  # noqa: F401  (path bootstrap)

import numpy as np

from repro.core.engine import DataLocalEngine, EngineConfig
from repro.core.tilegrid import square_grid
from repro.graph import apps, rmat_edges

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_engine.json")


def _mk_engine(app_name: str, g, grid, oq_cap: int, use_proxy: bool,
               compaction: int = 0):
    spec = {"bfs": apps.BFS_SPEC, "sssp": apps.SSSP_SPEC,
            "pagerank": apps.PAGERANK_SPEC}[app_name]
    proxy = apps.table2_proxy(grid, app_name) if use_proxy else None
    cfg = EngineConfig(grid=grid, n_src=g.n_rows, n_dst=g.n_cols,
                       oq_cap=oq_cap, proxy=proxy, compaction=compaction)
    return spec, DataLocalEngine(spec, cfg, g.row_lo, g.row_hi, g.col_idx,
                                 g.weights)


def _init(app_name: str, eng, g, root):
    if app_name == "pagerank":
        deg = np.maximum(g.out_degree(), 1).astype(np.float32)
        contrib = 0.85 / g.n_rows / deg
        state = eng.init_state()
        return eng.activate_all(state, contrib)
    return eng.init_state(seed_idx=root, seed_val=0.0)


def _run_mode(app_name, eng, g, root, chunk, repeats: int, observer=None):
    """Best-of-N wall clock of a full drained run (compile excluded:
    the first run warms the jit cache).  Returns (best_s, RunResult,
    final_state) — the state feeds the compaction bit-identity check."""
    eng.run(_init(app_name, eng, g, root), chunk=chunk)      # warm/compile
    best, result, final = float("inf"), None, None
    for _ in range(repeats):
        state = _init(app_name, eng, g, root)
        t0 = time.time()
        st, r = eng.run(state, chunk=chunk, observer=observer)
        best = min(best, time.time() - t0)
        result, final = r, st
    return best, result, final


def bench_config(app_name: str, tiles: int, scale: int, oq_cap: int,
                 chunk: int, use_proxy: bool = False,
                 repeats: int = 3, compaction: int = 0) -> dict:
    """One benchmark row: legacy (chunk=0) vs chunked loop on the same
    engine, with bit-identity of counters/trace asserted.  With
    ``compaction > 0`` a third leg runs the same chunked loop through
    the shape-bucketed active-set path and records its wall clock,
    measured host syncs (must match the dense chunked loop — bucket
    selection happens on device inside the scan) and the run's mean
    active-tile fraction (from the ``active_tiles`` telemetry stat via
    a TimelineRecorder — rides the chunk fetch, no extra syncs)."""
    from repro.obs.metrics import default_registry
    g = rmat_edges(scale, edge_factor=8, seed=1)
    grid = square_grid(tiles)
    root = int(np.argmax(g.out_degree()))
    _, eng = _mk_engine(app_name, g, grid, oq_cap, use_proxy)
    sync_ctr = default_registry().counter("engine.host_syncs")
    t_legacy, r_legacy, _ = _run_mode(app_name, eng, g, root, 0, repeats)
    s0 = sync_ctr.value
    t_chunk, r_chunk, st_chunk = _run_mode(app_name, eng, g, root, chunk,
                                           repeats)
    syncs_chunked = (sync_ctr.value - s0) / (repeats + 1)  # incl. warm run

    counters_equal = (r_legacy.counters.as_dict()
                      == r_chunk.counters.as_dict())
    trace_equal = r_legacy.trace.to_dict() == r_chunk.trace.to_dict()
    assert counters_equal, f"{app_name}: chunked counters diverged"
    assert trace_equal, f"{app_name}: chunked trace diverged"
    steps = r_chunk.supersteps
    teps = float(g.nnz)          # simulated edges traversed (upper bound)
    out = dict(
        app=app_name, tiles=tiles, scale=scale, oq_cap=oq_cap,
        proxy=use_proxy, chunk=chunk, compaction=compaction,
        supersteps=steps,
        wall_s_legacy=t_legacy, wall_s_chunked=t_chunk,
        steps_per_s_legacy=steps / t_legacy,
        steps_per_s_chunked=steps / t_chunk,
        speedup=t_legacy / t_chunk,
        host_syncs_legacy=steps,
        host_syncs_chunked=-(-steps // chunk),
        sim_time_s=r_chunk.time_s,
        sim_gteps_per_wall_s_legacy=teps / r_chunk.time_s / 1e9 / t_legacy,
        sim_gteps_per_wall_s_chunked=teps / r_chunk.time_s / 1e9 / t_chunk,
        counters_equal=counters_equal, trace_equal=trace_equal,
    )
    if compaction:
        from repro import obs
        _, ceng = _mk_engine(app_name, g, grid, oq_cap, use_proxy,
                             compaction)
        rec = obs.TimelineRecorder()
        s1 = sync_ctr.value
        t_comp, r_comp, st_comp = _run_mode(app_name, ceng, g, root, chunk,
                                            repeats, observer=rec)
        syncs_comp = (sync_ctr.value - s1) / (repeats + 1)
        act = rec.stat_matrix("active_tiles")
        compaction_equal = (
            r_comp.counters.as_dict() == r_chunk.counters.as_dict()
            and r_comp.trace.to_dict() == r_chunk.trace.to_dict()
            and r_comp.supersteps == r_chunk.supersteps
            and bool(np.array_equal(np.asarray(st_comp["values"]),
                                    np.asarray(st_chunk["values"]))))
        assert compaction_equal, f"{app_name}: compacted run diverged"
        assert syncs_comp == syncs_chunked, \
            f"{app_name}: compaction changed the host-sync count"
        out.update(
            wall_s_compacted=t_comp,
            steps_per_s_compacted=steps / t_comp,
            speedup_compaction=t_chunk / t_comp,
            host_syncs_compacted=int(syncs_comp),
            mean_active_fraction=float(np.mean(act)) / (grid.ny * grid.nx)
            if act.size else 1.0,
            compaction_equal=compaction_equal,
        )
    row(f"engine_throughput/{app_name}-{tiles}t-oq{oq_cap}"
        f"{'-proxy' if use_proxy else ''}"
        f"{f'-c{compaction}' if compaction else ''}",
        t_chunk * 1e6,
        f"speedup={out['speedup']:.2f}x "
        f"steps/s {out['steps_per_s_legacy']:.0f}->"
        f"{out['steps_per_s_chunked']:.0f} "
        f"syncs {steps}->{out['host_syncs_chunked']}"
        + (f" compaction {out['speedup_compaction']:.2f}x "
           f"act {out['mean_active_fraction']:.3f}" if compaction else ""))
    return out


def _device_row(app_name: str, tiles: int, scale: int, oq_cap: int,
                chunk: int, use_proxy: bool, devices: int,
                repeats: int = 2) -> dict:
    """One devices-axis row, executed *inside* the forced-device-count
    subprocess: 4-chip distributed run, sync vs double-buffered exchange
    on the same ExecMesh, with bit-identity of everything but the priced
    overlap asserted."""
    import jax
    g = rmat_edges(scale, edge_factor=8, seed=1)
    grid = square_grid(tiles)
    root = int(np.argmax(g.out_degree()))
    proxy = apps.table2_proxy(grid, app_name) if use_proxy else None
    res = {}
    for db in (False, True):
        eng, state, _seeds = apps.engine_and_state(
            app_name, g, grid, proxy=proxy, root=root,
            backend="shard_map", chips=4, oq_cap=oq_cap,
            double_buffer=db)
        eng.run(state, chunk=chunk)                      # warm/compile
        best, r, fin = float("inf"), None, None
        for _ in range(repeats):
            t0 = time.time()
            st, rr = eng.run(state, chunk=chunk)
            best = min(best, time.time() - t0)
            r, fin = rr, st
        res[db] = (best, r, fin, eng.mesh.ndev)
    (t_sync, r_sync, st_sync, ndev), (t_db, r_db, st_db, _) = \
        res[False], res[True]
    td_s, td_d = r_sync.trace.to_dict(), r_db.trace.to_dict()
    td_s.pop("double_buffer"), td_d.pop("double_buffer")
    counters_equal = r_sync.counters.as_dict() == r_db.counters.as_dict()
    values_equal = bool(np.array_equal(np.asarray(st_sync["values"]),
                                       np.asarray(st_db["values"])))
    assert counters_equal, f"{app_name}: db counters diverged"
    assert td_s == td_d, f"{app_name}: db physical trace diverged"
    assert values_equal, f"{app_name}: db values diverged"
    assert r_sync.supersteps == r_db.supersteps
    return dict(
        app=app_name, tiles=tiles, scale=scale, oq_cap=oq_cap,
        proxy=use_proxy, chunk=chunk, chips=4, devices=devices,
        host_devices=jax.device_count(), mesh_devices=ndev,
        supersteps=r_sync.supersteps,
        wall_s_sync=t_sync, wall_s_db=t_db,
        speedup=t_sync / t_db,
        sim_time_s=r_sync.time_s, sim_time_s_db=r_db.time_s,
        db_sim_win=1.0 - r_db.time_s / r_sync.time_s,
        counters_equal=counters_equal, trace_equal=True,
        values_equal=values_equal,
    )


def bench_devices(app_name: str, tiles: int, scale: int, oq_cap: int,
                  chunk: int, use_proxy: bool, devices: int,
                  repeats: int = 2) -> dict:
    """Spawn the forced-device-count worker and collect its row.  The
    device count must be baked into XLA_FLAGS before jax imports, hence
    the subprocess re-exec."""
    spec = dict(app_name=app_name, tiles=tiles, scale=scale,
                oq_cap=oq_cap, chunk=chunk, use_proxy=use_proxy,
                devices=devices, repeats=repeats)
    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.abspath(os.path.join(here, "..", "src")),
                    env.get("PYTHONPATH")) if p)
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--_worker",
         json.dumps(spec)],
        env=env, capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(
            f"device worker ({devices} devices) failed:\n"
            f"{proc.stdout[-1000:]}\n{proc.stderr[-2000:]}")
    lines = [ln for ln in proc.stdout.splitlines() if ln.startswith("ROW ")]
    out = json.loads(lines[-1][4:])
    row(f"engine_throughput/{app_name}-4chips-{devices}dev"
        f"{'-proxy' if use_proxy else ''}",
        out["wall_s_db"] * 1e6,
        f"db sim win {out['db_sim_win'] * 100:.1f}% "
        f"wall sync/db {out['speedup']:.2f}x "
        f"mesh {out['mesh_devices']}dev")
    return out


# (app, oq_cap, chunk, use_proxy, compaction): the dispatch-bound
# small-OQ regimes the chunked loop targets plus one compute-heavy point
# per app for contrast.  The compaction level adds a third leg to the
# row — the shape-bucketed active-set path — on the sparse-regime
# configs (small OQ => long drained tails with few active tiles, the
# regime compaction exists for); the dense-regime rows keep it off, so
# the axis records both sides of the sparsity contrast.
CONFIGS_1024 = [
    ("bfs", 1, 128, False, 3),
    ("bfs", 8, 32, False, 2),
    ("bfs", 1, 128, True, 2),
    ("sssp", 1, 128, False, 2),
    ("sssp", 8, 32, True, 0),
    ("pagerank", 4, 64, True, 0),
]
CONFIGS_4096 = [
    ("bfs", 1, 128, False, 3),
    ("sssp", 4, 64, True, 0),
    ("pagerank", 4, 64, True, 0),
]
# (app, tiles, scale, oq_cap, chunk, use_proxy) x DEVICE_COUNTS forced
# CPU devices: the 4-chip mesh sweep (sync vs double-buffered exchange).
DEVICE_CONFIGS = [
    ("bfs", 256, 10, 8, 32, False),
    ("sssp", 256, 10, 8, 32, True),
]
DEVICE_COUNTS = (1, 2, 4)


def run(small: bool = True, out_path: str = DEFAULT_OUT,
        device_counts=DEVICE_COUNTS) -> list:
    rows = []
    for app_name, oq, chunk, px, comp in CONFIGS_1024:
        rows.append(bench_config(app_name, 1024, 11, oq, chunk, px,
                                 compaction=comp))
    if not small:
        for app_name, oq, chunk, px, comp in CONFIGS_4096:
            rows.append(bench_config(app_name, 4096, 13, oq, chunk, px,
                                     compaction=comp))
    for app_name, tiles, scale, oq, chunk, px in DEVICE_CONFIGS:
        for ndev in device_counts:
            rows.append(bench_devices(app_name, tiles, scale, oq, chunk,
                                      px, ndev))
    _write(rows, out_path)
    return rows


def smoke(out_path: str = DEFAULT_OUT) -> None:
    """CI gate: tiny grid, asserts chunked == legacy counters/trace for a
    write-through and a write-back app, writes the JSON artifact."""
    rows = [bench_config("bfs", 64, 9, 4, 16, False, repeats=1,
                         compaction=2),
            bench_config("pagerank", 64, 9, 8, 16, True, repeats=1)]
    for r in rows:
        assert r["counters_equal"] and r["trace_equal"]
        assert r["host_syncs_chunked"] < r["host_syncs_legacy"]
        if r["compaction"]:
            assert r["compaction_equal"]
            assert r["host_syncs_compacted"] >= 0
    _write(rows, out_path)
    print(f"# smoke OK -> {out_path}")


def _write(rows: list, out_path: str) -> None:
    payload = dict(
        benchmark="engine_throughput",
        description="device-resident (scan-chunked) run loop vs legacy "
                    "per-superstep dispatch; bit-identical counters/trace",
        rows=rows,
        best_speedup=max((r["speedup"] for r in rows
                          if "devices" not in r), default=0.0),
        best_db_sim_win=max((r["db_sim_win"] for r in rows
                             if "db_sim_win" in r), default=0.0),
        best_speedup_compaction=max(
            (r["speedup_compaction"] for r in rows
             if "speedup_compaction" in r), default=0.0),
        note="CPU-only container: speedup bounded by the XLA superstep's "
             "own synchronous execution time; on async-dispatch "
             "accelerator backends the eliminated per-step host sync is "
             "the dominant term. host_syncs_* records the exact "
             "O(supersteps) -> O(supersteps/K) reduction.",
    )
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {out_path} (best speedup "
          f"{payload['best_speedup']:.2f}x)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config, asserts bit-identity")
    ap.add_argument("--full", action="store_true",
                    help="include the 4096-tile grids")
    ap.add_argument("--devices", default=",".join(map(str, DEVICE_COUNTS)),
                    help="comma-separated forced CPU device counts for "
                         "the 4-chip mesh sweep (empty string skips it)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="output JSON path")
    ap.add_argument("--_worker", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args._worker is not None:
        print("ROW " + json.dumps(_device_row(**json.loads(args._worker))))
    elif args.smoke:
        smoke(args.out)
    else:
        counts = tuple(int(c) for c in args.devices.split(",") if c)
        run(small=not args.full, out_path=args.out, device_counts=counts)
