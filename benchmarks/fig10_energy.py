"""Fig. 10: energy breakdown (PU / memory / network) for DCRA-SRAM vs
DCRA-HBM-Horiz.  Expected: SRAM config (16x more tiles) spends more on
wires; HBM config is DRAM-energy dominated; PUs are a small fraction."""
from __future__ import annotations

import numpy as np

from common import MSG_BITS, dataset, row

from repro.core.costmodel import (DCRA_HBM_HORIZ, DCRA_SRAM,
                                  dcache_memory_bits, price)
from repro.core.proxy import ProxyConfig
from repro.core.tilegrid import square_grid
from repro.graph import apps


def run(small: bool = True):
    g = dataset(11)
    root = int(np.argmax(g.out_degree()))
    out = {}
    for name, pkg, tiles in (("dcra-sram", DCRA_SRAM, 1024),
                             ("dcra-hbm-horiz", DCRA_HBM_HORIZ, 64)):
        grid = square_grid(tiles if small else tiles * 16)
        px = ProxyConfig(max(grid.ny // 4, 2), max(grid.nx // 4, 2),
                         slots=512)
        r = apps.sssp(g, root, grid, proxy=px, oq_cap=32, pkg=pkg)
        touched = (r.run.counters.edges_processed * MSG_BITS
                   + r.run.counters.records_consumed * MSG_BITS)
        sram, hbm = dcache_memory_bits(pkg, touched)
        rep = price(pkg, grid, r.run.counters, mem_bits_sram=sram,
                    mem_bits_hbm=hbm,
                    per_superstep_peak=r.run.trace)
        tot = max(sum(v for k, v in rep.breakdown.items()
                      if k.endswith("_j")), 1e-12)
        pct = {k: 100 * v / tot for k, v in rep.breakdown.items()
               if k.endswith("_j")}
        out[name] = pct
        row(f"fig10/{name}", rep.energy_j * 1e6,
            ";".join(f"{k}={v:.1f}%" for k, v in pct.items()))
    return out


if __name__ == "__main__":
    run()
