"""Fig. 9: packaging options — throughput/$ and energy-efficiency/$.

Dalorex & DCRA-SRAM on the big grid; DCRA-HBM (horizontal / vertical)
on a 16x-smaller grid backed by HBM — same measured task stream, priced
under each package (die yield, interposer/substrate/bonding, $7.5/GB).
Expected shape (paper §V-C): SRAM-only wins throughput/$; +HBM wins
energy-eff/$; vertical HBM beats horizontal on energy (wire savings).
"""
from __future__ import annotations

import numpy as np

from common import MSG_BITS, dataset, row

from repro.core.costmodel import (DALOREX, DCRA_HBM_HORIZ, DCRA_HBM_VERT,
                                  DCRA_SRAM, dcache_memory_bits, price)
from repro.core.proxy import ProxyConfig
from repro.core.tilegrid import square_grid
from repro.graph import apps


def run(small: bool = True):
    # dataset big enough that the 16x-tile SRAM grid still strong-scales
    # (several vertices per tile at 64x64)
    g = dataset(15)
    root = int(np.argmax(g.out_degree()))
    # paper ratio: the SRAM product uses 16x the tiles (16 dies vs 1)
    big = square_grid(4096 if small else 16384)     # SRAM-parallelized
    tiny = square_grid(256 if small else 1024)      # HBM-backed, 16x fewer
    bits = float(g.footprint_bytes() * 8)

    def run_on(grid, pkg, proxy_div=4):
        px = ProxyConfig(max(grid.ny // proxy_div, 2),
                         max(grid.nx // proxy_div, 2), slots=512,
                         write_back=False)
        return apps.sssp(g, root, grid, proxy=px, oq_cap=32, pkg=pkg)

    r_big = run_on(big, DCRA_SRAM)
    r_dal = apps.sssp(g, root, big, proxy=None, oq_cap=32, pkg=DALOREX)
    r_tiny = run_on(tiny, DCRA_HBM_HORIZ)

    touched = (r_tiny.run.counters.edges_processed * MSG_BITS
               + r_tiny.run.counters.records_consumed * MSG_BITS)

    reports = {}
    reports["dalorex"] = price(DALOREX, big, r_dal.run.counters,
                               mem_bits_sram=bits,
                               per_superstep_peak=r_dal.run.trace)
    reports["dcra-sram"] = price(DCRA_SRAM, big, r_big.run.counters,
                                 mem_bits_sram=bits,
                                 per_superstep_peak=r_big.run.trace)
    for name, pkg in (("dcra-hbm-horiz", DCRA_HBM_HORIZ),
                      ("dcra-hbm-vert", DCRA_HBM_VERT)):
        # shared D$ policy; price() folds the HBM drain into the
        # per-superstep BSP max
        sram_bits, hbm_bits = dcache_memory_bits(pkg, touched)
        reports[name] = price(pkg, tiny, r_tiny.run.counters,
                              mem_bits_sram=sram_bits,
                              mem_bits_hbm=hbm_bits,
                              per_superstep_peak=r_tiny.run.trace)

    base = reports["dalorex"]
    out = {}
    for name, rep in reports.items():
        thr_per_usd = (1.0 / rep.time_s) / rep.cost_usd
        eff_per_usd = (1.0 / rep.energy_j) / rep.cost_usd
        out[name] = (thr_per_usd, eff_per_usd)
        row(f"fig9/{name}", rep.time_s * 1e6,
            f"thr_per_$_x={thr_per_usd/((1/base.time_s)/base.cost_usd):.2f};"
            f"eff_per_$_x={eff_per_usd/((1/base.energy_j)/base.cost_usd):.2f};"
            f"cost=${rep.cost_usd:.0f};power_w={rep.power_w:.1f}")
    return out


if __name__ == "__main__":
    run()
