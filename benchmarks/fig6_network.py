"""Fig. 6: network link-width options (a)-(d) vs runtime + PU utilization.

The paper evaluates four tapeout-time link configurations on a 64x64
grid; option (c) (64-bit intra-die, 2x32-bit inter-die) wins ~1.72x
geomean over (a).  We replay the engine's exact per-superstep traffic
under each option's bandwidth model.
"""
from __future__ import annotations

import numpy as np

from common import dataset, row, wiki

from repro.core.costmodel import NETWORK_OPTIONS
from repro.core.proxy import ProxyConfig
from repro.core.tilegrid import square_grid
from repro.graph import apps


def run(small: bool = True):
    # needs >= 2x2 dies: options (a)-(d) differ in INTER-DIE link width,
    # which a single-die grid never exercises
    grid = square_grid(1024 if small else 4096)  # 32x32 (64x64 at full)
    px = ProxyConfig(grid.ny // 2, grid.nx // 2, slots=256)
    g = dataset(12)
    gw = wiki(11)
    root = int(np.argmax(g.out_degree()))
    runs = {
        "bfs/rmat": lambda pkg: apps.bfs(g, root, grid, proxy=px,
                                         oq_cap=32, pkg=pkg),
        "sssp/rmat": lambda pkg: apps.sssp(g, root, grid, proxy=px,
                                           oq_cap=32, pkg=pkg),
        "histo/wiki": lambda pkg: apps.histogram(
            np.asarray(gw.col_idx) % (gw.n_rows // 8), gw.n_rows // 8,
            grid, proxy=ProxyConfig(grid.ny // 2, grid.nx // 2, slots=256,
                                    write_back=True), oq_cap=32, pkg=pkg),
    }
    geo = {}
    for app, fn in runs.items():
        base_t = None
        for okey, pkg in NETWORK_OPTIONS.items():
            r = fn(pkg)
            t = r.run.time_s
            if okey.startswith("a"):
                base_t = t
            speed = base_t / t if t else float("nan")
            geo.setdefault(okey, []).append(speed)
            row(f"fig6/{app}/{okey}", t * 1e6, f"speedup_vs_a={speed:.3f}")
    for okey, sp in geo.items():
        gm = float(np.exp(np.mean(np.log(sp))))
        row(f"fig6/geomean/{okey}", 0.0, f"speedup_vs_a={gm:.3f}")
    return geo


if __name__ == "__main__":
    run()
