"""Fig. 11: strong scaling — throughput, TEPS, on-chip memory bandwidth,
throughput/W and throughput/$ across grid sizes (paper: 256 -> 2^20
tiles; here 64 -> 4096 tiles at CPU-simulation scale, same trends:
superlinear region, then utilization decay from shrinking per-tile work;
throughput/W peaks at the smallest fitting config).

The spmv sweep also runs with a 2-level selective cascade: as the grid
grows, proxy-flush records cross more die boundaries on their way to the
owners, and the region reduction tree combines them level-by-level — the
cross-chip (inter-die) traffic reduction widens with grid size, which is
what lets the paper scale to 256 chips / a million PUs."""
from __future__ import annotations

import numpy as np

from common import SCALE, dataset, row

from repro.core.costmodel import DCRA_SRAM, price
from repro.core.netstats import MSG_BITS as _MB
from repro.core.proxy import ProxyConfig
from repro.core.tilegrid import square_grid
from repro.graph import apps


def run(small: bool = True):
    g = dataset(12)
    root = int(np.argmax(g.out_degree()))
    x = np.random.default_rng(0).random(g.n_cols).astype(np.float32)
    sizes = (64, 256, 1024) if small else (256, 1024, 4096, 16384)
    out = {}
    for app_name, fn in {
        "bfs": lambda grid, px: apps.bfs(g, root, grid, proxy=px,
                                         oq_cap=32),
        "spmv": lambda grid, px: apps.spmv(
            g, x, grid, proxy=apps.table2_proxy(grid, "spmv"), oq_cap=32),
        "spmv_cascade": lambda grid, px: apps.spmv(
            g, x, grid,
            proxy=apps.table2_proxy(grid, "spmv", cascade_levels=2),
            oq_cap=32),
    }.items():
        for n_tiles in sizes:
            grid = square_grid(n_tiles)
            px = ProxyConfig(max(grid.ny // 4, 2), max(grid.nx // 4, 2),
                             slots=512)
            r = fn(grid, px)
            t = r.run.time_s
            gteps = r.gteps
            ops = (r.run.counters.edges_processed
                   + r.run.counters.records_consumed)
            thr = ops / t
            membw = (ops * 64 + r.run.counters.hop_msgs * _MB) / t / 8
            bits = float(g.footprint_bytes() * 8)
            rep = price(DCRA_SRAM, grid, r.run.counters,
                        mem_bits_sram=bits,
                        per_superstep_peak=dict(time_s=t))
            out[(app_name, n_tiles)] = dict(
                gteps=gteps, thr=thr,
                xregion=r.run.counters.cross_region_msgs,
                die_x=r.run.counters.inter_die_crossings)
            row(f"fig11/{app_name}/{n_tiles}tiles", t * 1e6,
                f"gteps={gteps:.3f};ops_per_s={thr:.3g};"
                f"membw_GBs={membw/1e9:.2f};"
                f"thr_per_w={thr/max(rep.power_w,1e-9):.3g};"
                f"thr_per_$={thr/rep.cost_usd:.3g};"
                f"xregion={r.run.counters.cross_region_msgs:.0f};"
                f"die_crossings={r.run.counters.inter_die_crossings:.0f}")
    return out


if __name__ == "__main__":
    run()
