"""Fig. 11: strong scaling — throughput, TEPS, on-chip memory bandwidth,
throughput/W and throughput/$ across grid sizes (paper: 256 -> 2^20
tiles; here 64 -> 4096 tiles at CPU-simulation scale, same trends:
superlinear region, then utilization decay from shrinking per-tile work;
throughput/W peaks at the smallest fitting config).

The spmv sweep also runs with a 2-level selective cascade: as the grid
grows, proxy-flush records cross more die boundaries on their way to the
owners, and the region reduction tree combines them level-by-level — the
cross-chip (inter-die) traffic reduction widens with grid size, which is
what lets the paper scale to 256 chips / a million PUs.

With ``--chips N`` (or ``run(chips=N)``) every sweep point additionally
executes on the distributed runtime partitioned into N chips: measured
multi-chip rows carry the off-chip traffic and its energy share next to
the monolithic numbers.
"""
from __future__ import annotations

import numpy as np

from common import SCALE, dataset, row

from repro.core.costmodel import DCRA_SRAM, price
from repro.core.netstats import MSG_BITS as _MB
from repro.core.proxy import ProxyConfig
from repro.core.tilegrid import partition_grid, square_grid
from repro.graph import apps


def _partitionable(grid, chips: int) -> bool:
    try:
        partition_grid(grid, chips)
        return True
    except ValueError:
        return False


def run(small: bool = True, chips: int = 0):
    g = dataset(12)
    root = int(np.argmax(g.out_degree()))
    x = np.random.default_rng(0).random(g.n_cols).astype(np.float32)
    sizes = (64, 256, 1024) if small else (256, 1024, 4096, 16384)
    out = {}
    for app_name, fn in {
        "bfs": lambda grid, px, **kw: apps.bfs(g, root, grid, proxy=px,
                                               oq_cap=32, **kw),
        "spmv": lambda grid, px, **kw: apps.spmv(
            g, x, grid, proxy=apps.table2_proxy(grid, "spmv"), oq_cap=32,
            **kw),
        "spmv_cascade": lambda grid, px, **kw: apps.spmv(
            g, x, grid,
            proxy=apps.table2_proxy(grid, "spmv", cascade_levels=2),
            oq_cap=32, **kw),
    }.items():
        for n_tiles in sizes:
            grid = square_grid(n_tiles)
            px = ProxyConfig(max(grid.ny // 4, 2), max(grid.nx // 4, 2),
                             slots=512)
            variants = [("", {})]
            if chips and chips > 1:
                if _partitionable(grid, chips):
                    variants.append((f"/{chips}chips", dict(chips=chips)))
                else:
                    print(f"# fig11: skipped {app_name}/{n_tiles}tiles at "
                          f"{chips} chips (does not partition the grid)",
                          flush=True)
            for suffix, kw in variants:
                r = fn(grid, px, **kw)
                t = r.run.time_s
                gteps = r.gteps
                ops = (r.run.counters.edges_processed
                       + r.run.counters.records_consumed)
                thr = ops / t
                membw = (ops * _MB + r.run.counters.hop_msgs * _MB) / t / 8
                bits = float(g.footprint_bytes() * 8)
                rep = price(DCRA_SRAM, grid, r.run.counters,
                            mem_bits_sram=bits,
                            per_superstep_peak=r.run.trace)
                out[(app_name + suffix, n_tiles)] = dict(
                    gteps=gteps, thr=thr,
                    xregion=r.run.counters.cross_region_msgs,
                    die_x=r.run.counters.inter_die_crossings,
                    off_chip=r.run.counters.off_chip_msgs)
                row(f"fig11/{app_name}{suffix}/{n_tiles}tiles", t * 1e6,
                    f"gteps={gteps:.3f};ops_per_s={thr:.3g};"
                    f"membw_GBs={membw/1e9:.2f};"
                    f"thr_per_w={thr/max(rep.power_w,1e-9):.3g};"
                    f"thr_per_$={thr/rep.cost_usd:.3g};"
                    f"xregion={r.run.counters.cross_region_msgs:.0f};"
                    f"die_crossings={r.run.counters.inter_die_crossings:.0f};"
                    f"off_chip_msgs={r.run.counters.off_chip_msgs:.0f};"
                    f"off_chip_j={rep.breakdown['off_chip_j']:.3e}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=int, default=0,
                    help="also run each point on the distributed runtime "
                         "partitioned into this many chips")
    ap.add_argument("--full", action="store_true")
    a = ap.parse_args()
    run(small=not a.full, chips=a.chips)
