"""Benchmark harness driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  REPRO_BENCH_SCALE=k bumps
dataset/grid sizes for longer runs.
"""
from __future__ import annotations

import os
import sys
import time
import traceback

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MODULES = [
    ("engine_throughput", "Run-loop throughput: chunked vs legacy loop"),
    ("fig6_network", "Fig. 6  network link-width options"),
    ("fig7_queues", "Fig. 7  IQ:OQ ratio (Goldilocks)"),
    ("fig8_proxy", "Fig. 8  proxies vs Dalorex"),
    ("fig9_packaging", "Fig. 9  packaging: thr/$ & eff/$"),
    ("fig10_energy", "Fig. 10 energy breakdown"),
    ("fig11_scaling", "Fig. 11 strong scaling"),
    ("product_search", "Package-time product search (measure-once/price-many)"),
    ("multichip_scaling", "Multi-chip weak/strong scaling (distributed)"),
    ("graph500_compare", "Graph500 BFS accounting + measured multi-chip"),
    ("kernels_bench", "Pallas kernel microbench"),
    ("roofline", "Roofline terms from dry-run artifacts"),
]


def main() -> None:
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    for mod_name, desc in MODULES:
        print(f"# === {mod_name}: {desc} ===", flush=True)
        try:
            mod = __import__(mod_name)
            mod.run(small=True)
        except Exception as e:
            failures += 1
            print(f"# FAILED {mod_name}: {type(e).__name__}: {e}",
                  flush=True)
            traceback.print_exc()
    print(f"# total {time.time()-t0:.1f}s, failures={failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
