"""Benchmark harness driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes
``BENCH_manifest.json`` (benchmark name → status / wall time / output
file, plus the git SHA) so the bench trajectory is machine-readable
across PRs.  ``--trace out.json`` instead exports a BFS 4-chip telemetry
run as Chrome trace-event JSON (load it in chrome://tracing or
ui.perfetto.dev) plus the markdown+JSON run report next to it.
REPRO_BENCH_SCALE=k bumps dataset/grid sizes for longer runs.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
import traceback

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MODULES = [
    ("engine_throughput", "Run-loop throughput: chunked vs legacy loop"),
    ("fig6_network", "Fig. 6  network link-width options"),
    ("fig7_queues", "Fig. 7  IQ:OQ ratio (Goldilocks)"),
    ("fig8_proxy", "Fig. 8  proxies vs Dalorex"),
    ("fig9_packaging", "Fig. 9  packaging: thr/$ & eff/$"),
    ("fig10_energy", "Fig. 10 energy breakdown"),
    ("fig11_scaling", "Fig. 11 strong scaling"),
    ("product_search", "Package-time product search (measure-once/price-many)"),
    ("multichip_scaling", "Multi-chip weak/strong scaling (distributed)"),
    ("graph500_compare", "Graph500 BFS accounting + measured multi-chip"),
    ("kernels_bench", "Pallas kernel microbench"),
    ("roofline", "Roofline terms from dry-run artifacts"),
]

MANIFEST_OUT = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_manifest.json")


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(__file__), timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def export_trace(trace_path: str, report_stem: str | None = None) -> None:
    """The ``--trace`` CLI path: run BFS 4-chip chunked with telemetry on
    the RMAT test graph, export the Chrome trace-event JSON and the run
    report (same artifacts the tier1 CI smoke step uploads)."""
    import numpy as np

    from repro import obs
    from repro.core.tilegrid import square_grid
    from repro.graph import apps, rmat_edges

    grid = square_grid(64)
    g = rmat_edges(8, edge_factor=8, seed=1)
    root = int(np.argmax(g.out_degree()))
    rec = obs.TimelineRecorder()
    baseline = apps.bfs(g, root, grid, oq_cap=16, run_chunk=8, chips=4)
    r = apps.bfs(g, root, grid,
                 proxy=apps.table2_proxy(grid, "bfs", cascade_levels=2,
                                         selective=False),
                 oq_cap=16, run_chunk=8, chips=4, telemetry=True,
                 observer=rec)
    out_dir = os.path.dirname(os.path.abspath(trace_path))
    os.makedirs(out_dir, exist_ok=True)
    obs.write_trace(rec, trace_path)
    stem = report_stem or os.path.splitext(trace_path)[0] + "_report"
    paths = obs.write_report(
        obs.run_report(rec, teps_edges=r.teps_edges,
                       baseline_counters=baseline.run.counters), stem)
    print(f"# trace: {trace_path}")
    print(f"# report: {paths['json']} {paths['markdown']}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", metavar="OUT_JSON",
                    help="export a BFS 4-chip telemetry trace "
                         "(Chrome trace-event JSON) + run report and exit")
    ap.add_argument("--report-stem", default=None,
                    help="with --trace: write the run report at this stem "
                         "(default: alongside the trace)")
    ap.add_argument("--manifest", default=MANIFEST_OUT,
                    help="where to write BENCH_manifest.json")
    args = ap.parse_args(argv)
    if args.trace:
        export_trace(args.trace, args.report_stem)
        return

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    manifest = dict(git_sha=_git_sha(), benchmarks={})
    for mod_name, desc in MODULES:
        print(f"# === {mod_name}: {desc} ===", flush=True)
        m0 = time.time()
        entry = dict(description=desc, status="ok")
        try:
            mod = __import__(mod_name)
            mod.run(small=True)
            out = getattr(mod, "DEFAULT_OUT", None)
            if out:
                entry["output"] = os.path.relpath(
                    os.path.abspath(out),
                    os.path.dirname(os.path.abspath(args.manifest)))
        except Exception as e:
            failures += 1
            entry["status"] = f"failed: {type(e).__name__}: {e}"
            print(f"# FAILED {mod_name}: {type(e).__name__}: {e}",
                  flush=True)
            traceback.print_exc()
        entry["wall_s"] = round(time.time() - m0, 3)
        manifest["benchmarks"][mod_name] = entry
    manifest["wall_s"] = round(time.time() - t0, 3)
    with open(args.manifest, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# manifest: {args.manifest}")
    print(f"# total {time.time()-t0:.1f}s, failures={failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
