"""Roofline report: aggregates artifacts/dryrun/*.json into the
EXPERIMENTS.md table (single-pod terms per arch x shape; dominant term;
MODEL_FLOPS/HLO_FLOPs ratio)."""
from __future__ import annotations

import glob
import json
import os

from common import row

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(mesh="single"):
    cells = {}
    for path in sorted(glob.glob(os.path.join(ART, f"*_{mesh}.json"))):
        art = json.load(open(path))
        if art.get("status") != "ok":
            continue
        cells[(art["arch"], art["shape"])] = art
    return cells


def run(small: bool = True):
    cells = load("single")
    if not cells:
        row("roofline/missing", 0.0, "run repro.launch.dryrun --all first")
        return {}
    for (arch, shape), art in sorted(cells.items()):
        t = art["roofline_terms_s"]
        bound = max(t, key=t.get)
        frac = art["useful_flops_ratio"]
        row(f"roofline/{arch}/{shape}", t[bound] * 1e6,
            f"dom={bound};compute_s={t['compute_s']:.4g};"
            f"memory_s={t['memory_s']:.4g};"
            f"collective_s={t['collective_s']:.4g};"
            f"useful_flops={frac:.3f};"
            f"coll_bytes={art['collectives']['total_bytes']:.3g}")
    # summary: worst cells by each criterion (the hillclimb shortlist)
    def ratio(a):
        t = a["roofline_terms_s"]
        dom = max(t.values())
        return t["compute_s"] / max(dom, 1e-12)

    worst = min(cells.items(), key=lambda kv: ratio(kv[1]))
    collbound = max(cells.items(),
                    key=lambda kv: kv[1]["roofline_terms_s"]["collective_s"]
                    / max(max(kv[1]["roofline_terms_s"].values()), 1e-12))
    row("roofline/worst_fraction", 0.0,
        f"{worst[0][0]}/{worst[0][1]}")
    row("roofline/most_collective_bound", 0.0,
        f"{collbound[0][0]}/{collbound[0][1]}")
    return cells


if __name__ == "__main__":
    run()
