"""Fault-tolerance recovery benchmark: chip loss, rollback, re-shard.

Each row runs one app on the distributed engine twice — unfailed, then
with a :class:`repro.runtime.fault.FaultInjector` dropping a chip
mid-run — with superstep checkpointing on a cadence
(``EngineConfig.ckpt_every_supersteps``).  Recorded per row:

  * ``recovery_equal`` — the PR's core guarantee, asserted: final
    values, TrafficCounters, superstep count and every SuperstepTrace
    vector of the recovered run are **bit-identical** to the unfailed
    run's.
  * ``reprice_ratio`` — ``costmodel.trace_time_s`` of the faulted
    run's trace divided by its measured ``time_s``.  Exactly 1.0: the
    recovery overhead legs (checkpoint writes, the discarded replay
    window, the re-shard restore) are priced from
    ``trace.recovery_events`` with the same shared helpers the run
    loop's separate overhead accumulator used.
  * ``overhead_cycles`` / ``overhead_frac`` — the simulated cost of
    fault tolerance (faulted minus unfailed cycles), deterministic f64.
  * ``recovery_wall_s`` — host wall-clock the failure cost (faulted
    minus unfailed run wall), dominated by the mesh rebuild/recompile;
    noisy on CI, gated ratio-only.
  * ``n_checkpoints`` / ``n_rollbacks`` / ``ckpt_image_bits`` — event
    log shape.

Rows sweep checkpoint cadence and chip count (4- and 16-chip
partitions of a 64-tile grid), plus one legacy-dispatch (``chunk=0``)
row.  Emits BENCH_recovery.json; --smoke runs two tiny configs,
asserts the bit-identity and exact-reprice contracts, and still writes
the JSON (scripts/bench_check.py gates it against the committed copy).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from common import row, timed  # noqa: F401  (path bootstrap)

import numpy as np

from repro.core.costmodel import trace_time_s
from repro.core.netstats import SuperstepTrace
from repro.core.tilegrid import square_grid
from repro.graph import rmat_edges
from repro.graph.apps import engine_and_state
from repro.runtime import FaultInjector

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_recovery.json")

# (app, scale, tiles, chips, oq_cap, chunk, ckpt_every, at_superstep, chip)
CONFIGS = [
    ("bfs", 9, 64, 4, 16, 8, 2, 5, 1),
    ("bfs", 9, 64, 4, 16, 8, 5, 7, 3),
    ("bfs", 9, 64, 16, 16, 8, 2, 5, 9),
    ("bfs", 9, 64, 16, 16, 8, 5, 7, 14),
    ("bfs", 9, 64, 4, 16, 0, 3, 5, 2),      # legacy per-step dispatch
    ("sssp", 9, 64, 4, 16, 8, 3, 5, 0),
    ("pagerank", 9, 64, 4, 16, 8, 3, 4, 2),
]
SMOKE_CONFIGS = [
    ("bfs", 8, 16, 4, 16, 8, 3, 4, 1),
    ("bfs", 8, 16, 4, 16, 0, 3, 4, 2),
]


def _engines(app, g, grid, chips, oq_cap, ckpt_every):
    kw = dict(chips=chips, oq_cap=oq_cap,
              ckpt_every_supersteps=ckpt_every)
    if app in ("bfs", "sssp"):
        kw["root"] = int(np.argmax(g.out_degree()))
    eng, state, _ = engine_and_state(app, g, grid, **kw)
    return eng, state


def bench_recovery(app, scale, tiles, chips, oq_cap, chunk, ckpt_every,
                   at_superstep, chip) -> dict:
    g = rmat_edges(scale, edge_factor=8, seed=1)
    grid = square_grid(tiles)

    eng, state = _engines(app, g, grid, chips, oq_cap, ckpt_every)
    t0 = time.time()
    base_state, base = eng.run(dict(state), chunk=chunk)
    wall_unfailed = time.time() - t0

    eng2, state2 = _engines(app, g, grid, chips, oq_cap, ckpt_every)
    inj = FaultInjector(at_superstep=at_superstep, chip=chip)
    t0 = time.time()
    f_state, f = eng2.run(dict(state2), chunk=chunk, fault_injector=inj)
    wall_faulted = time.time() - t0
    assert inj.fired, (app, at_superstep, base.supersteps)

    recovery_equal = bool(
        np.array_equal(base_state["values"], f_state["values"])
        and base.counters.as_dict() == f.counters.as_dict()
        and base.supersteps == f.supersteps
        and all(getattr(base.trace, k) == getattr(f.trace, k)
                for k in SuperstepTrace._VECTOR_FIELDS))
    assert recovery_equal, f"recovery not bit-identical: {app}"
    reprice = trace_time_s(eng2.cfg.pkg, grid, f.trace) / f.time_s
    events = f.trace.recovery_events
    ckpts = [e for e in events if e["kind"] == "checkpoint"]
    r = dict(app=app, tiles=tiles, scale=scale, chips=chips,
             oq_cap=oq_cap, chunk=chunk, ckpt_every=ckpt_every,
             at_superstep=at_superstep, lost_chip=chip,
             supersteps=int(base.supersteps),
             recovery_equal=recovery_equal,
             reprice_ratio=float(reprice),
             overhead_cycles=float(f.cycles - base.cycles),
             overhead_frac=float((f.cycles - base.cycles)
                                 / max(base.cycles, 1e-12)),
             wall_s_unfailed=wall_unfailed, wall_s_faulted=wall_faulted,
             recovery_wall_s=max(wall_faulted - wall_unfailed, 0.0),
             n_checkpoints=len(ckpts),
             n_rollbacks=sum(1 for e in events
                             if e["kind"] == "rollback"),
             ckpt_image_bits=float(ckpts[0]["bits"]) if ckpts else 0.0)
    print(f"# {app}/{chips}chips/chunk{chunk}/every{ckpt_every}: "
          f"steps={r['supersteps']} equal={recovery_equal} "
          f"reprice={reprice!r} overhead={r['overhead_frac']*100:.2f}% "
          f"recovery_wall={r['recovery_wall_s']*1e3:.0f}ms", flush=True)
    return r


def run(small: bool = True, out_path: str = DEFAULT_OUT) -> list:
    # smoke rows ride along so the committed baseline contains the rows
    # CI regenerates (bench_check compares the smoke subset by row key)
    rows = [bench_recovery(*c) for c in CONFIGS + SMOKE_CONFIGS]
    _write(rows, out_path)
    return rows


def smoke(out_path: str = DEFAULT_OUT) -> None:
    """CI gate: tiny configs, asserts the recovery contracts, writes
    the JSON artifact."""
    rows = [bench_recovery(*c) for c in SMOKE_CONFIGS]
    for r in rows:
        assert r["recovery_equal"]
        assert r["reprice_ratio"] == 1.0, r["reprice_ratio"]
        assert r["n_rollbacks"] >= 1
    _write(rows, out_path)
    print(f"# smoke OK -> {out_path}")


def _write(rows: list, out_path: str) -> None:
    payload = dict(
        benchmark="recovery",
        description="chip-loss recovery: superstep checkpoint/rollback "
                    "+ re-shard onto survivors; recovered runs are "
                    "bit-identical and reprice exactly",
        rows=rows,
        all_recovery_equal=all(r["recovery_equal"] for r in rows),
        all_reprice_exact=all(r["reprice_ratio"] == 1.0 for r in rows),
        max_overhead_frac=max(r["overhead_frac"] for r in rows),
        note="overhead_cycles/reprice_ratio are deterministic f64 "
             "(simulated BSP time); recovery_wall_s is host wall clock "
             "dominated by the post-loss mesh rebuild + recompile and "
             "is gated ratio-only.",
    )
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {out_path} (max overhead "
          f"{payload['max_overhead_frac']*100:.2f}%)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs + contract asserts")
    ap.add_argument("--full", action="store_true",
                    help="(alias of the default row set)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.smoke:
        smoke(args.out)
    else:
        run(small=not args.full, out_path=args.out)
