"""Fig. 7: IQ:OQ size-ratio sweep (the Goldilocks effect).

In our TPU rendering the mailbox coalesces records on arrival, so the
contention-relief side of the paper's curve is flattened by design (the
paper's FIFO IQs only coalesce at the P$); the sweep exposes the
*staleness* side — larger IQ budgets admit more stale values per
superstep, growing wasted re-expansions (EXPERIMENTS.md §Paper-validation
discusses the deviation)."""
from __future__ import annotations

import numpy as np

from common import dataset, row

from repro.core.tilegrid import square_grid
from repro.graph import apps


def run(small: bool = True):
    # the IQ budget must actually bind: several owned items per tile and
    # a small OQ so message bursts queue at the endpoints
    grid = square_grid(256 if small else 4096)
    g = dataset(13)
    root = int(np.argmax(g.out_degree()))
    x = np.random.default_rng(0).random(g.n_cols).astype(np.float32)
    out = {}
    for app, fn in {
        "sssp": lambda r: apps.sssp(g, root, grid, oq_cap=4, iq_ratio=r),
        "bfs": lambda r: apps.bfs(g, root, grid, oq_cap=4, iq_ratio=r),
        "spmv": lambda r: apps.spmv(g, x, grid, oq_cap=4, iq_ratio=r),
    }.items():
        base = None
        for ratio in (1, 2, 4, 8, 16):
            r = fn(ratio)
            t = r.run.time_s
            if ratio == 1:
                base = t
            imp = base / t
            out[(app, ratio)] = imp
            row(f"fig7/{app}/iq_ratio={ratio}", t * 1e6,
                f"improvement={imp:.3f};supersteps={r.run.supersteps};"
                f"wasted_work={r.run.counters.records_consumed:.0f}")
    return out


if __name__ == "__main__":
    run()
