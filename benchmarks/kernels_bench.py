"""Kernel micro-benchmarks (interpret-mode timings are NOT TPU perf —
they validate plumbing; the structural figure of merit is bytes/FLOPs per
block from the BlockSpec tiling, reported as derived columns)."""
from __future__ import annotations

import numpy as np

from common import row, timed

import jax.numpy as jnp

from repro.kernels import ops


def run(small: bool = True):
    rng = np.random.default_rng(0)
    n = 1 << 14
    bins = 1024
    idx = jnp.asarray(rng.integers(0, bins, n).astype(np.int32))
    _, us = timed(lambda: np.asarray(ops.histogram(idx, bins)))
    # VMEM working set per grid step: block_r idx + block_b partials
    row("kernels/histogram", us,
        f"n={n};bins={bins};vmem_block_bytes={1024*4 + 512*4}")

    v = jnp.asarray(rng.random(n).astype(np.float32))
    m = jnp.asarray(rng.random(n).astype(np.float32))
    f = jnp.asarray(rng.random(n) < 0.5)
    _, us = timed(lambda: [np.asarray(x) for x in
                           ops.relax(v, m, f, combine="min")])
    row("kernels/relax_min", us, f"n={n};streams=3x{2048*4}B")

    seg = jnp.asarray(rng.integers(0, 512, n).astype(np.int32))
    _, us = timed(lambda: np.asarray(
        ops.segment_combine(seg, v, 512, combine="add")))
    row("kernels/segment_combine", us, f"n={n};segments=512")

    from repro.graph import rmat_edges
    g = rmat_edges(9, edge_factor=8, seed=3)
    mat = ops.bcsr_from_csr(g.row_ptr, g.col_idx, g.weights,
                            (g.n_rows, g.n_cols), bm=64, bk=64)
    x = jnp.asarray(rng.random(g.n_cols).astype(np.float32))
    _, us = timed(lambda: np.asarray(ops.spmv(mat, x)))
    density = g.nnz / (g.n_rows * g.n_cols)
    row("kernels/spmv_bcsr", us,
        f"nnz={g.nnz};kmax={mat.kmax};density={density:.4f};"
        f"mxu_tile=64x64")

    b, h, hkv, s, d = 2, 8, 2, 2048, 64
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.bfloat16)
    vv = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.bfloat16)
    lens = jnp.full((b,), s, jnp.int32)
    _, us = timed(lambda: np.asarray(
        ops.decode_attention(q, k, vv, lens, block_s=512)))
    row("kernels/decode_attention", us,
        f"S={s};kv_block_bytes={512*d*2*2};flash_decode=1")
    return True


if __name__ == "__main__":
    run()
