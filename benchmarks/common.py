"""Shared benchmark utilities: dataset prep, timing, CSV rows."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                    # noqa: E402

from repro.core.costmodel import D_CACHE_HIT          # noqa: E402,F401
from repro.core.netstats import MSG_BITS              # noqa: E402,F401

SCALE = int(os.environ.get("REPRO_BENCH_SCALE", "1"))


def dataset(scale_exp: int = 11, edge_factor: int = 8, seed: int = 1):
    """RMAT graph at benchmark scale (env REPRO_BENCH_SCALE bumps it)."""
    from repro.graph import rmat_edges
    return rmat_edges(scale_exp + (SCALE - 1), edge_factor=edge_factor,
                      seed=seed)


def wiki(scale: int = 12):
    from repro.graph import wikipedia_like
    return wikipedia_like(n=1 << (scale + (SCALE - 1)), avg_deg=16)


def row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    us = (time.time() - t0) * 1e6
    from repro.obs import default_registry
    name = getattr(fn, "__name__", "call")
    default_registry().histogram(f"bench.{name}.us").observe(us)
    return out, us
