"""Multi-chip weak + strong scaling on the distributed runtime.

The paper's Fig. 11 multi-package regime, measured: the tile grid is
partitioned across 1..256 emulated chips (``repro.distrib``), each chip
runs its own engine supersteps, boundary mailbox records ride the
off-chip network leg, and GTEPS / energy / $ come from the measured
traffic — including the off-chip share (OFF_PKG_PJ_BIT per board hop,
IO-die latency in the BSP time).

  weak:   constant tiles + dataset per chip (the Graph500 experiment
          shape) — the GTEPS curve should grow monotonically with chips;
  strong: fixed grid + dataset re-partitioned across more chips — what
          the chip boundary costs at constant total work.
"""
from __future__ import annotations

from common import SCALE, row

from repro.distrib import harness


def _emit(kind, rows):
    for m in rows:
        # re-pricing cross-check: the analytic board-level pricing of the
        # measured trace must match the directly measured N-chip run
        assert abs(m["reprice_ratio"] - 1.0) < 1e-9, \
            (kind, m["chips"], m["reprice_time_s"], m["time_s"])
        row(f"multichip/{kind}/{m['chips']}chips", m["time_s"] * 1e6,
            f"gteps={m['gteps']:.3f};tiles={m['tiles']};"
            f"vertices={m['n_vertices']};supersteps={m['supersteps']};"
            f"off_chip_msgs={m['off_chip_msgs']:.0f};"
            f"off_chip_hops={m['off_chip_hop_msgs']:.0f};"
            f"off_chip_j={m['off_chip_j']:.3e};energy_j={m['energy_j']:.3e};"
            f"cost_usd={m['cost_usd']:.0f};"
            f"gteps_per_w={m['gteps_per_w']:.3g};"
            f"gteps_per_usd={m['gteps_per_usd']:.3g};"
            f"reprice_ratio={m['reprice_ratio']:.12f}")


def run(small: bool = True, chips=None, double_buffer: bool = False):
    counts = tuple(chips) if chips else (
        (1, 4, 16, 64) if small else (1, 4, 16, 64, 256))
    tag = "-db" if double_buffer else ""
    weak = harness.weak_scaling(chip_counts=counts,
                                tiles_per_chip=16 if small else 64,
                                base_scale=6 if small else 8,
                                double_buffer=double_buffer)
    _emit(f"weak{tag}", weak)
    strong = harness.strong_scaling(
        chip_counts=tuple(c for c in counts if c <= 64),
        n_tiles=256 if small else 4096, scale=9 if small else 12,
        double_buffer=double_buffer)
    _emit(f"strong{tag}", strong)
    return dict(weak=weak, strong=strong)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=str, default=None,
                    help="comma-separated chip counts (e.g. 1,4,16,64,256)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--double-buffer", action="store_true",
                    help="overlap each boundary exchange with the next "
                         "superstep's compute (same counters, lower BSP "
                         "time)")
    a = ap.parse_args()
    counts = tuple(int(c) for c in a.chips.split(",")) if a.chips else None
    run(small=not a.full, chips=counts, double_buffer=a.double_buffer)
