"""Synthetic data sources.

zipf_tokens: heavy-tailed token stream (Zipf ids mirror the hot-vertex
skew the paper's proxies exploit — hot token ids concentrate embedding
gradient traffic exactly like hot vertices concentrate updates).

SyntheticLM: deterministic, seekable LM batch source with a learnable
structure (order-2 mixture) so a ~100M model's loss demonstrably drops.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def zipf_tokens(rng: np.random.Generator, vocab: int, shape,
                alpha: float = 1.2) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    return rng.choice(vocab, size=shape, p=probs).astype(np.int32)


@dataclasses.dataclass
class SyntheticLM:
    """Order-2 synthetic language: token t depends on (t-1, t-2) through a
    fixed random hash, with Zipf unigram noise.  Deterministic per
    (seed, step) — restart-safe without data-state checkpointing."""

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    noise: float = 0.1
    d_model: int = 0            # >0 => also emit stub 'embeds'

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        b, s, v = self.batch, self.seq_len, self.vocab
        mix = rng.integers(0, v, size=(b, 2)).astype(np.int64)
        toks = np.zeros((b, s + 1), np.int64)
        toks[:, 0], toks[:, 1] = mix[:, 0], mix[:, 1]
        c1, c2, c3 = 1000003, 10007, 101
        for t in range(2, s + 1):
            det = (toks[:, t - 1] * c1 + toks[:, t - 2] * c2 + c3) % v
            noise = zipf_tokens(rng, v, (b,))
            pick = rng.random(b) < self.noise
            toks[:, t] = np.where(pick, noise, det)
        out = dict(tokens=toks[:, :-1].astype(np.int32),
                   labels=toks[:, 1:].astype(np.int32))
        if self.d_model:
            out["embeds"] = rng.standard_normal(
                (b, s, self.d_model)).astype(np.float32)
        return out
