"""Host -> device data pipeline: sharded placement + background prefetch.

Batches are laid out over the mesh's batch axes with NamedSharding; a
single background thread keeps ``prefetch`` batches in flight so host
generation overlaps device compute (the standard input-pipeline overlap).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def shard_batch(batch: dict, mesh: Mesh, batch_axes=("data",)):
    """Place a host batch onto the mesh, sharded over batch_axes."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def put(x):
        spec = P(axes) if x.ndim >= 1 else P()
        return jax.device_put(x, NamedSharding(mesh, spec))

    return {k: put(np.asarray(v)) for k, v in batch.items()}


class DataPipeline:
    def __init__(self, source, mesh: Optional[Mesh] = None,
                 batch_axes=("data",), prefetch: int = 2,
                 start_step: int = 0):
        self.source = source
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            if self.mesh is not None:
                batch = shard_batch(batch, self.mesh, self.batch_axes)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self):
        self._stop.set()
