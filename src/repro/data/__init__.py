from .synthetic import SyntheticLM, zipf_tokens
from .pipeline import DataPipeline, shard_batch

__all__ = ["SyntheticLM", "zipf_tokens", "DataPipeline", "shard_batch"]
