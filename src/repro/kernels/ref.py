"""Pure-jnp oracles for every Pallas kernel (ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def histogram_ref(idx, num_bins: int):
    idx = jnp.asarray(idx)
    ok = idx >= 0
    safe = jnp.where(ok, idx, num_bins)
    return jnp.zeros((num_bins + 1,), jnp.float32).at[safe].add(
        ok.astype(jnp.float32))[:num_bins]


def relax_ref(values, mail_val, mail_flag, combine: str = "min"):
    v = jnp.asarray(values, jnp.float32)
    m = jnp.asarray(mail_val, jnp.float32)
    f = jnp.asarray(mail_flag) != 0
    if combine == "min":
        imp = f & (m < v)
        return jnp.where(imp, m, v), imp.astype(jnp.int8)
    return jnp.where(f, v + m, v), f.astype(jnp.int8)


def segment_combine_ref(seg, val, num_segments: int, combine: str = "min"):
    seg = jnp.asarray(seg)
    val = jnp.asarray(val, jnp.float32)
    ok = seg >= 0
    safe = jnp.where(ok, seg, num_segments)
    if combine == "min":
        out = jnp.full((num_segments + 1,), jnp.inf, jnp.float32)
        out = out.at[safe].min(jnp.where(ok, val, jnp.inf))
    else:
        out = jnp.zeros((num_segments + 1,), jnp.float32)
        out = out.at[safe].add(jnp.where(ok, val, 0.0))
    return out[:num_segments]


def spmv_ref_csr(row_ptr, col_idx, weights, x):
    """CSR oracle in numpy (matches spmv_bcsr through the BCSR conversion)."""
    row_ptr = np.asarray(row_ptr)
    col_idx = np.asarray(col_idx)
    n = row_ptr.shape[0] - 1
    w = (np.ones_like(col_idx, np.float32) if weights is None
         else np.asarray(weights, np.float32))
    x = np.asarray(x, np.float32)
    src = np.repeat(np.arange(n), np.diff(row_ptr))
    y = np.zeros(n, np.float32)
    np.add.at(y, src, w * x[col_idx])
    return y


def decode_attention_ref(q, k, v, lengths, scale=None):
    q = jnp.asarray(q, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    b, h, d = q.shape
    _, hkv, s, _ = k.shape
    group = h // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kq = jnp.repeat(k, group, axis=1)           # (B, H, S, D)
    vq = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhd,bhsd->bhs", q, kq) * scale
    pos = jnp.arange(s)[None, None, :]
    mask = pos < jnp.asarray(lengths)[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, vq)



