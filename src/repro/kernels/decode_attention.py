"""Flash-decode GQA attention kernel — the serving-side hot spot.

One new query token attends to a long KV cache (decode_32k / long_500k
shapes).  Grid is (batch, q_head, kv_block); the KV sequence dim is the
innermost (sequential) grid axis, and the online-softmax running state
(max, denominator, weighted accumulator) lives in VMEM scratch that
persists across the kv-block revisits of the same (b, h) output block.
Lengths are scalar-prefetched and mask the tail block.

Block sizing: a (block_s, d) KV tile at d=128, block_s=512 is 256 KiB of
bf16 in VMEM for K plus the same for V — comfortably double-buffered
inside the ~16 MiB v5e VMEM while the MXU computes (block_s,d)@(d,) dots.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
_NEG = -1e30   # python float literal (jnp constants would be captured)


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, block_s: int, scale: float):
    b = pl.program_id(0)
    s = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[0, 0] = _NEG
        l_ref[0, 0] = 0.0

    q = q_ref[0, 0].astype(jnp.float32)                    # (d,)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bS, d)
    v = v_ref[0, 0].astype(jnp.float32)                    # (bS, d)
    scores = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale
    pos = s * block_s + jax.lax.broadcasted_iota(jnp.int32, (block_s, 1), 0)[:, 0]
    scores = jnp.where(pos < len_ref[b], scores, _NEG)

    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(scores))
    p = jnp.exp(scores - m_new)                            # (bS,)
    corr = jnp.exp(m_prev - m_new)
    l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p[None, :], v, preferred_element_type=jnp.float32)
    m_ref[0, 0] = m_new

    @pl.when(s == ns - 1)
    def _fini():
        o_ref[0, 0] = (acc_ref[0] / jnp.maximum(l_ref[0, 0], 1e-30)
                       ).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, scale: float | None = None,
                     block_s: int = DEFAULT_BLOCK_S,
                     interpret: bool = True) -> jax.Array:
    """Single-token GQA attention.

    q: (B, H, D); k, v: (B, Hkv, S, D); lengths: (B,) valid KV lengths.
    Returns (B, H, D) in q's dtype.  H must be a multiple of Hkv.
    """
    bsz, h, d = q.shape
    _, hkv, seq, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s_pad = -(-seq // block_s) * block_s
    if s_pad != seq:
        pad = [(0, 0), (0, 0), (0, s_pad - seq), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    ns = s_pad // block_s
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, h, ns),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, hh, s, ln: (b, hh, 0)),
            pl.BlockSpec((1, 1, block_s, d),
                         lambda b, hh, s, ln: (b, hh // group, s, 0)),
            pl.BlockSpec((1, 1, block_s, d),
                         lambda b, hh, s, ln: (b, hh // group, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b, hh, s, ln: (b, hh, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, scale=float(scale)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, h, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
