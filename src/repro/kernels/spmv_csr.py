"""Block-sparse SpMV kernel — the paper's SPMV app, re-tiled for the MXU.

Hardware adaptation (DESIGN.md §2): the paper traverses CSR edge-by-edge
on scalar PUs; a TPU wants 128x128 MXU tiles.  We convert each tile's CSR
chunk to BCSR (bm x bk dense blocks, ELL-padded to a fixed number of
blocks per block-row) and compute  y[m] += A_blk[m,k] @ x_blk[cols[m,k]].

The x block to load depends on data (cols) — exactly the paper's
data-dependent routing.  On TPU this is expressed with scalar prefetch:
the block-column table is prefetched to SMEM and *drives the BlockSpec
index_map*, so the pipeline fetches the right x block from HBM into VMEM
ahead of each grid step.  This is the TPU-native rendering of "route the
message by its array index".
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BK = 128


@dataclasses.dataclass
class BCSR:
    """ELL-padded block-sparse matrix: every block-row holds exactly
    ``kmax`` (bm x bk) blocks; absent blocks are all-zero with col 0."""

    blocks: np.ndarray     # (Mb, kmax, bm, bk) float32
    cols: np.ndarray       # (Mb, kmax) int32 block-column ids
    shape: tuple           # (M, K) logical
    bm: int
    bk: int

    @property
    def mb(self) -> int:
        return self.blocks.shape[0]

    @property
    def kmax(self) -> int:
        return self.blocks.shape[1]


def bcsr_from_csr(row_ptr, col_idx, weights, shape, bm: int = DEFAULT_BM,
                  bk: int = DEFAULT_BK) -> BCSR:
    """Host-side CSR -> BCSR conversion (the 'dataset load' step)."""
    m, k = shape
    mb = -(-m // bm)
    row_ptr = np.asarray(row_ptr)
    col_idx = np.asarray(col_idx)
    weights = (np.ones_like(col_idx, np.float32) if weights is None
               else np.asarray(weights, np.float32))
    # collect per-block-row set of touched block-columns
    block_maps = []
    kmax = 1
    for mblk in range(mb):
        r0, r1 = mblk * bm, min((mblk + 1) * bm, m)
        lo, hi = row_ptr[r0], row_ptr[r1]
        bcols = np.unique(col_idx[lo:hi] // bk) if hi > lo else np.zeros(0, np.int64)
        block_maps.append(bcols)
        kmax = max(kmax, len(bcols))
    blocks = np.zeros((mb, kmax, bm, bk), np.float32)
    cols = np.zeros((mb, kmax), np.int32)
    for mblk in range(mb):
        bcols = block_maps[mblk]
        lut = {int(c): i for i, c in enumerate(bcols)}
        cols[mblk, : len(bcols)] = bcols
        r0, r1 = mblk * bm, min((mblk + 1) * bm, m)
        for r in range(r0, r1):
            for e in range(row_ptr[r], row_ptr[r + 1]):
                c = int(col_idx[e])
                slot = lut[c // bk]
                blocks[mblk, slot, r - r0, c % bk] += weights[e]
    return BCSR(blocks=blocks, cols=cols, shape=(m, k), bm=bm, bk=bk)


def _kernel(cols_ref, a_ref, x_ref, y_ref):
    del cols_ref
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    a = a_ref[0, 0]                  # (bm, bk)
    x = x_ref[...]                   # (1, bk)
    y_ref[...] += jnp.dot(a, x[0], preferred_element_type=jnp.float32)[None, :]


def spmv_bcsr(mat: BCSR, x: jax.Array, interpret: bool = True) -> jax.Array:
    """y = A @ x for a BCSR matrix.  Returns (M,) float32."""
    m, k = mat.shape
    bm, bk = mat.bm, mat.bk
    kb = -(-k // bk)
    x_pad = jnp.zeros((kb * bk,), jnp.float32).at[:k].set(
        x.astype(jnp.float32)).reshape(kb, bk)
    blocks = jnp.asarray(mat.blocks)
    cols = jnp.asarray(mat.cols)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(mat.mb, mat.kmax),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bk), lambda mi, ki, cols: (mi, ki, 0, 0)),
            pl.BlockSpec((1, bk), lambda mi, ki, cols: (cols[mi, ki], 0)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda mi, ki, cols: (mi, 0)),
    )
    y = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mat.mb, bm), jnp.float32),
        interpret=interpret,
    )(cols, blocks, x_pad)
    return y.reshape(-1)[:m]
