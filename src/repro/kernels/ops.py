"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to auto: compiled on TPU, interpreted elsewhere
(this container is CPU-only; interpret=True executes the kernel bodies in
Python for bit-faithful validation against ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import decode_attention as _da
from . import histogram_bin as _hb
from . import relax_min as _rx
from . import segment_combine as _sc
from . import spmv_csr as _sp

bcsr_from_csr = _sp.bcsr_from_csr
BCSR = _sp.BCSR


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("num_bins", "interpret"))
def histogram(idx, num_bins: int, interpret=None):
    return _hb.histogram_bin(idx, num_bins,
                             interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("combine", "interpret"))
def relax(values, mail_val, mail_flag, combine: str = "min", interpret=None):
    return _rx.relax(values, mail_val, mail_flag, combine,
                     interpret=_auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "combine", "interpret"))
def segment_combine(seg, val, num_segments: int, combine: str = "min",
                    interpret=None):
    return _sc.segment_combine(seg, val, num_segments, combine,
                               interpret=_auto_interpret(interpret))


def spmv(mat: _sp.BCSR, x, interpret=None):
    """y = A @ x.  (Not jitted at this level: BCSR holds host numpy; the
    pallas_call inside is jit-compiled by JAX on first use.)"""
    return _sp.spmv_bcsr(mat, jnp.asarray(x),
                         interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("scale", "block_s", "interpret"))
def decode_attention(q, k, v, lengths, scale=None, block_s: int = 512,
                     interpret=None):
    return _da.decode_attention(q, k, v, lengths, scale=scale,
                                block_s=block_s,
                                interpret=_auto_interpret(interpret))
