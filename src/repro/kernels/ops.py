"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to auto: compiled on TPU, interpreted elsewhere
(this container is CPU-only; interpret=True executes the kernel bodies in
Python for bit-faithful validation against ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import decode_attention as _da
from . import deliver_fused as _df
from . import histogram_bin as _hb
from . import relax_min as _rx
from . import segment_combine as _sc
from . import spmv_csr as _sp

bcsr_from_csr = _sp.bcsr_from_csr
BCSR = _sp.BCSR


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("num_bins", "interpret"))
def histogram(idx, num_bins: int, interpret=None):
    return _hb.histogram_bin(idx, num_bins,
                             interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("combine", "interpret"))
def relax(values, mail_val, mail_flag, combine: str = "min", interpret=None):
    return _rx.relax(values, mail_val, mail_flag, combine,
                     interpret=_auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "combine", "interpret"))
def segment_combine(seg, val, num_segments: int, combine: str = "min",
                    interpret=None):
    return _sc.segment_combine(seg, val, num_segments, combine,
                               interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("combine", "interpret"))
def deliver_fused(seg, val, mail_val, combine: str = "min", interpret=None):
    return _df.deliver_fused(seg, val, mail_val, combine,
                             interpret=_auto_interpret(interpret))


def spmv(mat: _sp.BCSR, x, interpret=None):
    """y = A @ x.  (Not jitted at this level: BCSR holds host numpy; the
    pallas_call inside is jit-compiled by JAX on first use.)"""
    return _sp.spmv_bcsr(mat, jnp.asarray(x),
                         interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("scale", "block_s", "interpret"))
def decode_attention(q, k, v, lengths, scale=None, block_s: int = 512,
                     interpret=None):
    return _da.decode_attention(q, k, v, lengths, scale=scale,
                                block_s=block_s,
                                interpret=_auto_interpret(interpret))


def analysis_cases():
    """(name, thunk, combine) cases for ``repro.analysis.pallas_races``
    covering the scalar-prefetch kernels behind this module's entry
    points.  The thunks call the *unjitted* kernel functions so the race
    pass's ``pallas_call`` capture sees the invocation (the jitted
    wrappers above would hide it behind the trace cache).

    ``decode_attention`` is declared ``softmax-carry``: its output window
    is revisited across KV blocks with an order-dependent online-softmax
    rescale, safe only because the TPU grid executes sequentially — the
    race pass reports it, and the finding lives in the committed
    baseline as the documented exception."""
    import numpy as np

    row_ptr = np.array([0, 2, 3, 3, 5, 6, 8], np.int32)
    col_idx = np.array([0, 9, 4, 1, 8, 2, 0, 5], np.int32)
    mat = bcsr_from_csr(row_ptr, col_idx, None, (6, 10), bm=4, bk=8)
    x = jnp.arange(10, dtype=jnp.float32)

    q = jnp.ones((1, 2, 8), jnp.float32)
    k = jnp.ones((1, 1, 6, 8), jnp.float32)
    v = jnp.ones((1, 1, 6, 8), jnp.float32)
    lengths = jnp.array([6], jnp.int32)
    return [
        ("spmv_bcsr",
         functools.partial(_sp.spmv_bcsr, mat, x, interpret=True), "add"),
        ("decode_attention",
         functools.partial(_da.decode_attention, q, k, v, lengths,
                           block_s=4, interpret=True), "softmax-carry"),
    ]
