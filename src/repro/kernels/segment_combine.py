"""Segment combine kernel — the proxy (P$) coalescing operation itself.

The paper's proxy tile merges all same-destination updates arriving in a
region (min for SSSP/BFS/WCC, add for PageRank/SPMV/Histo) before
forwarding one combined record to the owner.  On TPU the proxy store is a
dense regional buffer; combining a batch of (segment_id, value) records
into it is a dense segment reduction.

Kernel shape: grid over (segment-blocks, record-blocks), the record dim
innermost so each output segment-block is revisited and reduced in VMEM.
Membership is a one-hot compare (VPU); `add` reduces with +=, `min` with
an elementwise running minimum against masked +inf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 1024
DEFAULT_BLOCK_S = 512

_BIG = 3.4e38   # stand-in for +inf (TPU-safe); python float so the kernel
                # body sees a literal, not a captured traced constant.


def _kernel(seg_ref, val_ref, out_ref, *, block_s: int, combine: str):
    r = pl.program_id(1)
    s_blk = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        if combine == "min":
            out_ref[...] = jnp.full_like(out_ref, _BIG)
        else:
            out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...][0]                     # (Rb,) int32
    val = val_ref[...][0]                     # (Rb,) float32
    base = s_blk * block_s
    local = seg - base
    cols = jax.lax.broadcasted_iota(jnp.int32, (local.shape[0], block_s), 1)
    hit = local[:, None] == cols              # (Rb, Sb)
    if combine == "min":
        cand = jnp.where(hit, val[:, None], _BIG)
        out_ref[...] = jnp.minimum(out_ref[...], jnp.min(cand, axis=0,
                                                         keepdims=True))
    else:
        cand = jnp.where(hit, val[:, None], 0.0)
        out_ref[...] += jnp.sum(cand, axis=0, keepdims=True)


def segment_combine(seg: jax.Array, val: jax.Array, num_segments: int,
                    combine: str = "min",
                    block_r: int = DEFAULT_BLOCK_R,
                    block_s: int = DEFAULT_BLOCK_S,
                    interpret: bool = True) -> jax.Array:
    """Dense segment reduction.  seg: (N,) int32 in [0, num_segments)
    (negative = padding); val: (N,) float32.  Returns (num_segments,)
    combined values; untouched segments get the combine identity
    (+inf for min — returned as jnp.inf — and 0 for add)."""
    assert combine in ("min", "add")
    n = seg.shape[0]
    n_pad = -(-n // block_r) * block_r
    s_pad = -(-num_segments // block_s) * block_s
    seg2 = jnp.full((n_pad,), -1, jnp.int32).at[:n].set(seg.astype(jnp.int32))
    val2 = jnp.zeros((n_pad,), jnp.float32).at[:n].set(val.astype(jnp.float32))
    seg2 = seg2.reshape(n_pad // block_r, block_r)
    val2 = val2.reshape(n_pad // block_r, block_r)
    ns, nr = s_pad // block_s, n_pad // block_r
    out = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, combine=combine),
        grid=(ns, nr),
        in_specs=[pl.BlockSpec((1, block_r), lambda s, r: (r, 0)),
                  pl.BlockSpec((1, block_r), lambda s, r: (r, 0))],
        out_specs=pl.BlockSpec((1, block_s), lambda s, r: (0, s)),
        out_shape=jax.ShapeDtypeStruct((1, s_pad), jnp.float32),
        interpret=interpret,
    )(seg2, val2)
    out = out[0, :num_segments]
    if combine == "min":
        out = jnp.where(out >= _BIG, jnp.inf, out)
    return out


def analysis_cases():
    """(name, thunk, combine) cases for ``repro.analysis.pallas_races``:
    tiny multi-block invocations whose grid revisits each output
    segment-block across record blocks (the reduction idiom the race
    pass must accept for commutative combines)."""
    seg = jnp.asarray([0, 3, 3, 7, 1, 0], jnp.int32)
    val = jnp.arange(6, dtype=jnp.float32)
    # compacted segment window: shorter record stream with dropped-lane
    # sentinels interleaved (what the engine's active-set compaction
    # branches produce), still multi-block over the record dim
    wseg = jnp.asarray([4, -1, 0, 4, -1, 6], jnp.int32)
    wval = jnp.arange(6, dtype=jnp.float32) + 0.5
    return ([(f"segment_combine:{c}",
              functools.partial(segment_combine, seg, val, 8, c,
                                block_r=4, block_s=8),
              c)
             for c in ("min", "add")]
            + [(f"segment_combine:compact:{c}",
                functools.partial(segment_combine, wseg, wval, 8, c,
                                  block_r=4, block_s=8),
                c)
               for c in ("min", "add")])
