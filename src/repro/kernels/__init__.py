"""Pallas TPU kernels for the framework's compute hot-spots.

spmv_csr        block-sparse (BCSR) SpMV — the paper's SPMV app, re-tiled
                for the MXU with scalar-prefetch dynamic x-block gather.
histogram_bin   one-hot-reduce binning — the paper's Histogram app.
relax_min       fused mailbox drain (min/add combine + improved mask) —
                the vertex-update task of BFS/SSSP/WCC.
segment_combine dense segment min/add reduction — the proxy (P$)
                filter/coalesce operation itself.
decode_attention flash-decode GQA attention — the serving-side hot spot.

Each kernel is a pl.pallas_call with explicit BlockSpec VMEM tiling,
validated in interpret mode against the pure-jnp oracles in ref.py.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
