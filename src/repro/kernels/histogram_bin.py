"""Histogram binning kernel (the paper's Histo app hot loop).

TPU adaptation: instead of per-element scatter (no efficient arbitrary
scatter on the VPU), each (record-block, bin-block) grid cell builds a
one-hot membership matrix in VMEM and reduces over records — turning the
bin update into dense vector ops the VPU/MXU execute at full width.  The
output bin-block is revisited across record blocks (reduction grid dim is
innermost), accumulating in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 1024    # records per grid step
DEFAULT_BLOCK_B = 512     # bins per grid step (4 x 128 lanes)


def _kernel(idx_ref, out_ref, *, block_b: int):
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    b = pl.program_id(0)
    idx = idx_ref[...]                       # (1, Rb) int32
    base = b * block_b
    local = idx[0] - base                    # (Rb,)
    # one-hot membership (Rb, Bb); padded records carry idx=-1 => never hit
    cols = jax.lax.broadcasted_iota(jnp.int32, (local.shape[0], block_b), 1)
    oh = (local[:, None] == cols).astype(jnp.float32)
    out_ref[...] += jnp.sum(oh, axis=0, keepdims=True)


def histogram_bin(idx: jax.Array, num_bins: int,
                  block_r: int = DEFAULT_BLOCK_R,
                  block_b: int = DEFAULT_BLOCK_B,
                  interpret: bool = True) -> jax.Array:
    """Count occurrences of each bin id.  idx: (N,) int32 in [0, num_bins)
    (negative = padding, ignored).  Returns (num_bins,) float32 counts."""
    n = idx.shape[0]
    n_pad = -(-n // block_r) * block_r
    b_pad = -(-num_bins // block_b) * block_b
    idx2 = jnp.full((n_pad,), -1, jnp.int32).at[:n].set(idx.astype(jnp.int32))
    idx2 = idx2.reshape(n_pad // block_r, block_r)
    nb, nr = b_pad // block_b, n_pad // block_r
    out = pl.pallas_call(
        functools.partial(_kernel, block_b=block_b),
        grid=(nb, nr),
        in_specs=[pl.BlockSpec((1, block_r), lambda b, r: (r, 0))],
        out_specs=pl.BlockSpec((1, block_b), lambda b, r: (0, b)),
        out_shape=jax.ShapeDtypeStruct((1, b_pad), jnp.float32),
        interpret=interpret,
    )(idx2)
    return out[0, :num_bins]


def analysis_cases():
    """(name, thunk, combine) case for ``repro.analysis.pallas_races``:
    a multi-record-block invocation whose bin-block windows are revisited
    across record blocks (accumulating add — commutative-safe)."""
    idx = jnp.asarray([0, 5, 5, 2, 7, 0], jnp.int32)
    return [("histogram_bin",
             functools.partial(histogram_bin, idx, 8, block_r=4,
                               block_b=8),
             "add")]
