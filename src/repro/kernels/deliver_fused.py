"""Fused owner-delivery kernel — proxy→combine→deliver in one launch.

The engine's Pallas delivery path used to chain four ``pallas_call``
launches per superstep (segment_combine for the arriving values, a
histogram for presence, the relax fold into the mailbox, and a second
histogram for per-tile endpoint contention).  This kernel fuses the hot
path: one launch reads the record stream once and produces both the
relaxed mailbox *and* the per-index arrival counts — presence and the
per-tile contention fall out of the counts outside the kernel (mailbox
indices of one tile are contiguous, so per-tile delivered records are a
reshape-sum; counts are integer-valued, so the derived flags are
bit-identical to the histogram formulation).

Kernel shape: same reduction idiom as ``segment_combine`` — grid over
(mailbox-blocks, record-blocks) with the record dim innermost, so each
output block is revisited and reduced in VMEM.  The mailbox block seeds
the output at the first record block; min folds a *guarded* running
minimum (only columns some record actually hit are touched — the
mailbox legitimately holds +inf, which an unconditional ``minimum``
against the finite ``_BIG`` stand-in would corrupt) and add
accumulates.  Both revisit orders commute with the combine, which is
what ``analysis.pallas_races`` proves via :func:`analysis_cases`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 1024
DEFAULT_BLOCK_S = 512

_BIG = 3.4e38   # stand-in for +inf (TPU-safe); python float so the kernel
                # body sees a literal, not a captured traced constant.


def _kernel(seg_ref, val_ref, mail_ref, out_ref, cnt_ref, *, block_s: int,
            combine: str):
    r = pl.program_id(1)
    s_blk = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        out_ref[...] = mail_ref[...]
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    seg = seg_ref[...][0]                     # (Rb,) int32
    val = val_ref[...][0]                     # (Rb,) float32
    base = s_blk * block_s
    local = seg - base
    cols = jax.lax.broadcasted_iota(jnp.int32, (local.shape[0], block_s), 1)
    hit = local[:, None] == cols              # (Rb, Sb)
    cnt_ref[...] += jnp.sum(hit.astype(jnp.float32), axis=0, keepdims=True)
    if combine == "min":
        cand = jnp.where(hit, val[:, None], _BIG)
        hitcol = jnp.any(hit, axis=0, keepdims=True)
        out_ref[...] = jnp.where(
            hitcol,
            jnp.minimum(out_ref[...], jnp.min(cand, axis=0, keepdims=True)),
            out_ref[...])
    else:
        cand = jnp.where(hit, val[:, None], 0.0)
        out_ref[...] += jnp.sum(cand, axis=0, keepdims=True)


def deliver_fused(seg: jax.Array, val: jax.Array, mail_val: jax.Array,
                  combine: str = "min",
                  block_r: int = DEFAULT_BLOCK_R,
                  block_s: int = DEFAULT_BLOCK_S,
                  interpret: bool = True):
    """Fused mailbox delivery.  seg: (N,) int32 mailbox indices in
    [0, Nd) (negative = padding); val: (N,) float32; mail_val: (Nd,)
    current mailbox.  Returns ``(new_mail_val, counts)`` — the mailbox
    with every record combined in (min relax / add accumulate) and the
    float32 per-index arrival counts (``counts > 0`` is the flag update;
    a tile-contiguous reshape-sum is the endpoint contention)."""
    assert combine in ("min", "add")
    n = seg.shape[0]
    nd = mail_val.shape[0]
    n_pad = -(-n // block_r) * block_r
    s_pad = -(-nd // block_s) * block_s
    seg2 = jnp.full((n_pad,), -1, jnp.int32).at[:n].set(seg.astype(jnp.int32))
    val2 = jnp.zeros((n_pad,), jnp.float32).at[:n].set(val.astype(jnp.float32))
    mail2 = jnp.zeros((s_pad,), jnp.float32).at[:nd].set(mail_val)
    seg2 = seg2.reshape(n_pad // block_r, block_r)
    val2 = val2.reshape(n_pad // block_r, block_r)
    mail2 = mail2.reshape(1, s_pad)
    ns, nr = s_pad // block_s, n_pad // block_r
    out, cnt = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, combine=combine),
        grid=(ns, nr),
        in_specs=[pl.BlockSpec((1, block_r), lambda s, r: (r, 0)),
                  pl.BlockSpec((1, block_r), lambda s, r: (r, 0)),
                  pl.BlockSpec((1, block_s), lambda s, r: (0, s))],
        out_specs=[pl.BlockSpec((1, block_s), lambda s, r: (0, s)),
                   pl.BlockSpec((1, block_s), lambda s, r: (0, s))],
        out_shape=[jax.ShapeDtypeStruct((1, s_pad), jnp.float32),
                   jax.ShapeDtypeStruct((1, s_pad), jnp.float32)],
        interpret=interpret,
    )(seg2, val2, mail2)
    return out[0, :nd], cnt[0, :nd]


def analysis_cases():
    """(name, thunk, combine) cases for ``repro.analysis.pallas_races``:
    tiny multi-block invocations revisiting each mailbox block across
    record blocks.  Both outputs of a case are reduced with the declared
    combine (min relax guarded by hit presence commutes across record
    blocks; the count output is an add either way)."""
    seg = jnp.asarray([0, 3, 3, 7, 1, 0], jnp.int32)
    val = jnp.arange(6, dtype=jnp.float32)
    mail = jnp.full((8,), jnp.inf, jnp.float32).at[1].set(0.5)
    # compacted segment window: the record stream the engine's
    # active-set branches hand the kernel — shorter than the mailbox,
    # with dropped-lane sentinels (-1) interleaved mid-stream, still
    # spanning multiple record blocks so the revisit reduction is
    # exercised at the compacted shape too
    wseg = jnp.asarray([2, -1, 5, 2, -1, 1], jnp.int32)
    wval = jnp.arange(6, dtype=jnp.float32) + 0.25
    cases = [(f"deliver_fused:{c}",
              functools.partial(deliver_fused, seg, val,
                                jnp.zeros((8,), jnp.float32) if c == "add"
                                else mail, c, block_r=4, block_s=8),
              c)
             for c in ("min", "add")]
    cases += [(f"deliver_fused:compact:{c}",
               functools.partial(deliver_fused, wseg, wval,
                                 jnp.zeros((8,), jnp.float32) if c == "add"
                                 else mail, c, block_r=4, block_s=8),
               c)
              for c in ("min", "add")]
    return cases
