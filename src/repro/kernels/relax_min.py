"""Fused mailbox-drain / relaxation kernel (BFS/SSSP/WCC vertex update).

The engine's IQ drain is: for every owned item, combine the pending
mailbox record into the value array and report whether it improved
(improvements re-activate the item's edge cursor).  One elementwise pass,
fused so values/mailbox/flags stream through VMEM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048


def _kernel(v_ref, m_ref, f_ref, out_v_ref, out_i_ref, *, combine: str):
    v = v_ref[...]
    m = m_ref[...]
    f = f_ref[...] != 0
    if combine == "min":
        imp = f & (m < v)
        out_v_ref[...] = jnp.where(imp, m, v)
    else:  # add: every flagged record "improves" (accumulates)
        imp = f
        out_v_ref[...] = jnp.where(f, v + m, v)
    out_i_ref[...] = imp.astype(jnp.int8)


def relax(values: jax.Array, mail_val: jax.Array, mail_flag: jax.Array,
          combine: str = "min", block: int = DEFAULT_BLOCK,
          interpret: bool = True):
    """Returns (new_values, improved int8 mask)."""
    assert combine in ("min", "add")
    n = values.shape[0]
    n_pad = -(-n // block) * block
    ident = jnp.inf if combine == "min" else 0.0

    def pad(a, fill, dt):
        return jnp.full((n_pad,), fill, dt).at[:n].set(a.astype(dt)) \
            .reshape(n_pad // block, block)

    v = pad(values, ident, jnp.float32)
    m = pad(mail_val, ident, jnp.float32)
    f = pad(mail_flag, 0, jnp.int8)
    nb = n_pad // block
    spec = pl.BlockSpec((1, block), lambda i: (i, 0))
    out_v, out_i = pl.pallas_call(
        functools.partial(_kernel, combine=combine),
        grid=(nb,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.float32),
                   jax.ShapeDtypeStruct((nb, block), jnp.int8)],
        interpret=interpret,
    )(v, m, f)
    return out_v.reshape(-1)[:n], out_i.reshape(-1)[:n]


def analysis_cases():
    """(name, thunk, combine) cases for ``repro.analysis.pallas_races``.
    The relax kernel is elementwise — each grid program owns a disjoint
    output window — so it is declared ``overwrite``: the race pass must
    prove disjointness rather than rely on combine commutativity."""
    n = 10
    vals = jnp.full((n,), jnp.inf, jnp.float32)
    mail = jnp.arange(n, dtype=jnp.float32)
    flag = jnp.ones((n,), jnp.bool_)
    return [(f"relax:{c}",
             functools.partial(relax, vals, mail, flag, c, block=8),
             "overwrite")
            for c in ("min", "add")]
