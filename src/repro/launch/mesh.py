"""Production meshes.

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is the
paper's proxy-region boundary — cheap wide links inside, expensive links
across (DCI), exactly the cost structure proxy regions exploit.

Functions, never module-level constants: importing this module must not
touch jax device state (the dry-run pins the device count *before* any
jax initialisation).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
