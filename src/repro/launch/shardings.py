"""Rule-based sharding assignment (GSPMD path).

Parameters, optimizer state, batches and caches get PartitionSpecs from
name+shape rules.  Divisibility is always checked against the mesh —
axes that don't divide fall back to replication (correctness first; the
hillclimb refines placement for the three chosen cells).

Scheme (Megatron/FSDP hybrid, per DESIGN.md §6):
  column-parallel weights (w_in, wq, ...):  (..., fsdp->'data', 'model')
  row-parallel weights (w_out, wo, ...):    (..., 'model', fsdp->'data')
  embeddings / lm_head (V, d):              ('model', fsdp->'data')
  MoE experts (E, d, ff):                   ('data' on E, ..., 'model')
  norms / scalars / small state:            replicated
  batch leaves:                             (('pod','data'), None, ...)
  KV caches (L, B, T, H, D):                B->('pod','data') else
                                            H->'model' else T->'model'
Scan-stacked leading layer axes are detected by path and skipped.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# trailing-name classes
_COL = ("w_in", "w_gate", "wq", "wk", "wv", "wq_a", "wq_b", "wkv_a",
        "wk_b", "wv_b", "up", "in_proj", "ff_in", "ff_gate", "wx",
        "router", "proj")
_ROW = ("w_out", "wo", "down", "out_proj", "ff_out")
_EMB = ("tok_emb", "lm_head")
# path components that carry stacked layer/group axes (skip leading dims)
_STACKS = ("layers", "moe_layers", "dense_layers", "mamba", "groups",
           "enc_layers", "dec_layers", "mlstm")


def _leading_stack_dims(path: str, ndim: int, trailing: int) -> int:
    """How many leading axes are layer stacks (not shardable weight dims)."""
    n = 0
    if any(f"'{s}'" in path for s in _STACKS):
        n = 1
        if "'mlstm'" in path:         # (G, m_per, ...) double stack
            n = 2
        elif "'groups'" in path and "'slstm'" in path:
            n = 1
    return min(n, max(ndim - trailing, 0))


def _name(path: str) -> str:
    parts = re.findall(r"\['([^']+)'\]", path)
    return parts[-1] if parts else path


def _div(size: int, mesh_sizes: dict, axis: Optional[str]) -> bool:
    return axis in mesh_sizes and size % mesh_sizes[axis] == 0


def param_spec(path: str, shape: tuple, mesh: Mesh,
               fsdp: bool = True) -> P:
    sizes = dict(zip(mesh.axis_names, np.array(mesh.devices.shape)))
    name = _name(path)
    nd = len(shape)
    if nd == 0:
        return P()
    spec = [None] * nd

    is_moe_expert = ("'moe'" in path or "'shared'" in path) and name in (
        "w_in", "w_gate", "w_out") and nd >= 3 and "'shared'" not in path

    if name in _EMB:
        if _div(shape[0], sizes, "model"):
            spec[0] = "model"
        if fsdp and nd > 1 and _div(shape[1], sizes, "data"):
            spec[1] = "data"
        return P(*spec)

    skip = _leading_stack_dims(path, nd, 2)
    if is_moe_expert:
        # (L?, E, d_in, d_out): expert-parallel over as much of the mesh
        # as divides — ('data','model') for deepseek-v3's 256 experts,
        # 'model' for granite's 32.  Per-expert dims stay unsharded (the
        # dispatch all-to-all moves tokens to the experts; DESIGN.md §3).
        e_ax = skip
        if e_ax < nd:
            both = sizes.get("data", 1) * sizes.get("model", 1)
            if "data" in sizes and "model" in sizes \
                    and shape[e_ax] % both == 0:
                spec[e_ax] = ("data", "model")
            elif _div(shape[e_ax], sizes, "model"):
                spec[e_ax] = "model"
            elif _div(shape[e_ax], sizes, "data"):
                spec[e_ax] = "data"
        return P(*spec)

    if nd - skip >= 2:
        a_in, a_out = nd - 2, nd - 1
        if name in _COL:
            if _div(shape[a_out], sizes, "model"):
                spec[a_out] = "model"
            if fsdp and _div(shape[a_in], sizes, "data"):
                spec[a_in] = "data"
            return P(*spec)
        if name in _ROW:
            if _div(shape[a_in], sizes, "model"):
                spec[a_in] = "model"
            if fsdp and _div(shape[a_out], sizes, "data"):
                spec[a_out] = "data"
            return P(*spec)
    return P()                                   # norms, gates, small state


def opt_spec(path: str, shape: tuple, mesh: Mesh, fsdp: bool = True) -> P:
    """Optimizer-state leaves mirror their parameter's spec; factored
    adafactor rows/cols lose the last/second-to-last axis."""
    name = _name(path)

    def padded(base, n):
        lst = list(base)
        return lst + [None] * (n - len(lst))

    if name == "vr":           # param.shape[:-1] (reduced over cols)
        base = padded(param_spec(path.replace("['vr']", ""),
                                 shape + (1,), mesh, fsdp),
                      len(shape) + 1)
        return P(*base[: len(shape)])
    if name == "vc":           # param.shape[:-2] + param.shape[-1:]
        full = shape[:-1] + (1,) + shape[-1:]
        base = padded(param_spec(path.replace("['vc']", ""), full, mesh,
                                 fsdp), len(full))
        return P(*(base[: len(shape) - 1] + [base[-1]]))
    for k in ("mu", "nu", "v"):
        path = path.replace(f"['{k}']", "")
    return param_spec(path, shape, mesh, fsdp)


def batch_spec(path: str, shape: tuple, mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n = int(np.prod([dict(zip(mesh.axis_names,
                              mesh.devices.shape))[a] for a in axes]))
    if len(shape) >= 1 and shape[0] % n == 0:
        return P(axes)
    return P()


def cache_spec(path: str, shape: tuple, mesh: Mesh) -> P:
    """Decode caches: (L, B, T, H, D)-like stacks.  Prefer batch
    sharding, then heads over 'model', then sequence over 'model'."""
    sizes = dict(zip(mesh.axis_names, np.array(mesh.devices.shape)))
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nbatch = int(np.prod([sizes[a] for a in axes]))
    nd = len(shape)
    spec = [None] * nd
    # find the batch axis: first axis after the leading stack dims whose
    # size divides the batch submesh — heuristically axis 1 for stacked
    # caches, axis 0 for unstacked.
    b_ax = 1 if nd >= 3 else 0
    if nd > b_ax and shape[b_ax] % nbatch == 0 and shape[b_ax] >= nbatch:
        spec[b_ax] = axes
    if "model" in sizes and nd >= 2:
        m = sizes["model"]
        # prefer a head-like axis (between batch and last), else seq
        for ax in range(nd - 2, b_ax, -1):
            if spec[ax] is None and shape[ax] % m == 0 and shape[ax] >= m:
                spec[ax] = "model"
                break
    return P(*spec)


def tree_specs(tree, rule, mesh: Mesh, **kw):
    """Map a rule over a pytree (of arrays or ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        specs.append(rule(jax.tree_util.keystr(path), tuple(leaf.shape),
                          mesh, **kw))
    return jax.tree_util.tree_unflatten(treedef, specs)


def tree_shardings(tree, rule, mesh: Mesh, **kw):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_specs(tree, rule, mesh, **kw),
                        is_leaf=lambda x: isinstance(x, P))
