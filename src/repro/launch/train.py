"""End-to-end training driver.

Trains any registered arch (reduced or custom-scaled config) on the
synthetic LM stream with checkpointing + fault-tolerant loop — the
runnable rendering of the same train_step the dry-run lowers at
production scale.

  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
      --smoke --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..data.synthetic import SyntheticLM
from ..models import registry
from ..training.optimizer import adafactor, adamw
from ..training.train_step import TrainState, make_train_step


def scale_config(cfg, d_model=None, n_layers=None, vocab=None):
    """Scale a registered config (e.g. to ~100M params for examples)."""
    kw = {}
    if d_model:
        ratio = d_model / cfg.d_model
        kw.update(d_model=d_model,
                  d_ff=max(64, int(cfg.d_ff * ratio) // 64 * 64)
                  if cfg.d_ff else 0,
                  head_dim=max(16, d_model // max(cfg.n_heads, 1)))
    if n_layers:
        kw["n_layers"] = n_layers
    if vocab:
        kw["vocab"] = vocab
    return dataclasses.replace(cfg, **kw)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=0,
                    help="lr warmup steps; 0 = auto (steps//10, capped at "
                         "100) so short smoke runs are not spent entirely "
                         "inside the ramp")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg, fam = registry.get(args.arch, smoke=args.smoke)
    if args.d_model or args.n_layers or args.vocab:
        cfg = scale_config(cfg, args.d_model or None, args.n_layers or None,
                           args.vocab or None)
    n_params_est = cfg.param_count()
    print(f"arch={cfg.arch} family={cfg.family} ~{n_params_est/1e6:.1f}M "
          f"params, {len(jax.devices())} device(s)")

    warmup = args.warmup or min(100, max(1, args.steps // 10))
    opt = adafactor(lr=args.lr, warmup=warmup) if cfg.family == "mla_moe" \
        else adamw(lr=args.lr, warmup=warmup)
    params = fam["init"](cfg, jax.random.PRNGKey(0))
    real = sum(x.size for x in jax.tree.leaves(params))
    print(f"initialized {real/1e6:.1f}M params")
    state = TrainState.create(params, opt)
    step_fn = jax.jit(make_train_step(cfg, fam, opt,
                                      microbatches=args.microbatches),
                      donate_argnums=(0,))
    src = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch,
                      d_model=cfg.d_model if cfg.input_embeds
                      or cfg.family == "encdec" else 0)

    def batch_at(i):
        b = src.batch_at(i)
        if cfg.family == "encdec":
            dec = min(b["tokens"].shape[1], 448)
            b = dict(embeds=b["embeds"], tokens=b["tokens"][:, :dec],
                     labels=b["labels"][:, :dec])
        elif cfg.input_embeds:
            b = dict(embeds=b["embeds"], labels=b["labels"])
        else:
            b = dict(tokens=b["tokens"], labels=b["labels"])
        return jax.tree.map(jnp.asarray, b)

    if args.ckpt_dir:
        from ..runtime.fault import FaultTolerantLoop
        loop = FaultTolerantLoop(step_fn, batch_at, args.ckpt_dir,
                                 ckpt_every=args.ckpt_every)
        state, history = loop.run(state, args.steps)
        losses = [float(h["loss"]) for h in history]
    else:
        losses = []
        t0 = time.time()
        for i in range(args.steps):
            state, metrics = step_fn(state, batch_at(i))
            losses.append(float(metrics["loss"]))
            if i % args.log_every == 0 or i == args.steps - 1:
                dt = time.time() - t0
                print(f"step {i:4d} loss={losses[-1]:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({dt/(i+1):.2f}s/step)", flush=True)
    print(f"first loss {losses[0]:.4f} -> last {losses[-1]:.4f} "
          f"(drop {(losses[0]-losses[-1]):.4f})")
    return losses


if __name__ == "__main__":
    main()
