import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step with
optimizer, prefill, or serve_step), attaches rule-based shardings, and
runs ``jax.jit(...).lower(**abstract_inputs).compile()`` on the
production mesh — 16x16 single-pod and 2x16x16 multi-pod.  Success
proves the distribution config is coherent: every sharding divides, the
partitioner finds a collective schedule, and per-device memory is known.

Artifacts (one JSON per cell) record memory_analysis, cost_analysis,
per-class collective bytes parsed from the optimized HLO, and the
derived roofline terms (§Roofline constants: 197 TFLOP/s bf16, 819 GB/s
HBM, 50 GB/s ICI per link).

Usage:
  python -m repro.launch.dryrun --arch starcoder2-3b --shape train_4k \
      --mesh single [--out artifacts/dryrun] [--opt '{"microbatches":2}']
  python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import registry
from ..training.optimizer import adafactor, adamw
from ..training.train_step import TrainState, make_train_step
from ..serving.decode import make_serve_step
from . import shapes as shp
from .mesh import make_production_mesh
from .shardings import (batch_spec, cache_spec, opt_spec, param_spec,
                        tree_shardings)

# ---------------------------------------------------------------- constants
PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = dict(f64=8, f32=4, bf16=2, f16=2, s64=8, u64=8, s32=4,
                    u32=4, s16=2, u16=2, s8=1, u8=1, pred=1, f8e4m3fn=1,
                    f8e5m2=1)
_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")

FSDP_ARCHS = {"starcoder2-3b", "starcoder2-15b", "deepseek-7b",
              "h2o-danube-3-4b", "pixtral-12b", "deepseek-v3-671b"}


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out = {k: 0.0 for k in ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute")}
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("%") or ls.startswith("ROOT"):
            m = _COLL_RE.search(ls)
            if not m:
                continue
            op = m.group(1)
            # result shape(s): before the '=' we have the op name; take
            # the shape annotations on the LHS of '='
            lhs = ls.split("=", 1)
            region = lhs[1] if len(lhs) > 1 else ls
            # first shape group after op name = result
            head = region.split(m.group(0))[0] if m.group(0) in region \
                else region
            shapes = _SHAPE_RE.findall(head)
            if not shapes:
                shapes = _SHAPE_RE.findall(ls)[:1]
            b = 0
            for dt, dims in shapes:
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                b += n * _DTYPE_BYTES[dt]
            out[op] += b
            counts[op] += 1
    return dict(bytes=out, counts=counts,
                total_bytes=float(sum(out.values())))


def _mem_dict(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    return {k: int(getattr(m, k, 0) or 0) for k in keys}


def _cost_dict(compiled) -> dict:
    try:
        c = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return {k: float(v) for k, v in dict(c).items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" not in k)
            and not k.startswith("%")}


def build_cell(arch: str, shape_name: str, mesh, opt_overrides=None):
    """Returns (jitted_fn, example_args) for lowering."""
    opt_overrides = opt_overrides or {}
    cfg, fam = registry.get(arch)
    cell = shp.SHAPES[shape_name]
    fsdp = arch in FSDP_ARCHS
    from ..models import layers as _l
    q_chunk = opt_overrides.get("q_chunk")
    if q_chunk:
        # hillclimb knob: cap the attention-score transient
        _l.DEFAULT_Q_CHUNK = int(q_chunk)
    # activation + expert-parallel sharding constraints (DESIGN.md §6)
    sizes = dict(zip(mesh.axis_names, np.array(mesh.devices.shape)))
    _l.BATCH_AXES = tuple(a for a in ("pod", "data") if a in sizes)
    _l.MODEL_SIZE = int(sizes.get("model", 0))
    _l.FSDP_GATHER = fsdp
    _l.SEQ_SHARD = bool(opt_overrides.get("seq_parallel", False))
    if "moe_group" in opt_overrides:
        _l.MOE_GROUP = int(opt_overrides["moe_group"])
    if "moe_cf" in opt_overrides:
        _l.MOE_CF = float(opt_overrides["moe_cf"])
    if "carry_cache" in opt_overrides:
        from ..models import lm as _lm
        _lm.CARRY_CACHE = bool(opt_overrides["carry_cache"])
    if "two_hop_dispatch" in opt_overrides:
        _l.TWO_HOP_DISPATCH = bool(opt_overrides["two_hop_dispatch"])
    if cfg.n_experts:
        both = sizes.get("data", 1) * sizes.get("model", 1)
        if cfg.n_experts % both == 0:
            _l.EP_AXES = ("data", "model")
        elif cfg.n_experts % sizes.get("model", 1) == 0:
            _l.EP_AXES = ("model",)
        elif cfg.n_experts % sizes.get("data", 1) == 0:
            _l.EP_AXES = ("data",)
        else:
            _l.EP_AXES = None
    else:
        _l.EP_AXES = None

    params_abs = jax.eval_shape(
        lambda: fam["init"](cfg, jax.random.PRNGKey(0)))
    p_shard = tree_shardings(params_abs, param_spec, mesh, fsdp=fsdp)

    if cell.kind == "train":
        opt = adafactor() if cfg.family == "mla_moe" else adamw()
        micro = opt_overrides.get("microbatches", 1)
        step = make_train_step(cfg, fam, opt, microbatches=micro)
        state_abs = jax.eval_shape(
            lambda: TrainState.create(
                fam["init"](cfg, jax.random.PRNGKey(0)), opt))
        s_shard = TrainState(
            params=p_shard,
            opt_state=tree_shardings(state_abs.opt_state, opt_spec, mesh,
                                     fsdp=fsdp),
            step=NamedSharding(mesh, P()))
        batch_abs = shp.batch_specs(cfg, cell)
        b_shard = tree_shardings(batch_abs, batch_spec, mesh)
        fn = jax.jit(step, in_shardings=(s_shard, b_shard),
                     out_shardings=(s_shard, None),
                     donate_argnums=(0,))
        return fn, (state_abs, batch_abs)

    if cell.kind == "prefill":
        def prefill(params, batch):
            return fam["prefill"](params, batch, cfg)

        batch_abs = shp.batch_specs(cfg, cell)
        b_shard = tree_shardings(batch_abs, batch_spec, mesh)
        fn = jax.jit(prefill, in_shardings=(p_shard, b_shard))
        return fn, (params_abs, batch_abs)

    # decode
    serve = make_serve_step(cfg, fam)
    cache_abs, tok_abs, pos_abs, key_abs = shp.decode_specs(cfg, fam, cell)
    c_shard = tree_shardings(cache_abs, cache_spec, mesh)
    t_shard = tree_shardings({"t": tok_abs}, batch_spec, mesh)["t"]
    repl = NamedSharding(mesh, P())
    fn = jax.jit(serve, in_shardings=(p_shard, c_shard, t_shard, repl,
                                      repl),
                 donate_argnums=(1,))
    return fn, (params_abs, cache_abs, tok_abs, pos_abs, key_abs)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str = "artifacts/dryrun", opt_overrides=None,
             tag: str = "") -> dict:
    cfg, _ = registry.get(arch)
    if not shp.applicable(cfg, shape_name):
        return dict(arch=arch, shape=shape_name, mesh=mesh_kind,
                    status="skipped",
                    reason="full-attention arch at 500k (DESIGN.md §5)")
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    t0 = time.time()
    fn, args = build_cell(arch, shape_name, mesh, opt_overrides)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = _mem_dict(compiled)
    cost = _cost_dict(compiled)
    hlo_text = compiled.as_text()
    coll = parse_collectives(hlo_text)

    # Trip-count-aware analysis: XLA's cost_analysis visits while bodies
    # once; hloanalysis multiplies scanned layers back in (the honest
    # numbers — raw ones are kept for comparison).
    from .hloanalysis import analyze_hlo
    corrected = analyze_hlo(hlo_text)

    raw_flops_dev = cost.get("flops", 0.0)
    raw_bytes_dev = cost.get("bytes accessed", 0.0)
    flops_dev = corrected["flops"]
    bytes_dev = corrected["hbm_bytes"]
    coll_dev = corrected["collective_total_bytes"]
    cell = shp.SHAPES[shape_name]
    tokens = cell.batch * (cell.seq if cell.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    mf = (6 if cell.kind == "train" else 2) * n_active * tokens
    terms = dict(
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=bytes_dev / HBM_BW,
        collective_s=coll_dev / ICI_BW,
    )
    raw_terms = dict(
        compute_s=raw_flops_dev / PEAK_FLOPS,
        memory_s=raw_bytes_dev / HBM_BW,
        collective_s=coll["total_bytes"] / ICI_BW,
    )
    dom = max(terms, key=terms.get)
    result = dict(
        arch=arch, shape=shape_name, mesh=mesh_kind, status="ok",
        n_devices=n_dev, kind=cell.kind,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory=mem,
        cost=dict(flops_per_device=flops_dev,
                  bytes_per_device=bytes_dev,
                  raw_flops_per_device=raw_flops_dev,
                  raw_bytes_per_device=raw_bytes_dev),
        collectives=dict(bytes=corrected["collective_bytes"],
                         counts=corrected["collective_counts"],
                         total_bytes=coll_dev,
                         raw_unrolled=coll),
        model_flops_global=float(mf),
        hlo_flops_global=flops_dev * n_dev,
        useful_flops_ratio=(float(mf) / max(flops_dev * n_dev, 1.0)),
        roofline_terms_s=terms, raw_roofline_terms_s=raw_terms,
        dominant=dom,
        opt_overrides=opt_overrides or {},
    )
    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}_{shape_name}_{mesh_kind}{('_' + tag) if tag else ''}"
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--opt", default=None,
                    help="JSON dict of optimization overrides")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    overrides = json.loads(args.opt) if args.opt else None

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in registry.ARCHS:
            for shape in shp.SHAPES:
                for m in meshes:
                    cells.append((arch, shape, m))
    else:
        cells = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    for arch, shape, m in cells:
        name = f"{arch}_{shape}_{m}"
        path = os.path.join(args.out, name + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {name}")
            continue
        try:
            r = run_cell(arch, shape, m, args.out, overrides, args.tag)
            if r["status"] == "skipped":
                print(f"[SKIP] {name}: {r['reason']}", flush=True)
                continue
            t = r["roofline_terms_s"]
            print(f"[ OK ] {name}: compile={r['compile_s']}s "
                  f"flops/dev={r['cost']['flops_per_device']:.3g} "
                  f"coll={r['collectives']['total_bytes']:.3g}B "
                  f"dom={r['dominant']} "
                  f"(c={t['compute_s']:.4f} m={t['memory_s']:.4f} "
                  f"x={t['collective_s']:.4f})", flush=True)
        except Exception as e:
            failures += 1
            print(f"[FAIL] {name}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
