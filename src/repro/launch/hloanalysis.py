"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` visits a ``while`` body ONCE
— for scanned-layer models that undercounts FLOPs/bytes/collective
traffic by the layer count (verified empirically; see EXPERIMENTS.md
§Dry-run).  This module re-derives the three roofline inputs from the
optimized HLO text with loop multiplicities applied:

  flops        2 * numel(result) * prod(contracted dims) per dot, summed
               with multiplicity; elementwise ops contribute numel.
  hbm_bytes    operand + result bytes at fusion boundaries (the XLA
               memory-traffic accounting convention), with multiplicity.
  collectives  operand bytes per collective class, with multiplicity.

Loop trip counts are recovered from the loop condition's comparison
constant (jax scans lower to a counted while); conditionals take the
max-cost branch.  Fusion/call bodies are charged flops (their dots) but
not bytes (internal traffic stays in registers/VMEM).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = dict(f64=8, f32=4, bf16=2, f16=2, s64=8, u64=8, s32=4,
                    u32=4, s16=2, u16=2, s8=1, u8=1, pred=1, f8e4m3fn=1,
                    f8e5m2=1, c64=8, c128=16, token=0, opaque=0)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\w+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(|\{)")
_CALL_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes_numel(type_str: str) -> Tuple[float, float]:
    """Total (bytes, numel) across possibly-tuple type string."""
    bts = 0.0
    numel = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        for d in dims.split(","):
            if d:
                n *= int(d)
        if not dims:
            n = 1.0
        bts += n * _DTYPE_BYTES[dt]
        numel += n
    return bts, numel


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * mult
            self.coll_counts[k] += other.coll_counts[k] * mult

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def parse_computations(hlo: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and ("{" in line):
            m = _COMP_HDR_RE.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if stripped.startswith("}"):
            continue
        if cur is None:
            continue
        m = _OP_RE.match(stripped)
        if m:
            comps[cur].append(Op(name=m.group(1), type_str=m.group(2),
                                 opcode=m.group(3), rest=m.group(4)))
    return comps


def _entry_name(hlo: str, comps: Dict[str, List[Op]]) -> str:
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip()[len("ENTRY"):].strip()
                                   if line.strip().startswith("ENTRY")
                                   else line.strip())
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m and m.group(1) in comps:
                return m.group(1)
    # fallback: computation named main
    for name in comps:
        if "main" in name:
            return name
    return next(iter(comps))


def _trip_count(cond_ops: List[Op]) -> float:
    """Counted jax loops compare the induction var with a constant."""
    best = 1.0
    for op in cond_ops:
        if op.opcode == "constant":
            m = _CONST_RE.search(op.opcode + "(" + op.rest)
            if m:
                best = max(best, float(m.group(1)))
        m = _CONST_RE.search(op.rest)
        if m:
            best = max(best, float(m.group(1)))
    return best


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps = parse_computations(hlo_text)
        self.entry = _entry_name(hlo_text, self.comps)
        self._memo: Dict[Tuple[str, bool], CostTotals] = {}
        # symbol tables: op name -> type string (for dot operand lookup)
        self.symbols: Dict[str, Dict[str, str]] = {
            cname: {op.name: op.type_str for op in ops}
            for cname, ops in self.comps.items()}
        # parameters appear as ops with opcode 'parameter'
        self.totals = self._cost(self.entry, count_bytes=True)

    # ------------------------------------------------------------------
    def _operand_names(self, rest: str) -> List[str]:
        # operands are %refs before the closing paren at depth 0
        out = []
        depth = 0
        token = ""
        for ch in rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
            token += ch
        for m in re.finditer(r"%([\w\.\-]+)", token):
            out.append(m.group(1))
        return out

    def _dot_flops(self, comp: str, op: Op) -> float:
        _, numel = _shape_bytes_numel(op.type_str)
        mult = 2.0 * numel
        m = _CONTRACT_RE.search(op.rest)
        ops = self._operand_names(op.rest)
        if m and ops:
            lhs_type = self.symbols[comp].get(ops[0], "")
            shapes = _SHAPE_RE.findall(lhs_type)
            if shapes:
                dims = [int(d) for d in shapes[0][1].split(",") if d]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        mult *= dims[int(ci)]
        return mult

    _ALIAS_OPS = ("get-tuple-element", "bitcast", "reshape", "transpose",
                  "copy", "convert")

    def _carry_gtes(self, cname: str) -> set:
        """Names of ops aliasing the loop-carry parameter (scan xs stacks
        / invariants), transitively through view-like ops.  Their bytes
        are charged ONCE at the while site, not per trip: a scan reads
        each stack element exactly once across the whole loop."""
        params = {op.name for op in self.comps.get(cname, ())
                  if op.opcode == "parameter"}
        out = set()
        changed = True
        while changed:
            changed = False
            for op in self.comps.get(cname, ()):
                if op.name in out or op.opcode not in self._ALIAS_OPS:
                    continue
                ops_ = self._operand_names(op.rest)
                if ops_ and all(o in params or o in out for o in ops_):
                    out.add(op.name)
                    changed = True
        return out

    def _cost(self, cname: str, count_bytes: bool) -> CostTotals:
        key = (cname, count_bytes)
        if key in self._memo:
            return self._memo[key]
        total = CostTotals()
        self._memo[key] = total                 # break cycles defensively
        skip_operands = self._carry_gtes(cname) if count_bytes else set()
        for op in self.comps.get(cname, ()):
            code = op.opcode
            base = code.replace("-start", "")
            if base in COLLECTIVES:
                b, _ = _shape_bytes_numel(op.type_str)
                if not code.endswith("-done"):
                    total.coll[base] += b
                    total.coll_counts[base] += 1
                continue
            if code == "while":
                body = None
                mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
                mc = _COND_RE.search(op.rest)
                if mb:
                    body = mb.group(1)
                trips = 1.0
                if mc and mc.group(1) in self.comps:
                    trips = _trip_count(self.comps[mc.group(1)])
                if body in self.comps:
                    total.add(self._cost(body, count_bytes), trips)
                if count_bytes:
                    # the carry tuple (stacked xs + invariants) streams
                    # through HBM once across the whole loop
                    total.bytes += _shape_bytes_numel(op.type_str)[0]
                continue
            if code == "conditional":
                mbr = _BRANCH_RE.search(op.rest)
                branches = []
                if mbr:
                    branches = re.findall(r"%?([\w\.\-]+)",
                                          mbr.group(1))
                sub = [self._cost(b, count_bytes) for b in branches
                       if b in self.comps]
                if sub:
                    best = max(sub, key=lambda t: (t.coll_bytes, t.flops))
                    total.add(best)
                continue
            if code in ("fusion", "call", "async-start"):
                mcall = _CALL_RE.search(op.rest)
                if mcall and mcall.group(1) in self.comps:
                    # flops inside fusions count; internal bytes do not
                    total.add(self._cost(mcall.group(1), False))
                if count_bytes:
                    b, _ = _shape_bytes_numel(op.type_str)
                    opb = []
                    for o in self._operand_names(op.rest):
                        if o in skip_operands:
                            continue
                        t = self.symbols[cname].get(o)
                        if t:
                            opb.append(_shape_bytes_numel(t)[0])
                    if "dynamic-update-slice" in op.name and opb:
                        # in-place buffer update: XLA aliases the big
                        # operand; traffic = small operands + the written
                        # slice (~= update operand), not 2x the buffer.
                        total.bytes += sum(opb) - max(opb)
                    else:
                        total.bytes += b + sum(opb)
                continue
            if code in ("dot", "convolution"):
                total.flops += self._dot_flops(cname, op)
                if count_bytes:
                    b, _ = _shape_bytes_numel(op.type_str)
                    total.bytes += b
                    for o in self._operand_names(op.rest):
                        if o in skip_operands:
                            continue
                        t = self.symbols[cname].get(o)
                        if t:
                            total.bytes += _shape_bytes_numel(t)[0]
                continue
            if code == "dynamic-update-slice":
                if count_bytes:
                    opb = []
                    for o in self._operand_names(op.rest):
                        if o in skip_operands:
                            continue
                        t = self.symbols[cname].get(o)
                        if t:
                            opb.append(_shape_bytes_numel(t)[0])
                    if opb:                       # in-place: slice traffic
                        total.bytes += sum(opb) - max(opb)
                continue
            if code in ("parameter", "constant", "get-tuple-element",
                        "tuple", "bitcast", "copy-start", "copy-done",
                        "after-all", "partition-id"):
                continue
            # elementwise / reduce / transcendental: 1 flop per output elt
            b, numel = _shape_bytes_numel(op.type_str)
            total.flops += numel
            if count_bytes and code in ("copy", "reduce", "scatter",
                                        "gather", "dynamic-slice", "sort",
                                        "transpose", "reshape", "select",
                                        "iota", "broadcast", "convert",
                                        "slice", "concatenate", "pad",
                                        "reduce-window", "rng",
                                        "select-and-scatter", "map"):
                total.bytes += b
        return total

    def summary(self) -> dict:
        t = self.totals
        return dict(flops=t.flops, hbm_bytes=t.bytes,
                    collective_bytes=dict(t.coll),
                    collective_counts=dict(t.coll_counts),
                    collective_total_bytes=t.coll_bytes)


def analyze_hlo(hlo_text: str) -> dict:
    return HloCost(hlo_text).summary()
