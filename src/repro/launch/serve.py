# seed: unused — serving driver from the repo seed; the chiplet engine has no
# serving path, nothing imports it (repro.analysis.deadcode quarantine).
"""Serving driver: continuous-batching over a reduced model.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --smoke \
      --requests 6 --slots 2 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..models import registry
from ..serving.scheduler import Request, ServeScheduler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg, fam = registry.get(args.arch, smoke=args.smoke)
    params = fam["init"](cfg, jax.random.PRNGKey(0))
    sched = ServeScheduler(cfg, fam, params, batch_slots=args.slots,
                           max_len=args.max_len,
                           temperature=args.temperature)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              size=rng.integers(3, 10)).astype(np.int32)
        sched.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))
    done = sched.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)}/{args.requests} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks/max(dt,1e-9):.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} "
              f"-> {r.out[:6]}")
    return done


if __name__ == "__main__":
    main()
