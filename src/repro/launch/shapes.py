"""Assigned input shapes x architecture -> abstract input specs.

Every (arch, shape) cell resolves to a step kind and a pytree of
ShapeDtypeStructs (weak-type-correct, shardable, no allocation):

  train_4k     train_step   seq=4096    global_batch=256
  prefill_32k  prefill      seq=32768   global_batch=32
  decode_32k   serve_step   cache=32768 global_batch=128
  long_500k    serve_step   cache=524288 global_batch=1 (sub-quadratic only)

Whisper note: the assigned seq_len is the *audio frame* length (encoder);
the decoder runs its native 448-token context (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

S = jax.ShapeDtypeStruct
BF16 = jnp.bfloat16
I32 = jnp.int32

WHISPER_DEC = 448


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str                # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def applicable(cfg, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.subquadratic
    return True


def batch_specs(cfg, cell: ShapeCell) -> dict:
    """Abstract train/prefill batch for an architecture."""
    b, s = cell.batch, cell.seq
    if cfg.family == "encdec":
        d = min(WHISPER_DEC, s)
        out = dict(embeds=S((b, s, cfg.d_model), BF16),
                   tokens=S((b, d), I32))
        if cell.kind == "train":
            out["labels"] = S((b, d), I32)
        return out
    if cfg.input_embeds:
        out = dict(embeds=S((b, s, cfg.d_model), BF16))
        if cell.kind == "train":
            out["labels"] = S((b, s), I32)
        return out
    out = dict(tokens=S((b, s), I32))
    if cell.kind == "train":
        out["labels"] = S((b, s), I32)
    return out


def cache_specs(cfg, fam, cell: ShapeCell):
    """Abstract decode cache via the family's init_cache under eval_shape."""
    return jax.eval_shape(
        lambda: fam["init_cache"](cfg, cell.batch, cell.seq))


def decode_specs(cfg, fam, cell: ShapeCell):
    cache = cache_specs(cfg, fam, cell)
    tokens = S((cell.batch, 1), I32)
    pos = S((), I32)
    key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    return cache, tokens, pos, key
