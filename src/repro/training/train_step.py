"""Training step factory.

Builds a jitted train step for any registered architecture, with:
  * microbatched gradient accumulation (lax.scan over the micro axis),
  * global-norm gradient clipping,
  * optimizer update (adamw / adafactor),
  * optional *explicit* proxy gradient sync (the paper's hierarchical
    schedule) when the step is built in manual (shard_map) mode — the
    default GSPMD mode lets the partitioner place the reductions and is
    what the dry-run lowers.

The GSPMD path is a plain jax.jit over (state, batch) with shardings
attached by launch/shardings.py; batch is sharded over ('pod','data') so
gradients are averaged over the batch axes by the partitioner.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models.lm import lm_loss
from .optimizer import Optimizer

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt_state: PyTree
    step: jnp.ndarray

    @staticmethod
    def create(params, optimizer: Optimizer) -> "TrainState":
        return TrainState(params=params, opt_state=optimizer.init(params),
                          step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), norm


def make_loss_fn(cfg, fam, mtp_weight: float = 0.1):
    def loss_fn(params, batch):
        logits, aux = fam["forward"](params, batch, cfg)
        labels = batch["labels"]
        if isinstance(logits, tuple):              # deepseek-v3 MTP head
            main, mtp = logits
            # MTP predicts token t+2: shift labels one extra step.
            mtp_labels = jnp.roll(labels, -1, axis=1)
            return (lm_loss(main, labels, cfg, aux)
                    + mtp_weight * lm_loss(mtp, mtp_labels, cfg))
        return lm_loss(logits, labels, cfg, aux)

    return loss_fn


def make_train_step(cfg, fam, optimizer: Optimizer,
                    microbatches: int = 1,
                    clip_norm: float = 1.0,
                    mtp_weight: float = 0.1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves are (B, ...) arrays; with microbatches > 1 the leading
    axis is split (B = microbatches * micro_bs) and gradients accumulate
    across a lax.scan — compute of microbatch i+1 overlaps the reduction
    tail of i under GSPMD's async collectives.
    """
    loss_fn = make_loss_fn(cfg, fam, mtp_weight)

    def train_step(state: TrainState, batch):
        params = state.params

        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            micro = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc_body(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grads_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32),
                    grads_acc, grads)
                return (loss_acc + loss, grads_acc), None

            (loss, grads), _ = jax.lax.scan(acc_body, (0.0, zero), micro)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               params, state.step)
        metrics = dict(loss=loss, grad_norm=gnorm,
                       step=state.step.astype(jnp.float32))
        return TrainState(params=new_params, opt_state=new_opt,
                          step=state.step + 1), metrics

    return train_step
