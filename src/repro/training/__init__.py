from .optimizer import adamw, adafactor
from .train_step import TrainState, make_train_step

__all__ = ["adamw", "adafactor", "TrainState", "make_train_step"]
