"""Optimizers (pytree-native, no external deps).

adamw      — default for the <=15B dense archs.
adafactor  — factored second moment, no first moment: the optimizer state
             for deepseek-v3-671b must stay sub-linear in params to fit a
             256-chip pod (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, jnp.ndarray], tuple]
    name: str = "opt"


# ------------------------------------------------------------------- adamw
def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          warmup: int = 100) -> Optimizer:
    """``warmup`` is a linear lr ramp from 0; callers running short smoke
    loops must size it well below the step budget (launch/train.py does
    this automatically) or the whole run executes at near-zero lr."""
    warmup = max(warmup, 1)

    def init(params):
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return dict(mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))

    def schedule(step):
        w = jnp.minimum(step.astype(jnp.float32) / warmup, 1.0)
        return lr * w

    def update(grads, state, params, step):
        lr_t = schedule(step)
        t = step.astype(jnp.float32) + 1.0

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * gf * gf
            mhat = m2 / (1 - b1 ** t)
            vhat = v2 / (1 - b2 ** t)
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            if p.ndim >= 2:                       # decay matrices only
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * step_).astype(p.dtype), \
                m2, v2

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda o: o[2], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, dict(mu=new_mu, nu=new_nu)

    return Optimizer(init=init, update=update, name="adamw")


# --------------------------------------------------------------- adafactor
def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, warmup: int = 100) -> Optimizer:
    """Factored RMS optimizer (Shazeer & Stern): O(rows+cols) state for
    matrices, O(n) for vectors; no momentum."""
    warmup = max(warmup, 1)

    def init(params):
        def one(p):
            if p.ndim >= 2:
                return dict(
                    vr=jnp.zeros(p.shape[:-1], jnp.float32),
                    vc=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
            return dict(v=jnp.zeros(p.shape, jnp.float32))

        return jax.tree.map(one, params)

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr * jnp.minimum(t / warmup, 1.0)

        def one(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(
                        jnp.mean(vr, axis=-1, keepdims=True), eps))
                cfac = jax.lax.rsqrt(vc)
                u = gf * rfac[..., None] * cfac[..., None, :]
                ns = dict(vr=vr, vc=vc)
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(v)
                ns = dict(v=v)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), ns

        # grads is a structural prefix of state (state has a dict per leaf)
        out = jax.tree.map(one, grads, state, params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda o: o[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_params, new_state

    return Optimizer(init=init, update=update, name="adafactor")
