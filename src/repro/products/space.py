"""The package-time product design space (paper §IV, Fig. 9/10).

One silicon design — the DCRA die — becomes many chip *products* at
packaging time: memory style (SRAM-only, interposer HBM, 3D-stacked
HBM), the Fig. 6 network options (intra-die link width, inter-die link
width x count), and SRAM capacity per tile.  ``product_space`` spans
the cross-product as concrete :class:`PackageConfig` objects the cost
model prices directly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from ..core.costmodel import NETWORK_OPTIONS, PackageConfig

# Memory integration styles (Fig. 5): name -> (hbm_gb_per_die, vertical)
MEMORY_STYLES: Dict[str, tuple] = {
    "sram": (0.0, False),
    "hbm-horiz": (8.0, False),
    "hbm-vert": (8.0, True),
}

DEFAULT_SRAM_MIB = (1.5,)
FULL_SRAM_MIB = (0.75, 1.5, 3.0)


def product_space(memory: Sequence[str] = tuple(MEMORY_STYLES),
                  network: Sequence[str] = tuple(NETWORK_OPTIONS),
                  sram_mib: Sequence[float] = DEFAULT_SRAM_MIB,
                  ) -> List[PackageConfig]:
    """Cross-product of package-time decisions as PackageConfigs.

    Names encode the decisions (``hbm-vert/net-c/sram1.5``) so sweep
    tables are self-describing.  Defaults give the 3 x 4 = 12-config
    space of the paper's evaluation; pass ``sram_mib=FULL_SRAM_MIB`` for
    the 36-config full sweep.
    """
    configs = []
    for mem in memory:
        hbm_gb, vertical = MEMORY_STYLES[mem]
        for netkey in network:
            net = NETWORK_OPTIONS[netkey]
            for mib in sram_mib:
                configs.append(dataclasses.replace(
                    net,
                    name=f"{mem}/net-{net.name}/sram{mib:g}",
                    sram_per_tile_mib=mib,
                    hbm_gb_per_die=hbm_gb,
                    hbm_vertical=vertical,
                ))
    return configs
