"""The package-time product design space (paper §IV, Fig. 9/10).

One silicon design — the DCRA die — becomes many chip *products* at
packaging time: memory style (SRAM-only, interposer HBM, 3D-stacked
HBM), the Fig. 6 network options (intra-die link width, inter-die link
width x count), SRAM capacity per tile, and — the multi-node regime —
the chip partitioning (how many separately packaged chips the tile grid
splits into at board level) together with the per-axis board-link
provisioning between them.  ``product_space`` spans the cross-product as
concrete :class:`PackageConfig` objects the cost model prices directly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from ..core.costmodel import NETWORK_OPTIONS, PackageConfig
from ..core.tilegrid import partition_grid, square_grid

# Memory integration styles (Fig. 5): name -> (hbm_gb_per_die, vertical)
MEMORY_STYLES: Dict[str, tuple] = {
    "sram": (0.0, False),
    "hbm-horiz": (8.0, False),
    "hbm-vert": (8.0, True),
}

DEFAULT_SRAM_MIB = (1.5,)
FULL_SRAM_MIB = (0.75, 1.5, 3.0)

# Chip-partitioning axis (paper §V multi-node regime): block
# partitionings the sweep explores, and the default per-axis board-link
# provisioning (2 matches the distributed runtime's historical value).
CHIP_COUNTS = (1, 4, 16, 64)
DEFAULT_BOARD_LINKS = (2,)


def product_space(memory: Sequence[str] = tuple(MEMORY_STYLES),
                  network: Sequence[str] = tuple(NETWORK_OPTIONS),
                  sram_mib: Sequence[float] = DEFAULT_SRAM_MIB,
                  chips: Sequence[int] = (0,),
                  board_links: Sequence[int] = DEFAULT_BOARD_LINKS,
                  ) -> List[PackageConfig]:
    """Cross-product of package-time decisions as PackageConfigs.

    Names encode the decisions (``hbm-vert/net-c/sram1.5/c16/bl4``) so
    sweep tables are self-describing.  Defaults give the 3 x 4 =
    12-config space of the paper's evaluation; pass
    ``sram_mib=FULL_SRAM_MIB`` for the 36-config full sweep, and
    ``chips=CHIP_COUNTS`` (x ``board_links`` provisioning values) to add
    the chip-partitioning axis — each chip count is priced as a
    board-level product of separately packaged chips, and
    :meth:`ProductSearch.sweep` measures it on the distributed runtime.
    Unpartitioned configs (``chips`` 0, the default) carry no name
    suffix, keeping the historical 12-config names stable.
    """
    configs = []
    for mem in memory:
        hbm_gb, vertical = MEMORY_STYLES[mem]
        for netkey in network:
            net = NETWORK_OPTIONS[netkey]
            for mib in sram_mib:
                for n in chips:
                    for bl in (board_links if n > 1
                               else DEFAULT_BOARD_LINKS[:1]):
                        suffix = f"/c{n}" if n >= 1 else ""
                        if n > 1 and bl != DEFAULT_BOARD_LINKS[0]:
                            suffix += f"/bl{bl}"
                        configs.append(dataclasses.replace(
                            net,
                            name=f"{mem}/net-{net.name}/sram{mib:g}"
                                 f"{suffix}",
                            sram_per_tile_mib=mib,
                            hbm_gb_per_die=hbm_gb,
                            hbm_vertical=vertical,
                            chips=n,
                            board_links_y=bl,
                            board_links_x=bl,
                        ))
    return configs


def chip_counts_for(tiles: int,
                    counts: Sequence[int] = CHIP_COUNTS) -> List[int]:
    """The subset of ``counts`` that block-partitions a square grid of
    ``tiles`` tiles, deduplicated (chips<=1 all normalize to 1, which
    always qualifies)."""
    grid = square_grid(tiles)
    out: List[int] = []
    for n in counts:
        n = max(n, 1)
        if n in out:
            continue
        if n > 1:
            try:
                partition_grid(grid, n)
            except ValueError:
                continue
        out.append(n)
    return out
