"""Package-time product search (paper §IV, Fig. 9/10).

One silicon design, many chip products: measure an application's traffic
once on the engine, cache the per-superstep counter vectors, and
analytically re-price them across the packaging design space (memory
style x network option x SRAM capacity) to select Pareto-optimal
products per target metric.
"""
from .cache import CounterCache, stable_hash
from .search import (OBJECTIVES, Measurement, MeasureSpec, ProductSearch,
                     pareto_front, product_row, select_products)
from .space import (CHIP_COUNTS, DEFAULT_BOARD_LINKS, DEFAULT_SRAM_MIB,
                    FULL_SRAM_MIB, MEMORY_STYLES, chip_counts_for,
                    product_space)

__all__ = [
    "CounterCache", "stable_hash",
    "OBJECTIVES", "Measurement", "MeasureSpec", "ProductSearch",
    "pareto_front", "product_row", "select_products",
    "CHIP_COUNTS", "DEFAULT_BOARD_LINKS", "DEFAULT_SRAM_MIB",
    "FULL_SRAM_MIB", "MEMORY_STYLES", "chip_counts_for", "product_space",
]
