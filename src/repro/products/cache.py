"""Disk cache of measured counter vectors (measure-once / price-many).

A 16384-tile engine run takes minutes; re-pricing it under a package
config takes microseconds.  The cache stores everything ``price()``
needs — whole-run :class:`TrafficCounters`, the per-superstep
:class:`SuperstepTrace`, and the memory-traffic totals — as one JSON
file per measurement, keyed by a stable hash of the measurement spec
(app, dataset, grid, cascade config, ...).  Product sweeps then re-price
the cached traffic across the whole package design space without ever
re-running the engine.

Files are written atomically (tmp + rename) so an interrupted sweep
never leaves a corrupt entry; unreadable entries are treated as misses.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Dict, Optional

# v2: SuperstepTrace payloads carry the measured chip-partition geometry
# (chips_y / chips_x) — v1 entries predate the chips packaging axis and
# are rejected as misses (re-measured), never silently re-priced without
# their partition geometry.
SCHEMA_VERSION = 2


def stable_hash(obj) -> str:
    """Deterministic short hash of a JSON-serializable spec."""
    blob = json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


class CounterCache:
    """One-JSON-file-per-measurement store under ``root``."""

    def __init__(self, root: str):
        self.root = root

    def path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.json")

    def get(self, key: str) -> Optional[Dict]:
        try:
            with open(self.path(key)) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            return None
        if not isinstance(payload, dict) or \
                payload.get("schema") != SCHEMA_VERSION:
            return None
        return payload

    def put(self, key: str, payload: Dict) -> str:
        payload = dict(payload, schema=SCHEMA_VERSION)
        os.makedirs(self.root, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, self.path(key))
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return self.path(key)
