"""Package-time product search: measure once, price many (paper §IV).

The engine run is the expensive part (a 16384-tile run takes minutes);
pricing is purely analytic over the measured traffic.  ``ProductSearch``
therefore splits the design-space exploration loop into:

  1. **measure** — run each (app, dataset, cascade level/grouping)
     combination through the engine exactly once and cache the counter
     vectors (whole-run :class:`TrafficCounters` + the per-superstep
     :class:`SuperstepTrace`) as JSON on disk, keyed by a stable hash of
     the spec;
  2. **sweep** — re-price the cached traffic across an arbitrary set of
     :class:`PackageConfig` products (``costmodel.price`` recomputes the
     BSP time superstep-wise under each config's link widths/counts, NoC
     count and HBM channels);
  3. **select** — Pareto-filter the swept rows per target metric pair
     and pick the best product per objective (time-to-solution, energy,
     $, throughput/$, efficiency/$) — the paper's claim that one silicon
     design yields differently-optimal chip products post-silicon.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.costmodel import (PackageConfig, SystemReport,
                              dcache_memory_bits, price)
from ..core.netstats import MSG_BITS, SuperstepTrace, TrafficCounters
from ..core.tilegrid import TileGrid, partition_grid, square_grid
from .cache import CounterCache, stable_hash

DEFAULT_CACHE_DIR = ".repro_cache/products"

# Objectives a product can be selected for: (row key, maximize?)
OBJECTIVES: Dict[str, Tuple[str, bool]] = {
    "time": ("time_s", False),
    "energy": ("energy_j", False),
    "cost": ("cost_usd", False),
    "throughput_per_dollar": ("thr_per_usd", True),
    "efficiency_per_dollar": ("eff_per_usd", True),
}


@dataclasses.dataclass(frozen=True)
class MeasureSpec:
    """One engine measurement: app + dataset + grid + cascade policy.

    Everything that changes the measured traffic belongs here (it is the
    cache key); everything that only changes pricing belongs in the
    :class:`PackageConfig` sweep instead.
    """

    app: str                  # bfs | sssp | wcc | pagerank | spmv | histo
    scale: int                # RMAT scale (log2 vertices) / log2 elements
    tiles: int                # square tile grid size
    edge_factor: int = 8
    seed: int = 1
    oq_cap: int = 32
    slots: int = 512
    region_div: int = 4
    cascade_levels: int = 0
    cascade_group: int = 2
    selective: bool = True
    chips: int = 0            # >1: measure on the distributed runtime
    epochs: int = 3           # pagerank only

    def key(self) -> str:
        return stable_hash(dict(dataclasses.asdict(self), v=1))

    @property
    def label(self) -> str:
        casc = (f"/casc{self.cascade_levels}x{self.cascade_group}"
                if self.cascade_levels else "")
        chips = f"/{self.chips}chips" if self.chips > 1 else ""
        return f"{self.app}/s{self.scale}/{self.tiles}t{casc}{chips}"


@dataclasses.dataclass
class Measurement:
    """Cached engine output: everything pricing needs, nothing more."""

    spec: MeasureSpec
    counters: TrafficCounters
    trace: SuperstepTrace
    touched_bits: float       # dataset bits touched (drives the D$ model)
    dataset_bits: float       # resident dataset footprint
    teps_edges: float
    time_s: float             # measured under the spec's own run config
    supersteps: int
    from_cache: bool = False

    @property
    def grid(self) -> TileGrid:
        return square_grid(self.spec.tiles)

    def to_payload(self) -> Dict:
        return dict(spec=dataclasses.asdict(self.spec),
                    counters=self.counters.as_dict(),
                    trace=self.trace.to_dict(),
                    touched_bits=self.touched_bits,
                    dataset_bits=self.dataset_bits,
                    teps_edges=self.teps_edges,
                    time_s=self.time_s, supersteps=self.supersteps)

    @classmethod
    def from_payload(cls, spec: MeasureSpec, payload: Dict) -> "Measurement":
        c = TrafficCounters()
        for k, v in payload["counters"].items():
            if hasattr(c, k):
                setattr(c, k, v)
        return cls(spec=spec, counters=c,
                   trace=SuperstepTrace.from_dict(payload["trace"]),
                   touched_bits=float(payload["touched_bits"]),
                   dataset_bits=float(payload["dataset_bits"]),
                   teps_edges=float(payload.get("teps_edges", 0.0)),
                   time_s=float(payload["time_s"]),
                   supersteps=int(payload["supersteps"]),
                   from_cache=True)


class ProductSearch:
    """Measure-once / price-many sweep over the package design space."""

    def __init__(self, cache_dir: str = DEFAULT_CACHE_DIR):
        self.cache = CounterCache(cache_dir)
        self.engine_runs = 0     # measurements that actually ran the engine

    # ------------------------------------------------------------- measure
    @staticmethod
    def validate(spec: MeasureSpec) -> None:
        """Reject unmeasurable specs up front with actionable errors —
        before dataset generation, and instead of silently passing a
        ``chips`` the app layer cannot honour."""
        from ..graph import apps
        if spec.app not in apps.APPS:
            raise ValueError(
                f"unknown app {spec.app!r}; measurable apps: "
                f"{sorted(apps.APPS)}")
        if spec.chips > 1:
            if spec.app not in apps.DISTRIBUTED_APPS:
                raise ValueError(
                    f"app {spec.app!r} does not support distributed "
                    f"measurement (chips={spec.chips}); distributed apps: "
                    f"{sorted(apps.DISTRIBUTED_APPS)}")
            try:
                partition_grid(square_grid(spec.tiles), spec.chips)
            except ValueError as e:
                raise ValueError(
                    f"spec {spec.label!r}: chips={spec.chips} cannot "
                    f"block-partition the {spec.tiles}-tile grid ({e})"
                ) from None

    def measure(self, spec: MeasureSpec,
                run_chunk: Optional[int] = None) -> Measurement:
        """Cached engine measurement of ``spec``.

        ``run_chunk`` only selects the run loop's supersteps-per-dispatch
        (chunked execution is bit-identical to per-step — see
        tests/test_chunked.py), so it is *not* part of the cache key.
        """
        self.validate(spec)
        from ..obs.metrics import default_registry
        reg = default_registry()
        key = spec.key()
        payload = self.cache.get(key)
        if payload is not None:
            reg.counter("products.measure.cache_hits").inc()
            return Measurement.from_payload(spec, payload)
        reg.counter("products.measure.cache_misses").inc()
        m = self._run_engine(spec, run_chunk=run_chunk)
        self.cache.put(key, m.to_payload())
        return m

    def _run_engine(self, spec: MeasureSpec,
                    run_chunk: Optional[int] = None) -> Measurement:
        from ..graph import apps
        from ..graph.rmat import rmat_edges

        self.engine_runs += 1
        grid = square_grid(spec.tiles)
        proxy = apps.table2_proxy(
            grid, spec.app, slots=spec.slots, region_div=spec.region_div,
            cascade_levels=spec.cascade_levels,
            cascade_group=spec.cascade_group, selective=spec.selective)
        kw = dict(proxy=proxy, oq_cap=spec.oq_cap)
        if spec.chips > 1:
            kw["chips"] = spec.chips
        if run_chunk is not None:
            kw["run_chunk"] = run_chunk
        if spec.app == "histo":
            rng = np.random.default_rng(spec.seed)
            n = spec.edge_factor << spec.scale
            bins = max(grid.num_tiles, 1 << spec.scale >> 3)
            values = rng.integers(0, bins, size=n, dtype=np.int32)
            r = apps.histogram(values, bins, grid, **kw)
            dataset_bits = float(values.nbytes * 8)
        else:
            g = rmat_edges(spec.scale, edge_factor=spec.edge_factor,
                           seed=spec.seed)
            dataset_bits = float(g.footprint_bytes() * 8)
            if spec.app in ("bfs", "sssp"):
                root = int(np.argmax(g.out_degree()))
                r = getattr(apps, spec.app)(g, root, grid, **kw)
            elif spec.app == "wcc":
                r = apps.wcc(g, grid, **kw)
            elif spec.app == "pagerank":
                r = apps.pagerank(g, grid, epochs=spec.epochs, **kw)
            elif spec.app == "spmv":
                rng = np.random.default_rng(spec.seed)
                x = rng.random(g.n_cols).astype(np.float32)
                r = apps.spmv(g, x, grid, **kw)
            else:
                raise ValueError(f"unknown app {spec.app!r}")
        # normalize device scalars (np.float32) to Python floats so a
        # live measurement prices bit-identically to its cached JSON form
        c = TrafficCounters()
        for k, v in r.run.counters.as_dict().items():
            setattr(c, k, v)
        touched = (c.edges_processed + c.records_consumed) * MSG_BITS
        return Measurement(spec=spec, counters=c, trace=r.run.trace,
                           touched_bits=float(touched),
                           dataset_bits=dataset_bits,
                           teps_edges=float(r.teps_edges),
                           time_s=float(r.run.time_s),
                           supersteps=r.run.supersteps)

    # --------------------------------------------------------------- price
    def price_product(self, m: Measurement,
                      cfg: PackageConfig) -> SystemReport:
        """Analytic re-pricing of one measurement under one product,
        using the shared D$ memory policy (``dcache_memory_bits``).

        A config that names a chip count must be priced on a measurement
        taken at that chip count — the trace's off-chip traffic is a
        property of the measured partition (``sweep`` re-measures per
        chip count; ``price`` enforces the same rule on the trace).
        """
        if cfg.chips >= 1 and cfg.chips != max(m.spec.chips, 1):
            raise ValueError(
                f"product {cfg.name!r} is a {cfg.chips}-chip packaging "
                f"but measurement {m.spec.label!r} ran on "
                f"{max(m.spec.chips, 1)} chip(s); measure at "
                f"chips={cfg.chips} (sweep() does this per chip count)")
        sram, hbm = dcache_memory_bits(cfg, m.touched_bits)
        return price(cfg, m.grid, m.counters, mem_bits_sram=sram,
                     mem_bits_hbm=hbm, per_superstep_peak=m.trace)

    # --------------------------------------------------------------- sweep
    @staticmethod
    def spec_for_product(spec: MeasureSpec,
                         cfg: PackageConfig) -> MeasureSpec:
        """The measurement a product config must be priced on: the spec
        re-based to the config's chip count (chips<=1 products price the
        monolithic measurement; chips=0 configs inherit the spec's own
        partition)."""
        if cfg.chips == 0:
            return spec
        chips = cfg.chips if cfg.chips > 1 else 0
        if chips == spec.chips:
            return spec
        return dataclasses.replace(spec, chips=chips)

    def sweep(self, specs: Iterable[MeasureSpec],
              configs: Sequence[PackageConfig]) -> List[Dict]:
        """Measure each spec once *per chip count*, price it under every
        config of that chip count.

        Configs with ``chips >= 1`` re-base the spec onto the distributed
        runtime at that partition (measured once and cached like any
        other spec); all same-chip-count configs re-price the one cached
        board-level trace analytically.  Returns flat rows (one per spec
        x config) carrying the metric columns the paper's Fig. 9/10
        curves plot.
        """
        rows = []
        for spec in specs:
            measured: Dict[str, Measurement] = {}
            for cfg in configs:
                s = self.spec_for_product(spec, cfg)
                m = measured.get(s.key())
                if m is None:
                    m = measured[s.key()] = self.measure(s)
                rep = self.price_product(m, cfg)
                rows.append(product_row(m, cfg, rep))
        return rows


def product_row(m: Measurement, cfg: PackageConfig,
                rep: SystemReport) -> Dict:
    gteps = m.teps_edges / max(rep.time_s, 1e-12) / 1e9
    return dict(
        measurement=m.spec.label, product=cfg.name,
        app=m.spec.app, tiles=m.spec.tiles,
        chips=max(m.spec.chips, 1),
        cascade_levels=m.spec.cascade_levels,
        cascade_group=m.spec.cascade_group,
        time_s=rep.time_s, energy_j=rep.energy_j, cost_usd=rep.cost_usd,
        power_w=rep.power_w, gteps=gteps,
        thr_per_usd=rep.throughput_per_dollar,
        eff_per_usd=rep.efficiency_per_dollar,
        cascade_combined=m.counters.cascade_combined,
        cross_region_msgs=m.counters.cross_region_msgs,
        from_cache=m.from_cache,
    )


# --------------------------------------------------------------------------
# Pareto selection
# --------------------------------------------------------------------------
def _objective_value(row: Dict, metric: str) -> float:
    key, maximize = OBJECTIVES[metric]
    v = float(row[key])
    return v if maximize else -v


def pareto_front(rows: Sequence[Dict],
                 metrics: Tuple[str, str] = ("throughput_per_dollar",
                                             "efficiency_per_dollar"),
                 ) -> List[Dict]:
    """Non-dominated rows on a metric pair (both oriented to maximize).

    A row is dominated when another row is >= on both objectives and
    strictly > on at least one.
    """
    vals = [(_objective_value(r, metrics[0]),
             _objective_value(r, metrics[1])) for r in rows]
    front = []
    for i, (a0, a1) in enumerate(vals):
        dominated = any(
            (b0 >= a0 and b1 >= a1) and (b0 > a0 or b1 > a1)
            for j, (b0, b1) in enumerate(vals) if j != i)
        if not dominated:
            front.append(rows[i])
    return front


def select_products(rows: Sequence[Dict],
                    objectives: Optional[Sequence[str]] = None,
                    ) -> Dict[str, Dict]:
    """Best product per objective over the given rows.

    Pass one measurement's rows to pick its per-objective winners — the
    package-time reconfiguration story in one table: the same measured
    run selects *different* products depending on what the customer
    optimizes for.
    """
    objectives = list(objectives or OBJECTIVES)
    out = {}
    for metric in objectives:
        out[metric] = max(rows, key=lambda r: _objective_value(r, metric))
    return out
