"""Distributed multi-chip runtime: chip partitioning, per-chip engine
supersteps, boundary exchange, and the 1 -> 256-chip scaling harness."""
from .driver import (DistributedEngine, exchange, partition,  # noqa: F401
                     run_distributed)
