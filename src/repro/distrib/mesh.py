"""ExecMesh: the one device-placement abstraction of the distributed runtime.

The driver used to branch between two parallel renderings of every step
and chunk function — a vmapped single-device emulation and a
``shard_map`` multi-device path — that had to be kept numerically in
lockstep by hand.  :class:`ExecMesh` collapses the branching: it names a
placement (``ndev`` devices x ``per`` chips per device over one
``chips`` mesh axis) and exposes exactly the collective vocabulary the
distributed superstep needs (``axis_index`` / ``psum`` / ``pmax`` /
``all_gather`` / ``gather_records``) plus a ``shard_jit`` wrapper.

On a single device every helper degenerates to the identity / local
reduction (``axis_index`` is 0, ``per == num_chips``, gathers are
no-ops), so ONE step function written against the mesh reproduces the
old vmapped emulation *bitwise* — the exchanged records flatten to the
exact same scatter indices — while the same function under a real
multi-device mesh runs the collective path.  Single-device meshes are
traceable outside ``shard_map`` (no collectives appear), which is what
lets the analysis passes abstract-trace the distributed chunk function.

Placement is chosen by :meth:`ExecMesh.build`: any ``ndev`` that divides
the chip count works, and when the host's device count does not divide
it the mesh falls back to the largest dividing device subset with a
warning instead of failing (the old driver raised a hard ``ValueError``).
Force real CPU devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before JAX
is imported — see ``tests/_subproc.py``).
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp

from ..core import collectives
from ..core.compat import shard_map


def largest_dividing_devices(num_chips: int, device_count: int) -> int:
    """The largest ``ndev <= device_count`` with ``num_chips % ndev == 0``
    (>= 1 always: one device trivially divides any chip count)."""
    ndev = max(1, min(int(device_count), int(num_chips)))
    while num_chips % ndev:
        ndev -= 1
    return ndev


@dataclasses.dataclass(frozen=True)
class ExecMesh:
    """A ``num_chips = ndev * per`` placement over one mesh axis."""

    num_chips: int
    ndev: int
    axis: str = "chips"

    def __post_init__(self):
        if self.ndev < 1 or self.num_chips % self.ndev:
            raise ValueError(
                f"{self.ndev} devices do not divide {self.num_chips} chips")

    # ------------------------------------------------------------ geometry
    @property
    def per(self) -> int:
        """Chips per device (the vmapped width inside each shard)."""
        return self.num_chips // self.ndev

    @property
    def is_sharded(self) -> bool:
        return self.ndev > 1

    @property
    def backend_name(self) -> str:
        """The driver's historical backend label for this placement."""
        return "shard_map" if self.is_sharded else "vmap"

    # ------------------------------------------------------------- factory
    @classmethod
    def build(cls, num_chips: int, backend: str = "auto",
              device_count: int | None = None) -> "ExecMesh":
        """Choose a placement for ``num_chips`` chips.

        ``backend``: 'auto' (multi-device when more than one device can
        divide the chips), 'vmap' (force single-device emulation) or
        'shard_map' (request multi-device; falls back gracefully).  When
        ``device_count`` (default ``jax.device_count()``) does not divide
        the chip count, the mesh uses the largest dividing subset and
        warns — it never raises.
        """
        if backend not in ("auto", "vmap", "shard_map"):
            raise ValueError(f"unknown distributed backend {backend!r}")
        dc = jax.device_count() if device_count is None else int(device_count)
        if backend == "vmap" or num_chips == 1:
            return cls(num_chips, 1)
        ndev = largest_dividing_devices(num_chips, dc)
        if backend == "shard_map" and ndev < dc:
            warnings.warn(
                f"{num_chips} chips do not divide {dc} devices; falling "
                f"back to the largest dividing subset ({ndev} device"
                f"{'s' if ndev != 1 else ''}, {num_chips // ndev} chips "
                f"per device)", RuntimeWarning, stacklevel=2)
        if backend == "auto" and ndev == 1:
            return cls(num_chips, 1)
        return cls(num_chips, ndev)

    # ----------------------------------------- in-region collective helpers
    # Each is the identity / a local reduction on a single-device mesh, so
    # the step function stays traceable outside shard_map there.
    def axis_index(self):
        if not self.is_sharded:
            return jnp.int32(0)
        return jax.lax.axis_index(self.axis)

    def chip_ids(self):
        """Global chip ids of this device's ``per`` chips."""
        return (self.axis_index() * self.per
                + jnp.arange(self.per, dtype=jnp.int32))

    def psum(self, x):
        return jax.lax.psum(x, self.axis) if self.is_sharded else x

    def pmax(self, x):
        return jax.lax.pmax(x, self.axis) if self.is_sharded else x

    def all_gather(self, x):
        """Tiled all-gather along the mesh axis (identity on one device:
        the stacked array already holds every chip)."""
        if not self.is_sharded:
            return x
        return jax.lax.all_gather(x, self.axis, tiled=True)

    def gather_records(self, parts):
        """Exchange compact per-device record buffers: every device ends
        up holding the full ``(num_chips * R,)`` record stream in chip
        order (see ``collectives.gather_records``)."""
        if not self.is_sharded:
            return parts
        return collectives.gather_records(parts, self.axis)

    # ----------------------------------------------------------- jit wrapper
    def shard_jit(self, fn, in_specs, out_specs):
        """``jax.jit(fn)`` on one device; ``jit(shard_map(fn, ...))`` on a
        real mesh.  ``in_specs`` / ``out_specs`` are pytrees of booleans
        (prefix trees allowed, like shard_map's): True = partitioned
        along the chips axis, False = replicated.
        """
        if not self.is_sharded:
            return jax.jit(fn)
        from jax.sharding import PartitionSpec as P

        def conv(tree):
            return jax.tree.map(lambda b: P(self.axis) if b else P(), tree)

        mesh = jax.make_mesh((self.ndev,), (self.axis,))
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=conv(in_specs),
                                 out_specs=conv(out_specs), check_vma=False))
