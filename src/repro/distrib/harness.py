"""Weak/strong-scaling harness: measured GTEPS across 1..256 emulated
chips (paper Fig. 11 multi-package regime, §V-D Graph500 comparison).

Replaces the old Graph500 *projection* with a measured curve: each chip
count actually runs the distributed engine (per-chip supersteps +
boundary exchange + off-chip charging) and reports GTEPS together with
the energy/$ report in which off-chip traffic is priced.

Weak scaling follows the paper's experiment shape: the per-chip tile
subgrid and per-chip dataset share stay constant while chips grow, so
the RMAT scale rises with the chip count.  Strong scaling fixes the
grid and dataset and only re-partitions across more chips.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.costmodel import DCRA_SRAM, PackageConfig, price
from ..core.tilegrid import TileGrid, partition_grid, square_grid
from ..graph.rmat import rmat_edges

WEAK_CHIP_COUNTS = (1, 4, 16, 64, 256)


def chip_grid(chips: int, tiles_per_chip: int) -> TileGrid:
    """Tile grid of ``chips`` square subgrids of ``tiles_per_chip`` tiles,
    arranged on the most square chip grid that ``chips`` factors into —
    so any chip count works, not only those making a square tile grid
    (e.g. chips=2, tiles_per_chip=16 -> a 4x8 grid of two 4x4 chips)."""
    s = int(round(math.sqrt(tiles_per_chip)))
    if s * s != tiles_per_chip:
        raise ValueError(f"tiles_per_chip={tiles_per_chip} must be a "
                         f"perfect square")
    best = None
    for cy in range(1, chips + 1):
        if chips % cy == 0:
            cx = chips // cy
            if best is None or abs(cy - cx) < abs(best[0] - best[1]):
                best = (cy, cx)
    cy, cx = best
    return TileGrid(cy * s, cx * s)


def _measure(g, grid, chips: int, oq_cap: int, pkg: PackageConfig,
             backend: str, use_proxy: bool,
             run_chunk: Optional[int] = None,
             double_buffer: bool = False) -> Dict[str, float]:
    from ..graph import apps
    root = int(np.argmax(g.out_degree()))
    proxy = apps.table2_proxy(grid, "bfs") if use_proxy else None
    kw = {} if run_chunk is None else dict(run_chunk=run_chunk)
    r = apps.bfs(g, root, grid, proxy=proxy, oq_cap=oq_cap,
                 chips=chips, backend=backend, pkg=pkg,
                 double_buffer=double_buffer, **kw)
    # re-price the measured trace under the run's own package config: the
    # cross-check that the analytic board-level pricing contract holds on
    # a *directly measured* N-chip run (reprice_ratio must be ~1)
    rep = price(pkg, grid, r.run.counters,
                mem_bits_sram=float(g.footprint_bytes() * 8),
                per_superstep_peak=r.run.trace)
    c = r.run.counters
    return dict(chips=chips, tiles=grid.num_tiles, n_vertices=g.n_rows,
                teps_edges=r.teps_edges, gteps=r.gteps,
                time_s=r.run.time_s, supersteps=r.run.supersteps,
                off_chip_msgs=c.off_chip_msgs,
                off_chip_hop_msgs=c.off_chip_hop_msgs,
                messages=c.messages,
                energy_j=rep.energy_j, cost_usd=rep.cost_usd,
                off_chip_j=rep.breakdown["off_chip_j"],
                gteps_per_w=r.gteps / max(rep.power_w, 1e-12),
                gteps_per_usd=r.gteps / rep.cost_usd,
                reprice_time_s=rep.time_s,
                reprice_ratio=rep.time_s / max(r.run.time_s, 1e-30))


def weak_scaling(chip_counts: Sequence[int] = WEAK_CHIP_COUNTS,
                 tiles_per_chip: int = 16, base_scale: int = 6,
                 edge_factor: int = 8, oq_cap: int = 16,
                 pkg: PackageConfig = DCRA_SRAM, seed: int = 1,
                 backend: str = "auto", use_proxy: bool = True,
                 run_chunk: Optional[int] = None,
                 double_buffer: bool = False) -> List[Dict[str, float]]:
    """Constant work per chip: RMAT scale and tile count grow with the
    chip count.  Returns one measurement dict per chip count; the GTEPS
    column is the measured multi-chip curve (monotone when the runtime
    scales, which is the property tests/test_distrib.py asserts).
    ``run_chunk`` overrides the engine's supersteps-per-dispatch (0 =
    legacy per-step loop); ``double_buffer`` overlaps each superstep's
    boundary exchange with the next superstep's compute (same counters
    and physical trace, lower BSP time — see distrib.driver)."""
    rows = []
    for chips in chip_counts:
        grid = chip_grid(chips, tiles_per_chip)
        scale = base_scale + int(round(math.log2(chips)))
        g = rmat_edges(scale, edge_factor=edge_factor, seed=seed)
        rows.append(_measure(g, grid, chips, oq_cap, pkg, backend,
                             use_proxy, run_chunk, double_buffer))
    return rows


def strong_scaling(chip_counts: Sequence[int] = (1, 4, 16, 64),
                   n_tiles: int = 1024, scale: int = 10,
                   edge_factor: int = 8, oq_cap: int = 16,
                   pkg: PackageConfig = DCRA_SRAM, seed: int = 1,
                   backend: str = "auto", use_proxy: bool = True,
                   run_chunk: Optional[int] = None,
                   double_buffer: bool = False) -> List[Dict[str, float]]:
    """Fixed grid and dataset, re-partitioned across more chips: isolates
    what the off-chip boundary costs at constant total work."""
    g = rmat_edges(scale, edge_factor=edge_factor, seed=seed)
    grid = square_grid(n_tiles)
    rows = []
    for chips in chip_counts:
        try:
            partition_grid(grid, chips)
        except ValueError:
            print(f"# strong_scaling: skipped chips={chips} "
                  f"(does not partition the {grid.ny}x{grid.nx} grid)")
            continue
        rows.append(_measure(g, grid, chips, oq_cap, pkg, backend,
                             use_proxy, run_chunk, double_buffer))
    return rows


def measured_gteps_curve(rows: List[Dict[str, float]]) -> Dict[int, float]:
    return {int(r["chips"]): float(r["gteps"]) for r in rows}
