"""Distributed multi-chip runtime for the tile-grid engine (paper §V).

The paper's headline numbers come from *distributed* execution: up to 256
chips — over a million PUs — run the tile grid cooperatively, with
owner-bound updates that leave a chip riding a board-level network
through each package's IO die.  This module is that execution layer:

  * ``partition`` splits a :class:`TileGrid` into a chip grid
    (:class:`ChipPartition`); tile ids, data placement and hop charging
    keep the monolithic engine's global numbering, so results are
    directly comparable.
  * Each chip runs one :class:`DataLocalEngine` superstep over its own
    subgrid per global superstep (the engine kernel is window-parametric
    — see ``core/engine.py``).  Proxy regions and cascade reduction
    trees are adapted chip-locally (``proxy.chip_local_proxy``): the
    cascade root sits at the chip boundary, and anything bound further
    out goes straight to its owner over the off-chip leg.
  * ``exchange`` delivers the boundary mailbox records between
    supersteps.  Under ``shard_map`` over a ``chips`` mesh axis the
    exchange is a real collective (``collectives.gather_records``); with
    a single device the runtime falls back to a vmapped emulation whose
    exchange is one combined scatter — numerically the same combine.
  * Off-chip records are charged a new network leg
    (``netstats.charge_off_chip``): OFF_PKG_PJ_BIT energy per board hop
    and IO-die Rx/Tx latency plus board-link serialization in the BSP
    time model.

Delivery order differs from the monolithic engine only in which records
a mailbox combines first; min-combine apps are therefore bitwise
identical, add-combine apps identical up to f32 re-association.

Like the monolithic engine, the run loop is device-resident: ``run``
scans ``EngineConfig.run_chunk`` whole distributed supersteps (chip
superstep + boundary exchange + stat aggregation) per dispatch — under
``shard_map`` the scan lives *inside* the sharded region, so state
stays device-sharded across the chunk and each iteration's collective
exchange executes on device — and the host checks pending/p_resident
once per chunk (``run(chunk=0)`` keeps the per-step dispatch).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import collectives
from ..core.compat import shard_map
from ..core.costmodel import (CLOCK_GHZ, IO_DIE_RXTX_LAT_NS,
                              _off_pkg_bits_per_cycle,
                              board_link_provisioning, link_provisioning)
from ..core.engine import (INF, AppSpec, DataLocalEngine, EngineConfig,
                           RunResult, _drain_chunked, _legacy_span, _pad,
                           _ProgressReporter, _sanitize_gate, _scan_steps,
                           _stat_keys, chunk_cycles,
                           superstep_counters, superstep_cycles)
from ..core.netstats import MSG_BITS, SuperstepTrace, TrafficCounters
from ..core.proxy import chip_local_proxy
from ..core.tilegrid import ChipPartition, TileGrid, partition_grid
from ..obs.metrics import default_registry
from ..obs.timeline import RunMeta


def partition(grid: TileGrid, num_chips: int) -> ChipPartition:
    """Partition ``grid`` into the most square chip grid that divides it."""
    return partition_grid(grid, num_chips)


# --------------------------------------------------------------------------
# boundary exchange
# --------------------------------------------------------------------------
def _owner_slots(part: ChipPartition, chunk_dst: int, dst):
    """Map global dst indices to (owner chip, owner's in-chip tile, local
    mailbox index within that chip).  The single source of the exchanged
    records' mailbox layout — shared by both backends' receive sides."""
    owner = jnp.minimum(dst // chunk_dst, part.grid.num_tiles - 1)
    chip = part.chip_of_tile(owner)
    ltile = part.local_tile(owner)
    return chip, ltile, ltile * chunk_dst + dst % chunk_dst


def _combine_into_mail(mail_val, mail_flag, flat, mask, val, seg, n_seg,
                       is_min):
    """Scatter-combine exchanged records into a flattened mailbox pair.

    ``flat`` indexes the flattened mailbox, ``seg`` the receiving tile
    (for endpoint contention); masked-out records go to a sentinel row.
    Shared by the emulated exchange and the shard_map receive side so
    the two backends cannot drift.  Returns (mail_val, mail_flag, recv)
    where ``recv`` is the per-receiving-tile arrival-count vector
    ``(n_seg,)`` — callers max it for endpoint contention (identical to
    the former recv_max return) and, under telemetry, also reduce it
    per chip for the ``pc_recv`` load vector.
    """
    n_flat = mail_val.shape[0]
    # masked records index one past the end; mode="drop" discards them at
    # the scatter (no padded mailbox copy — see engine._deliver)
    safe = jnp.where(mask, flat, n_flat)
    if is_min:
        mv = mail_val.at[safe].min(jnp.where(mask, val, INF), mode="drop")
    else:
        mv = mail_val.at[safe].add(jnp.where(mask, val, 0.0), mode="drop")
    mf = mail_flag.at[safe].max(mask, mode="drop")
    recv = jax.ops.segment_sum(mask.astype(jnp.float32),
                               jnp.where(mask, seg, n_seg),
                               num_segments=n_seg + 1)[:n_seg]
    return mv, mf, recv


def _pending(state):
    """Live work in a (possibly stacked) engine state — mailbox flags
    plus unfinished edge cursors.  Must be evaluated *after* the
    boundary exchange: a record that crossed chips this superstep is
    pending work even when every chip's pre-exchange queues are empty."""
    return (jnp.sum(state["mail_flag"])
            + jnp.sum(state["cur_hi"] > state["cur_lo"]))


def exchange(part: ChipPartition, chunk_dst: int, state, off, is_min: bool):
    """Deliver per-chip off-chip record buffers into their owner chips'
    mailboxes (the emulated board-level exchange; state is stacked
    ``(chips, ...)``).

    Combining into a mailbox is commutative (min / add / flag-or), so one
    global scatter is exactly equivalent to routing each record across
    the board and combining on arrival.  Returns (state, recv): the
    ``(chips, tiles_local)`` received-record counts, whose max feeds
    endpoint contention in the BSP time model and whose per-chip sums
    feed the ``pc_recv`` telemetry vector.
    """
    C = part.num_chips
    Tl = part.tiles_per_chip
    Nld = Tl * chunk_dst
    dst = off["dst"].reshape(-1)
    val = off["val"].reshape(-1)
    mask = off["mask"].reshape(-1)
    chip, ltile, off_idx = _owner_slots(part, chunk_dst, dst)
    mv, mf, recv = _combine_into_mail(
        state["mail_val"].reshape(-1), state["mail_flag"].reshape(-1),
        chip * Nld + off_idx, mask, val, chip * Tl + ltile, C * Tl, is_min)
    state = dict(state, mail_val=mv.reshape(C, Nld),
                 mail_flag=mf.reshape(C, Nld))
    return state, recv.reshape(C, Tl)


def _aggregate(stats, recv, telemetry: bool = False):
    """Reduce per-chip superstep stats to grid-global ones: traffic sums,
    bottleneck (per-tile) maxima; exchange receive contention (``recv``,
    the ``(chips, tiles_local)`` arrival counts, or None on a 1x1
    partition) folds into the delivery max.

    Under ``telemetry`` the vmapped per-chip/per-tile load vectors are
    additionally reduced to per-chip ``pc_*`` vectors (shape
    ``(chips,)``) that ride the scan's stacked-dict channel into
    ``obs.imbalance``; the engine's per-tile ``tv_*`` vectors are
    consumed here (a chip's intra-tile split stays chip-local)."""
    agg = {}
    vecs = {}
    for k, v in stats.items():
        if k.startswith("tv_"):
            vecs[k] = v                       # (chips, tiles_local)
            continue
        if k in ("compute_per_tile_max", "delivered_max_per_tile"):
            agg[k] = jnp.max(v)
        else:
            agg[k] = jnp.sum(v)
    recv_max = jnp.float32(0.0) if recv is None else jnp.max(recv)
    agg["delivered_max_per_tile"] = jnp.maximum(
        agg["delivered_max_per_tile"], recv_max)
    if telemetry:
        agg["pc_edges"] = jnp.sum(vecs["tv_edges"], axis=-1)
        agg["pc_records"] = jnp.sum(vecs["tv_records"], axis=-1)
        agg["pc_delivered"] = jnp.sum(vecs["tv_delivered"], axis=-1)
        agg["pc_delivmax"] = jnp.max(vecs["tv_delivered"], axis=-1)
        agg["pc_compute"] = stats["compute_per_tile_max"]
        agg["pc_owner"] = stats["owner_msgs"]
        if "off_chip_msgs" in stats:
            agg["pc_offchip"] = stats["off_chip_msgs"]
        agg["pc_recv"] = (jnp.zeros_like(agg["pc_edges"]) if recv is None
                          else jnp.sum(recv, axis=-1))
    return agg


# --------------------------------------------------------------------------
class DistributedEngine:
    """Multi-chip rendering of :class:`DataLocalEngine`.

    Mirrors the monolithic engine's interface (``init_state`` /
    ``activate_all`` / ``run``) so the six applications run unchanged;
    state is held stacked per chip ``(chips, local...)`` and ``run``
    reassembles ``values`` into global order.
    """

    def __init__(self, app: AppSpec, cfg: EngineConfig,
                 row_lo: np.ndarray, row_hi: np.ndarray,
                 col_idx: np.ndarray, weights: Optional[np.ndarray] = None,
                 part: Optional[ChipPartition] = None,
                 num_chips: Optional[int] = None, backend: str = "auto"):
        grid = cfg.grid
        if part is None:
            if num_chips is None:
                raise ValueError("pass part= or num_chips=")
            part = partition_grid(grid, num_chips)
        if cfg.proxy is not None:
            cfg = dataclasses.replace(
                cfg, proxy=chip_local_proxy(cfg.proxy, part.sub_ny,
                                            part.sub_nx))
        if cfg.backend != "jnp":
            raise ValueError(
                "EngineConfig.backend='pallas' (kernel hot spots) is "
                "monolithic-only; the distributed runtime vmaps the "
                "superstep across chips")
        self.app = app
        self.cfg = cfg
        self.part = part
        self.kernel = DataLocalEngine(app, cfg, row_lo, row_hi, col_idx,
                                      weights, part=part)
        self.C = part.num_chips
        self.Tl = part.tiles_per_chip
        self.Cs, self.Cd = cfg.chunk_src, cfg.chunk_dst
        self._is_min = app.combine == "min"
        # (chip, local) <-> global tile permutations, host-side
        perm = np.concatenate([part.tile_ids(c) for c in range(self.C)])
        self._perm = perm
        inv = np.empty_like(perm)
        inv[perm] = np.arange(perm.shape[0])
        self._inv = inv
        self._row_lo_s = self._shard(np.asarray(self.kernel.row_lo), self.Cs)
        self._row_hi_s = self._shard(np.asarray(self.kernel.row_hi), self.Cs)
        self._chip_ids = jnp.arange(self.C, dtype=jnp.int32)
        if backend == "auto":
            ndev = jax.device_count()
            backend = ("shard_map" if ndev > 1 and self.C % ndev == 0
                       else "vmap")
        if self.C == 1:
            backend = "vmap"    # 1x1 partition: no boundary to exchange
        if backend == "shard_map" and self.C % jax.device_count():
            raise ValueError(
                f"{self.C} chips do not divide {jax.device_count()} devices")
        self.backend = backend
        self._step = None
        self._chunk_fns = {}
        self._stat_names = None        # packed-stat layout, cached

    # ----------------------------------------------------------- data moves
    def _shard(self, a_global: np.ndarray, chunk: int) -> jnp.ndarray:
        """Global per-index array -> stacked (chips, tiles_local*chunk)."""
        a = np.asarray(a_global).reshape(self.part.grid.num_tiles, chunk)
        return jnp.asarray(a[self._perm].reshape(self.C, self.Tl * chunk))

    def _gather(self, a_stacked, chunk: int) -> np.ndarray:
        """Stacked (chips, tiles_local*chunk) -> global per-index array."""
        a = np.asarray(a_stacked).reshape(self.C * self.Tl, chunk)
        return a[self._inv].reshape(-1)

    # ---------------------------------------------------------------- state
    def init_state(self, seed_idx=None, seed_val=None,
                   values: Optional[np.ndarray] = None):
        k = self.kernel
        ident = self.app.identity
        vals_g = (np.full((k.Ngd,), ident, np.float32) if values is None
                  else np.asarray(_pad(np.asarray(values, np.float32),
                                       k.Ngd, ident), np.float32))
        mail_val_g = np.full((k.Ngd,), ident, np.float32)
        mail_flag_g = np.zeros((k.Ngd,), bool)
        self._n_seeds = 0   # mailbox seeds, for the sanitizer's consumed-bound
        if seed_idx is not None:
            si = np.atleast_1d(np.asarray(seed_idx)).astype(np.int64)
            sv = np.atleast_1d(np.asarray(seed_val)).astype(np.float32)
            mail_val_g[si] = sv
            mail_flag_g[si] = True
            self._n_seeds = int(si.shape[0])
        st = dict(
            values=self._shard(vals_g, self.Cd),
            mail_val=self._shard(mail_val_g, self.Cd),
            mail_flag=self._shard(mail_flag_g, self.Cd),
            cur_lo=jnp.zeros((self.C, k.Ns), jnp.int32),
            cur_hi=jnp.zeros((self.C, k.Ns), jnp.int32),
            cur_val=jnp.zeros((self.C, k.Ns), jnp.float32),
        )
        if self.cfg.proxy is not None:
            S = self.cfg.proxy.slots
            st["p_tag"] = jnp.full((self.C, self.Tl, S), -1, jnp.int32)
            st["p_val"] = jnp.full((self.C, self.Tl, S), ident, jnp.float32)
        return st

    def activate_all(self, state, cur_val):
        state = dict(state)
        state["cur_lo"] = self._row_lo_s
        state["cur_hi"] = self._row_hi_s
        state["cur_val"] = self._shard(
            _pad(np.asarray(cur_val, np.float32), self.kernel.Ngs, 0.0),
            self.Cs)
        return state

    # ---------------------------------------------------------------- steps
    def _get_step(self):
        if self._step is None:
            self._step = (self._make_vmap_step() if self.backend == "vmap"
                          else self._make_shard_step())
        return self._step

    def _get_chunk_fn(self, length: int):
        """Chunked (scan-of-supersteps) dispatch for this backend; one
        compiled function per chunk length, cached."""
        if length not in self._chunk_fns:
            make = (self._make_vmap_chunk if self.backend == "vmap"
                    else self._make_shard_chunk)
            self._chunk_fns[length] = make(length)
        return self._chunk_fns[length]

    @property
    def _write_back(self) -> bool:
        return self.cfg.proxy is not None and self.cfg.proxy.write_back

    def _raw_vmap_step(self):
        """One whole distributed superstep (vmapped chips + emulated
        exchange + stat aggregation), unjitted — the body both the
        legacy per-step dispatch and the scanned chunk share."""
        kernel, part, Cd, is_min = (self.kernel, self.part, self.Cd,
                                    self._is_min)
        multi = self.C > 1
        telemetry = self.cfg.telemetry

        def step(row_lo, row_hi, state, chip_ids, flush):
            new_state, stats, off = jax.vmap(
                kernel.chip_superstep, in_axes=(0, 0, 0, 0, None))(
                row_lo, row_hi, state, chip_ids, flush)
            if multi:
                new_state, recv = exchange(part, Cd, new_state, off,
                                           is_min)
            else:                       # 1x1 partition: nothing can leave
                recv = None
            agg = _aggregate(stats, recv, telemetry)
            # pending must see the post-exchange mailboxes: a record that
            # crossed chips this superstep is the next superstep's work
            agg["pending"] = _pending(new_state)
            return new_state, agg

        return step

    def _make_vmap_step(self):
        jstep = jax.jit(self._raw_vmap_step())
        return lambda state, flush: jstep(self._row_lo_s, self._row_hi_s,
                                          state, self._chip_ids, flush)

    def _make_vmap_chunk(self, length: int):
        step = self._raw_vmap_step()
        write_back = self._write_back

        def chunk(row_lo, row_hi, state, chip_ids, flush, done, left):
            return _scan_steps(
                lambda st, fl: step(row_lo, row_hi, st, chip_ids, fl),
                state, flush, done, left, length, write_back)

        jchunk = jax.jit(chunk)
        return lambda state, flush, done, left: jchunk(
            self._row_lo_s, self._row_hi_s, state, self._chip_ids, flush,
            done, left)

    def _raw_shard_step(self, per: int):
        """One whole distributed superstep under ``shard_map`` (vmapped
        chips per device + collective exchange + psum/pmax aggregation);
        must execute inside a ``chips`` mesh axis.  Shared by the legacy
        and chunked shard_map dispatches."""
        kernel, part, Cd, Tl = self.kernel, self.part, self.Cd, self.Tl
        is_min = self._is_min
        Nld = kernel.Nd
        telemetry = self.cfg.telemetry

        def step(row_lo, row_hi, state, chip_ids, flush):
            new_state, stats, off = jax.vmap(
                kernel.chip_superstep, in_axes=(0, 0, 0, 0, None))(
                row_lo, row_hi, state, chip_ids, flush)
            # board-level exchange: every chip gathers the full off-chip
            # record stream and keeps what it owns (collective all-to-all
            # without per-destination packing, so hub skew cannot
            # overflow a send buffer)
            g_dst, g_val, g_mask = collectives.gather_records(
                (off["dst"].reshape(-1), off["val"].reshape(-1),
                 off["mask"].reshape(-1)), "chips")
            ochip, ltile, off_idx = _owner_slots(part, Cd, g_dst)
            mine = g_mask & (ochip // per == jax.lax.axis_index("chips"))
            lane = ochip % per
            mv, mf, recv = _combine_into_mail(
                new_state["mail_val"].reshape(-1),
                new_state["mail_flag"].reshape(-1),
                lane * Nld + off_idx, mine, g_val, lane * Tl + ltile,
                per * Tl, is_min)
            recv = recv.reshape(per, Tl)
            new_state = dict(new_state,
                             mail_val=mv.reshape(per, Nld),
                             mail_flag=mf.reshape(per, Nld))
            agg = {}
            vecs = {}
            for k2, v in stats.items():
                if k2.startswith("tv_"):
                    vecs[k2] = v              # (per, tiles_local)
                    continue
                if k2 in ("compute_per_tile_max", "delivered_max_per_tile"):
                    agg[k2] = jax.lax.pmax(jnp.max(v), "chips")
                else:
                    agg[k2] = jax.lax.psum(jnp.sum(v), "chips")
            agg["delivered_max_per_tile"] = jnp.maximum(
                agg["delivered_max_per_tile"],
                jax.lax.pmax(jnp.max(recv), "chips"))
            if telemetry:
                # per-chip pc_* load vectors, replicated across devices so
                # the stacked stats channel stays out_specs=P()
                def gather(x):
                    return jax.lax.all_gather(x, "chips", tiled=True)

                agg["pc_edges"] = gather(jnp.sum(vecs["tv_edges"], axis=-1))
                agg["pc_records"] = gather(
                    jnp.sum(vecs["tv_records"], axis=-1))
                agg["pc_delivered"] = gather(
                    jnp.sum(vecs["tv_delivered"], axis=-1))
                agg["pc_delivmax"] = gather(
                    jnp.max(vecs["tv_delivered"], axis=-1))
                agg["pc_compute"] = gather(stats["compute_per_tile_max"])
                agg["pc_owner"] = gather(stats["owner_msgs"])
                if "off_chip_msgs" in stats:
                    agg["pc_offchip"] = gather(stats["off_chip_msgs"])
                agg["pc_recv"] = gather(jnp.sum(recv, axis=-1))
            # post-exchange pending, globally (see _raw_vmap_step)
            agg["pending"] = jax.lax.psum(_pending(new_state), "chips")
            return new_state, agg

        return step

    def _make_shard_step(self):
        from jax.sharding import PartitionSpec as P
        ndev = jax.device_count()
        per = self.C // ndev
        mesh = jax.make_mesh((ndev,), ("chips",))
        step = self._raw_shard_step(per)

        def fn(row_lo, row_hi, state, flush):
            cid0 = jax.lax.axis_index("chips") * per
            chip_ids = cid0 + jnp.arange(per, dtype=jnp.int32)
            return step(row_lo, row_hi, state, chip_ids, flush)

        jstep = jax.jit(shard_map(
            fn, mesh=mesh, in_specs=(P("chips"), P("chips"), P("chips"), P()),
            out_specs=(P("chips"), P()), check_vma=False))
        return lambda state, flush: jstep(self._row_lo_s, self._row_hi_s,
                                          state, flush)

    def _make_shard_chunk(self, length: int):
        from jax.sharding import PartitionSpec as P
        ndev = jax.device_count()
        per = self.C // ndev
        mesh = jax.make_mesh((ndev,), ("chips",))
        step = self._raw_shard_step(per)
        write_back = self._write_back

        def fn(row_lo, row_hi, state, flush, done, left):
            # the scan lives *inside* the shard_map region: state stays
            # device-sharded across the whole chunk and each iteration's
            # collective exchange/psum executes on device — the host only
            # sees the per-chunk carry and the stacked (replicated) stats
            cid0 = jax.lax.axis_index("chips") * per
            chip_ids = cid0 + jnp.arange(per, dtype=jnp.int32)
            return _scan_steps(
                lambda st, fl: step(row_lo, row_hi, st, chip_ids, fl),
                state, flush, done, left, length, write_back)

        jchunk = jax.jit(shard_map(
            fn, mesh=mesh,
            in_specs=(P("chips"), P("chips"), P("chips"), P(), P(), P()),
            out_specs=((P("chips"), P(), P(), P()), P()), check_vma=False))
        return lambda state, flush, done, left: jchunk(
            self._row_lo_s, self._row_hi_s, state, flush, done, left)

    # ------------------------------------------------------------------ run
    def run(self, state, max_supersteps: Optional[int] = None,
            progress_every: int = 0, chunk: Optional[int] = None,
            observer=None):
        """Run distributed supersteps until drained; returns
        (state-with-global-values, RunResult).

        Like the monolithic engine, the loop is device-resident:
        ``chunk`` supersteps (default ``EngineConfig.run_chunk``) run per
        dispatch — each including its boundary exchange — and the host
        checks pending/p_resident once per chunk.  ``chunk=0`` keeps the
        legacy per-superstep dispatch.  ``progress_every`` reports at
        chunk granularity with true executed superstep counts.

        ``observer`` (obs.timeline.Observer) hooks the existing chunk
        host-accounting boundary exactly like the monolithic engine —
        zero extra host syncs, bit-identical results; with
        ``EngineConfig.telemetry`` the spans carry per-chip ``pc_*``
        load vectors."""
        cfg, part = self.cfg, self.part
        maxs = max_supersteps or cfg.max_supersteps
        K = cfg.run_chunk if chunk is None else int(chunk)
        if observer is not None:
            observer.on_run_start(RunMeta(
                app=self.app.name, grid_ny=cfg.grid.ny, grid_nx=cfg.grid.nx,
                n_chips=self.C, chips_y=part.chips_y, chips_x=part.chips_x,
                chunk=K, backend=self.backend, sanitize=cfg.sanitize,
                telemetry=cfg.telemetry, pkg=cfg.pkg, grid=cfg.grid))
        counters = TrafficCounters()
        cycles = 0.0
        steps = 0
        pkg = cfg.pkg
        links = link_provisioning(cfg.grid, pkg)
        cy, cx = part.chips_y, part.chips_x
        # board links provisioned under the run's own PackageConfig (the
        # per-axis knobs) — shared formula with costmodel's re-pricing so
        # pricing the trace under this config reproduces this run's time
        n_board_links = board_link_provisioning(pkg, cy, cx)
        trace = SuperstepTrace(board_links=n_board_links,
                               chips_y=cy, chips_x=cx)
        io_lat_cycles = 2.0 * IO_DIE_RXTX_LAT_NS * CLOCK_GHZ   # Tx + Rx IO die

        def account(stats):
            """Legacy-loop per-superstep accounting.  The chunked branch
            uses the vectorized twin (add_chunk_cycles below with
            chunk_counters/append_chunk in _drain_chunked) — edit BOTH
            in lockstep; tests/test_chunked.py is the bit-identity gate."""
            nonlocal cycles
            _sanitize_gate(cfg, self.app.name,
                           float(stats.get("sanity_violations", 0.0)))
            counters.add(superstep_counters(stats))
            trace.append_step(stats, element_bits=cfg.element_bits)
            # ---- BSP time model: monolithic levels + the board-level leg
            t_board = float(stats.get("off_chip_hop_msgs", 0.0)) * MSG_BITS / (
                n_board_links * _off_pkg_bits_per_cycle(pkg))
            step_cycles = max(superstep_cycles(stats, pkg, links), t_board)
            if step_cycles > 0 or stats["pending"] > 0:
                cycles += step_cycles + links["diameter"] * 0.5  # pipeline fill
                if stats.get("off_chip_msgs", 0.0) > 0:
                    cycles += io_lat_cycles

        if K <= 0:
            state, steps = self._run_legacy(state, maxs, progress_every,
                                            account, observer=observer)
        else:
            chunk_fn = self._get_chunk_fn(K)
            progress = _ProgressReporter(f"{self.app.name}/{self.C}chips",
                                         progress_every,
                                         sanitize=cfg.sanitize)
            fill = links["diameter"] * 0.5
            board_div = n_board_links * _off_pkg_bits_per_cycle(pkg)
            # stat layout of the packed scan rows (the vmapped step's agg
            # carries the same keys the shard_map rendering emits)
            if self._stat_names is None:   # one abstract trace per engine
                raw = self._raw_vmap_step()
                self._stat_names = _stat_keys(
                    lambda st, fl: raw(self._row_lo_s, self._row_hi_s, st,
                                       self._chip_ids, fl),
                    state, jnp.zeros((), jnp.bool_))
            def add_chunk_cycles(stacked, n_act, cycles):
                # monolithic BSP terms maxed with the board leg, plus
                # IO-die latency on supersteps with off-chip records --
                # accumulated in execution order like the legacy loop
                if cfg.sanitize:
                    bad = stacked.get("sanity_violations")
                    if bad is not None:
                        _sanitize_gate(cfg, self.app.name,
                                       float(np.sum(bad[:n_act])))

                def offvec(key):           # absent on a 1x1 partition
                    a = stacked.get(key)
                    return (np.asarray(a[:n_act], np.float64)
                            if a is not None else np.zeros(n_act))

                t_board = offvec("off_chip_hop_msgs") * MSG_BITS / board_div
                sc = np.maximum(
                    chunk_cycles(stacked, n_act, pkg, links), t_board)
                pend = np.asarray(stacked["pending"][:n_act])
                offm = offvec("off_chip_msgs")
                for s, p, o in zip(sc.tolist(), pend.tolist(),
                                   offm.tolist()):
                    if s > 0 or p > 0:
                        cycles += s + fill
                        if o > 0:
                            cycles += io_lat_cycles
                return cycles

            state, steps, cycles = _drain_chunked(
                chunk_fn, state, maxs, self._stat_names, counters, trace,
                cfg.element_bits, progress, add_chunk_cycles, cycles,
                observer=observer)
        counters.supersteps = steps
        time_s = cycles / (CLOCK_GHZ * 1e9)
        out_state = dict(state)
        out_state["values"] = self._gather(state["values"], self.Cd)
        result = RunResult(counters=counters, cycles=cycles, time_s=time_s,
                           supersteps=steps, trace=trace)
        if cfg.sanitize:
            from ..analysis import invariants as _inv
            findings = _inv.check_run(
                result, pkg=pkg, grid=cfg.grid,
                where=f"sanitize/{self.app.name}/{self.C}chips",
                write_back=self._write_back,
                seeds=getattr(self, "_n_seeds", 0), drained=steps < maxs)
            _inv.assert_clean(
                findings, context=f"run({self.app.name}, {self.C} chips)")
        if observer is not None:
            observer.on_run_end(result)
        return out_state, result

    def _run_legacy(self, state, maxs, progress_every, account,
                    observer=None):
        """The seed per-superstep dispatch loop (one host sync per
        superstep) — the measured baseline for the chunked loop.  With an
        ``observer``, each superstep emits one single-step span at the
        per-step host sync this loop already pays."""
        write_back = self._write_back
        step_fn = self._get_step()
        sync_ctr = default_registry().counter("engine.host_syncs")
        steps = 0
        flush_flag = jnp.asarray(False)
        while steps < maxs:
            t0 = time.perf_counter()
            state, stats = step_fn(state, flush_flag)
            t1 = time.perf_counter()
            stats = jax.device_get(stats)
            sync_ctr.inc()
            t2 = time.perf_counter()
            steps += 1
            account(stats)
            t3 = time.perf_counter()
            if observer is not None:
                observer.on_chunk(_legacy_span(steps, stats, (t0, t1),
                                               (t1, t2), (t2, t3)))
            if flush_flag:
                flush_flag = jnp.asarray(False)
            if stats["pending"] == 0:
                if write_back and stats["p_resident"] > 0:
                    flush_flag = jnp.asarray(True)
                    continue
                break
            if progress_every and steps % progress_every == 0:
                print(f"  [{self.app.name}/{self.C}chips] step {steps} "
                      f"pending={stats['pending']:.0f}")
        return state, steps


# --------------------------------------------------------------------------
def run_distributed(app: AppSpec, cfg: EngineConfig, row_lo, row_hi, col_idx,
                    weights=None, *, chips: Optional[int] = None,
                    part: Optional[ChipPartition] = None,
                    backend: str = "auto", seed_idx=None, seed_val=None,
                    values=None, activate=None,
                    max_supersteps: Optional[int] = None):
    """One-call distributed run: partition, seed/activate, run to drain.

    Returns (global values array, RunResult).  ``activate`` (a global
    per-source value array) selects epoch-style activation
    (PageRank/SPMV/Histogram); ``seed_idx``/``seed_val`` seed mailboxes
    (BFS/SSSP/WCC).
    """
    eng = DistributedEngine(app, cfg, row_lo, row_hi, col_idx, weights,
                            part=part, num_chips=chips, backend=backend)
    state = eng.init_state(seed_idx=seed_idx, seed_val=seed_val,
                           values=values)
    if activate is not None:
        state = eng.activate_all(state, activate)
    state, run = eng.run(state, max_supersteps)
    return state["values"], run
