"""Distributed multi-chip runtime for the tile-grid engine (paper §V).

The paper's headline numbers come from *distributed* execution: up to 256
chips — over a million PUs — run the tile grid cooperatively, with
owner-bound updates that leave a chip riding a board-level network
through each package's IO die.  This module is that execution layer:

  * ``partition`` splits a :class:`TileGrid` into a chip grid
    (:class:`ChipPartition`); tile ids, data placement and hop charging
    keep the monolithic engine's global numbering, so results are
    directly comparable.
  * Each chip runs one :class:`DataLocalEngine` superstep over its own
    subgrid per global superstep (the engine kernel is window-parametric
    — see ``core/engine.py``).  Proxy regions and cascade reduction
    trees are adapted chip-locally (``proxy.chip_local_proxy``): the
    cascade root sits at the chip boundary, and anything bound further
    out goes straight to its owner over the off-chip leg.
  * ``exchange`` delivers the boundary mailbox records between
    supersteps.  One step function, written against an
    :class:`~repro.distrib.mesh.ExecMesh`, serves every placement: on a
    real multi-device mesh the exchange is a collective
    (``gather_records`` under ``shard_map``), on a single device the
    mesh helpers degenerate to the identity and the same code is the
    vmapped emulation whose exchange is one combined scatter —
    numerically the same combine, bitwise the same scatter indices.
  * With ``EngineConfig.double_buffer`` the chunked scan carries a
    second mailbox bank: superstep *k* merges flags (the pending
    signal) and stats eagerly but defers the mailbox-*value* scatter to
    the start of superstep *k+1*, so the collective exchange overlaps
    the next superstep's chip-local compute.  Mailbox combining is
    commutative and nothing touches the mailbox between the two fold
    points, so values/counters/trace are bit-identical to the
    synchronous exchange; only the BSP time accumulation changes
    (board + IO-die cycles hidden under the next superstep's compute).
  * Off-chip records are charged a new network leg
    (``netstats.charge_off_chip``): OFF_PKG_PJ_BIT energy per board hop
    and IO-die Rx/Tx latency plus board-link serialization in the BSP
    time model.

Delivery order differs from the monolithic engine only in which records
a mailbox combines first; min-combine apps are therefore bitwise
identical, add-combine apps identical up to f32 re-association.

Like the monolithic engine, the run loop is device-resident: ``run``
scans ``EngineConfig.run_chunk`` whole distributed supersteps (chip
superstep + boundary exchange + stat aggregation) per dispatch — under
``shard_map`` the scan lives *inside* the sharded region, so state
stays device-sharded across the chunk and each iteration's collective
exchange executes on device — and the host checks pending/p_resident
once per chunk (``run(chunk=0)`` keeps the per-step dispatch).
"""
from __future__ import annotations

import dataclasses
import functools
import tempfile
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..checkpoint.ckpt import save_checkpoint
from ..core.costmodel import (CLOCK_GHZ, IO_DIE_RXTX_LAT_NS,
                              PU_OPS_PER_EDGE, PU_OPS_PER_RECORD,
                              _off_pkg_bits_per_cycle,
                              board_link_provisioning, checkpoint_leg_cycles,
                              link_provisioning, recovery_waste_cycles)
from ..runtime.elastic import reshard_checkpoint
from ..runtime.fault import ChipLostError
from ..runtime.straggler import detect_stragglers, rebalance_chunks
from ..core.engine import (INF, AppSpec, DataLocalEngine, EngineConfig,
                           RunResult, _drain_chunked, _legacy_span, _pad,
                           _ProgressReporter, _sanitize_gate, _scan_steps,
                           _stat_keys, bucket_index, chunk_cycles,
                           superstep_counters, superstep_cycles)
from ..core.netstats import MSG_BITS, SuperstepTrace, TrafficCounters
from ..core.proxy import chip_local_proxy
from ..core.tilegrid import ChipPartition, TileGrid, partition_grid
from ..obs.metrics import default_registry
from ..obs.timeline import RunMeta
from .mesh import ExecMesh


def partition(grid: TileGrid, num_chips: int) -> ChipPartition:
    """Partition ``grid`` into the most square chip grid that divides it."""
    return partition_grid(grid, num_chips)


# --------------------------------------------------------------------------
# boundary exchange
# --------------------------------------------------------------------------
def _owner_slots(part: ChipPartition, chunk_dst: int, dst):
    """Map global dst indices to (owner chip, owner's in-chip tile, local
    mailbox index within that chip).  The single source of the exchanged
    records' mailbox layout — shared by both backends' receive sides."""
    owner = jnp.minimum(dst // chunk_dst, part.grid.num_tiles - 1)
    chip = part.chip_of_tile(owner)
    ltile = part.local_tile(owner)
    return chip, ltile, ltile * chunk_dst + dst % chunk_dst


def _combine_into_mail(mail_val, mail_flag, flat, mask, val, seg, n_seg,
                       is_min):
    """Scatter-combine exchanged records into a flattened mailbox pair.

    ``flat`` indexes the flattened mailbox, ``seg`` the receiving tile
    (for endpoint contention); masked-out records go to a sentinel row.
    Shared by the emulated exchange and the shard_map receive side so
    the two backends cannot drift.  Returns (mail_val, mail_flag, recv)
    where ``recv`` is the per-receiving-tile arrival-count vector
    ``(n_seg,)`` — callers max it for endpoint contention (identical to
    the former recv_max return) and, under telemetry, also reduce it
    per chip for the ``pc_recv`` load vector.
    """
    n_flat = mail_val.shape[0]
    # masked records index one past the end; mode="drop" discards them at
    # the scatter (no padded mailbox copy — see engine._deliver)
    safe = jnp.where(mask, flat, n_flat)
    if is_min:
        mv = mail_val.at[safe].min(jnp.where(mask, val, INF), mode="drop")
    else:
        mv = mail_val.at[safe].add(jnp.where(mask, val, 0.0), mode="drop")
    mf = mail_flag.at[safe].max(mask, mode="drop")
    recv = jax.ops.segment_sum(mask.astype(jnp.float32),
                               jnp.where(mask, seg, n_seg),
                               num_segments=n_seg + 1)[:n_seg]
    return mv, mf, recv


def _merge_flags(mail_flag, flat, mask, seg, n_seg):
    """The *eager* half of the double-buffered exchange: mailbox flags
    (the pending signal) and per-receiving-tile arrival counts merge in
    superstep k itself — only the mailbox-value scatter is deferred to
    the bank.  Identical flag/recv math to :func:`_combine_into_mail`."""
    n_flat = mail_flag.shape[0]
    safe = jnp.where(mask, flat, n_flat)
    mf = mail_flag.at[safe].max(mask, mode="drop")
    recv = jax.ops.segment_sum(mask.astype(jnp.float32),
                               jnp.where(mask, seg, n_seg),
                               num_segments=n_seg + 1)[:n_seg]
    return mf, recv


def _fold_bank(state, is_min):
    """Apply the deferred mailbox-value scatter of the double-buffered
    exchange (bank keys ``_db_idx`` / ``_db_val`` / ``_db_mask``) and
    drop the bank from the state dict.

    This is the *same* scatter :func:`_combine_into_mail` would have run
    at the end of the previous superstep, on the *same* mailbox (nothing
    writes ``mail_val`` between the two fold points), so the result is
    bitwise identical for min AND add — deferral only reorders the
    program, not the arithmetic."""
    idx, val, mask = state["_db_idx"], state["_db_val"], state["_db_mask"]
    state = {k: v for k, v in state.items() if not k.startswith("_db_")}
    mv = state["mail_val"].reshape(-1)
    safe = jnp.where(mask, idx, mv.shape[0])
    if is_min:
        mv = mv.at[safe].min(jnp.where(mask, val, INF), mode="drop")
    else:
        mv = mv.at[safe].add(jnp.where(mask, val, 0.0), mode="drop")
    return dict(state, mail_val=mv.reshape(state["mail_val"].shape))


def _pending(state):
    """Live work in a (possibly stacked) engine state — mailbox flags
    plus unfinished edge cursors.  Must be evaluated *after* the
    boundary exchange: a record that crossed chips this superstep is
    pending work even when every chip's pre-exchange queues are empty.
    (The double-buffered exchange merges flags eagerly for exactly this
    reason — a deferred mailbox *value* is never a pending signal.)"""
    return (jnp.sum(state["mail_flag"])
            + jnp.sum(state["cur_hi"] > state["cur_lo"]))


def exchange(part: ChipPartition, chunk_dst: int, state, off, is_min: bool):
    """Deliver per-chip off-chip record buffers into their owner chips'
    mailboxes (the emulated board-level exchange; state is stacked
    ``(chips, ...)``).

    Combining into a mailbox is commutative (min / add / flag-or), so one
    global scatter is exactly equivalent to routing each record across
    the board and combining on arrival.  Returns (state, recv): the
    ``(chips, tiles_local)`` received-record counts, whose max feeds
    endpoint contention in the BSP time model and whose per-chip sums
    feed the ``pc_recv`` telemetry vector.
    """
    C = part.num_chips
    Tl = part.tiles_per_chip
    Nld = Tl * chunk_dst
    dst = off["dst"].reshape(-1)
    val = off["val"].reshape(-1)
    mask = off["mask"].reshape(-1)
    chip, ltile, off_idx = _owner_slots(part, chunk_dst, dst)
    mv, mf, recv = _combine_into_mail(
        state["mail_val"].reshape(-1), state["mail_flag"].reshape(-1),
        chip * Nld + off_idx, mask, val, chip * Tl + ltile, C * Tl, is_min)
    state = dict(state, mail_val=mv.reshape(C, Nld),
                 mail_flag=mf.reshape(C, Nld))
    return state, recv.reshape(C, Tl)


def _aggregate(stats, recv, telemetry: bool = False, mesh=None):
    """Reduce per-chip superstep stats to grid-global ones: traffic sums,
    bottleneck (per-tile) maxima; exchange receive contention (``recv``,
    the ``(chips, tiles_local)`` arrival counts, or None on a 1x1
    partition) folds into the delivery max.

    With an :class:`ExecMesh` the local reductions finish as mesh
    collectives (``psum`` / ``pmax``; identity on a single device, so
    ``mesh=None`` and a 1-device mesh are the same arithmetic).

    Under ``telemetry`` the vmapped per-chip/per-tile load vectors are
    additionally reduced to per-chip ``pc_*`` vectors (shape
    ``(chips,)``, all-gathered so the stacked stats channel stays
    replicated) that ride the scan's stacked-dict channel into
    ``obs.imbalance``; the engine's per-tile ``tv_*`` vectors are
    consumed here (a chip's intra-tile split stays chip-local)."""
    ident = lambda x: x                       # noqa: E731
    psum = mesh.psum if mesh is not None else ident
    pmax = mesh.pmax if mesh is not None else ident
    gather = mesh.all_gather if mesh is not None else ident
    agg = {}
    vecs = {}
    for k, v in stats.items():
        if k.startswith("tv_"):
            vecs[k] = v                       # (chips_local, tiles_local)
            continue
        if k in ("compute_per_tile_max", "delivered_max_per_tile",
                 "bucket_cap"):
            agg[k] = pmax(jnp.max(v))
        else:
            agg[k] = psum(jnp.sum(v))
    recv_max = jnp.float32(0.0) if recv is None else pmax(jnp.max(recv))
    agg["delivered_max_per_tile"] = jnp.maximum(
        agg["delivered_max_per_tile"], recv_max)
    if telemetry:
        agg["pc_edges"] = gather(jnp.sum(vecs["tv_edges"], axis=-1))
        agg["pc_records"] = gather(jnp.sum(vecs["tv_records"], axis=-1))
        agg["pc_delivered"] = gather(jnp.sum(vecs["tv_delivered"], axis=-1))
        agg["pc_delivmax"] = gather(jnp.max(vecs["tv_delivered"], axis=-1))
        agg["pc_compute"] = gather(stats["compute_per_tile_max"])
        agg["pc_owner"] = gather(stats["owner_msgs"])
        if "off_chip_msgs" in stats:
            agg["pc_offchip"] = gather(stats["off_chip_msgs"])
        agg["pc_recv"] = (jnp.zeros_like(agg["pc_edges"]) if recv is None
                          else gather(jnp.sum(recv, axis=-1)))
    return agg


# --------------------------------------------------------------------------
class _FaultTolerance:
    """Superstep checkpoint/rollback controller for one ``run()`` call.

    At each host-accounting boundary (per chunk on the chunked loop, per
    superstep on the legacy loop) it polls the fault injector — a raised
    :class:`ChipLostError` unwinds to ``run()``'s retry loop — and, on
    cadence, writes the scan carry through the atomic checkpoint writer
    plus an in-memory snapshot of the host accounting (counters, trace
    length, BSP cycles, in-flight exchange, telemetry sums).

    ``recover()`` rebuilds the :class:`ExecMesh` on the surviving
    devices, restores the carry through ``runtime.elastic``'s
    reshard-on-restore path, rolls the host accounting back to the
    snapshot, and prices every overhead leg (checkpoint writes,
    discarded replay window, re-shard restore) into a *separate*
    accumulator the run adds exactly once at the very end.  Keeping the
    overhead out of the main accumulator is what makes a recovered run
    bit-identical to an unfailed one: the replay re-adds the identical
    floats in the identical order, and the cost model re-prices the
    overhead from the trace's recovery events with the same shared
    helpers (``checkpoint_leg_cycles`` / ``recovery_waste_cycles``), so
    ``reprice_ratio`` stays exactly 1.0.
    """

    def __init__(self, eng, directory, every, injector, counters, trace,
                 prev_exch, overhead, vec_sums, n_board_links):
        self.eng = eng
        self.dir = directory
        self.every = int(every)
        self.injector = injector
        self.counters = counters
        self.trace = trace
        self.prev_exch = prev_exch
        self.overhead = overhead
        self.vec_sums = vec_sums
        self.blinks = n_board_links
        self.pkg = eng.cfg.pkg
        self.grid = eng.cfg.grid
        self.events = trace.recovery_events
        self._snap = None
        self._next = self.every if self.every > 0 else None
        self._bits = None              # carry image size (static shapes)
        self._tmpl = None              # restore template (shape/dtype tree)

    def _image_bits(self, state) -> float:
        if self._bits is None:
            self._bits = 8.0 * (sum(
                int(np.prod(v.shape)) * v.dtype.itemsize
                for v in state.values()) + 1)       # +1: the flush flag
        return self._bits

    def checkpoint(self, steps, state, flush, cycles) -> None:
        """Write the carry at superstep ``steps`` + snapshot accounting."""
        bits = self._image_bits(state)
        host_state = jax.device_get(state)
        if self._tmpl is None:
            self._tmpl = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                          for k, v in host_state.items()}
        flush_b = bool(np.asarray(flush))
        save_checkpoint(
            self.dir, int(steps),
            dict(state=host_state, flush=np.asarray(flush_b)),
            extra_meta=dict(cycles=float(cycles),
                            prev_exch=float(self.prev_exch[0]),
                            overhead=float(self.overhead[0]),
                            counters=self.counters.as_dict()))
        # the write is priced as overhead, never into `cycles`: the main
        # accumulator must replay bit-identically to an unfailed run
        self.overhead[0] += checkpoint_leg_cycles(self.pkg, bits,
                                                  self.blinks)
        self.events.append(dict(kind="checkpoint", step=int(steps),
                                bits=float(bits)))
        self._snap = dict(
            steps=int(steps), flush=flush_b, cycles=float(cycles),
            prev_exch=float(self.prev_exch[0]),
            counters=self.counters.as_dict(),
            vec_sums=(None if self.vec_sums is None else
                      {k: np.array(v, np.float64)
                       for k, v in self.vec_sums.items()}))

    def at_boundary(self, steps, state, flush, done, cycles):
        """The run loop's boundary hook: poll the injector first (so a
        loss at a checkpoint boundary still forces a real rollback),
        then checkpoint on cadence.  Returns ``cycles`` unchanged — the
        hook never perturbs the main accumulator."""
        if self.injector is not None:
            self.injector.poll(int(steps))          # may raise ChipLostError
        if self._next is not None and steps >= self._next and not done:
            self.checkpoint(steps, state, flush, cycles)
            while self._next <= steps:
                self._next += self.every
        return cycles

    def recover(self, err):
        """Chip loss: re-shard onto the survivors + roll back.

        Returns ``(state, flush, steps, cycles)`` for the retry loop to
        resume from the last checkpoint."""
        eng, snap = self.eng, self._snap
        lo, hi = snap["steps"], int(err.at_step)
        # 1. price the discarded window [lo, hi) from the trace rows
        #    BEFORE truncating — with the same vectorized helper the
        #    cost model's replay uses, so both sides sum the identical
        #    floats in the identical order
        self.overhead[0] += recovery_waste_cycles(
            self.pkg, self.grid, self.trace, lo, hi)
        self.events.append(dict(kind="rollback", chip=int(err.chip),
                                from_step=int(lo), at_step=int(hi)))
        # 2. roll host accounting back to the snapshot
        self.trace.truncate(lo)
        for k, v in snap["counters"].items():
            setattr(self.counters, k, v)
        self.counters.supersteps = int(snap["counters"]["supersteps"])
        self.prev_exch[0] = snap["prev_exch"]
        if self.vec_sums is not None:
            self.vec_sums.clear()
            if snap["vec_sums"]:
                self.vec_sums.update(snap["vec_sums"])
        # 3. rebuild the mesh on the survivors; recompiles on next call
        _, new_ndev = eng._drop_device()
        # 4. restore the carry through the elastic reshard path: chip-
        #    stacked leaves re-shard over the surviving device axis
        jmesh = jax.make_mesh((eng.mesh.ndev,), (eng.mesh.axis,))

        def rule(path, shape):
            if shape and shape[0] == eng.C and eng.mesh.is_sharded:
                return P(eng.mesh.axis)
            return P()

        restored = reshard_checkpoint(
            self.dir, dict(state=self._tmpl,
                           flush=jax.ShapeDtypeStruct((), np.bool_)),
            jmesh, rule, step=lo)
        state = restored["state"]
        flush = bool(np.asarray(restored["flush"]))
        # 5. the restore streams the carry image back over board links
        self.overhead[0] += checkpoint_leg_cycles(self.pkg, self._bits,
                                                  self.blinks)
        self.events.append(dict(kind="reshard", step=int(lo),
                                bits=float(self._bits),
                                chip=int(err.chip), devices=int(new_ndev)))
        if self._next is not None:
            self._next = lo + self.every
        return state, flush, lo, snap["cycles"]


# --------------------------------------------------------------------------
class DistributedEngine:
    """Multi-chip rendering of :class:`DataLocalEngine`.

    Mirrors the monolithic engine's interface (``init_state`` /
    ``activate_all`` / ``run``) so the six applications run unchanged;
    state is held stacked per chip ``(chips, local...)`` and ``run``
    reassembles ``values`` into global order.
    """

    def __init__(self, app: AppSpec, cfg: EngineConfig,
                 row_lo: np.ndarray, row_hi: np.ndarray,
                 col_idx: np.ndarray, weights: Optional[np.ndarray] = None,
                 part: Optional[ChipPartition] = None,
                 num_chips: Optional[int] = None, backend: str = "auto"):
        grid = cfg.grid
        if part is None:
            if num_chips is None:
                raise ValueError("pass part= or num_chips=")
            part = partition_grid(grid, num_chips)
        if cfg.proxy is not None:
            cfg = dataclasses.replace(
                cfg, proxy=chip_local_proxy(cfg.proxy, part.sub_ny,
                                            part.sub_nx))
        if cfg.backend != "jnp":
            raise ValueError(
                "EngineConfig.backend='pallas' (kernel hot spots) is "
                "monolithic-only; the distributed runtime vmaps the "
                "superstep across chips")
        self.app = app
        self.cfg = cfg
        self.part = part
        self.kernel = DataLocalEngine(app, cfg, row_lo, row_hi, col_idx,
                                      weights, part=part)
        self.C = part.num_chips
        self.Tl = part.tiles_per_chip
        self.Cs, self.Cd = cfg.chunk_src, cfg.chunk_dst
        self._is_min = app.combine == "min"
        # (chip, local) <-> global tile permutations, host-side
        perm = np.concatenate([part.tile_ids(c) for c in range(self.C)])
        self._perm = perm
        inv = np.empty_like(perm)
        inv[perm] = np.arange(perm.shape[0])
        self._inv = inv
        self._row_lo_s = self._shard(np.asarray(self.kernel.row_lo), self.Cs)
        self._row_hi_s = self._shard(np.asarray(self.kernel.row_hi), self.Cs)
        self._chip_ids = jnp.arange(self.C, dtype=jnp.int32)
        # device placement: any ndev dividing C works; when the host's
        # device count doesn't divide, ExecMesh falls back to the largest
        # dividing subset with a warning (no hard failure)
        self.mesh = ExecMesh.build(self.C, backend=backend)
        self.backend = self.mesh.backend_name
        self._backend_req = backend
        self.last_load_vecs = None     # summed pc_* vectors of the last run
        # execute the deferred-bank exchange only where there IS an
        # exchange; the cost model's double_buffer flag stays cfg-driven
        self._db_exec = bool(cfg.double_buffer) and self.C > 1
        self._step = None
        self._chunk_fns = {}
        self._stat_names = None        # packed-stat layout, cached
        self._off_len = None           # per-chip off-record buffer length

    # ----------------------------------------------------------- data moves
    def _shard(self, a_global: np.ndarray, chunk: int) -> jnp.ndarray:
        """Global per-index array -> stacked (chips, tiles_local*chunk)."""
        a = np.asarray(a_global).reshape(self.part.grid.num_tiles, chunk)
        return jnp.asarray(a[self._perm].reshape(self.C, self.Tl * chunk))

    def _gather(self, a_stacked, chunk: int) -> np.ndarray:
        """Stacked (chips, tiles_local*chunk) -> global per-index array."""
        a = np.asarray(a_stacked).reshape(self.C * self.Tl, chunk)
        return a[self._inv].reshape(-1)

    # ------------------------------------------------------------- elasticity
    def _drop_device(self) -> tuple:
        """Rebuild the execution mesh on one fewer device (chip loss).

        The logical chip count stays ``self.C`` — the grid partition and
        global tile numbering are placement invariants — only the device
        set hosting the chip blocks shrinks, so the lost chip's block is
        re-hosted by the survivors.  Compiled step/chunk functions are
        mesh-bound and dropped; the packed-stat layout and off-record
        buffer length are mesh-independent and kept.  Returns
        (old_ndev, new_ndev)."""
        old_ndev = self.mesh.ndev
        if old_ndev > 1:
            backend = "vmap" if self._backend_req == "vmap" else "auto"
            self.mesh = ExecMesh.build(self.C, backend=backend,
                                       device_count=old_ndev - 1)
            self.backend = self.mesh.backend_name
            self._step = None
            self._chunk_fns = {}
        return old_ndev, self.mesh.ndev

    # ---------------------------------------------------------------- state
    def init_state(self, seed_idx=None, seed_val=None,
                   values: Optional[np.ndarray] = None):
        k = self.kernel
        ident = self.app.identity
        vals_g = (np.full((k.Ngd,), ident, np.float32) if values is None
                  else np.asarray(_pad(np.asarray(values, np.float32),
                                       k.Ngd, ident), np.float32))
        mail_val_g = np.full((k.Ngd,), ident, np.float32)
        mail_flag_g = np.zeros((k.Ngd,), bool)
        self._n_seeds = 0   # mailbox seeds, for the sanitizer's consumed-bound
        if seed_idx is not None:
            si = np.atleast_1d(np.asarray(seed_idx)).astype(np.int64)
            sv = np.atleast_1d(np.asarray(seed_val)).astype(np.float32)
            mail_val_g[si] = sv
            mail_flag_g[si] = True
            self._n_seeds = int(si.shape[0])
        st = dict(
            values=self._shard(vals_g, self.Cd),
            mail_val=self._shard(mail_val_g, self.Cd),
            mail_flag=self._shard(mail_flag_g, self.Cd),
            cur_lo=jnp.zeros((self.C, k.Ns), jnp.int32),
            cur_hi=jnp.zeros((self.C, k.Ns), jnp.int32),
            cur_val=jnp.zeros((self.C, k.Ns), jnp.float32),
        )
        if self.cfg.proxy is not None:
            S = self.cfg.proxy.slots
            st["p_tag"] = jnp.full((self.C, self.Tl, S), -1, jnp.int32)
            st["p_val"] = jnp.full((self.C, self.Tl, S), ident, jnp.float32)
        return st

    def activate_all(self, state, cur_val):
        state = dict(state)
        state["cur_lo"] = self._row_lo_s
        state["cur_hi"] = self._row_hi_s
        state["cur_val"] = self._shard(
            _pad(np.asarray(cur_val, np.float32), self.kernel.Ngs, 0.0),
            self.Cs)
        return state

    # ---------------------------------------------------------------- steps
    def _get_step(self):
        """Legacy per-superstep dispatch (always the synchronous
        exchange: one host sync per superstep hides nothing anyway)."""
        if self._step is None:
            mesh = self.mesh
            step = self._raw_step(mesh)

            def fn(row_lo, row_hi, state, flush):
                return step(row_lo, row_hi, state, mesh.chip_ids(), flush)

            jstep = mesh.shard_jit(fn, in_specs=(True, True, True, False),
                                   out_specs=(True, False))
            self._step = lambda state, flush: jstep(
                self._row_lo_s, self._row_hi_s, state, flush)
        return self._step

    def _get_chunk_fn(self, length: int):
        """Chunked (scan-of-supersteps) dispatch on the mesh; one
        compiled function per chunk length, cached."""
        if length not in self._chunk_fns:
            self._chunk_fns[length] = self._make_chunk(length)
        return self._chunk_fns[length]

    @property
    def _write_back(self) -> bool:
        return self.cfg.proxy is not None and self.cfg.proxy.write_back

    def _raw_vmap_step(self):
        """The unified step on a single-device (identity) mesh — what
        the analysis passes abstract-trace and the stat-layout probe
        uses; bitwise the chips-axis emulation regardless of the mesh
        the engine itself runs on."""
        return self._raw_step(ExecMesh(self.C, 1))

    def _raw_step(self, mesh: ExecMesh, double_buffer: bool = False):
        """One whole distributed superstep against ``mesh`` (vmapped
        chips per device + boundary exchange + stat aggregation),
        unjitted — the one body every dispatch shares.  On a sharded
        mesh it must execute inside the mesh's ``chips`` axis; on a
        single-device mesh every collective is the identity and the
        function is plain-traceable.

        ``double_buffer`` defers the exchanged mailbox-*value* scatter
        into a ``_db_*`` bank in the carried state (folded in at the
        start of the next superstep — see :func:`_fold_bank`); flags,
        arrival counts and all stats still merge eagerly, so pending
        and the recorded trace are identical to the synchronous path."""
        kernel, part, Cd, Tl = self.kernel, self.part, self.Cd, self.Tl
        is_min = self._is_min
        Nld = kernel.Nd
        per = mesh.per
        telemetry = self.cfg.telemetry
        multi = self.C > 1
        ladder = kernel._ladder
        # compacted buckets pad their off-chip buffers to the dense
        # length, so all switch branches (and the double-buffer bank)
        # share one shape
        pad_off = (self._off_record_len()
                   if multi and len(ladder) > 1 else None)

        def step(row_lo, row_hi, state, chip_ids, flush):
            if double_buffer:
                # previous superstep's deferred exchange lands first —
                # the same scatter, one superstep later (the mailbox is
                # untouched in between), overlapping this compute
                state = _fold_bank(state, is_min)
            if len(ladder) > 1:
                # per-device bucket selection: the switch index is the
                # *unbatched* max over this device's chips, so exactly
                # one pre-traced branch executes per device (a per-chip
                # index under vmap would run every branch); flags merge
                # eagerly under double_buffer, so the post-fold mask is
                # the true pending signal
                active = jax.vmap(kernel._active_tiles)(state)
                n_act = jnp.sum(active.astype(jnp.int32), axis=1)
                idx = bucket_index(jnp.max(n_act), ladder)

                def branch(w):
                    def run(st, act):
                        return jax.vmap(
                            functools.partial(kernel.chip_superstep,
                                              window=w, pad_off_to=pad_off),
                            in_axes=(0, 0, 0, 0, None, 0))(
                            row_lo, row_hi, st, chip_ids, flush, act)
                    return run

                new_state, stats, off = jax.lax.switch(
                    idx, [branch(None if j == 0 else cap)
                          for j, cap in enumerate(ladder)], state, active)
                stats = dict(
                    stats, active_tiles=n_act.astype(jnp.float32),
                    bucket_cap=jnp.full((per,), jnp.take(
                        jnp.asarray(ladder, jnp.float32), idx)))
            else:
                new_state, stats, off = jax.vmap(
                    kernel.chip_superstep, in_axes=(0, 0, 0, 0, None))(
                    row_lo, row_hi, state, chip_ids, flush)
            if multi:
                # board-level exchange: every chip gathers the full
                # off-chip record stream and keeps what it owns
                # (collective all-to-all without per-destination packing,
                # so hub skew cannot overflow a send buffer; identity
                # gather on one device — the stacked stream is already
                # global and the scatter indices match the emulation)
                g_dst, g_val, g_mask = mesh.gather_records(
                    (off["dst"].reshape(-1), off["val"].reshape(-1),
                     off["mask"].reshape(-1)))
                ochip, ltile, off_idx = _owner_slots(part, Cd, g_dst)
                mine = g_mask & (ochip // per == mesh.axis_index())
                lane = ochip % per
                flat = lane * Nld + off_idx
                seg = lane * Tl + ltile
                if double_buffer:
                    mf, recv = _merge_flags(
                        new_state["mail_flag"].reshape(-1), flat, mine,
                        seg, per * Tl)
                    new_state = dict(new_state,
                                     mail_flag=mf.reshape(per, Nld),
                                     _db_idx=flat, _db_val=g_val,
                                     _db_mask=mine)
                else:
                    mv, mf, recv = _combine_into_mail(
                        new_state["mail_val"].reshape(-1),
                        new_state["mail_flag"].reshape(-1),
                        flat, mine, g_val, seg, per * Tl, is_min)
                    new_state = dict(new_state,
                                     mail_val=mv.reshape(per, Nld),
                                     mail_flag=mf.reshape(per, Nld))
                recv = recv.reshape(per, Tl)
            else:                       # 1x1 partition: nothing can leave
                recv = None
            agg = _aggregate(stats, recv, telemetry, mesh)
            # pending must see the post-exchange mailbox flags: a record
            # that crossed chips this superstep is the next superstep's
            # work (flags merge eagerly even when double-buffered)
            agg["pending"] = mesh.psum(_pending(new_state))
            return new_state, agg

        return step

    def _off_record_len(self) -> int:
        """Per-chip off-chip record-buffer length (static: OQ emissions
        plus proxy flush legs), via abstract eval of the superstep —
        sizes the double-buffer bank."""
        if self._off_len is None:
            k = self.kernel
            st = {
                "values": jax.ShapeDtypeStruct((self.C, k.Nd), jnp.float32),
                "mail_val": jax.ShapeDtypeStruct((self.C, k.Nd),
                                                 jnp.float32),
                "mail_flag": jax.ShapeDtypeStruct((self.C, k.Nd), jnp.bool_),
                "cur_lo": jax.ShapeDtypeStruct((self.C, k.Ns), jnp.int32),
                "cur_hi": jax.ShapeDtypeStruct((self.C, k.Ns), jnp.int32),
                "cur_val": jax.ShapeDtypeStruct((self.C, k.Ns), jnp.float32),
            }
            if self.cfg.proxy is not None:
                S = self.cfg.proxy.slots
                st["p_tag"] = jax.ShapeDtypeStruct((self.C, self.Tl, S),
                                                   jnp.int32)
                st["p_val"] = jax.ShapeDtypeStruct((self.C, self.Tl, S),
                                                   jnp.float32)
            off = jax.eval_shape(
                lambda s: jax.vmap(k.chip_superstep,
                                   in_axes=(0, 0, 0, 0, None))(
                    self._row_lo_s, self._row_hi_s, s, self._chip_ids,
                    jnp.zeros((), jnp.bool_))[2],
                st)
            self._off_len = int(off["dst"].shape[1])
        return self._off_len

    def _make_chunk(self, length: int):
        mesh = self.mesh
        db = self._db_exec
        step = self._raw_step(mesh, double_buffer=db)
        write_back = self._write_back
        is_min = self._is_min
        # the bank holds the gathered global record stream (same shape on
        # every device at any ndev)
        bank_len = self.C * self._off_record_len() if db else 0

        def fn(row_lo, row_hi, state, flush, done, left):
            # the scan lives *inside* the sharded region: state stays
            # device-sharded across the whole chunk and each iteration's
            # collective exchange/psum executes on device — the host only
            # sees the per-chunk carry and the stacked (replicated) stats
            chip_ids = mesh.chip_ids()
            if db:
                # empty bank entering the chunk (the previous chunk
                # drained its own); the bank lives only inside this
                # function, so specs/carry crossing the host are unchanged
                state = dict(state,
                             _db_idx=jnp.zeros((bank_len,), jnp.int32),
                             _db_val=jnp.zeros((bank_len,), jnp.float32),
                             _db_mask=jnp.zeros((bank_len,), bool))
            carry, out = _scan_steps(
                lambda st, fl: step(row_lo, row_hi, st, chip_ids, fl),
                state, flush, done, left, length, write_back)
            if db:
                st, fl2, dn, lf = carry
                carry = (_fold_bank(st, is_min), fl2, dn, lf)
            return carry, out

        jfn = mesh.shard_jit(
            fn, in_specs=(True, True, True, False, False, False),
            out_specs=((True, False, False, False), False))
        return lambda state, flush, done, left: jfn(
            self._row_lo_s, self._row_hi_s, state, flush, done, left)

    # ------------------------------------------------------------------ run
    def run(self, state, max_supersteps: Optional[int] = None,
            progress_every: int = 0, chunk: Optional[int] = None,
            observer=None, fault_injector=None,
            ckpt_dir: Optional[str] = None):
        """Run distributed supersteps until drained; returns
        (state-with-global-values, RunResult).

        Like the monolithic engine, the loop is device-resident:
        ``chunk`` supersteps (default ``EngineConfig.run_chunk``) run per
        dispatch — each including its boundary exchange — and the host
        checks pending/p_resident once per chunk.  ``chunk=0`` keeps the
        legacy per-superstep dispatch.  ``progress_every`` reports at
        chunk granularity with true executed superstep counts.

        ``observer`` (obs.timeline.Observer) hooks the existing chunk
        host-accounting boundary exactly like the monolithic engine —
        zero extra host syncs, bit-identical results; with
        ``EngineConfig.telemetry`` the spans carry per-chip ``pc_*``
        load vectors.

        Fault tolerance: with ``EngineConfig.ckpt_every_supersteps > 0``
        the scan carry is checkpointed at the same boundaries (cadence
        in supersteps, zero extra host syncs — the carry is already on
        the host's side of the sync).  ``fault_injector``
        (runtime.fault.FaultInjector) injects a chip loss mid-run; the
        engine re-shards onto the surviving devices, rolls back to the
        last checkpoint and replays — final values, counters, supersteps
        and trace are bit-identical to an unfailed run, with all
        recovery overhead priced separately (see trace.recovery_events).
        ``ckpt_dir`` overrides the checkpoint directory (default: a
        fresh temp dir per run)."""
        cfg, part = self.cfg, self.part
        maxs = max_supersteps or cfg.max_supersteps
        K = cfg.run_chunk if chunk is None else int(chunk)
        if observer is not None:
            observer.on_run_start(RunMeta(
                app=self.app.name, grid_ny=cfg.grid.ny, grid_nx=cfg.grid.nx,
                n_chips=self.C, chips_y=part.chips_y, chips_x=part.chips_x,
                chunk=K, backend=self.backend, sanitize=cfg.sanitize,
                telemetry=cfg.telemetry, pkg=cfg.pkg, grid=cfg.grid,
                n_devices=self.mesh.ndev))
        counters = TrafficCounters()
        cycles = 0.0
        steps = 0
        pkg = cfg.pkg
        links = link_provisioning(cfg.grid, pkg)
        cy, cx = part.chips_y, part.chips_x
        # board links provisioned under the run's own PackageConfig (the
        # per-axis knobs) — shared formula with costmodel's re-pricing so
        # pricing the trace under this config reproduces this run's time
        n_board_links = board_link_provisioning(pkg, cy, cx)
        db = bool(cfg.double_buffer)
        trace = SuperstepTrace(board_links=n_board_links,
                               chips_y=cy, chips_x=cx, double_buffer=db)
        io_lat_cycles = 2.0 * IO_DIE_RXTX_LAT_NS * CLOCK_GHZ   # Tx + Rx IO die
        fill = links["diameter"] * 0.5                         # pipeline fill
        # double-buffer accounting: the exchange leg (board serialization
        # + IO-die latency) of the previous charged superstep, still in
        # flight while this superstep computes; the final one drains in
        # the open (tail charge after the loop).  Stays 0.0 synchronous.
        prev_exch = [0.0]
        # recovery overhead (checkpoint legs, discarded replay windows,
        # re-shard restores) accumulates apart from `cycles` and is added
        # exactly once after the drain tail — see _FaultTolerance
        overhead = [0.0]
        vec_sums = {} if cfg.telemetry else None
        ft = None
        if cfg.ckpt_every_supersteps > 0 or fault_injector is not None:
            ft = _FaultTolerance(
                self,
                directory=(ckpt_dir or tempfile.mkdtemp(
                    prefix=f"repro_ckpt_{self.app.name}_")),
                every=cfg.ckpt_every_supersteps, injector=fault_injector,
                counters=counters, trace=trace, prev_exch=prev_exch,
                overhead=overhead, vec_sums=vec_sums,
                n_board_links=n_board_links)

        def account(stats):
            """Legacy-loop per-superstep accounting.  The chunked branch
            uses the vectorized twin (add_chunk_cycles below with
            chunk_counters/append_chunk in _drain_chunked) AND
            costmodel._trace_time_s_parsed replays both rules from the
            trace — edit ALL in lockstep; tests/test_chunked.py and the
            reprice contract are the bit-identity gates."""
            nonlocal cycles
            _sanitize_gate(cfg, self.app.name,
                           float(stats.get("sanity_violations", 0.0)))
            counters.add(superstep_counters(stats))
            trace.append_step(stats, element_bits=cfg.element_bits)
            if vec_sums is not None:
                for k, v in stats.items():
                    if k.startswith("pc_"):
                        vec_sums[k] = (vec_sums.get(k, 0.0)
                                       + np.asarray(v, np.float64))
            # ---- BSP time model: monolithic levels + the board-level leg
            t_board = float(stats.get("off_chip_hop_msgs", 0.0)) * MSG_BITS / (
                n_board_links * _off_pkg_bits_per_cycle(pkg))
            core = superstep_cycles(stats, pkg, links)
            if db:
                # overlap-aware: this superstep pays max(its chip-local
                # work, the previous exchange); its own exchange hides
                # under the next superstep
                if core > 0 or t_board > 0 or stats["pending"] > 0:
                    cycles += max(core, prev_exch[0]) + fill
                    prev_exch[0] = t_board + (
                        io_lat_cycles
                        if stats.get("off_chip_msgs", 0.0) > 0 else 0.0)
            else:
                step_cycles = max(core, t_board)
                if step_cycles > 0 or stats["pending"] > 0:
                    cycles += step_cycles + fill
                    if stats.get("off_chip_msgs", 0.0) > 0:
                        cycles += io_lat_cycles

        boundary = None
        if ft is not None:
            if K <= 0:
                def boundary(bsteps, bstate, bflush, bdone):
                    nonlocal cycles
                    cycles = ft.at_boundary(bsteps, bstate, bflush, bdone,
                                            cycles)
            else:
                boundary = ft.at_boundary
            ft.checkpoint(0, state, False, cycles)   # step-0 baseline

        if K <= 0:
            steps0, flush0 = 0, False
            while True:
                try:
                    state, steps = self._run_legacy(
                        state, maxs, progress_every, account,
                        observer=observer, steps0=steps0, flush0=flush0,
                        boundary=boundary)
                    break
                except ChipLostError as e:
                    state, flush0, steps0, cycles = ft.recover(e)
        else:
            progress = _ProgressReporter(f"{self.app.name}/{self.C}chips",
                                         progress_every,
                                         sanitize=cfg.sanitize,
                                         tiles=self.C * self.Tl)
            fill = links["diameter"] * 0.5
            board_div = n_board_links * _off_pkg_bits_per_cycle(pkg)
            # stat layout of the packed scan rows (the vmapped step's agg
            # carries the same keys the shard_map rendering emits)
            if self._stat_names is None:   # one abstract trace per engine
                raw = self._raw_vmap_step()
                self._stat_names = _stat_keys(
                    lambda st, fl: raw(self._row_lo_s, self._row_hi_s, st,
                                       self._chip_ids, fl),
                    state, jnp.zeros((), jnp.bool_))
            def add_chunk_cycles(stacked, n_act, cycles):
                # monolithic BSP terms maxed with the board leg, plus
                # IO-die latency on supersteps with off-chip records --
                # accumulated in execution order like the legacy loop
                # (double-buffered: each superstep pays max(chip-local
                # work, previous exchange), its exchange carries over)
                if cfg.sanitize:
                    bad = stacked.get("sanity_violations")
                    if bad is not None:
                        _sanitize_gate(cfg, self.app.name,
                                       float(np.sum(bad[:n_act])))

                def offvec(key):           # absent on a 1x1 partition
                    a = stacked.get(key)
                    return (np.asarray(a[:n_act], np.float64)
                            if a is not None else np.zeros(n_act))

                t_board = offvec("off_chip_hop_msgs") * MSG_BITS / board_div
                core = chunk_cycles(stacked, n_act, pkg, links)
                pend = np.asarray(stacked["pending"][:n_act])
                offm = offvec("off_chip_msgs")
                if db:
                    for c, b, p, o in zip(core.tolist(), t_board.tolist(),
                                          pend.tolist(), offm.tolist()):
                        if c > 0 or b > 0 or p > 0:
                            cycles += max(c, prev_exch[0]) + fill
                            prev_exch[0] = b + (io_lat_cycles if o > 0
                                                else 0.0)
                    return cycles
                sc = np.maximum(core, t_board)
                for s, p, o in zip(sc.tolist(), pend.tolist(),
                                   offm.tolist()):
                    if s > 0 or p > 0:
                        cycles += s + fill
                        if o > 0:
                            cycles += io_lat_cycles
                return cycles

            steps0, flush0 = 0, False
            while True:
                try:
                    # re-fetched each attempt: a recovery rebuilds the
                    # mesh, so the compiled chunk fn must be re-bound
                    chunk_fn = self._get_chunk_fn(K)
                    state, steps, cycles = _drain_chunked(
                        chunk_fn, state, maxs, self._stat_names, counters,
                        trace, cfg.element_bits, progress, add_chunk_cycles,
                        cycles, observer=observer, steps0=steps0,
                        flush0=flush0, boundary=boundary,
                        vec_sums=vec_sums)
                    break
                except ChipLostError as e:
                    state, flush0, steps0, cycles = ft.recover(e)
        cycles += prev_exch[0]   # final in-flight exchange drains in the open
        cycles += overhead[0]    # recovery legs, priced once at the end
        counters.supersteps = steps
        self.last_load_vecs = vec_sums
        time_s = cycles / (CLOCK_GHZ * 1e9)
        out_state = dict(state)
        out_state["values"] = self._gather(state["values"], self.Cd)
        result = RunResult(counters=counters, cycles=cycles, time_s=time_s,
                           supersteps=steps, trace=trace)
        if cfg.sanitize:
            from ..analysis import invariants as _inv
            findings = _inv.check_run(
                result, pkg=pkg, grid=cfg.grid,
                where=f"sanitize/{self.app.name}/{self.C}chips",
                write_back=self._write_back,
                seeds=getattr(self, "_n_seeds", 0), drained=steps < maxs)
            _inv.assert_clean(
                findings, context=f"run({self.app.name}, {self.C} chips)")
        if observer is not None:
            observer.on_run_end(result)
        return out_state, result

    def _run_legacy(self, state, maxs, progress_every, account,
                    observer=None, *, steps0=0, flush0=False,
                    boundary=None):
        """The seed per-superstep dispatch loop (one host sync per
        superstep) — the measured baseline for the chunked loop.  With an
        ``observer``, each superstep emits one single-step span at the
        per-step host sync this loop already pays.

        ``steps0``/``flush0`` resume mid-run from a checkpoint;
        ``boundary(steps, state, flush, done)`` hooks the per-superstep
        host sync (fault injection + checkpoint cadence) at the point
        where the loop's continue/break decision is already known."""
        write_back = self._write_back
        step_fn = self._get_step()
        sync_ctr = default_registry().counter("engine.host_syncs")
        steps = int(steps0)
        flush_flag = jnp.asarray(bool(flush0))
        while steps < maxs:
            t0 = time.perf_counter()
            state, stats = step_fn(state, flush_flag)
            t1 = time.perf_counter()
            stats = jax.device_get(stats)
            sync_ctr.inc()
            t2 = time.perf_counter()
            steps += 1
            account(stats)
            t3 = time.perf_counter()
            if observer is not None:
                observer.on_chunk(_legacy_span(steps, stats, (t0, t1),
                                               (t1, t2), (t2, t3)))
            if flush_flag:
                flush_flag = jnp.asarray(False)
            pending_zero = stats["pending"] == 0
            want_flush = bool(pending_zero and write_back
                              and stats["p_resident"] > 0)
            if want_flush:
                flush_flag = jnp.asarray(True)
            done = pending_zero and not want_flush
            if boundary is not None:
                # sees the NEXT iteration's flush flag, so a checkpoint
                # taken here resumes with the correct write-back phase
                boundary(steps, state, flush_flag, done)
            if done:
                break
            if want_flush:
                continue
            if progress_every and steps % progress_every == 0:
                print(f"  [{self.app.name}/{self.C}chips] step {steps} "
                      f"pending={stats['pending']:.0f}")
        return state, steps

    # ---------------------------------------------------- straggler handling
    def rebalance_plan(self, n_items: Optional[int] = None,
                       max_ratio: float = 1.5, threshold: float = 2.0):
        """Straggler-aware ownership re-chunking plan for the next wave.

        Feeds the last run's accumulated per-chip ``pc_*`` telemetry
        (requires ``EngineConfig.telemetry``) into ``runtime.straggler``:
        per-chip load is modeled in PU ops — edges streamed plus records
        drained (the cost model's ``PU_OPS_PER_EDGE`` /
        ``PU_OPS_PER_RECORD``) plus exchange arrivals — and
        ``rebalance_chunks`` returns new destination-range boundaries
        over ``n_items`` (default: the global destination index space).
        Purely advisory between query waves: applying it re-partitions
        ownership for the *next* run, never perturbing the current one,
        so every wave stays bit-exact.  Returns a dict with the measured
        load, straggler mask/imbalance ratio, new boundaries, and the
        predicted post-rebalance imbalance."""
        v = self.last_load_vecs
        if not v:
            raise ValueError(
                "no per-chip load telemetry: run() with "
                "EngineConfig.telemetry=True before rebalance_plan()")
        zero = np.zeros(self.C, np.float64)
        load = (np.asarray(v.get("pc_edges", zero), np.float64)
                * PU_OPS_PER_EDGE
                + np.asarray(v.get("pc_records", zero), np.float64)
                * PU_OPS_PER_RECORD
                + np.asarray(v.get("pc_recv", zero), np.float64))
        mask, ratio = detect_stragglers(load, threshold=threshold)
        n = int(self.part.grid.num_tiles * self.Cd
                if n_items is None else n_items)
        bounds = rebalance_chunks(load, n, max_ratio=max_ratio)
        # predicted post-rebalance load: piecewise-uniform density over
        # the old equal chunks, integrated over the new boundaries
        eq = n / self.C
        cum = np.concatenate([[0.0], np.cumsum(load)])
        new_load = np.diff(np.interp(bounds, np.arange(self.C + 1) * eq,
                                     cum))
        pred = float(new_load.max() / max(new_load.mean(), 1e-9))
        return dict(load=load, stragglers=mask, imbalance=float(ratio),
                    boundaries=bounds, predicted_imbalance=pred)


# --------------------------------------------------------------------------
def run_distributed(app: AppSpec, cfg: EngineConfig, row_lo, row_hi, col_idx,
                    weights=None, *, chips: Optional[int] = None,
                    part: Optional[ChipPartition] = None,
                    backend: str = "auto", seed_idx=None, seed_val=None,
                    values=None, activate=None,
                    max_supersteps: Optional[int] = None):
    """One-call distributed run: partition, seed/activate, run to drain.

    Returns (global values array, RunResult).  ``activate`` (a global
    per-source value array) selects epoch-style activation
    (PageRank/SPMV/Histogram); ``seed_idx``/``seed_val`` seed mailboxes
    (BFS/SSSP/WCC).
    """
    eng = DistributedEngine(app, cfg, row_lo, row_hi, col_idx, weights,
                            part=part, num_chips=chips, backend=backend)
    state = eng.init_state(seed_idx=seed_idx, seed_val=seed_val,
                           values=values)
    if activate is not None:
        state = eng.activate_all(state, activate)
    state, run = eng.run(state, max_supersteps)
    return state["values"], run
