"""Markdown + JSON run reports from a recorded telemetry run.

:func:`run_report` folds everything the observability stack measured —
wall-clock breakdown, simulated BSP time, GTEPS, a per-superstep message
histogram, the load-imbalance summary (``obs.imbalance``), sanitizer
status and the metrics-registry snapshot — into one plain dict;
:func:`to_markdown` renders it human-readable and :func:`write_report`
writes both forms next to each other (``<stem>.json`` / ``<stem>.md``).

The report is the artifact CI uploads per run (see tier1.yml) and the
standard shape later perf/fault/serving work reports through.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

import numpy as np

from .imbalance import imbalance_report
from .metrics import default_registry

_HIST_BINS = 8


def _superstep_histogram(rec) -> Dict[str, list]:
    """Histogram of per-superstep injected messages (how bursty the run
    is): log-spaced bins over the observed range."""
    msgs = rec.stat_matrix("messages")
    if msgs.size == 0:
        return dict(edges=[], counts=[])
    top = float(msgs.max())
    if top <= 0:
        return dict(edges=[0.0, 1.0], counts=[int(msgs.size)])
    edges = np.unique(np.concatenate(
        [[0.0], np.geomspace(1.0, max(top, 1.0), _HIST_BINS)]))
    counts, edges = np.histogram(msgs, bins=edges)
    return dict(edges=[float(e) for e in edges],
                counts=[int(c) for c in counts])


def run_report(rec, *, teps_edges: Optional[float] = None,
               baseline_counters=None, registry=None,
               top: int = 5) -> Dict[str, object]:
    """Build the run-report dict for a recorded telemetry run.

    ``teps_edges`` (the app's Graph500-style edge count, e.g.
    ``AppResult.teps_edges``) enables the GTEPS line; ``baseline_counters``
    (a no-proxy/no-cascade run's TrafficCounters) enables cascade
    efficacy; ``registry`` defaults to the process-wide metrics registry.
    """
    meta, result = rec.meta, rec.result
    reg = registry if registry is not None else default_registry()
    rep: Dict[str, object] = dict(
        app=meta.app if meta is not None else "?",
        grid=(f"{meta.grid_ny}x{meta.grid_nx}" if meta is not None else "?"),
        n_chips=meta.n_chips if meta is not None else 1,
        chunk=meta.chunk if meta is not None else 0,
        backend=meta.backend if meta is not None else "?",
        supersteps=rec.supersteps,
        wall=rec.wall_breakdown(),
    )
    if result is not None:
        rep["sim_time_s"] = float(result.time_s)
        rep["sim_cycles"] = float(result.cycles)
        rep["counters"] = result.counters.as_dict()
        if teps_edges is not None:
            rep["teps_edges"] = float(teps_edges)
            rep["gteps"] = float(teps_edges) / max(result.time_s,
                                                   1e-12) / 1e9
    rep["superstep_histogram"] = _superstep_histogram(rec)
    rep["imbalance"] = imbalance_report(rec, baseline_counters, top=top)
    sanitize_on = bool(meta.sanitize) if meta is not None else False
    rep["sanitizer"] = dict(
        enabled=sanitize_on,
        # a sanitize run that produced a result raised on any violation,
        # so reaching the report means clean
        status=("clean" if sanitize_on and result is not None
                else ("off" if not sanitize_on else "unknown")))
    rep["metrics"] = reg.snapshot()
    return rep


def _fmt(v: float) -> str:
    return f"{v:,.4g}" if isinstance(v, float) else str(v)


def to_markdown(rep: Dict[str, object]) -> str:
    """Render a :func:`run_report` dict as markdown."""
    lines = [f"# Run report: {rep['app']} "
             f"({rep['grid']} tiles, {rep['n_chips']} chip(s), "
             f"chunk={rep['chunk']}, backend={rep['backend']})", ""]
    lines.append(f"- supersteps: **{rep['supersteps']}**")
    if "sim_time_s" in rep:
        lines.append(f"- simulated time: **{_fmt(rep['sim_time_s'])} s** "
                     f"({_fmt(rep['sim_cycles'])} cycles)")
    if "gteps" in rep:
        lines.append(f"- GTEPS: **{_fmt(rep['gteps'])}** "
                     f"({_fmt(rep['teps_edges'])} edges)")
    w = rep["wall"]
    lines.append(f"- wall: {_fmt(w['total_s'])} s over {w['chunks']} "
                 f"chunk(s) — dispatch {_fmt(w['dispatch_s'])} s, "
                 f"fetch {_fmt(w['fetch_s'])} s, "
                 f"account {_fmt(w['account_s'])} s")
    san = rep["sanitizer"]
    lines.append(f"- sanitizer: {san['status']}"
                 + ("" if san["enabled"] else " (disabled)"))
    hist = rep["superstep_histogram"]
    if hist["counts"]:
        lines += ["", "## Superstep message histogram", "",
                  "| messages ≤ | supersteps |", "|---:|---:|"]
        for hi, c in zip(hist["edges"][1:], hist["counts"]):
            lines.append(f"| {_fmt(float(hi))} | {c} |")
    imb = rep["imbalance"]
    lines += ["", "## Load imbalance", ""]
    if imb["supersteps"]:
        lines.append(f"- workers: {imb['workers']} — total Gini "
                     f"**{_fmt(imb['total_gini'])}**, total max/mean "
                     f"{_fmt(imb['total_max_over_mean'])}")
        lines.append(f"- per-step: mean Gini {_fmt(imb['mean_step_gini'])}, "
                     f"max Gini {_fmt(imb['max_step_gini'])}, mean max/mean "
                     f"{_fmt(imb['mean_step_max_over_mean'])}")
        if "cascade_efficacy" in imb:
            lines.append(f"- cascade efficacy: "
                         f"**{_fmt(imb['cascade_efficacy'])}** "
                         f"(owner msgs {_fmt(imb['owner_msgs'])} vs "
                         f"baseline {_fmt(imb['baseline_owner_msgs'])})")
        if imb["top_steps"]:
            lines += ["", "| top imbalanced superstep | Gini | max/mean "
                      "| load |", "|---:|---:|---:|---:|"]
            for t in imb["top_steps"]:
                lines.append(f"| {t['step']} | {_fmt(t['gini'])} | "
                             f"{_fmt(t['max_over_mean'])} | "
                             f"{_fmt(t['load'])} |")
    else:
        lines.append("- no telemetry load vectors recorded "
                     "(run with `EngineConfig.telemetry=True`)")
    return "\n".join(lines) + "\n"


def write_report(rep: Dict[str, object], stem: str) -> Dict[str, str]:
    """Write ``<stem>.json`` and ``<stem>.md``; returns their paths."""
    os.makedirs(os.path.dirname(stem) or ".", exist_ok=True)
    jpath, mpath = stem + ".json", stem + ".md"
    with open(jpath, "w") as f:
        json.dump(rep, f, indent=2)
    with open(mpath, "w") as f:
        f.write(to_markdown(rep))
    return dict(json=jpath, markdown=mpath)
