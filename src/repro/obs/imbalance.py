"""Load-balance metrics over telemetry load matrices.

The paper's cascading argument ("proxy regions + selective cascading …
improve load balancing") is a measurable claim: take the per-worker load
each superstep — delivered records per chip (``pc_delivered`` +
``pc_recv``) distributed, per tile (``tv_delivered``) monolithic — and
ask how unequal it is.  This module turns a telemetry run's
``(supersteps, workers)`` load matrix into those numbers:

  * :func:`gini` — Gini coefficient of a load vector (0 = perfectly
    balanced, → 1 = one worker holds everything);
  * :func:`max_over_mean` — the bottleneck ratio the BSP time model
    actually pays (a superstep costs its *max* worker, so max/mean is
    the slowdown vs perfect balance);
  * :func:`summarize` — whole-run report: totals-based and per-step
    Gini/max-over-mean plus the top imbalanced supersteps;
  * :func:`cascade_efficacy` — owner-message reduction vs a baseline
    run (the Tascade comparison: how much owner-bound traffic the
    proxy/cascade tree absorbed).

Everything here is plain NumPy over host-side matrices — nothing touches
the engine or devices (see the layering note in ``obs/__init__``).
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def gini(x) -> float:
    """Gini coefficient of a nonnegative load vector.

    0 = perfectly balanced; (n-1)/n = one worker holds all the load.
    Zero-total or empty vectors read as perfectly balanced (0.0).
    """
    x = np.asarray(x, np.float64).ravel()
    n = x.size
    if n == 0:
        return 0.0
    total = float(x.sum())
    if total <= 0.0:
        return 0.0
    xs = np.sort(x)
    i = np.arange(1, n + 1, dtype=np.float64)
    # sorted-prefix identity of the mean-absolute-difference definition
    return float((2.0 * np.sum(i * xs) - (n + 1) * total) / (n * total))


def max_over_mean(x) -> float:
    """Bottleneck ratio of a load vector: max / mean (1 = perfect
    balance; the factor by which the slowest worker stretches a BSP
    superstep).  Zero-total or empty vectors read as 0.0."""
    x = np.asarray(x, np.float64).ravel()
    if x.size == 0:
        return 0.0
    m = float(x.mean())
    return float(x.max() / m) if m > 0 else 0.0


def step_metrics(load) -> Dict[str, np.ndarray]:
    """Per-superstep balance metrics of a ``(supersteps, workers)`` load
    matrix: ``gini`` and ``max_over_mean`` vectors of length
    supersteps."""
    load = np.atleast_2d(np.asarray(load, np.float64))
    return dict(
        gini=np.array([gini(r) for r in load]),
        max_over_mean=np.array([max_over_mean(r) for r in load]),
    )


def summarize(load, top: int = 5) -> Dict[str, object]:
    """Whole-run imbalance summary of a ``(supersteps, workers)`` load
    matrix.

    ``total_*`` metrics look at each worker's load summed over the run
    (does anyone do more work overall?); ``mean_step_*`` average the
    per-superstep metrics over steps that moved any load (is any single
    barrier stretched?).  ``top_steps`` lists the most imbalanced
    supersteps by per-step Gini — the ones to inspect in the trace.
    """
    load = np.atleast_2d(np.asarray(load, np.float64))
    if load.size == 0:
        return dict(supersteps=0, workers=0, total_gini=0.0,
                    total_max_over_mean=0.0, mean_step_gini=0.0,
                    max_step_gini=0.0, mean_step_max_over_mean=0.0,
                    top_steps=[])
    per = step_metrics(load)
    totals = load.sum(axis=0)
    active = load.sum(axis=1) > 0
    order = np.argsort(-per["gini"], kind="stable")
    top_steps = [
        dict(step=int(s), gini=float(per["gini"][s]),
             max_over_mean=float(per["max_over_mean"][s]),
             load=float(load[s].sum()))
        for s in order[:top] if load[s].sum() > 0
    ]
    return dict(
        supersteps=int(load.shape[0]),
        workers=int(load.shape[1]),
        total_gini=gini(totals),
        total_max_over_mean=max_over_mean(totals),
        mean_step_gini=(float(per["gini"][active].mean())
                        if active.any() else 0.0),
        max_step_gini=float(per["gini"].max()) if per["gini"].size else 0.0,
        mean_step_max_over_mean=(float(per["max_over_mean"][active].mean())
                                 if active.any() else 0.0),
        top_steps=top_steps,
    )


def run_load_matrix(recorder) -> np.ndarray:
    """Per-worker per-superstep load of a recorded telemetry run.

    Distributed runs: delivered + exchange-received records per chip
    (``pc_delivered + pc_recv``) — the endpoint work each chip's barrier
    waits on.  Monolithic runs: delivered records per tile
    (``tv_delivered``).  Returns ``(supersteps, workers)``; empty when
    the run recorded no telemetry vectors.
    """
    avail = recorder.vec_keys()
    if "pc_delivered" in avail:
        m = recorder.vec_matrix("pc_delivered")
        if "pc_recv" in avail:
            m = m + recorder.vec_matrix("pc_recv")
        return m
    if "tv_delivered" in avail:
        return recorder.vec_matrix("tv_delivered")
    return np.zeros((0, 0))


def cascade_efficacy(owner_msgs: float, baseline_owner_msgs: float) -> float:
    """Owner-message reduction vs a baseline run: ``1 - with/without``
    (1 = every owner-bound message absorbed before the owner leg; 0 = no
    effect; negative = the tree added traffic).  The baseline is a run
    of the same app/graph without the proxy (or without the cascade),
    whose ``counters.owner_msgs`` the caller passes in."""
    if baseline_owner_msgs <= 0:
        return 0.0
    return float(1.0 - owner_msgs / baseline_owner_msgs)


def imbalance_report(recorder, baseline_counters=None,
                     top: int = 5) -> Dict[str, object]:
    """Full imbalance report for a recorded telemetry run: the
    :func:`summarize` metrics over :func:`run_load_matrix`, plus the
    run's owner-message totals and — when ``baseline_counters`` (a
    :class:`~repro.core.netstats.TrafficCounters` of a no-proxy or
    no-cascade run) is given — the :func:`cascade_efficacy`."""
    rep = summarize(run_load_matrix(recorder), top=top)
    result = recorder.result
    if result is not None:
        rep["owner_msgs"] = float(result.counters.owner_msgs)
        rep["messages"] = float(result.counters.messages)
        rep["supersteps_run"] = int(result.supersteps)
    if baseline_counters is not None and result is not None:
        rep["baseline_owner_msgs"] = float(baseline_counters.owner_msgs)
        rep["cascade_efficacy"] = cascade_efficacy(
            rep["owner_msgs"], rep["baseline_owner_msgs"])
    return rep
