"""Superstep timeline tracing: the Observer protocol + recorder.

The run loops (``core/engine.py`` and ``distrib/driver.py``) accept an
``observer=`` and call it at the *existing* chunk host-accounting
boundary — the one host sync per chunk the device-resident loop already
pays.  The observer only reads arrays that sync fetched, so attaching
one adds **zero host syncs** and the engine's computation (counters,
trace, final state) is bit-identical with or without it.  The legacy
per-step loop (``chunk=0``) emits one single-step span per superstep
(it already syncs per step).

Wall-clock spans per chunk:
  dispatch  — the ``chunk_fn`` call (device compute; on async-dispatch
              backends mostly enqueue time),
  fetch     — the ``jax.device_get`` host sync,
  account   — host-side counter/trace/BSP accounting.

With ``EngineConfig.telemetry=True`` the engine additionally emits
per-tile (monolithic, ``tv_*``) or per-chip (distributed, ``pc_*``)
load vectors per superstep; they ride the same chunk fetch and feed
``obs.imbalance``.  The simulated-time BSP spans are derived after the
run from ``RunResult.trace`` (``obs.export``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Protocol, runtime_checkable

import numpy as np


@dataclasses.dataclass(frozen=True)
class RunMeta:
    """Static facts about the run, emitted once at ``on_run_start``."""

    app: str
    grid_ny: int
    grid_nx: int
    n_chips: int = 1
    chips_y: int = 1
    chips_x: int = 1
    chunk: int = 0                 # supersteps per dispatch (0 = legacy)
    backend: str = "jnp"           # engine or distributed backend name
    sanitize: bool = False
    telemetry: bool = False
    pkg: object = None             # PackageConfig (for sim-span pricing)
    grid: object = None            # TileGrid
    n_devices: int = 1             # ExecMesh device count (chips/device
                                   # = n_chips // n_devices)

    @property
    def tiles(self) -> int:
        return self.grid_ny * self.grid_nx


@dataclasses.dataclass
class ChunkSpan:
    """One chunk (or one legacy superstep) of wall-clock + stat data.

    ``step_lo``/``step_hi`` are the global superstep numbers this chunk
    executed (half-open).  ``stats`` maps scalar stat names to
    ``(n_act,)`` numpy arrays; ``vecs`` maps telemetry vector names
    (``tv_*`` per-tile, ``pc_*`` per-chip) to ``(n_act, W)`` arrays.
    Times are ``time.perf_counter()`` seconds.
    """

    index: int
    step_lo: int
    step_hi: int
    t_dispatch: tuple           # (t0, t1)
    t_fetch: tuple
    t_account: tuple
    stats: Dict[str, np.ndarray]
    vecs: Dict[str, np.ndarray]

    @property
    def n_steps(self) -> int:
        return self.step_hi - self.step_lo

    @property
    def wall_dispatch_s(self) -> float:
        return self.t_dispatch[1] - self.t_dispatch[0]

    @property
    def wall_fetch_s(self) -> float:
        return self.t_fetch[1] - self.t_fetch[0]

    @property
    def wall_account_s(self) -> float:
        return self.t_account[1] - self.t_account[0]


@runtime_checkable
class Observer(Protocol):
    """What the run loops call.  Implementations must only *read* the
    arrays they are handed — the loops hand them the same buffers the
    accounting uses."""

    def on_run_start(self, meta: RunMeta) -> None: ...

    def on_chunk(self, span: ChunkSpan) -> None: ...

    def on_run_end(self, result) -> None: ...


def now() -> float:
    return time.perf_counter()


class TimelineRecorder:
    """Observer that records every span plus the run's meta/result.

    After the run, the recorder holds everything ``obs.export`` needs
    for a Chrome-trace/Perfetto file and ``obs.imbalance`` needs for
    load-balance metrics:

      * ``spans`` — wall-clock chunk spans, in execution order;
      * ``meta`` / ``result`` — run configuration and the finished
        :class:`~repro.core.engine.RunResult` (whose ``trace`` yields
        the simulated BSP spans);
      * ``stat_matrix(key)`` — per-superstep scalar stat vector over the
        whole run; ``vec_matrix(key)`` — ``(supersteps, W)`` telemetry
        load matrix (tiles monolithic, chips distributed).
    """

    def __init__(self):
        self.meta: Optional[RunMeta] = None
        self.result = None
        self.spans: List[ChunkSpan] = []
        self._t0: Optional[float] = None

    # ------------------------------------------------------------ protocol
    def on_run_start(self, meta: RunMeta) -> None:
        self.meta = meta
        self._t0 = now()

    def on_chunk(self, span: ChunkSpan) -> None:
        self.spans.append(span)

    def on_run_end(self, result) -> None:
        self.result = result

    # ------------------------------------------------------------- derived
    @property
    def t0(self) -> float:
        """Wall origin of the run (perf_counter seconds)."""
        if self._t0 is not None:
            return self._t0
        return self.spans[0].t_dispatch[0] if self.spans else 0.0

    @property
    def supersteps(self) -> int:
        return self.spans[-1].step_hi if self.spans else 0

    @property
    def wall_s(self) -> float:
        if not self.spans:
            return 0.0
        return self.spans[-1].t_account[1] - self.t0

    def wall_breakdown(self) -> Dict[str, float]:
        """Total wall seconds per phase across the run."""
        return dict(
            dispatch_s=sum(s.wall_dispatch_s for s in self.spans),
            fetch_s=sum(s.wall_fetch_s for s in self.spans),
            account_s=sum(s.wall_account_s for s in self.spans),
            total_s=self.wall_s,
            chunks=len(self.spans),
        )

    def stat_matrix(self, key: str) -> np.ndarray:
        """Per-superstep values of scalar stat ``key`` over the run."""
        parts = [s.stats[key] for s in self.spans if key in s.stats]
        if not parts:
            return np.zeros((0,))
        return np.concatenate([np.asarray(p, np.float64) for p in parts])

    def vec_keys(self):
        return sorted({k for s in self.spans for k in s.vecs})

    def vec_matrix(self, key: str) -> np.ndarray:
        """(supersteps, W) telemetry load matrix for vector stat ``key``
        (``W`` = tiles for monolithic ``tv_*``, chips for ``pc_*``)."""
        parts = [np.asarray(s.vecs[key], np.float64)
                 for s in self.spans if key in s.vecs]
        if not parts:
            return np.zeros((0, 0))
        return np.concatenate(parts, axis=0)
