"""Lightweight counter/gauge/histogram registry for engine telemetry.

One process-wide :class:`MetricsRegistry` (``default_registry()``)
collects operational metrics from the run loops, the distributed driver,
the product search and the benchmark harness — host-side only, so
attaching metrics never adds a device sync and never perturbs the
engine's computation.

The registry is deliberately tiny (no labels, no exporters): metric
names are dotted strings (``"engine.host_syncs"``), values are floats,
and a snapshot is a plain dict that the run report serializes.  Tests
that assert "telemetry does not change execution" diff two snapshots
(``snapshot()`` / ``Counter.value``) around a run.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional


class Counter:
    """Monotonically increasing count (events, syncs, cache hits)."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Last-written value (progress step count, pending work)."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Streaming distribution: count/sum/min/max plus a bounded sample
    reservoir for percentile estimates (deterministic stride thinning —
    no RNG, so two identical runs record identical state)."""

    __slots__ = ("name", "count", "total", "min", "max", "_sample",
                 "_stride", "_seen", "_cap")

    def __init__(self, name: str, sample_cap: int = 1024):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._sample: List[float] = []
        self._stride = 1
        self._seen = 0
        self._cap = sample_cap

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self._seen += 1
        if (self._seen - 1) % self._stride == 0:
            self._sample.append(v)
            if len(self._sample) >= self._cap:
                # thin deterministically: keep every other sample, double
                # the stride — the reservoir stays a uniform systematic
                # sample of the stream
                self._sample = self._sample[::2]
                self._stride *= 2

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        if not self._sample:
            return 0.0
        s = sorted(self._sample)
        idx = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
        return s[idx]

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return dict(count=0, mean=0.0, min=0.0, max=0.0, p50=0.0,
                        p95=0.0)
        return dict(count=self.count, mean=self.mean, min=self.min,
                    max=self.max, p50=self.percentile(50),
                    p95=self.percentile(95))


class MetricsRegistry:
    """Name -> metric map with get-or-create accessors.

    Thread-safe creation (benchmarks may time concurrently); observation
    itself is a plain float update — the engine hot path must not take a
    lock per superstep.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(name, Histogram(name))
        return h

    def snapshot(self) -> Dict[str, object]:
        """Plain-dict view of every metric (JSON-serializable)."""
        return dict(
            counters={k: c.value for k, c in sorted(self._counters.items())},
            gauges={k: g.value for k, g in sorted(self._gauges.items())},
            histograms={k: h.summary()
                        for k, h in sorted(self._histograms.items())},
        )

    def reset(self) -> None:
        """Drop every metric (tests isolate runs with this)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


_DEFAULT: Optional[MetricsRegistry] = None
_DEFAULT_LOCK = threading.Lock()


def default_registry() -> MetricsRegistry:
    """The process-wide registry the run loops emit into."""
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = MetricsRegistry()
    return _DEFAULT
