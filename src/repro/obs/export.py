"""Chrome trace-event / Perfetto JSON export of a recorded telemetry run.

Feed the output of :func:`write_trace` to ``chrome://tracing`` or
https://ui.perfetto.dev.  The trace has three process groups:

  * **host wall-clock** (pid 0) — one track per phase of the chunked run
    loop (``dispatch`` / ``fetch`` / ``account``), one complete-span
    ("X") event per chunk per phase, in real microseconds since the run
    started.  This is where host time goes.
  * **BSP timeline (simulated)** (pid 1) — one track per network level
    of the BSP time model (:data:`~repro.core.costmodel.STEP_CYCLE_LEVELS`:
    compute, intra-NoC, inter-die, off-package, endpoint, board, HBM),
    one span per superstep per level whose duration is that level's
    serialization term in simulated microseconds (cycles / 1000 at the
    1 GHz tile clock).  The superstep's cost is the *max* across tracks
    (``costmodel.step_cycles``), so the widest track per superstep is
    the binding level.  This is where simulated time goes.  When the run
    was double-buffered (``SuperstepTrace.double_buffer``) the board
    track instead shows ``exchange k (overlap)`` spans drawn over the
    *next* superstep's compute window — the overlap the accumulation
    rule credits.  Compacted runs (``EngineConfig.compaction > 1``)
    add an ``active-set compaction`` counter track here: per-superstep
    ``active_fraction`` (active tiles / grid tiles) and ``bucket_cap``
    (the selected capacity-ladder rung) sampled from the chunk stat
    rows — no extra host syncs.  Fault-tolerant runs add a ``fault
    tolerance`` track: checkpoint / re-shard spans sized by the image's
    board-leg serialization and rollback spans covering the discarded
    replay window (``SuperstepTrace.recovery_events``).
  * **chip c (sim load)** (pids 10+c) — per-chip counter ("C") tracks of
    the telemetry load vectors (delivered / recv / edges / …) sampled at
    each superstep's simulated start time; monolithic runs group tiles
    by grid row instead.  Only present when the run had
    ``EngineConfig.telemetry=True``.

All events follow the Chrome trace-event format (``ph``/``pid``/``tid``/
``ts``/``dur`` in µs); the top-level object is
``{"traceEvents": [...], "displayTimeUnit": "ms", "otherData": {...}}``.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.costmodel import (CLOCK_GHZ, IO_DIE_RXTX_LAT_NS, PackageConfig,
                              STEP_CYCLE_LEVELS, checkpoint_leg_cycles,
                              link_provisioning, step_cycle_terms)

PID_HOST = 0
PID_SIM = 1
PID_CHIP0 = 10            # chip c -> pid PID_CHIP0 + c

_US_PER_CYCLE = 1.0 / (CLOCK_GHZ * 1e3)       # 1 GHz: 1000 cycles per µs

_LEVEL_LABELS = dict(compute="compute (PU ops)", intra="intra-die NoC",
                     die="inter-die links", pkg="off-package links",
                     endpoint="endpoint contention", board="board links",
                     hbm="HBM drain")

_WALL_TRACKS = (("dispatch", 1), ("fetch", 2), ("account", 3))


def _meta_event(pid: int, name: str, tid: Optional[int] = None,
                thread: Optional[str] = None) -> dict:
    if thread is not None:
        return {"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                "args": {"name": thread}}
    return {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "args": {"name": name}}


def _wall_events(rec) -> List[dict]:
    """Host wall-clock spans: one X event per chunk per loop phase."""
    evs = [_meta_event(PID_HOST, "host wall-clock")]
    for name, tid in _WALL_TRACKS:
        evs.append(_meta_event(PID_HOST, "", tid=tid, thread=name))
    t0 = rec.t0
    for s in rec.spans:
        label = f"chunk {s.index} [{s.step_lo}:{s.step_hi})"
        for (a, b), (_, tid) in zip(
                (s.t_dispatch, s.t_fetch, s.t_account), _WALL_TRACKS):
            evs.append({"ph": "X", "name": label, "pid": PID_HOST,
                        "tid": tid, "ts": (a - t0) * 1e6,
                        "dur": max(b - a, 0.0) * 1e6,
                        "args": {"steps": s.n_steps}})
    return evs


def _sim_terms(rec):
    """Per-superstep BSP level terms (cycles) from the run's
    SuperstepTrace, or None when the recorder has no priced result."""
    result, meta = rec.result, rec.meta
    if (result is None or result.trace is None or meta is None
            or meta.grid is None or len(result.trace) == 0):
        return None
    trace = result.trace
    pkg = meta.pkg if meta.pkg is not None else PackageConfig()
    links = link_provisioning(meta.grid, pkg)
    terms = step_cycle_terms(
        pkg, links,
        compute_ops=np.asarray(trace.compute_ops, np.float64),
        intra_bits=np.asarray(trace.intra_bits, np.float64),
        die_bits=np.asarray(trace.die_bits, np.float64),
        pkg_bits=np.asarray(trace.pkg_bits, np.float64),
        endpoint_bits=np.asarray(trace.endpoint_bits, np.float64),
        off_chip_bits=np.asarray(trace.off_chip_bits, np.float64),
        board_links=trace.board_links)
    return (terms, links, np.asarray(trace.pending, np.float64),
            np.asarray(trace.off_chip_msgs, np.float64),
            bool(getattr(trace, "double_buffer", False)))


def _sim_events(rec) -> Tuple[List[dict], List[float]]:
    """Simulated-time spans per superstep per BSP level; returns the
    events plus each superstep's simulated start time (µs) so the load
    counters can sample on the same clock."""
    out = _sim_terms(rec)
    if out is None:
        return [], []
    terms, links, pending, off_msgs, double_buffer = out
    evs = [_meta_event(PID_SIM, "BSP timeline (simulated)")]
    levels = [lv for lv in STEP_CYCLE_LEVELS if lv in terms]
    for i, lv in enumerate(levels):
        evs.append(_meta_event(PID_SIM, "", tid=i + 1,
                               thread=_LEVEL_LABELS.get(lv, lv)))
    fill_us = links["diameter"] * 0.5 * _US_PER_CYCLE
    io_us = 2.0 * IO_DIE_RXTX_LAT_NS * CLOCK_GHZ * _US_PER_CYCLE
    n = len(pending)
    starts: List[float] = []
    cur = 0.0
    if double_buffer:
        # double-buffered accumulation rule: a charged step pays
        # max(core, previous step's in-flight exchange) + fill, and its
        # own boundary exchange (board + IO-die latency) overlaps the
        # *next* step's compute — so the exchange span is drawn starting
        # where the next compute window opens (see driver.run).
        prev_exch = 0.0
        board_i = levels.index("board") + 1 if "board" in levels else None
        for s in range(n):
            starts.append(cur)
            core = 0.0
            for i, lv in enumerate(levels):
                if lv == "board":
                    continue
                t_us = float(terms[lv][s]) * _US_PER_CYCLE
                core = max(core, t_us)
                if t_us > 0.0:
                    evs.append({"ph": "X", "name": f"superstep {s}",
                                "pid": PID_SIM, "tid": i + 1, "ts": cur,
                                "dur": t_us, "args": {"level": lv}})
            board_us = float(terms["board"][s]) * _US_PER_CYCLE \
                if board_i is not None else 0.0
            if core > 0.0 or board_us > 0.0 or pending[s] > 0.0:
                cur += max(core, prev_exch) + fill_us
                prev_exch = board_us + (io_us if off_msgs[s] > 0.0 else 0.0)
                if prev_exch > 0.0 and board_i is not None:
                    evs.append({"ph": "X", "name": f"exchange {s} (overlap)",
                                "pid": PID_SIM, "tid": board_i, "ts": cur,
                                "dur": prev_exch, "args": {"level": "board"}})
        return evs, starts
    for s in range(n):
        starts.append(cur)
        step = 0.0
        for i, lv in enumerate(levels):
            t_us = float(terms[lv][s]) * _US_PER_CYCLE
            step = max(step, t_us)
            if t_us > 0.0:
                evs.append({"ph": "X", "name": f"superstep {s}",
                            "pid": PID_SIM, "tid": i + 1, "ts": cur,
                            "dur": t_us, "args": {"level": lv}})
        # the run loop's accumulation rule: charged steps pay the level
        # max plus pipeline fill, plus IO-die latency when records
        # crossed chips (see engine.run / driver.run)
        if step > 0.0 or pending[s] > 0.0:
            cur += step + fill_us
            if off_msgs[s] > 0.0:
                cur += io_us
    return evs, starts


def _load_events(rec, starts: List[float]) -> List[dict]:
    """Per-chip (or per-tile-row) load counter tracks on the simulated
    clock, from the run's telemetry vectors."""
    keys = rec.vec_keys()
    if not keys or not starts:
        return []
    evs: List[dict] = []
    pc = sorted(k for k in keys if k.startswith("pc_"))
    if pc:
        mats = {k: rec.vec_matrix(k) for k in pc}
        n_chips = next(iter(mats.values())).shape[1]
        ndev = getattr(rec.meta, "n_devices", 1) if rec.meta else 1
        per = n_chips // ndev if ndev and n_chips % ndev == 0 else n_chips
        for c in range(n_chips):
            name = f"chip {c} (sim load)" if ndev <= 1 else \
                f"chip {c} / dev {c // per} (sim load)"
            evs.append(_meta_event(PID_CHIP0 + c, name))
        for k, m in mats.items():
            name = k[3:]
            s_max = min(len(starts), m.shape[0])
            for c in range(n_chips):
                for s in range(s_max):
                    evs.append({"ph": "C", "name": name,
                                "pid": PID_CHIP0 + c, "tid": 0,
                                "ts": starts[s],
                                "args": {name: float(m[s, c])}})
        return evs
    # monolithic: group the per-tile vectors by grid row (tile groups)
    meta = rec.meta
    evs.append(_meta_event(PID_CHIP0, "chip 0 (sim load)"))
    for k in ("tv_delivered", "tv_edges"):
        if k not in keys:
            continue
        m = rec.vec_matrix(k)
        ny = meta.grid_ny if meta is not None and meta.grid_ny else 1
        if ny and m.shape[1] % ny == 0:
            m = m.reshape(m.shape[0], ny, -1).sum(axis=2)
        name = k[3:]
        s_max = min(len(starts), m.shape[0])
        for r in range(m.shape[1]):
            for s in range(s_max):
                evs.append({"ph": "C", "name": f"{name} row{r}",
                            "pid": PID_CHIP0, "tid": 0, "ts": starts[s],
                            "args": {name: float(m[s, r])}})
    return evs


_TID_COMPACTION = 90      # counter track on the sim process


def _compaction_events(rec, starts: List[float]) -> List[dict]:
    """Active-set compaction counter ("C") tracks on the simulated
    clock: ``active_fraction`` (active tiles / grid tiles) and
    ``bucket_cap`` (the capacity-ladder rung the superstep ran in),
    one sample per superstep.  Both come from the telemetry stats the
    engine's bucket switch emits into the packed chunk stat row — they
    ride the existing chunk fetch, so rendering them adds no host
    syncs.  Empty (and absent from the trace) on dense runs."""
    act = rec.stat_matrix("active_tiles")
    if act.size == 0 or not starts:
        return []
    cap = rec.stat_matrix("bucket_cap")
    tiles = rec.meta.tiles if rec.meta is not None else 0
    frac = act / tiles if tiles else act
    evs = [_meta_event(PID_SIM, "", tid=_TID_COMPACTION,
                       thread="active-set compaction")]
    s_max = min(len(starts), act.shape[0])
    for s in range(s_max):
        evs.append({"ph": "C", "name": "active_fraction", "pid": PID_SIM,
                    "tid": _TID_COMPACTION, "ts": starts[s],
                    "args": {"active_fraction": float(frac[s])}})
        if s < cap.shape[0]:
            evs.append({"ph": "C", "name": "bucket_cap", "pid": PID_SIM,
                        "tid": _TID_COMPACTION, "ts": starts[s],
                        "args": {"bucket_cap": float(cap[s])}})
    return evs


_TID_RECOVERY = 91        # fault-tolerance track on the sim process


def _recovery_events(rec, starts: List[float]) -> List[dict]:
    """Fault-tolerance spans ("X") on the simulated clock, from the
    run's ``SuperstepTrace.recovery_events`` log: ``checkpoint`` and
    ``re-shard`` spans sized by the image's board-leg serialization
    (``costmodel.checkpoint_leg_cycles`` — the same pricing the run's
    separate overhead accumulator uses) and ``rollback`` spans covering
    the discarded ``[from_step, at_step)`` replay window.  Empty (and
    absent) on unfailed runs without a checkpoint cadence."""
    result, meta = rec.result, rec.meta
    if result is None or result.trace is None or not starts:
        return []
    events = getattr(result.trace, "recovery_events", None)
    if not events:
        return []
    pkg = meta.pkg if meta is not None and meta.pkg is not None \
        else PackageConfig()
    blinks = int(getattr(result.trace, "board_links", 1))
    end = starts[-1]

    def at(step):
        s = int(step)
        return starts[s] if s < len(starts) else end

    evs = [_meta_event(PID_SIM, "", tid=_TID_RECOVERY,
                       thread="fault tolerance")]
    for ev in events:
        kind = ev.get("kind")
        if kind in ("checkpoint", "reshard"):
            dur = checkpoint_leg_cycles(pkg, float(ev.get("bits", 0.0)),
                                        blinks) * _US_PER_CYCLE
            name = ("checkpoint" if kind == "checkpoint"
                    else f"re-shard (chip {ev.get('chip', '?')} lost)")
            evs.append({"ph": "X", "name": f"{name} @ step {ev['step']}",
                        "pid": PID_SIM, "tid": _TID_RECOVERY,
                        "ts": at(ev["step"]), "dur": dur,
                        "args": dict(ev)})
        elif kind == "rollback":
            lo, hi = int(ev["from_step"]), int(ev["at_step"])
            evs.append({"ph": "X",
                        "name": f"rollback [{lo}:{hi}) "
                                f"(chip {ev.get('chip', '?')})",
                        "pid": PID_SIM, "tid": _TID_RECOVERY,
                        "ts": at(lo), "dur": max(at(hi) - at(lo), 0.0),
                        "args": dict(ev)})
    return evs


def to_trace_events(rec) -> List[dict]:
    """All trace events of a recorded run (see module docstring)."""
    evs = _wall_events(rec)
    sim_evs, starts = _sim_events(rec)
    evs.extend(sim_evs)
    evs.extend(_load_events(rec, starts))
    evs.extend(_compaction_events(rec, starts))
    evs.extend(_recovery_events(rec, starts))
    return evs


def trace_dict(rec) -> Dict[str, object]:
    """The complete Chrome trace-event JSON object for ``rec``."""
    meta = rec.meta
    other: Dict[str, object] = dict(wall_s=rec.wall_s,
                                    supersteps=rec.supersteps)
    if meta is not None:
        other.update(app=meta.app, grid=f"{meta.grid_ny}x{meta.grid_nx}",
                     n_chips=meta.n_chips, chunk=meta.chunk,
                     backend=meta.backend, telemetry=meta.telemetry,
                     n_devices=getattr(meta, "n_devices", 1))
    return {"traceEvents": to_trace_events(rec),
            "displayTimeUnit": "ms", "otherData": other}


def write_trace(rec, path: str) -> str:
    """Write ``rec`` as Chrome trace-event JSON; returns ``path``."""
    with open(path, "w") as f:
        json.dump(trace_dict(rec), f)
    return path
