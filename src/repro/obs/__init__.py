"""Engine telemetry: metrics registry, superstep timeline tracing,
Perfetto/Chrome-trace export, load-imbalance metrics and run reports.

Import layering: this package must never import ``repro.core.engine`` or
``repro.distrib`` (the run loops import *us* for the Observer/metrics
hooks); ``export``/``report`` may use ``core.costmodel``/``core.netstats``.
"""
from .export import to_trace_events, trace_dict, write_trace
from .imbalance import (cascade_efficacy, gini, imbalance_report,
                        max_over_mean, run_load_matrix, step_metrics,
                        summarize)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      default_registry)
from .report import run_report, to_markdown, write_report
from .timeline import ChunkSpan, Observer, RunMeta, TimelineRecorder

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "default_registry",
    "ChunkSpan", "Observer", "RunMeta", "TimelineRecorder",
    "to_trace_events", "trace_dict", "write_trace",
    "cascade_efficacy", "gini", "imbalance_report", "max_over_mean",
    "run_load_matrix", "step_metrics", "summarize",
    "run_report", "to_markdown", "write_report",
]
