from .fault import (ChipLostError, FaultInjector, FaultTolerantLoop,
                    SimulatedFailure)
from .straggler import detect_stragglers, rebalance_chunks
from .elastic import reshard_checkpoint

__all__ = ["ChipLostError", "FaultInjector", "FaultTolerantLoop",
           "SimulatedFailure", "detect_stragglers", "rebalance_chunks",
           "reshard_checkpoint"]
