from .fault import FaultTolerantLoop, SimulatedFailure
from .straggler import rebalance_chunks
from .elastic import reshard_checkpoint

__all__ = ["FaultTolerantLoop", "SimulatedFailure", "rebalance_chunks",
           "reshard_checkpoint"]
