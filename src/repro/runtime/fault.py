"""Fault-tolerant training loop.

At thousand-node scale *something* fails every few minutes; the loop
must (a) checkpoint on a cadence, (b) catch step failures, (c) roll back
to the last checkpoint and continue, (d) give up only after repeated
failures at the same step.  Failures are injected in tests via
SimulatedFailure; on real hardware the same except-path catches XLA/ICI
errors surfaced as RuntimeError/jaxlib errors.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Optional

import jax

from ..checkpoint.ckpt import (latest_step, restore_checkpoint,
                               save_checkpoint)

log = logging.getLogger("repro.fault")


class SimulatedFailure(RuntimeError):
    """Raised by test hooks to emulate a node loss / ICI timeout."""


@dataclasses.dataclass
class FaultTolerantLoop:
    train_step: Callable            # (state, batch) -> (state, metrics)
    batch_at: Callable              # step -> batch (deterministic, seekable)
    ckpt_dir: str
    ckpt_every: int = 50
    max_retries_per_step: int = 3
    failure_hook: Optional[Callable] = None   # (step) -> None, may raise

    def run(self, state, num_steps: int, start_step: int = 0):
        """Runs to ``num_steps``; returns (state, history).  Restores from
        the newest checkpoint if one is ahead of start_step."""
        last = latest_step(self.ckpt_dir)
        if last is not None and last > start_step:
            state = restore_checkpoint(self.ckpt_dir, state, step=last)
            start_step = last
            log.info("restored checkpoint at step %d", last)
        history = []
        step = start_step
        retries = 0
        while step < num_steps:
            batch = self.batch_at(step)
            try:
                if self.failure_hook is not None:
                    self.failure_hook(step)
                new_state, metrics = self.train_step(state, batch)
                # block so device-side failures surface inside the try
                metrics = jax.tree.map(
                    lambda x: x.block_until_ready()
                    if hasattr(x, "block_until_ready") else x, metrics)
            except (SimulatedFailure, RuntimeError) as e:
                retries += 1
                log.warning("step %d failed (%s); retry %d", step, e,
                            retries)
                if retries > self.max_retries_per_step:
                    raise
                last = latest_step(self.ckpt_dir)
                if last is not None:
                    state = restore_checkpoint(self.ckpt_dir, state,
                                               step=last)
                    step = last
                continue
            retries = 0
            state = new_state
            history.append(jax.device_get(metrics))
            step += 1
            if step % self.ckpt_every == 0:
                save_checkpoint(self.ckpt_dir, step, state)
        return state, history
