"""Fault-tolerant loops: training-step rollback and engine chip loss.

At thousand-node scale *something* fails every few minutes; a loop
must (a) checkpoint on a cadence, (b) catch step failures, (c) roll back
to the last checkpoint and continue, (d) give up only after repeated
failures at the same step.  Failures are injected in tests via
SimulatedFailure; on real hardware the same except-path catches XLA/ICI
errors surfaced as RuntimeError/jaxlib errors.

Two consumers share this module:

  * :class:`FaultTolerantLoop` — the training-step rendering (step /
    batch / metrics history).
  * :class:`FaultInjector` / :class:`ChipLostError` — the distributed
    graph engine's rendering: the injector is polled at every superstep
    host-accounting boundary of ``DistributedEngine.run`` and raises a
    chip loss once; the engine's recovery path re-shards the lost
    device's chip block onto the survivors (``ExecMesh`` rebuild +
    ``elastic.reshard_checkpoint``) and replays from the last superstep
    checkpoint, bit-identically.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Optional

import jax
import numpy as np

from ..checkpoint.ckpt import (latest_step, restore_checkpoint,
                               save_checkpoint)

log = logging.getLogger("repro.fault")


class SimulatedFailure(RuntimeError):
    """Raised by test hooks to emulate a node loss / ICI timeout."""


class ChipLostError(RuntimeError):
    """A chip (and the device hosting its block) dropped out mid-run.

    Raised by :class:`FaultInjector` inside ``DistributedEngine.run``'s
    boundary hook; the engine's retry loop catches it and recovers."""

    def __init__(self, chip: int, at_step: int):
        super().__init__(f"chip {chip} lost at superstep {at_step}")
        self.chip = int(chip)
        self.at_step = int(at_step)


@dataclasses.dataclass
class FaultInjector:
    """Injects one chip loss at a chosen (or seeded-random) superstep.

    ``poll(steps)`` is called by the distributed run loop at every
    superstep host-accounting boundary (per chunk on the chunked loop,
    per step on the legacy loop); the first boundary at or past
    ``at_superstep`` raises :class:`ChipLostError` once.  Because the
    chunked loop only observes steps at chunk granularity, the loss
    surfaces at the first boundary covering ``at_superstep`` — exactly
    where a real loss would first be *detected* by the host.
    """

    at_superstep: int
    chip: int = 0
    fired: bool = False

    @classmethod
    def seeded(cls, seed: int, max_superstep: int,
               num_chips: int = 1) -> "FaultInjector":
        """Uniform random loss point in ``[1, max_superstep]`` and chip in
        ``[0, num_chips)`` from a deterministic seed (test harnesses)."""
        rng = np.random.default_rng(seed)
        return cls(
            at_superstep=int(rng.integers(1, max(int(max_superstep), 1) + 1)),
            chip=int(rng.integers(0, max(int(num_chips), 1))))

    def poll(self, steps: int) -> None:
        if not self.fired and steps >= self.at_superstep:
            self.fired = True
            raise ChipLostError(self.chip, steps)


@dataclasses.dataclass
class FaultTolerantLoop:
    train_step: Callable            # (state, batch) -> (state, metrics)
    batch_at: Callable              # step -> batch (deterministic, seekable)
    ckpt_dir: str
    ckpt_every: int = 50
    max_retries_per_step: int = 3
    failure_hook: Optional[Callable] = None   # (step) -> None, may raise

    def run(self, state, num_steps: int, start_step: int = 0):
        """Runs to ``num_steps``; returns (state, history).  Restores from
        the newest checkpoint if one is ahead of start_step."""
        last = latest_step(self.ckpt_dir)
        if last is not None and last > start_step:
            state = restore_checkpoint(self.ckpt_dir, state, step=last)
            start_step = last
            log.info("restored checkpoint at step %d", last)
        history = []
        step = start_step
        retries = 0
        fail_step: Optional[int] = None
        while step < num_steps:
            batch = self.batch_at(step)
            try:
                if self.failure_hook is not None:
                    self.failure_hook(step)
                new_state, metrics = self.train_step(state, batch)
                # block so device-side failures surface inside the try
                metrics = jax.tree.map(
                    lambda x: x.block_until_ready()
                    if hasattr(x, "block_until_ready") else x, metrics)
            except (SimulatedFailure, RuntimeError) as e:
                # per-step retry budget: a failure at a *different* step
                # starts a fresh count (the docstring's contract — one
                # flaky step must not eat another's budget)
                if fail_step != step:
                    fail_step, retries = step, 0
                retries += 1
                log.warning("step %d failed (%s); retry %d", step, e,
                            retries)
                if retries > self.max_retries_per_step:
                    raise
                last = latest_step(self.ckpt_dir)
                if last is not None:
                    state = restore_checkpoint(self.ckpt_dir, state,
                                               step=last)
                    step = last
                    # roll metrics back with the state: the replayed
                    # steps re-append their metrics, so keeping the old
                    # entries would double-count every replayed step
                    del history[max(last - start_step, 0):]
                continue
            state = new_state
            history.append(jax.device_get(metrics))
            step += 1
            if step % self.ckpt_every == 0:
                save_checkpoint(self.ckpt_dir, step, state)
        return state, history
