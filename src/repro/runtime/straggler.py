"""Straggler mitigation = the paper's work-imbalance story, reused.

The engine records per-tile load (edges processed / records consumed).
A straggler is a tile whose load is far above the mean — exactly the
paper's "hot data owner".  Two mitigations, both from the paper:

  1. proxy regions (spread the hot tile's combine work regionally) —
     already in the execution path;
  2. re-chunking: skew the ownership map so hot index ranges are split
     across more tiles (the paper's data-placement/partitioning knob).

For LM training the same logic applies to expert imbalance: the MoE
router's aux loss is the *preventive* fix; rebalance_experts() is the
corrective one (capacity re-assignment from observed expert load).
"""
from __future__ import annotations

import numpy as np


def detect_stragglers(load: np.ndarray, threshold: float = 2.0):
    """Tiles with load > threshold * mean.  Returns (mask, ratio)."""
    load = np.asarray(load, np.float64)
    mean = max(load.mean(), 1e-9)
    return load > threshold * mean, load.max() / mean


def rebalance_chunks(load: np.ndarray, n_items: int,
                     max_ratio: float = 1.5) -> np.ndarray:
    """Compute new chunk boundaries from per-tile load.

    Input: per-tile load under equal chunks; output: (T+1,) int64 offsets
    assigning index ranges to tiles such that estimated per-tile load is
    balanced (inverse-load-proportional chunk sizes, clamped to
    max_ratio x equal size to bound churn).
    Returns boundaries; tile t owns [b[t], b[t+1]).
    """
    t = load.shape[0]
    load = np.maximum(np.asarray(load, np.float64), 1e-9)
    eq = n_items / t
    # per-item density within old chunk ~ load/chunk; target boundaries
    # equalize cumulative load.
    density = load / eq                             # per old chunk
    cum = np.concatenate([[0.0], np.cumsum(density)])
    targets = np.linspace(0, cum[-1], t + 1)
    # invert the cumulative-load curve at old-chunk granularity
    pos = np.interp(targets, cum, np.arange(t + 1) * eq)
    pos[0], pos[-1] = 0, n_items
    pos = np.round(pos).astype(np.int64)
    # clamp chunk sizes to [eq/max_ratio, eq*max_ratio] to bound movement
    sizes = np.diff(pos)
    lo_sz = min(int(eq / max_ratio), n_items // t)
    hi_sz = max(int(np.ceil(eq * max_ratio)), int(np.ceil(eq)))
    sizes = np.clip(sizes, lo_sz, hi_sz)
    # repair the post-clip drift fully: the clip can move the total by
    # up to t * (hi_sz - lo_sz), so one +-1 pass over at most t chunks
    # is not enough — keep spreading +-1 corrections (largest chunks
    # shrink first, smallest grow first) until the sizes sum exactly,
    # never leaving the clip window, so the cumulative boundaries are
    # monotone by construction and no final-chunk overwrite is needed.
    # (termination: t*lo_sz <= n_items <= t*hi_sz, so whenever the sum is
    # off there is room in the needed direction, and every pass moves the
    # sum at least 1 toward n_items)
    while True:
        diff = int(n_items - sizes.sum())
        if diff == 0:
            break
        if diff > 0:
            room = sizes < hi_sz
            order = np.argsort(sizes[room], kind="stable")
            sizes[np.flatnonzero(room)[order][:diff]] += 1
        else:
            room = sizes > lo_sz
            order = np.argsort(-sizes[room], kind="stable")
            sizes[np.flatnonzero(room)[order][:-diff]] -= 1
    return np.concatenate([[0], np.cumsum(sizes)])


def rebalance_experts(expert_load: np.ndarray, capacity: int):
    """Corrective expert capacity assignment: experts get capacity
    proportional to observed load (sum preserved)."""
    load = np.maximum(np.asarray(expert_load, np.float64), 1e-9)
    total = capacity * load.shape[0]
    cap = np.maximum(1, np.round(total * load / load.sum())).astype(int)
    # fix rounding drift
    drift = total - cap.sum()
    cap[np.argsort(-cap)[: abs(int(drift))]] += np.sign(drift)
    return cap
