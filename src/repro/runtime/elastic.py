"""Elastic restart: resume a checkpoint on a different mesh shape.

The checkpoint stores plain host arrays; re-placement happens through the
target mesh's sharding rules.  This makes "pod died, continue on half the
mesh" (or "doubled the job, continue on 2x") a pure-restore operation —
no resharding communication step, because leaves stream from storage
directly into their new layout.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..checkpoint.ckpt import restore_checkpoint

PyTree = Any


def reshard_checkpoint(directory: str, template: PyTree, mesh: Mesh,
                       rule: Callable[[str, tuple], P],
                       step: Optional[int] = None) -> PyTree:
    """Restore ``directory`` onto ``mesh`` using sharding ``rule``.

    rule(path_str, shape) -> PartitionSpec; axes whose sizes don't divide
    are expected to be handled by the rule (it should return a spec that
    divides — see launch/shardings.py).
    """

    def sharding_fn(path, shape):
        spec = rule(path, tuple(shape))
        return NamedSharding(mesh, spec)

    return restore_checkpoint(directory, template, step=step,
                              sharding_fn=sharding_fn)
