"""Mamba2 (SSD) block — used by zamba2-1.2b and as the sub-quadratic
long-context path (long_500k shapes).

Training uses the chunked state-space-dual algorithm: quadratic
attention-like compute *within* a chunk (MXU-friendly), linear recurrence
*across* chunks (lax.scan carrying the (H, P, N) state).  Decode is the
O(1) recurrent step.  The cross-chunk state hand-off is associative — the
same regional-combine structure the paper's proxies exploit (DESIGN §3).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import DTYPE, apply_norm, dense_init, norm_init

CONV_W = 4          # causal depthwise conv width
CHUNK = 256


def ssd_init(key, cfg) -> Dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    assert h * p == di, (h, p, di)
    ks = jax.random.split(key, 8)
    return dict(
        in_proj=dense_init(ks[0], d, 2 * di + 2 * n + h),
        conv_w=(jax.random.normal(ks[1], (CONV_W, di)) * 0.2).astype(DTYPE),
        a_log=jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        dt_bias=jnp.zeros((h,), jnp.float32),
        d_skip=jnp.ones((h,), jnp.float32),
        gate_norm=norm_init(di),
        out_proj=dense_init(ks[2], di, d),
        norm=norm_init(d, with_bias=cfg.norm_bias),
    )


def _split_proj(p, xn, cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h, n = cfg.ssm_heads, cfg.ssm_state
    z, xc, bc, cc, dt = jnp.split(xn @ p["in_proj"],
                                  [di, 2 * di, 2 * di + n, 2 * di + 2 * n],
                                  axis=-1)
    return z, xc, bc, cc, dt


def _conv(xc, conv_w, state=None):
    """Causal depthwise conv.  xc: (B,S,di).  With ``state`` (B,CONV_W-1,di)
    performs the single-step decode update; returns (out, new_state)."""
    if state is None:
        pad = jnp.pad(xc, ((0, 0), (CONV_W - 1, 0), (0, 0)))
        out = sum(pad[:, i: i + xc.shape[1]] * conv_w[i]
                  for i in range(CONV_W))
        return out, pad[:, -(CONV_W - 1):] if CONV_W > 1 else None
    win = jnp.concatenate([state, xc], axis=1)            # (B,CONV_W,di)
    out = jnp.einsum("bwd,wd->bd", win.astype(jnp.float32),
                     conv_w.astype(jnp.float32))[:, None].astype(xc.dtype)
    return out, win[:, 1:]


def ssd_forward(p, x, cfg, state: Tuple | None = None):
    """Full-sequence SSD.  x: (B,S,d).  Returns (y, (ssm_state, conv_state))
    where ssm_state: (B,H,P,N) f32 — the decode-ready carry."""
    b, s, d = x.shape
    h, pp, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = h * pp
    xn = apply_norm(p["norm"], x)
    z, xc, bc, cc, dt = _split_proj(p, xn, cfg)
    xc, conv_state = _conv(xc, p["conv_w"])
    xc = jax.nn.silu(xc.astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    la = -dt * jnp.exp(p["a_log"])                                # log decay
    xh = xc.reshape(b, s, h, pp)
    bcf = bc.astype(jnp.float32)
    ccf = cc.astype(jnp.float32)

    # pad to a chunk multiple
    c = min(CHUNK, s)
    s_pad = -(-s // c) * c
    if s_pad != s:
        z2 = lambda a: jnp.pad(a, [(0, 0), (0, s_pad - s)] +               # noqa: E731
                               [(0, 0)] * (a.ndim - 2))
        xh, bcf, ccf, dt, la = map(z2, (xh, bcf, ccf, dt, la))
    nc = s_pad // c
    xh = xh.reshape(b, nc, c, h, pp)
    bcf = bcf.reshape(b, nc, c, n)
    ccf = ccf.reshape(b, nc, c, n)
    dt = dt.reshape(b, nc, c, h)
    la = la.reshape(b, nc, c, h)

    fcs = jnp.cumsum(la, axis=2)                       # (B,nc,C,H) F_t
    if state is None:
        s0 = jnp.zeros((b, h, pp, n), jnp.float32)
    else:
        s0 = state[0]

    def chunk_body(carry, inp):
        s_prev = carry
        xh_c, b_c, c_c, dt_c, la_c, f_c = inp          # (B,C,...) per chunk
        # intra-chunk: w[t,s] = exp(F_t - F_s) * dt_s, s <= t.
        # Mask the exponent (not the value): exp would overflow above the
        # diagonal and poison the gradient through the where.
        diff = f_c[:, :, None, :] - f_c[:, None, :, :]          # (B,t,s,H)
        tri = jnp.tril(jnp.ones((c, c), bool))
        w = jnp.exp(jnp.where(tri[None, :, :, None], diff, -1e30)) \
            * dt_c[:, None, :, :]
        scores = jnp.einsum("btn,bsn->bts", c_c, b_c)           # (B,t,s)
        y_intra = jnp.einsum("bts,btsh,bshp->bthp", scores, w, xh_c)
        # inter-chunk: carry state decayed to position t
        et = jnp.exp(f_c)                                       # (B,C,H)
        y_inter = jnp.einsum("btn,bhpn,bth->bthp", c_c, s_prev, et)
        y = y_intra + y_inter
        # state update to chunk end
        dec_end = jnp.exp(f_c[:, -1])                           # (B,H)
        w_end = jnp.exp(f_c[:, -1][:, None] - f_c) * dt_c       # (B,C,H)
        s_new = (dec_end[:, :, None, None] * s_prev
                 + jnp.einsum("bch,bchp,bcn->bhpn", w_end, xh_c, b_c))
        return s_new, y

    inp = (xh, bcf, ccf, dt, la, fcs)
    inp = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), inp)    # scan over nc
    s_fin, ys = jax.lax.scan(chunk_body, s0, inp)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s_pad, h, pp)[:, :s]
    y = y + xh.reshape(b, s_pad, h, pp)[:, :s] * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di)
    y = apply_norm(p["gate_norm"], y.astype(x.dtype)) \
        * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return x + y @ p["out_proj"], (s_fin, conv_state)


def ssd_decode(p, x, state, cfg):
    """One-token SSD step.  x: (B,1,d); state: (ssm (B,H,P,N) f32,
    conv (B,CONV_W-1,di))."""
    b = x.shape[0]
    h, pp, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = h * pp
    ssm_state, conv_state = state
    xn = apply_norm(p["norm"], x)
    z, xc, bc, cc, dt = _split_proj(p, xn, cfg)
    xc, conv_state = _conv(xc, p["conv_w"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32))[:, 0]              # (B,di)
    dt = jax.nn.softplus(dt.astype(jnp.float32)[:, 0] + p["dt_bias"])  # (B,H)
    la = -dt * jnp.exp(p["a_log"])
    alpha = jnp.exp(la)                                          # (B,H)
    xh = xc.reshape(b, h, pp)
    bf = bc.astype(jnp.float32)[:, 0]                            # (B,N)
    cf = cc.astype(jnp.float32)[:, 0]
    ssm_state = (alpha[:, :, None, None] * ssm_state
                 + jnp.einsum("bh,bhp,bn->bhpn", dt, xh, bf))
    y = jnp.einsum("bn,bhpn->bhp", cf, ssm_state) \
        + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, di)
    y = apply_norm(p["gate_norm"], y.astype(x.dtype)) \
        * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return x + y @ p["out_proj"], (ssm_state, conv_state)
