"""Decoder-only LM assembly for all non-enc-dec families.

Families (dispatch table at the bottom):
  dense   - GQA/SWA attention + MLP              (starcoder2, deepseek-7b,
                                                   h2o-danube, pixtral bkbn)
  moe     - attention + top-k routed MoE          (granite-moe)
  mla_moe - MLA attention + MoE w/ shared expert  (deepseek-v3, opt. MTP)
  xlstm   - mLSTM/sLSTM groups                    (xlstm-1.3b)
  hybrid  - Mamba2 + shared attention block       (zamba2-1.2b)

Common protocol per family module:
  init(cfg, key) -> params
  forward(params, batch, cfg) -> (logits, aux_loss)
  prefill(params, batch, cfg) -> (logits, cache)
  decode(params, cache, tokens, pos, cfg) -> (logits, cache)
  init_cache(cfg, batch, cache_len) -> cache pytree (zeros; used via
      eval_shape by the dry-run to build ShapeDtypeStruct stand-ins)

Layers are stacked on a leading L axis and consumed with lax.scan
(+ jax.checkpoint for remat) — essential to keep 61-layer HLO small.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import ssm as ssm_mod
from . import xlstm as xl_mod
from .layers import (DTYPE, apply_norm, attention, attention_decode,
                     attn_init, constrain, dense_init, embed_init,
                     mla_attention, mla_decode, mla_init, mlp, mlp_init,
                     moe, moe_init, norm_init)

PyTree = Any


# ------------------------------------------------------------------ shared
def _embed_in(params, batch, cfg):
    if isinstance(batch, dict) and "embeds" in batch:
        return constrain(batch["embeds"].astype(DTYPE))
    tokens = batch["tokens"] if isinstance(batch, dict) else batch
    return constrain(jnp.take(params["tok_emb"], tokens, axis=0))


def _head(params, x, cfg):
    x = apply_norm(params["final_norm"], x)
    from .layers import wload
    return constrain(jnp.einsum("bsd,vd->bsv", x,
                                wload(params["lm_head"], 0)), "logits")


def lm_loss(logits, labels, cfg, aux=0.0):
    """CE over the (padded, possibly vocab-sharded) logits.  The true
    logit is extracted with an iota-compare masked sum — elementwise, so
    it shards like the logits (no gather over the vocab dim)."""
    lf = logits.astype(jnp.float32)
    vids = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    lf = jnp.where(vids < cfg.vocab, lf, -1e30)          # mask padding rows
    lse = jax.scipy.special.logsumexp(lf, axis=-1)       # (B,S)
    true = jnp.sum(jnp.where(vids == labels[..., None], lf, 0.0), axis=-1)
    ce = jnp.mean(lse - true)
    return ce + cfg.moe_aux_weight * aux


def _base_init(cfg, key):
    ks = jax.random.split(key, 3)
    p = dict(final_norm=norm_init(cfg.d_model, with_bias=cfg.norm_bias),
             lm_head=embed_init(ks[1], cfg.vocab_pad, cfg.d_model))
    if not cfg.input_embeds or cfg.family in ("dense", "moe", "mla_moe",
                                              "xlstm", "hybrid"):
        p["tok_emb"] = embed_init(ks[0], cfg.vocab_pad, cfg.d_model)
    return p


def _stack(layer_fn, keys):
    layers = [layer_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


# ======================================================================
# dense
# ======================================================================
def dense_init_params(cfg, key):
    p = _base_init(cfg, key)
    keys = jax.random.split(jax.random.fold_in(key, 7), cfg.n_layers)

    def one(k):
        k1, k2 = jax.random.split(k)
        return dict(attn=attn_init(k1, cfg), mlp=mlp_init(k2, cfg))

    p["layers"] = _stack(one, keys)
    return p


def _dense_block(lp, x, cfg, positions):
    x, kv = attention(lp["attn"], x, cfg, positions)
    x = constrain(mlp(lp["mlp"], constrain(x), cfg))
    return x, kv


def dense_forward(params, batch, cfg):
    x = _embed_in(params, batch, cfg)
    positions = jnp.arange(x.shape[1])[None, :]
    block = jax.checkpoint(
        lambda lp, x: _dense_block(lp, x, cfg, positions)[0],
        policy=jax.checkpoint_policies.nothing_saveable)

    def body(x, lp):
        return block(lp, x), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return _head(params, x, cfg), 0.0


def dense_prefill(params, batch, cfg):
    x = _embed_in(params, batch, cfg)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        x, kv = _dense_block(lp, x, cfg, positions)
        return x, kv

    x, kvs = jax.lax.scan(body, x, params["layers"])
    cache = dict(k=kvs[0], v=kvs[1])                     # (L,B,S,Hkv,D)
    logits = _head(params, x[:, -1:], cfg)
    return logits, cache


CARRY_CACHE = True    # decode-cache scheduling: True (default) = cache is
                      # a loop *carry* updated in place at the layer index
                      # (aliases with the donated input); False = cache
                      # flows through scan xs->ys, which makes XLA
                      # double-buffer the whole stacked cache (-95% decode
                      # temp with carry; EXPERIMENTS.md §Perf C)


def dense_decode(params, cache, tokens, pos, cfg):
    x = _embed_in(params, dict(tokens=tokens), cfg)
    ring = cfg.swa_window > 0 and cache["k"].shape[2] == cfg.swa_window

    if CARRY_CACHE:
        def body(carry, xs):
            x, ck, cv = carry
            lp, li = xs
            cl = dict(k=jax.lax.dynamic_index_in_dim(ck, li, 0, False),
                      v=jax.lax.dynamic_index_in_dim(cv, li, 0, False))
            x, ncl = attention_decode(lp["attn"], x, cl, pos, cfg,
                                      ring=ring)
            x = mlp(lp["mlp"], x, cfg)
            ck = jax.lax.dynamic_update_index_in_dim(ck, ncl["k"], li, 0)
            cv = jax.lax.dynamic_update_index_in_dim(cv, ncl["v"], li, 0)
            return (x, ck, cv), None

        (x, ck, cv), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
        return _head(params, x, cfg)[:, 0], dict(k=ck, v=cv)

    def body(x, xs):
        lp, ck, cv = xs
        x, ncl = attention_decode(lp["attn"], x, dict(k=ck, v=cv), pos, cfg,
                                  ring=ring)
        x = mlp(lp["mlp"], x, cfg)
        return x, ncl

    x, ncache = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                       cache["v"]))
    logits = _head(params, x, cfg)
    return logits[:, 0], dict(k=ncache["k"], v=ncache["v"])


def dense_init_cache(cfg, batch, cache_len):
    t = cache_len if not cfg.swa_window else min(cache_len, cfg.swa_window)
    shape = (cfg.n_layers, batch, t, cfg.n_kv, cfg.head_dim)
    return dict(k=jnp.zeros(shape, DTYPE), v=jnp.zeros(shape, DTYPE))


# ======================================================================
# moe (dense attention + routed MoE mlp)
# ======================================================================
def moe_init_params(cfg, key):
    p = _base_init(cfg, key)
    keys = jax.random.split(jax.random.fold_in(key, 11), cfg.n_layers)

    def one(k):
        k1, k2 = jax.random.split(k)
        return dict(attn=attn_init(k1, cfg), moe=moe_init(k2, cfg))

    p["layers"] = _stack(one, keys)
    return p


def _moe_block(lp, x, cfg, positions):
    x, kv = attention(lp["attn"], x, cfg, positions)
    x, aux = moe(lp["moe"], constrain(x), cfg)
    return constrain(x), aux, kv


def moe_forward(params, batch, cfg):
    x = _embed_in(params, batch, cfg)
    positions = jnp.arange(x.shape[1])[None, :]
    block = jax.checkpoint(
        lambda lp, x: _moe_block(lp, x, cfg, positions)[:2],
        policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, lp):
        x, aux = carry
        x, a = block(lp, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, 0.0), params["layers"])
    return _head(params, x, cfg), aux / cfg.n_layers


def moe_prefill(params, batch, cfg):
    x = _embed_in(params, batch, cfg)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        x, _, kv = _moe_block(lp, x, cfg, positions)
        return x, kv

    x, kvs = jax.lax.scan(body, x, params["layers"])
    return _head(params, x[:, -1:], cfg), dict(k=kvs[0], v=kvs[1])


def moe_decode(params, cache, tokens, pos, cfg):
    x = _embed_in(params, dict(tokens=tokens), cfg)

    def body(x, xs):
        lp, ck, cv = xs
        x, ncl = attention_decode(lp["attn"], x, dict(k=ck, v=cv), pos, cfg)
        x, _ = moe(lp["moe"], x, cfg)
        return x, ncl

    x, ncache = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                       cache["v"]))
    return _head(params, x, cfg)[:, 0], dict(k=ncache["k"], v=ncache["v"])


moe_init_cache = dense_init_cache


# ======================================================================
# mla_moe (deepseek-v3: MLA attention, leading dense layers, MoE + MTP)
# ======================================================================
def mla_moe_init_params(cfg, key):
    p = _base_init(cfg, key)
    kd = jax.random.split(jax.random.fold_in(key, 13), cfg.n_dense_layers)
    km = jax.random.split(jax.random.fold_in(key, 17),
                          cfg.n_layers - cfg.n_dense_layers)

    def one_dense(k):
        k1, k2 = jax.random.split(k)
        return dict(attn=mla_init(k1, cfg), mlp=mlp_init(k2, cfg))

    def one_moe(k):
        k1, k2 = jax.random.split(k)
        return dict(attn=mla_init(k1, cfg), moe=moe_init(k2, cfg))

    p["dense_layers"] = _stack(one_dense, kd)
    p["moe_layers"] = _stack(one_moe, km)
    if cfg.mtp:
        k1, k2, k3 = jax.random.split(jax.random.fold_in(key, 19), 3)
        p["mtp"] = dict(proj=dense_init(k1, 2 * cfg.d_model, cfg.d_model),
                        block=one_dense(k2),
                        norm=norm_init(cfg.d_model, with_bias=cfg.norm_bias))
    return p


def mla_moe_forward(params, batch, cfg):
    x = _embed_in(params, batch, cfg)
    positions = jnp.arange(x.shape[1])[None, :]

    def dense_block(lp, x):
        x, _ = mla_attention(lp["attn"], x, cfg, positions)
        return constrain(mlp(lp["mlp"], constrain(x), cfg))

    def moe_block(lp, x):
        x, _ = mla_attention(lp["attn"], x, cfg, positions)
        x, aux = moe(lp["moe"], constrain(x), cfg)
        return constrain(x), aux

    dense_ck = jax.checkpoint(dense_block,
                              policy=jax.checkpoint_policies.nothing_saveable)
    moe_ck = jax.checkpoint(moe_block,
                            policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(lambda x, lp: (dense_ck(lp, x), None), x,
                        params["dense_layers"])

    def body(carry, lp):
        x, aux = carry
        x, a = moe_ck(lp, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, 0.0), params["moe_layers"])
    logits = _head(params, x, cfg)
    aux = aux / max(cfg.n_layers - cfg.n_dense_layers, 1)
    if cfg.mtp and isinstance(batch, dict) and "tokens" in batch:
        # MTP: predict token t+2 from (h_t, emb(token_{t+1})).
        emb_next = jnp.take(params["tok_emb"],
                            jnp.roll(batch["tokens"], -1, axis=1), axis=0)
        xn = apply_norm(params["mtp"]["norm"], x)
        h = jnp.concatenate([xn, emb_next], axis=-1) @ params["mtp"]["proj"]
        h, _ = mla_attention(params["mtp"]["block"]["attn"], h, cfg,
                             positions)
        h = mlp(params["mtp"]["block"]["mlp"], h, cfg)
        mtp_logits = _head(params, h, cfg)
        return (logits, mtp_logits), aux
    return logits, aux


def mla_moe_prefill(params, batch, cfg):
    x = _embed_in(params, batch, cfg)
    positions = jnp.arange(x.shape[1])[None, :]

    def dbody(x, lp):
        x, lat = mla_attention(lp["attn"], x, cfg, positions)
        return mlp(lp["mlp"], x, cfg), lat

    x, dlat = jax.lax.scan(dbody, x, params["dense_layers"])

    def mbody(x, lp):
        x, lat = mla_attention(lp["attn"], x, cfg, positions)
        x, _ = moe(lp["moe"], x, cfg)
        return x, lat

    x, mlat = jax.lax.scan(mbody, x, params["moe_layers"])
    cache = dict(dc=dlat[0], dkr=dlat[1], mc=mlat[0], mkr=mlat[1])
    return _head(params, x[:, -1:], cfg), cache


def mla_moe_decode(params, cache, tokens, pos, cfg):
    x = _embed_in(params, dict(tokens=tokens), cfg)

    def dbody(x, xs):
        lp, c, kr = xs
        x, nc = mla_decode(lp["attn"], x, dict(c=c, kr=kr), pos, cfg)
        return mlp(lp["mlp"], x, cfg), nc

    x, dlat = jax.lax.scan(dbody, x, (params["dense_layers"], cache["dc"],
                                      cache["dkr"]))

    def mbody(x, xs):
        lp, c, kr = xs
        x, nc = mla_decode(lp["attn"], x, dict(c=c, kr=kr), pos, cfg)
        x, _ = moe(lp["moe"], x, cfg)
        return x, nc

    x, mlat = jax.lax.scan(mbody, x, (params["moe_layers"], cache["mc"],
                                      cache["mkr"]))
    ncache = dict(dc=dlat["c"], dkr=dlat["kr"], mc=mlat["c"],
                  mkr=mlat["kr"])
    return _head(params, x, cfg)[:, 0], ncache


def mla_moe_init_cache(cfg, batch, cache_len):
    nd = cfg.n_dense_layers
    nm = cfg.n_layers - nd
    return dict(
        dc=jnp.zeros((nd, batch, cache_len, cfg.kv_lora_rank), DTYPE),
        dkr=jnp.zeros((nd, batch, cache_len, cfg.qk_rope_dim), DTYPE),
        mc=jnp.zeros((nm, batch, cache_len, cfg.kv_lora_rank), DTYPE),
        mkr=jnp.zeros((nm, batch, cache_len, cfg.qk_rope_dim), DTYPE),
    )


# ======================================================================
# xlstm (groups of (slstm_every - 1) mLSTM + 1 sLSTM)
# ======================================================================
def xlstm_init_params(cfg, key):
    p = _base_init(cfg, key)
    g = cfg.n_layers // cfg.xlstm_slstm_every
    m_per = cfg.xlstm_slstm_every - 1
    gkeys = jax.random.split(jax.random.fold_in(key, 23), g)

    def one_group(k):
        mks = jax.random.split(k, m_per + 1)
        ml = _stack(lambda kk: xl_mod.mlstm_init(kk, cfg), mks[:m_per])
        sl = xl_mod.slstm_init(mks[-1], cfg)
        return dict(mlstm=ml, slstm=sl)

    p["groups"] = _stack(one_group, gkeys)
    return p


def _xlstm_group(gp, x, cfg, states=None):
    """Run one group; states = (m_states, s_state) or None."""

    def mbody(x, xs):
        if states is None:
            lp = xs
            x, st = xl_mod.mlstm_forward(lp, x, cfg)
        else:
            lp, st_in = xs
            x, st = xl_mod.mlstm_forward(lp, x, cfg, state=st_in)
        return x, st

    xs = gp["mlstm"] if states is None else (gp["mlstm"], states[0])
    x, m_states = jax.lax.scan(mbody, x, xs)
    x, s_state = xl_mod.slstm_forward(gp["slstm"], x, cfg,
                                      state=None if states is None
                                      else states[1])
    return x, (m_states, s_state)


def xlstm_forward(params, batch, cfg):
    x = _embed_in(params, batch, cfg)
    group = jax.checkpoint(lambda gp, x: _xlstm_group(gp, x, cfg)[0],
                           policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(lambda x, gp: (constrain(group(gp, x)), None), x,
                        params["groups"])
    return _head(params, x, cfg), 0.0


def xlstm_prefill(params, batch, cfg):
    x = _embed_in(params, batch, cfg)

    def body(x, gp):
        x, st = _xlstm_group(gp, x, cfg)
        return x, st

    x, states = jax.lax.scan(body, x, params["groups"])
    return _head(params, x[:, -1:], cfg), states


def xlstm_decode(params, cache, tokens, pos, cfg):
    x = _embed_in(params, dict(tokens=tokens), cfg)

    def body(x, xs):
        gp, st = xs

        def mbody(x, ys):
            lp, s = ys
            x, ns = xl_mod.mlstm_decode(lp, x, s, cfg)
            return x, ns

        x, m_states = jax.lax.scan(mbody, x, (gp["mlstm"], st[0]))
        x, s_state = xl_mod.slstm_decode(gp["slstm"], x, st[1], cfg)
        return x, (m_states, s_state)

    x, states = jax.lax.scan(body, x, (params["groups"], cache))
    return _head(params, x, cfg)[:, 0], states


def xlstm_init_cache(cfg, batch, cache_len):
    del cache_len                                  # O(1) state
    g = cfg.n_layers // cfg.xlstm_slstm_every
    m_per = cfg.xlstm_slstm_every - 1
    di = cfg.xlstm_proj * cfg.d_model
    pp = di // cfg.n_heads
    sp = cfg.d_model // cfg.n_heads
    f32 = jnp.float32
    m_states = (jnp.zeros((g, m_per, batch, cfg.n_heads, pp, pp), f32),
                jnp.zeros((g, m_per, batch, cfg.n_heads, pp), f32),
                jnp.full((g, m_per, batch, cfg.n_heads), -1e30, f32))
    s_state = (jnp.zeros((g, batch, cfg.n_heads, sp), f32),
               jnp.zeros((g, batch, cfg.n_heads, sp), f32),
               jnp.zeros((g, batch, cfg.n_heads, sp), f32),
               jnp.full((g, batch, cfg.n_heads), -1e30, f32))
    return (m_states, s_state)


# ======================================================================
# hybrid (zamba2: Mamba2 backbone + shared attention block every k layers)
# ======================================================================
def hybrid_init_params(cfg, key):
    p = _base_init(cfg, key)
    keys = jax.random.split(jax.random.fold_in(key, 29), cfg.n_layers)
    p["mamba"] = _stack(lambda k: ssm_mod.ssd_init(k, cfg), keys)
    k1, k2 = jax.random.split(jax.random.fold_in(key, 31))
    p["shared"] = dict(attn=attn_init(k1, cfg), mlp=mlp_init(k2, cfg))
    return p


def _n_shared(cfg):
    return cfg.n_layers // cfg.hybrid_every


def hybrid_forward(params, batch, cfg):
    x = _embed_in(params, batch, cfg)
    positions = jnp.arange(x.shape[1])[None, :]
    shared = params["shared"]

    def block(lp, x, idx):
        x, _ = ssm_mod.ssd_forward(lp, x, cfg)
        x = constrain(x)
        apply_shared = (idx % cfg.hybrid_every) == (cfg.hybrid_every - 1)

        def with_attn(x):
            x, _ = attention(shared["attn"], x, cfg, positions)
            return constrain(mlp(shared["mlp"], x, cfg))

        return jax.lax.cond(apply_shared, with_attn, lambda x: x, x)

    block_ck = jax.checkpoint(block,
                              policy=jax.checkpoint_policies.nothing_saveable)

    def body(x, xs):
        lp, idx = xs
        return block_ck(lp, x, idx), None

    x, _ = jax.lax.scan(body, x, (params["mamba"],
                                  jnp.arange(cfg.n_layers)))
    return _head(params, x, cfg), 0.0


def hybrid_prefill(params, batch, cfg):
    x = _embed_in(params, batch, cfg)
    positions = jnp.arange(x.shape[1])[None, :]
    shared = params["shared"]
    n_sh = _n_shared(cfg)
    b = x.shape[0]
    t = x.shape[1] if not cfg.swa_window else min(x.shape[1], cfg.swa_window)
    sh_cache = dict(
        k=jnp.zeros((n_sh, b, t, cfg.n_kv, cfg.head_dim), DTYPE),
        v=jnp.zeros((n_sh, b, t, cfg.n_kv, cfg.head_dim), DTYPE))

    def body(carry, xs):
        x, sh = carry
        lp, idx = xs
        x, st = ssm_mod.ssd_forward(lp, x, cfg)
        apply_shared = (idx % cfg.hybrid_every) == (cfg.hybrid_every - 1)
        sidx = idx // cfg.hybrid_every

        def with_attn(args):
            x, sh = args
            x2, (k, v) = attention(shared["attn"], x, cfg, positions)
            x2 = mlp(shared["mlp"], x2, cfg)
            kk = k[:, -t:].astype(DTYPE)
            vv = v[:, -t:].astype(DTYPE)
            sh = dict(
                k=jax.lax.dynamic_update_slice(
                    sh["k"], kk[None], (sidx, 0, 0, 0, 0)),
                v=jax.lax.dynamic_update_slice(
                    sh["v"], vv[None], (sidx, 0, 0, 0, 0)))
            return x2, sh

        x, sh = jax.lax.cond(apply_shared, with_attn, lambda a: a, (x, sh))
        return (x, sh), st

    (x, sh_cache), sstates = jax.lax.scan(
        body, (x, sh_cache), (params["mamba"], jnp.arange(cfg.n_layers)))
    cache = dict(ssm=sstates[0], conv=sstates[1], shared=sh_cache)
    return _head(params, x[:, -1:], cfg), cache


def hybrid_decode(params, cache, tokens, pos, cfg):
    x = _embed_in(params, dict(tokens=tokens), cfg)
    shared = params["shared"]
    t = cache["shared"]["k"].shape[2]
    ring = cfg.swa_window > 0 and t == cfg.swa_window

    def body(carry, xs):
        x, sh = carry
        lp, s_ssm, s_conv, idx = xs
        x, (n_ssm, n_conv) = ssm_mod.ssd_decode(lp, x, (s_ssm, s_conv), cfg)
        apply_shared = (idx % cfg.hybrid_every) == (cfg.hybrid_every - 1)
        sidx = idx // cfg.hybrid_every

        def with_attn(args):
            x, sh = args
            cl = dict(k=sh["k"][sidx], v=sh["v"][sidx])
            x2, ncl = attention_decode(shared["attn"], x, cl, pos, cfg,
                                       ring=ring)
            x2 = mlp(shared["mlp"], x2, cfg)
            sh = dict(
                k=jax.lax.dynamic_update_slice(
                    sh["k"], ncl["k"][None], (sidx, 0, 0, 0, 0)),
                v=jax.lax.dynamic_update_slice(
                    sh["v"], ncl["v"][None], (sidx, 0, 0, 0, 0)))
            return x2, sh

        x, sh = jax.lax.cond(apply_shared, with_attn, lambda a: a, (x, sh))
        return (x, sh), (n_ssm, n_conv)

    (x, sh_cache), sstates = jax.lax.scan(
        body, (x, cache["shared"]),
        (params["mamba"], cache["ssm"], cache["conv"],
         jnp.arange(cfg.n_layers)))
    ncache = dict(ssm=sstates[0], conv=sstates[1], shared=sh_cache)
    return _head(params, x, cfg)[:, 0], ncache


def hybrid_init_cache(cfg, batch, cache_len):
    di = cfg.ssm_expand * cfg.d_model
    t = cache_len if not cfg.swa_window else min(cache_len, cfg.swa_window)
    n_sh = _n_shared(cfg)
    return dict(
        ssm=jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                       cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((cfg.n_layers, batch, ssm_mod.CONV_W - 1, di), DTYPE),
        shared=dict(
            k=jnp.zeros((n_sh, batch, t, cfg.n_kv, cfg.head_dim), DTYPE),
            v=jnp.zeros((n_sh, batch, t, cfg.n_kv, cfg.head_dim), DTYPE)),
    )


# ----------------------------------------------------------------- dispatch
FAMILIES: Dict[str, Dict[str, Any]] = {
    "dense": dict(init=dense_init_params, forward=dense_forward,
                  prefill=dense_prefill, decode=dense_decode,
                  init_cache=dense_init_cache),
    "moe": dict(init=moe_init_params, forward=moe_forward,
                prefill=moe_prefill, decode=moe_decode,
                init_cache=moe_init_cache),
    "mla_moe": dict(init=mla_moe_init_params, forward=mla_moe_forward,
                    prefill=mla_moe_prefill, decode=mla_moe_decode,
                    init_cache=mla_moe_init_cache),
    "xlstm": dict(init=xlstm_init_params, forward=xlstm_forward,
                  prefill=xlstm_prefill, decode=xlstm_decode,
                  init_cache=xlstm_init_cache),
    "hybrid": dict(init=hybrid_init_params, forward=hybrid_forward,
                   prefill=hybrid_prefill, decode=hybrid_decode,
                   init_cache=hybrid_init_cache),
}
