"""xLSTM blocks (mLSTM + sLSTM) for xlstm-1.3b.

mLSTM: matrix-memory cell with exponential gating.  Training runs the
*chunkwise* form derived directly from the stabilised recurrence
(equivalence is property-tested): within a chunk the decay structure is a
lower-triangular matrix (quadratic, MXU-friendly); across chunks a
(C, n, m) state is carried by lax.scan.  Decode is the O(1) recurrence.

sLSTM: scalar-memory cell with hidden-to-hidden recurrence — inherently
sequential, so training scans over time.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .layers import DTYPE, apply_norm, dense_init, norm_init

MCHUNK = 256


# -------------------------------------------------------------------- mLSTM
def mlstm_init(key, cfg) -> Dict:
    d = cfg.d_model
    di = cfg.xlstm_proj * d
    h = cfg.n_heads
    pp = di // h
    ks = jax.random.split(key, 8)

    def blockdiag(k):
        # per-head (block-diagonal) projection, as in the xLSTM paper
        return (jax.random.normal(k, (h, pp, pp)) * (pp ** -0.5)
                ).astype(DTYPE)

    return dict(
        up=dense_init(ks[0], d, 2 * di),          # x-branch and o-gate branch
        wq=blockdiag(ks[1]),
        wk=blockdiag(ks[2]),
        wv=blockdiag(ks[3]),
        wif=dense_init(ks[4], di, 2 * h, dtype=jnp.float32, scale=0.02),
        gate_norm=norm_init(di),
        down=dense_init(ks[5], di, d),
        norm=norm_init(d, with_bias=cfg.norm_bias),
    )


def _mlstm_qkvif(p, x, cfg):
    b, s, d = x.shape
    di = cfg.xlstm_proj * d
    h = cfg.n_heads
    pp = di // h
    xn = apply_norm(p["norm"], x)
    up = xn @ p["up"]
    xb, og = up[..., :di], up[..., di:]
    xh = xb.reshape(b, s, h, pp)
    q = jnp.einsum("bshp,hpq->bshq", xh, p["wq"])
    k = jnp.einsum("bshp,hpq->bshq", xh, p["wk"]) * (pp ** -0.5)
    v = jnp.einsum("bshp,hpq->bshq", xh, p["wv"])
    gif = xb.astype(jnp.float32) @ p["wif"]
    li = gif[..., :h]                                   # log input gate
    lf = jax.nn.log_sigmoid(gif[..., h:])               # log forget gate
    return xn, q, k, v, li, lf, og


def mlstm_forward(p, x, cfg, state=None):
    """Chunkwise mLSTM.  Returns (y, state) with state =
    (C (B,H,P,P), n (B,H,P), m (B,H)) — all f32."""
    b, s, d = x.shape
    h = cfg.n_heads
    di = cfg.xlstm_proj * d
    pp = di // h
    xn, q, k, v, li, lf, og = _mlstm_qkvif(p, x, cfg)

    c = min(MCHUNK, s)
    s_pad = -(-s // c) * c
    if s_pad != s:
        padf = lambda a: jnp.pad(a, [(0, 0), (0, s_pad - s)] +             # noqa: E731
                                 [(0, 0)] * (a.ndim - 2))
        q, k, v, li, lf = map(padf, (q, k, v, li, lf))
        # padded forget gates must not decay the carried state: lf=0
        li = li.at[:, s:].set(-1e30)
    nc = s_pad // c
    rs = lambda a: jnp.moveaxis(                                            # noqa: E731
        a.reshape((b, nc, c) + a.shape[2:]), 1, 0)
    qc, kc, vc, lic, lfc = map(rs, (q, k, v, li, lf))

    if state is None:
        c0 = jnp.zeros((b, h, pp, pp), jnp.float32)
        n0 = jnp.zeros((b, h, pp), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state

    def body(carry, inp):
        c_st, n_st, m_st = carry
        q_c, k_c, v_c, li_c, lf_c = inp                 # (B,C,H,...)
        qf = q_c.astype(jnp.float32)
        kf = k_c.astype(jnp.float32)
        vf = v_c.astype(jnp.float32)
        f_cs = jnp.cumsum(lf_c, axis=1)                 # (B,C,H) F_t
        # m_t = F_t + max(m0, cummax_{s<=t}(li_s - F_s))
        g = jnp.maximum(m_st[:, None, :],
                        jax.lax.cummax(li_c - f_cs, axis=1))
        m_t = f_cs + g                                  # (B,C,H)
        # intra decay w[t,s] = exp(F_t - F_s + li_s - m_t), s<=t
        dd = (f_cs[:, :, None] - f_cs[:, None, :]
              + li_c[:, None, :, :] - m_t[:, :, None, :])   # (B,t,s,H)
        tri = jnp.tril(jnp.ones((c, c), bool))[None, :, :, None]
        # mask the exponent, not the value (grad-safe, see ssm.py)
        w = jnp.exp(jnp.where(tri, dd, -1e30))
        scores = jnp.einsum("bthp,bshp->btsh", qf, kf)
        num = jnp.einsum("btsh,btsh,bshp->bthp", scores, w, vf)
        den = jnp.einsum("btsh,btsh->bth", scores, w)
        # inter: carried state decayed to t.  c_st is (B,H,Pv,Pk); q lives
        # in key space, so contract q with the k-dim (last axis).
        e_t = jnp.exp(f_cs + m_st[:, None, :] - m_t)    # (B,C,H)
        num = num + jnp.einsum("bthk,bhpk,bth->bthp", qf, c_st, e_t)
        den = den + jnp.einsum("bthp,bhp,bth->bth", qf, n_st, e_t)
        y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # state to chunk end
        m_end = m_t[:, -1]                              # (B,H)
        dec = jnp.exp(f_cs[:, -1] + m_st - m_end)       # (B,H)
        wk_end = jnp.exp(f_cs[:, -1][:, None] - f_cs + li_c
                         - m_end[:, None])              # (B,C,H)
        c_new = (dec[:, :, None, None] * c_st
                 + jnp.einsum("bsh,bshp,bsho->bhpo", wk_end, vf, kf))
        n_new = dec[:, :, None] * n_st \
            + jnp.einsum("bsh,bshp->bhp", wk_end, kf)
        return (c_new, n_new, m_end), y

    (c_f, n_f, m_f), ys = jax.lax.scan(body, (c0, n0, m0),
                                       (qc, kc, vc, lic, lfc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s_pad, di)[:, :s]
    y = apply_norm(p["gate_norm"], y.astype(x.dtype)) \
        * jax.nn.sigmoid(og.astype(jnp.float32)).astype(x.dtype)
    return x + y @ p["down"], (c_f, n_f, m_f)


def mlstm_decode(p, x, state, cfg):
    b, _, d = x.shape
    h = cfg.n_heads
    di = cfg.xlstm_proj * d
    c_st, n_st, m_st = state
    xn, q, k, v, li, lf, og = _mlstm_qkvif(p, x, cfg)
    qf = q[:, 0].astype(jnp.float32)
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    li0, lf0 = li[:, 0], lf[:, 0]                      # (B,H)
    m_new = jnp.maximum(lf0 + m_st, li0)
    a = jnp.exp(lf0 + m_st - m_new)
    bgt = jnp.exp(li0 - m_new)
    c_new = a[:, :, None, None] * c_st \
        + bgt[:, :, None, None] * jnp.einsum("bhp,bho->bhpo", vf, kf)
    n_new = a[:, :, None] * n_st + bgt[:, :, None] * kf
    num = jnp.einsum("bhpo,bho->bhp", c_new, qf)  # contract k-dim with q
    den = jnp.einsum("bhp,bhp->bh", n_new, qf)
    y = (num / jnp.maximum(jnp.abs(den), 1.0)[..., None]).reshape(b, 1, di)
    y = apply_norm(p["gate_norm"], y.astype(x.dtype)) \
        * jax.nn.sigmoid(og.astype(jnp.float32)).astype(x.dtype)
    return x + y @ p["down"], (c_new, n_new, m_new)


# -------------------------------------------------------------------- sLSTM
def slstm_init(key, cfg) -> Dict:
    d = cfg.d_model
    h = cfg.n_heads
    pp = d // h
    ff = int(d * 4 / 3)
    ks = jax.random.split(key, 8)
    return dict(
        wx=dense_init(ks[0], d, 4 * d),                # i,f,z,o from x
        rh=(jax.random.normal(ks[1], (h, pp, 4 * pp)) * (pp ** -0.5)
            ).astype(jnp.float32),
        norm=norm_init(d, with_bias=cfg.norm_bias),
        gate_norm=norm_init(d),
        ff_in=dense_init(ks[2], d, ff),
        ff_gate=dense_init(ks[3], d, ff),
        ff_out=dense_init(ks[4], ff, d),
        ff_norm=norm_init(d, with_bias=cfg.norm_bias),
    )


def _slstm_cell(p, xg, carry, cfg):
    """One sLSTM time step.  xg: (B, 4d) gate preactivations from x;
    carry: (h, c, n, m) each (B, H, P)-shaped (m is (B,H))."""
    b = xg.shape[0]
    d = cfg.d_model
    h = cfg.n_heads
    pp = d // h
    h_prev, c_prev, n_prev, m_prev = carry
    rec = jnp.einsum("bhp,hpq->bhq", h_prev, p["rh"])   # (B,H,4P)
    g = xg.reshape(b, h, 4 * pp).astype(jnp.float32) + rec
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)           # (B,H,P)
    # scalar-per-head exponential gating (use mean preact per head)
    li = jnp.mean(gi, axis=-1)                          # (B,H)
    lf = jax.nn.log_sigmoid(jnp.mean(gf, axis=-1))
    m_new = jnp.maximum(lf + m_prev, li)
    fg = jnp.exp(lf + m_prev - m_new)[..., None]
    ig = jnp.exp(li - m_new)[..., None]
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c_new = fg * c_prev + ig * z
    n_new = fg * n_prev + ig
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (h_new, c_new, n_new, m_new)


def slstm_forward(p, x, cfg, state=None):
    b, s, d = x.shape
    h = cfg.n_heads
    pp = d // h
    xn = apply_norm(p["norm"], x)
    xg = xn @ p["wx"]                                   # (B,S,4d)
    if state is None:
        zeros = jnp.zeros((b, h, pp), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((b, h), -1e30, jnp.float32))

    def step(carry, xg_t):
        new = _slstm_cell(p, xg_t, carry, cfg)
        return new, new[0]

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(xg, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    y = apply_norm(p["gate_norm"], y)
    x = x + y
    # gated FFN (proj factor 4/3)
    xf = apply_norm(p["ff_norm"], x)
    mid = jax.nn.silu((xf @ p["ff_gate"]).astype(jnp.float32)).astype(x.dtype) \
        * (xf @ p["ff_in"])
    return x + mid @ p["ff_out"], state


def slstm_decode(p, x, state, cfg):
    y, state = slstm_forward(p, x, cfg, state=state)
    return y, state
