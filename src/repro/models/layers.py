"""Shared model building blocks (pure functions over param pytrees).

Conventions
-----------
* Params are nested dicts of jnp arrays; layer stacks carry a leading L
  axis and are consumed by ``jax.lax.scan``.
* Weights/activations are bf16; normalisation, softmax, router and gate
  math run in f32.
* Every block takes an explicit config dataclass (``registry.ModelConfig``)
  so the same code serves all ten assigned architectures.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

PyTree = Any
DTYPE = jnp.bfloat16

# ---------------------------------------------------------------- sharding
# Activation sharding constraints (set by the launcher; None = off).
# Without these GSPMD may satisfy FSDP-sharded weights by replicating
# activations over the batch axes — catastrophic at 32k seq.  With them,
# activations stay batch-sharded and the partitioner all-gathers weights
# instead (the FSDP schedule).
BATCH_AXES = None            # e.g. ('data',) or ('pod', 'data')
EP_AXES = None               # expert-parallel axes for MoE, e.g. ('model',)
FSDP_GATHER = False          # gather FSDP-sharded weights at use
MODEL_SIZE = 0               # 'model' axis size (for divisibility guards)
SEQ_SHARD = False            # sequence-parallel residual stream: shard the
                             # seq dim over 'model' between blocks, turning
                             # each TP all-reduce into reduce-scatter (+
                             # all-gather at the next projection) and
                             # cutting activation checkpoints by 1/TP
                             # (Korthikanti et al.; beyond-paper §Perf)
MOE_GROUP = 2048             # GShard dispatch group size: per-token
                             # dispatch matmul cost is 2*k*group*cf*d —
                             # linear in group size (hillclimb knob)
MOE_CF = 1.25                # expert capacity factor
TWO_HOP_DISPATCH = False     # factored per-axis dispatch exchange.
                             # Measured WORSE than the token-gather
                             # schedule on this partitioner (capacity
                             # buffers carry k*cf ~10x token bytes;
                             # EXPERIMENTS.md §Perf A, iterations 4-6) —
                             # kept as a flag because on ICI-optimized
                             # a2a hardware the balance may flip.


def wload(w, model_axis: int = -1):
    """FSDP weight load: constrain the weight to drop its 'data' (fsdp)
    sharding and keep only tensor-parallel 'model' on ``model_axis``.
    GSPMD then materialises the all-gather of the *weight* (small) rather
    than partial-summing and all-reducing *activations* (huge) — the
    standard FSDP schedule, stated explicitly so the partitioner cannot
    pick the wrong strategy."""
    if BATCH_AXES is None or not FSDP_GATHER:
        return w
    spec = [None] * w.ndim
    ax = model_axis % w.ndim
    if MODEL_SIZE and w.shape[ax] % MODEL_SIZE == 0:
        spec[ax] = "model"
    return jax.lax.with_sharding_constraint(w, P(*spec))


def constrain(x, kind: str = "act"):
    if BATCH_AXES is None:
        return x
    if kind == "act":        # (B, ..., D): batch over BATCH_AXES
        if (SEQ_SHARD and MODEL_SIZE and x.ndim >= 3
                and x.shape[1] % MODEL_SIZE == 0 and x.shape[1] > 1):
            spec = P(BATCH_AXES, "model", *([None] * (x.ndim - 2)))
        else:
            spec = P(BATCH_AXES, *([None] * (x.ndim - 1)))
    elif kind == "logits":   # (B, ..., V): vocab over model
        spec = P(BATCH_AXES, *([None] * (x.ndim - 2)), "model")
    elif kind == "expert":   # (G, E, C, D): experts over EP_AXES
        if EP_AXES is None:
            return x
        spec = P(None, EP_AXES, *([None] * (x.ndim - 2)))
    elif kind == "expert_hop1":
        # intermediate hop of the factored dispatch: experts over 'data'
        # only.  g->e(data) is a clean single-axis all-to-all; the
        # subsequent e(data)->e(data,model) step is a free local slice
        # (replicated->sharded).  Without this hop GSPMD faces a
        # cross-axis resharding it can only do by full replication.
        if EP_AXES is None or EP_AXES[0] != "data" or len(EP_AXES) == 1:
            return x
        spec = P(None, ("data",), *([None] * (x.ndim - 2)))
    else:
        raise ValueError(kind)
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------- init
def dense_init(key, in_dim: int, out_dim: int, dtype=DTYPE,
               scale: float | None = None):
    scale = scale if scale is not None else (1.0 / in_dim) ** 0.5
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=DTYPE):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# -------------------------------------------------------------------- norms
def rmsnorm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w).astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(x.dtype)


def norm_init(dim: int, with_bias: bool = False):
    p = {"w": jnp.ones((dim,), jnp.float32)}
    if with_bias:
        p["b"] = jnp.zeros((dim,), jnp.float32)
    return p


def apply_norm(p, x):
    if "b" in p:
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


# --------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    if x.ndim == ang.ndim + 1:                         # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
DEFAULT_Q_CHUNK = 1024   # query-block size for chunked attention (a
                         # dry-run/hillclimb knob: smaller blocks cap the
                         # (B,H,q,T) score transient)


def attn_init(key, cfg) -> Dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    ks = jax.random.split(key, 4)
    return dict(
        wq=dense_init(ks[0], d, h * hd),
        wk=dense_init(ks[1], d, hkv * hd),
        wv=dense_init(ks[2], d, hkv * hd),
        wo=dense_init(ks[3], h * hd, d),
        norm=norm_init(d, with_bias=cfg.norm_bias),
    )


def _attention_scores(q, k, v, mask, q_chunk: int = 0):
    """softmax(q kᵀ / sqrt(d)) v, GQA-aware.

    q: (B, S, H, D); k, v: (B, T, Hkv, D); mask: (B?, S, T) bool or callable
    producing the (Sq_chunk, T) mask for a query offset (used when
    chunking so the full S x T mask is never materialised).
    Returns (B, S, H, D).
    """
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                 # may differ from d (MLA)
    group = h // hkv
    scale = d ** -0.5
    qg = q.reshape(b, s, hkv, group, d)

    def block(q_blk, mask_blk):
        # q_blk: (B, Sb, Hkv, G, D); mask_blk: (Sb, T) or (B, Sb, T)
        scores = jnp.einsum("bskgd,btkd->bkgst", q_blk.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        m = mask_blk if mask_blk.ndim == 3 else mask_blk[None]
        scores = jnp.where(m[:, None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgst,btkd->bskgd", p, v)

    if q_chunk and s > q_chunk:
        nchunks = s // q_chunk
        qc = qg.reshape(b, nchunks, q_chunk, hkv, group, d)

        def body(i):
            mask_blk = mask(i * q_chunk, q_chunk)
            return block(qc[:, i], mask_blk)

        out = jax.lax.map(body, jnp.arange(nchunks))      # (n, B, Sb, ...)
        out = jnp.moveaxis(out, 0, 1).reshape(b, s, hkv, group, dv)
    else:
        mask_blk = mask(0, s) if callable(mask) else mask
        out = block(qg, mask_blk)
    return out.reshape(b, s, h, dv)


def causal_mask(q_off: int, s_q: int, t: int, window: int = 0):
    """(s_q, t) bool mask; query i at absolute position q_off + i."""
    qpos = q_off + jnp.arange(s_q)[:, None]
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m


def attention(p, x, cfg, positions=None, q_chunk: int = 0,
              bidirectional: bool = False):
    """Self-attention over a full sequence (training / prefill).

    Returns (out, kv) where kv = (k, v) for cache construction.
    """
    b, s, _ = x.shape
    q_chunk = q_chunk or DEFAULT_Q_CHUNK
    h, hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    xn = apply_norm(p["norm"], x)
    q = (xn @ wload(p["wq"])).reshape(b, s, h, hd)
    k = (xn @ wload(p["wk"])).reshape(b, s, hkv, hd)
    v = (xn @ wload(p["wv"])).reshape(b, s, hkv, hd)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if bidirectional:
        mask_fn = lambda off, sq: jnp.ones((sq, s), bool)   # noqa: E731
    else:
        mask_fn = lambda off, sq: causal_mask(off, sq, s, cfg.swa_window)  # noqa: E731
    chunk = q_chunk if s > (q_chunk * 2) else 0
    out = _attention_scores(q, k, v, mask_fn, q_chunk=chunk)
    out = out.reshape(b, s, h * hd) @ wload(p["wo"], 0)
    return x + out, (k, v)


def cross_attention(p, x, enc_kv, cfg):
    """Decoder cross-attention to precomputed encoder (k, v)."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    k, v = enc_kv
    xn = apply_norm(p["norm"], x)
    q = (xn @ p["wq"]).reshape(b, s, h, hd)
    t = k.shape[1]
    mask_fn = lambda off, sq: jnp.ones((sq, t), bool)       # noqa: E731
    out = _attention_scores(q, k, v, mask_fn, q_chunk=0)
    return x + out.reshape(b, s, h * hd) @ p["wo"]


def attention_decode(p, x, cache, pos, cfg, ring: bool = False):
    """One-token decode.  x: (B, 1, d); cache: dict(k=(B, T, Hkv, D), v=...);
    pos: scalar int32 absolute position.  With ``ring`` (sliding-window
    archs) the cache is a ring buffer of size window and positions wrap.
    Returns (out, new_cache)."""
    b = x.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    t = cache["k"].shape[1]
    xn = apply_norm(p["norm"], x)
    q = (xn @ wload(p["wq"])).reshape(b, 1, h, hd)
    k = (xn @ wload(p["wk"])).reshape(b, 1, hkv, hd)
    v = (xn @ wload(p["wv"])).reshape(b, 1, hkv, hd)
    if cfg.rope:
        pp = jnp.full((b, 1), pos)
        q = apply_rope(q, pp, cfg.rope_theta)
        k = apply_rope(k, pp, cfg.rope_theta)
    slot = jnp.where(ring, pos % t, jnp.minimum(pos, t - 1))
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    # valid positions: all <= pos (ring: the whole buffer once warm)
    kpos = jnp.arange(t)
    valid = jnp.where(ring, kpos <= jnp.maximum(pos, t - 1), kpos <= pos)
    mask_fn = valid[None, :]

    group = h // hkv
    qg = q.reshape(b, hkv, group, hd)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                        ck.astype(jnp.float32)) * (hd ** -0.5)
    scores = jnp.where(mask_fn[:, None, None], scores, -1e30)
    pr = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", pr, cv).reshape(b, 1, h * hd)
    return x + out @ wload(p["wo"], 0), dict(k=ck, v=cv)


# ---------------------------------------------------------------------- mlp
def mlp_init(key, cfg, d_ff: Optional[int] = None) -> Dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = dict(w_in=dense_init(ks[0], d, ff), w_out=dense_init(ks[1], ff, d),
             norm=norm_init(d, with_bias=cfg.norm_bias))
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = dense_init(ks[2], d, ff)
    return p


def mlp(p, x, cfg):
    xn = apply_norm(p["norm"], x)
    hmid = xn @ wload(p["w_in"])
    if cfg.mlp_act == "swiglu":
        hmid = jax.nn.silu((xn @ wload(p["w_gate"])).astype(jnp.float32)) \
            .astype(hmid.dtype) * hmid
    else:
        hmid = jax.nn.gelu(hmid.astype(jnp.float32)).astype(hmid.dtype)
    return x + hmid @ wload(p["w_out"], 0)


# ---------------------------------------------------------------------- moe
def moe_init(key, cfg) -> Dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    p = dict(
        router=dense_init(ks[0], d, e, dtype=jnp.float32, scale=0.02),
        w_in=(jax.random.normal(ks[1], (e, d, ff)) * (1 / d) ** 0.5).astype(DTYPE),
        w_gate=(jax.random.normal(ks[2], (e, d, ff)) * (1 / d) ** 0.5).astype(DTYPE),
        w_out=(jax.random.normal(ks[3], (e, ff, d)) * (1 / ff) ** 0.5).astype(DTYPE),
        norm=norm_init(d, with_bias=cfg.norm_bias),
    )
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg,
                               d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def moe(p, x, cfg, group_size: int = 0, capacity_factor: float = 0.0):
    """Top-k routed MoE, GShard-style grouped capacity dispatch.

    Tokens are reshaped into groups of ``group_size``; within each group
    every expert accepts at most C = ceil(k*group/E * cf) tokens (overflow
    dropped, standard GShard semantics).  The dispatch/combine tensor is
    (G, T_g, E, C) — groups shard over the batch ('data') axes and
    experts over 'model', so per-device memory is bounded.

    The *explicit* two-hop (proxy) dispatch across pods lives in
    core/collectives.two_hop_all_to_all and is used by the optimized
    schedule; this dense formulation is the GSPMD baseline.
    Returns (out, aux_loss).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    group_size = group_size or MOE_GROUP
    capacity_factor = capacity_factor or MOE_CF
    xn = apply_norm(p["norm"], x)
    t_total = b * s
    g_sz = min(group_size, t_total)
    ng = t_total // g_sz
    xg = xn.reshape(ng, g_sz, d)

    logits = (xg.astype(jnp.float32) @ p["router"])          # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (G,Tg,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # aux load-balance loss (switch-style)
    onehot_k = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (G,Tg,k,E)
    density = jnp.mean(onehot_k.sum(2), axis=(0, 1))
    router_prob = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * router_prob) * e

    cap = int(np.ceil(k * g_sz / e * capacity_factor))
    # position of each (token, k) slot within its expert's capacity
    flat_mask = onehot_k.reshape(ng, g_sz * k, e)
    pos = jnp.cumsum(flat_mask, axis=1) - 1.0                # (G,Tg*k,E)
    pos = jnp.sum(pos * flat_mask, axis=-1).reshape(ng, g_sz, k)
    keep = pos < cap
    # combine weights (G,Tg,E,C): sum over k of gate * 1[e] * 1[c]
    comb = jnp.zeros((ng, g_sz, e, cap), jnp.float32)
    for kk in range(k):
        oh_c = jax.nn.one_hot(jnp.where(keep[..., kk], pos[..., kk], cap),
                              cap, dtype=jnp.float32)        # (G,Tg,C)
        comb = comb + (gate_vals[..., kk, None, None]
                       * onehot_k[..., kk, :, None] * oh_c[..., None, :])
    dispatch = (comb > 0).astype(DTYPE)

    # Two-stage (proxy / two-hop) dispatch.  Stage 1 packs each group's
    # routed tokens into its (E, C, d) send buffer *locally* (the
    # regional coalesce: at most C tokens per expert survive).  Stage 2
    # is a single g-shard -> e-shard resharding, which GSPMD lowers to an
    # all-to-all of only the routed tokens.  Constraining only the final
    # expert-sharded layout lets the partitioner instead all-gather every
    # token to every expert shard — ~10x the wire bytes (EXPERIMENTS.md
    # §Perf, deepseek-v3 iteration 4).
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg.astype(DTYPE))
    if TWO_HOP_DISPATCH:
        # factored per-axis exchange: pack locally, a2a over 'data',
        # free slice over 'model' (and the reverse on the way out)
        xe = constrain(constrain(constrain(xe), "expert_hop1"), "expert")
    else:
        # token-gather schedule: constrain only the expert-sharded layout
        # and let the partitioner gather tokens to the expert shards
        xe = constrain(xe, "expert")
    hin = jnp.einsum("gecd,edf->gecf", xe, p["w_in"])
    hg = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    hmid = jax.nn.silu(hg.astype(jnp.float32)).astype(DTYPE) * hin
    oe = constrain(jnp.einsum("gecf,efd->gecd", hmid, p["w_out"]),
                   "expert")
    if TWO_HOP_DISPATCH:
        oe = constrain(constrain(oe, "expert_hop1"))
    out = constrain(jnp.einsum("gecd,gtec->gtd", oe, comb.astype(DTYPE)))
    out = out.reshape(b, s, d)
    if "shared" in p:
        out = out + (mlp(p["shared"], x, cfg) - x)
    return x + out, aux


# ---------------------------------------------------------------------- mla
def mla_init(key, cfg) -> Dict:
    """DeepSeek-V3 Multi-head Latent Attention."""
    d = cfg.d_model
    h = cfg.n_heads
    dq, dc = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return dict(
        wq_a=dense_init(ks[0], d, dq),
        q_norm=norm_init(dq),
        wq_b=dense_init(ks[1], dq, h * (dn + dr)),
        wkv_a=dense_init(ks[2], d, dc + dr),
        kv_norm=norm_init(dc),
        wk_b=dense_init(ks[3], dc, h * dn),
        wv_b=dense_init(ks[4], dc, h * dv),
        wo=dense_init(ks[5], h * dv, d),
        norm=norm_init(d, with_bias=cfg.norm_bias),
    )


def mla_attention(p, x, cfg, positions=None, q_chunk: int = 0):
    """MLA over a full sequence.  Returns (out, latent_cache) where
    latent_cache = (c_kv (B,S,dc), k_rope (B,S,dr)) — the compressed cache
    that makes 500k-class decode feasible (paper's data-local footprint
    argument applied to KV state)."""
    b, s, _ = x.shape
    q_chunk = q_chunk or DEFAULT_Q_CHUNK
    h = cfg.n_heads
    dn, dr, dv, dc = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, \
        cfg.kv_lora_rank
    xn = apply_norm(p["norm"], x)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q = apply_norm(p["q_norm"], xn @ wload(p["wq_a"])) @ wload(p["wq_b"])
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = xn @ wload(p["wkv_a"])                           # (B,S,dc+dr)
    c_kv = apply_norm(p["kv_norm"], kv[..., :dc])
    k_rope = apply_rope(kv[..., dc:], positions, cfg.rope_theta)
    k_nope = (c_kv @ wload(p["wk_b"])).reshape(b, s, h, dn)
    v = (c_kv @ wload(p["wv_b"])).reshape(b, s, h, dv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))],
        axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    mask_fn = lambda off, sq: causal_mask(off, sq, s, cfg.swa_window)  # noqa: E731
    chunk = q_chunk if s > (q_chunk * 2) else 0
    out = _attention_scores(qq, k, v, mask_fn, q_chunk=chunk)
    out = out.reshape(b, s, h * dv) @ wload(p["wo"], 0)
    return x + out, (c_kv, kv[..., dc:])


def mla_decode(p, x, cache, pos, cfg):
    """One-token MLA decode against the compressed latent cache.
    cache: dict(c=(B,T,dc), kr=(B,T,dr)).  Absorbs wk_b into the query
    (the paper-faithful low-rank trick): scores = (q_nope wk_bᵀ) · c."""
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv, dc = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, \
        cfg.kv_lora_rank
    t = cache["c"].shape[1]
    xn = apply_norm(p["norm"], x)
    q = apply_norm(p["q_norm"], xn @ wload(p["wq_a"])) @ wload(p["wq_b"])
    q = q.reshape(b, 1, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    pp = jnp.full((b, 1), pos)
    q_rope = apply_rope(q_rope, pp, cfg.rope_theta)

    kv = xn @ wload(p["wkv_a"])
    c_new = apply_norm(p["kv_norm"], kv[..., :dc])
    kr_new = apply_rope(kv[..., dc:], pp, cfg.rope_theta)
    slot = jnp.minimum(pos, t - 1)
    cc = jax.lax.dynamic_update_slice(cache["c"],
                                      c_new.astype(cache["c"].dtype),
                                      (0, slot, 0))
    ckr = jax.lax.dynamic_update_slice(cache["kr"],
                                       kr_new.astype(cache["kr"].dtype),
                                       (0, slot, 0))
    # absorb wk_b into the query (low-rank trick): score against the
    # *compressed* latent directly.  wkb: (dc, h, dn); contract dn.
    wkb = p["wk_b"].reshape(dc, h, dn)
    q_eff = jnp.einsum("bhn,chn->bhc", q_nope[:, 0].astype(jnp.float32),
                       wkb.astype(jnp.float32))
    scale = (dn + dr) ** -0.5
    scores = (jnp.einsum("bhc,btc->bht", q_eff, cc.astype(jnp.float32))
              + jnp.einsum("bhr,btr->bht", q_rope[:, 0].astype(jnp.float32),
                           ckr.astype(jnp.float32))) * scale
    valid = jnp.arange(t)[None, None, :] <= pos
    scores = jnp.where(valid, scores, -1e30)
    pr = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bht,btc->bhc", pr, cc.astype(jnp.float32))  # (B,h,dc)
    wvb = p["wv_b"].reshape(dc, h, dv)
    out = jnp.einsum("bhc,chv->bhv", ctx, wvb.astype(jnp.float32))
    out = out.reshape(b, 1, h * dv).astype(x.dtype) @ p["wo"]
    return x + out, dict(c=cc, kr=ckr)
