"""Architecture registry: one exact config per assigned architecture
(``--arch <id>``), plus reduced smoke-test variants.

The configs below are the assignment's exact published dimensions; the
reduced() variants keep the family structure (GQA ratios, MoE top-k,
group cadence) at laptop scale for CPU smoke tests.  FULL configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

VOCAB_ALIGN = 512      # pad vocab so 16-way model sharding always divides


def _pad_vocab(v: int) -> int:
    return -(-v // VOCAB_ALIGN) * VOCAB_ALIGN


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                  # dense | moe | mla_moe | xlstm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    # attention
    rope: bool = True
    rope_theta: float = 10000.0
    swa_window: int = 0          # 0 = full attention
    norm_bias: bool = False      # True => LayerNorm, False => RMSNorm
    mlp_act: str = "swiglu"      # swiglu | gelu
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    n_dense_layers: int = 0
    moe_aux_weight: float = 0.01
    # mla (deepseek-v3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False
    # ssm (mamba2 / zamba2)
    ssm_expand: int = 2
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_state: int = 0
    hybrid_every: int = 0
    # xlstm
    xlstm_proj: int = 2
    xlstm_slstm_every: int = 0
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    # frontend stubs
    input_embeds: bool = False   # vlm/audio: precomputed embeddings input
    # which inference shapes apply
    supports_decode: bool = True
    subquadratic: bool = False   # can run long_500k

    @property
    def vocab_pad(self) -> int:
        return _pad_vocab(self.vocab)

    def param_count(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS roofline)."""
        d, l = self.d_model, self.n_layers
        emb = 2 * self.vocab_pad * d
        if self.family == "dense":
            attn = d * self.n_heads * self.head_dim * 2 \
                + d * self.n_kv * self.head_dim * 2
            ff = d * self.d_ff * (3 if self.mlp_act == "swiglu" else 2)
            return emb + l * (attn + ff)
        if self.family == "moe":
            attn = d * self.n_heads * self.head_dim * 2 \
                + d * self.n_kv * self.head_dim * 2
            ff = self.n_experts * d * self.moe_d_ff * 3 + d * self.n_experts
            return emb + l * (attn + ff)
        if self.family == "mla_moe":
            attn = (d * self.q_lora_rank
                    + self.q_lora_rank * self.n_heads
                    * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads
                    * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
            moe_l = self.n_layers - self.n_dense_layers
            ff_moe = (self.n_experts + self.n_shared_experts) \
                * d * self.moe_d_ff * 3
            ff_dense = d * self.d_ff * 3
            return emb + self.n_layers * attn + moe_l * ff_moe \
                + self.n_dense_layers * ff_dense
        if self.family == "xlstm":
            di = self.xlstm_proj * d
            pp = di // self.n_heads
            m_per = self.xlstm_slstm_every - 1
            g = l // self.xlstm_slstm_every
            mlstm = d * 2 * di + 3 * self.n_heads * pp * pp + di * d
            slstm = d * 4 * d + 2 * d * int(d * 4 / 3) + int(d * 4 / 3) * d
            return emb + g * (m_per * mlstm + slstm)
        if self.family == "hybrid":
            di = self.ssm_expand * d
            mamba = d * (2 * di + 2 * self.ssm_state + self.ssm_heads) \
                + di * d
            attn = d * self.n_heads * self.head_dim * 2 \
                + d * self.n_kv * self.head_dim * 2
            ff = d * self.d_ff * 3
            return emb + l * mamba + attn + ff
        if self.family == "encdec":
            attn = d * self.n_heads * self.head_dim * 2 \
                + d * self.n_kv * self.head_dim * 2
            ff = d * self.d_ff * 2
            return emb + self.enc_layers * (attn + ff) \
                + self.dec_layers * (2 * attn + ff)
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k only)."""
        if self.family == "moe":
            dense_like = dataclasses.replace(
                self, family="dense",
                d_ff=self.moe_d_ff * self.top_k)
            return dense_like.param_count()
        if self.family == "mla_moe":
            total = self.param_count()
            moe_l = self.n_layers - self.n_dense_layers
            ff_moe_all = (self.n_experts + self.n_shared_experts) \
                * self.d_model * self.moe_d_ff * 3 * moe_l
            ff_active = (self.top_k + self.n_shared_experts) \
                * self.d_model * self.moe_d_ff * 3 * moe_l
            return total - ff_moe_all + ff_active
        return self.param_count()


# ---------------------------------------------------------------- the pool
ARCHS: Dict[str, ModelConfig] = {
    "starcoder2-3b": ModelConfig(
        arch="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
        n_heads=24, n_kv=2, head_dim=128, d_ff=12288, vocab=49152,
        rope_theta=1e5, norm_bias=True, mlp_act="gelu"),
    "starcoder2-15b": ModelConfig(
        arch="starcoder2-15b", family="dense", n_layers=40, d_model=6144,
        n_heads=48, n_kv=4, head_dim=128, d_ff=24576, vocab=49152,
        rope_theta=1e5, norm_bias=True, mlp_act="gelu"),
    "deepseek-7b": ModelConfig(
        arch="deepseek-7b", family="dense", n_layers=30, d_model=4096,
        n_heads=32, n_kv=32, head_dim=128, d_ff=11008, vocab=102400),
    "h2o-danube-3-4b": ModelConfig(
        arch="h2o-danube-3-4b", family="dense", n_layers=24, d_model=3840,
        n_heads=32, n_kv=8, head_dim=120, d_ff=10240, vocab=32000,
        swa_window=4096, subquadratic=True),
    "pixtral-12b": ModelConfig(
        arch="pixtral-12b", family="dense", n_layers=40, d_model=5120,
        n_heads=32, n_kv=8, head_dim=128, d_ff=14336, vocab=131072,
        rope_theta=1e6, input_embeds=True),
    "deepseek-v3-671b": ModelConfig(
        arch="deepseek-v3-671b", family="mla_moe", n_layers=61,
        d_model=7168, n_heads=128, n_kv=128, head_dim=128, d_ff=18432,
        vocab=129280, n_experts=256, top_k=8, moe_d_ff=2048,
        n_shared_experts=1, n_dense_layers=3, q_lora_rank=1536,
        kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        mtp=True),
    "granite-moe-1b-a400m": ModelConfig(
        arch="granite-moe-1b-a400m", family="moe", n_layers=24,
        d_model=1024, n_heads=16, n_kv=8, head_dim=64, d_ff=0, vocab=49155,
        n_experts=32, top_k=8, moe_d_ff=512),
    "xlstm-1.3b": ModelConfig(
        arch="xlstm-1.3b", family="xlstm", n_layers=48, d_model=2048,
        n_heads=4, n_kv=4, head_dim=512, d_ff=0, vocab=50304, rope=False,
        xlstm_proj=2, xlstm_slstm_every=8, subquadratic=True),
    "whisper-tiny": ModelConfig(
        arch="whisper-tiny", family="encdec", n_layers=8, d_model=384,
        n_heads=6, n_kv=6, head_dim=64, d_ff=1536, vocab=51865, rope=False,
        norm_bias=True, mlp_act="gelu", enc_layers=4, dec_layers=4,
        input_embeds=True),
    "zamba2-1.2b": ModelConfig(
        arch="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
        n_heads=32, n_kv=32, head_dim=64, d_ff=8192, vocab=32000,
        ssm_expand=2, ssm_heads=64, ssm_head_dim=64, ssm_state=64,
        hybrid_every=6, subquadratic=True),
}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Laptop-scale smoke-test variant preserving family structure."""
    common = dict(d_model=64, vocab=512, head_dim=16)
    if cfg.family in ("dense", "moe"):
        return dataclasses.replace(
            cfg, n_layers=2, n_heads=4, n_kv=max(1, 4 * cfg.n_kv // cfg.n_heads),
            d_ff=128 if cfg.d_ff else 0, swa_window=8 if cfg.swa_window else 0,
            n_experts=4 if cfg.n_experts else 0,
            top_k=2 if cfg.top_k else 0,
            moe_d_ff=32 if cfg.moe_d_ff else 0, **common)
    if cfg.family == "mla_moe":
        return dataclasses.replace(
            cfg, n_layers=3, n_dense_layers=1, n_heads=4, n_kv=4,
            d_ff=128, n_experts=4, top_k=2, moe_d_ff=32, q_lora_rank=32,
            kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
            **common)
    if cfg.family == "xlstm":
        return dataclasses.replace(
            cfg, n_layers=4, n_heads=2, n_kv=2, xlstm_slstm_every=2,
            d_model=64, vocab=512, head_dim=64)
    if cfg.family == "hybrid":
        return dataclasses.replace(
            cfg, n_layers=4, n_heads=4, n_kv=4, d_ff=128, ssm_heads=4,
            ssm_head_dim=32, ssm_state=16, hybrid_every=2, **common)
    if cfg.family == "encdec":
        return dataclasses.replace(
            cfg, n_layers=4, enc_layers=2, dec_layers=2, n_heads=4, n_kv=4,
            d_ff=128, **common)
    raise ValueError(cfg.family)


def get_family(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.family == "encdec":
        from .encdec import ENCDEC_FAMILY
        return ENCDEC_FAMILY
    from .lm import FAMILIES
    return FAMILIES[cfg.family]


def get(arch: str, smoke: bool = False):
    """Returns (cfg, family-fns dict) for an architecture id."""
    cfg = ARCHS[arch]
    if smoke:
        cfg = reduced(cfg)
    return cfg, get_family(cfg)
