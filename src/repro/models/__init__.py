# Subpackages are imported lazily by consumers (registry pulls in the
# family modules it needs); keep this light to avoid import cycles.
