"""Encoder-decoder model (whisper-tiny).

The conv/audio frontend is a STUB per the brief: ``input_specs()``
provides precomputed frame embeddings (B, S_enc, d).  The transformer
backbone — bidirectional encoder, causal decoder with cross-attention —
is complete.  Positional encodings are sinusoidal (length-agnostic, so
the assigned 32k shapes lower cleanly even though real Whisper caps at
448 decoder positions).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .layers import (DTYPE, apply_norm, attention, attention_decode,
                     attn_init, constrain, cross_attention, embed_init,
                     mlp, mlp_init, norm_init)


def sinusoidal(positions, dim: int):
    """positions: (...,) -> (..., dim) sinusoidal embedding."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encdec_init_params(cfg, key):
    ks = jax.random.split(key, 8)
    d = cfg.d_model

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return dict(attn=attn_init(k1, cfg), mlp=mlp_init(k2, cfg))

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return dict(self=attn_init(k1, cfg), cross=attn_init(k2, cfg),
                    mlp=mlp_init(k3, cfg))

    from .lm import _stack
    return dict(
        tok_emb=embed_init(ks[0], cfg.vocab_pad, d),
        enc_layers=_stack(enc_layer,
                          jax.random.split(ks[1], cfg.enc_layers)),
        dec_layers=_stack(dec_layer,
                          jax.random.split(ks[2], cfg.dec_layers)),
        enc_norm=norm_init(d, with_bias=cfg.norm_bias),
        final_norm=norm_init(d, with_bias=cfg.norm_bias),
        lm_head=embed_init(ks[3], cfg.vocab_pad, d),
    )


def _encode(params, embeds, cfg):
    b, s, d = embeds.shape
    x = embeds.astype(DTYPE) + sinusoidal(jnp.arange(s), d)[None].astype(DTYPE)

    def body(x, lp):
        x, _ = attention(lp["attn"], x, cfg, bidirectional=True)
        return constrain(mlp(lp["mlp"], x, cfg)), None

    x, _ = jax.lax.scan(body, constrain(x), params["enc_layers"])
    return apply_norm(params["enc_norm"], x)


def _cross_kv(params, enc_out, cfg):
    """Per-decoder-layer cross (k, v) from encoder output."""
    b, s, _ = enc_out.shape
    hkv, hd = cfg.n_kv, cfg.head_dim

    def body(_, lp):
        cp = lp["cross"]
        xn = apply_norm(cp["norm"], enc_out)
        k = (xn @ cp["wk"]).reshape(b, s, hkv, hd)
        v = (xn @ cp["wv"]).reshape(b, s, hkv, hd)
        return None, (k, v)

    _, kv = jax.lax.scan(body, None, params["dec_layers"])
    return kv                                           # (L,B,S,Hkv,D) x2


def _dec_embed(params, tokens, pos0, cfg):
    x = jnp.take(params["tok_emb"], tokens, axis=0)
    s = tokens.shape[1]
    return x + sinusoidal(pos0 + jnp.arange(s),
                          cfg.d_model)[None].astype(x.dtype)


def encdec_forward(params, batch, cfg):
    """Teacher-forced training pass.  batch: {embeds, tokens, labels}."""
    enc_out = _encode(params, batch["embeds"], cfg)
    ck, cv = _cross_kv(params, enc_out, cfg)
    x = _dec_embed(params, batch["tokens"], 0, cfg)
    positions = jnp.arange(x.shape[1])[None, :]

    def block(lp, kv, x):
        x, _ = attention(lp["self"], x, cfg, positions)
        # cross-attention skips re-projecting k/v (precomputed above);
        # cross_attention applies q-proj + out-proj around them.
        x = cross_attention(lp["cross"], x, kv, cfg)
        return constrain(mlp(lp["mlp"], x, cfg))

    block_ck = jax.checkpoint(block,
                              policy=jax.checkpoint_policies.nothing_saveable)

    def body(x, xs):
        lp, k, v = xs
        return block_ck(lp, (k, v), x), None

    x, _ = jax.lax.scan(body, x, (params["dec_layers"], ck, cv))
    x = apply_norm(params["final_norm"], x)
    return jnp.einsum("bsd,vd->bsv", x, params["lm_head"]), 0.0


def encdec_prefill(params, batch, cfg):
    """Encode audio + run the decoder prefix; returns (logits, cache)."""
    enc_out = _encode(params, batch["embeds"], cfg)
    ck, cv = _cross_kv(params, enc_out, cfg)
    x = _dec_embed(params, batch["tokens"], 0, cfg)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, xs):
        lp, k, v = xs
        x, kv_self = attention(lp["self"], x, cfg, positions)
        x = cross_attention(lp["cross"], x, (k, v), cfg)
        return mlp(lp["mlp"], x, cfg), kv_self

    x, selfkv = jax.lax.scan(body, x, (params["dec_layers"], ck, cv))
    x = apply_norm(params["final_norm"], x[:, -1:])
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"])
    return logits, dict(k=selfkv[0], v=selfkv[1], ck=ck, cv=cv)


def encdec_decode(params, cache, tokens, pos, cfg):
    x = _dec_embed(params, tokens, pos, cfg)

    def body(x, xs):
        lp, sk, sv, k, v = xs
        x, ncl = attention_decode(lp["self"], x, dict(k=sk, v=sv), pos, cfg)
        x = cross_attention(lp["cross"], x, (k, v), cfg)
        return mlp(lp["mlp"], x, cfg), ncl

    x, ncache = jax.lax.scan(body, x, (params["dec_layers"], cache["k"],
                                       cache["v"], cache["ck"], cache["cv"]))
    x = apply_norm(params["final_norm"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"])
    return logits[:, 0], dict(k=ncache["k"], v=ncache["v"], ck=cache["ck"],
                              cv=cache["cv"])


def encdec_init_cache(cfg, batch, cache_len):
    l = cfg.dec_layers
    shape = (l, batch, cache_len, cfg.n_kv, cfg.head_dim)
    return dict(k=jnp.zeros(shape, DTYPE), v=jnp.zeros(shape, DTYPE),
                ck=jnp.zeros(shape, DTYPE), cv=jnp.zeros(shape, DTYPE))


ENCDEC_FAMILY: Dict[str, Any] = dict(
    init=encdec_init_params, forward=encdec_forward, prefill=encdec_prefill,
    decode=encdec_decode, init_cache=encdec_init_cache)
