"""The paper's six applications (§IV) on the data-local engine.

Each app declares how it maps onto the engine's (combine, edge_value)
algebra and which proxy policy it uses (§III-A):

  BFS    min / add_one   write-through proxy on vertex update
  SSSP   min / add_w     write-through proxy on vertex update
  WCC    min / carry     write-through proxy on vertex update
  PageRank add / carry   BSP epochs; write-back proxy, flushed per epoch
  SPMV   add / mul_w     write-back proxy on the row reduction
  Histo  add / one       write-back proxy on the bin reduction

All return the computed values plus the engine's RunResult (traffic
counters + BSP time), which benchmarks convert into the paper's metrics
(GTEPS, hops/message, energy, $).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.engine import AppSpec, DataLocalEngine, EngineConfig, RunResult
from ..core.proxy import CascadeConfig, ProxyConfig
from ..core.tilegrid import TileGrid
from .csr import CSR, transpose_csr

# Table II per-app cascade profitability (the selective criterion):
# the add-combine accumulators drain dense write-back flushes where
# records from sibling regions merge at every tree level, so cascading
# strictly shrinks cross-region traffic.  The write-through min
# propagators forward sparse improvement streams with few same-index
# duplicates per superstep — tree detours cost more than the merges
# save (measured; see tests/test_cascade.py) — so under
# CascadeConfig(selective=True) they bypass the reduction tree.  Forcing
# them through it (selective=False) stays numerically exact.
BFS_SPEC = AppSpec("bfs", combine="min", edge_value="add_one",
                   cascade_profitable=False)
SSSP_SPEC = AppSpec("sssp", combine="min", edge_value="add_w",
                    cascade_profitable=False)
WCC_SPEC = AppSpec("wcc", combine="min", edge_value="carry",
                   cascade_profitable=False)
PAGERANK_SPEC = AppSpec("pagerank", combine="add", edge_value="carry",
                        reactivate=False)
SPMV_SPEC = AppSpec("spmv", combine="add", edge_value="mul_w",
                    reactivate=False)
HISTO_SPEC = AppSpec("histo", combine="add", edge_value="one",
                     reactivate=False)

# Table II per-task proxy policy: which apps run the write-back P$.
WRITE_BACK_APPS = frozenset({"pagerank", "spmv", "histo"})


def table2_proxy(grid: TileGrid, app: str, *, slots: int = 512,
                 region_div: int = 4, cascade_levels: int = 0,
                 cascade_group: int = 2,
                 selective: bool = True) -> ProxyConfig:
    """Build the Table-II proxy config for ``app`` on ``grid``.

    region_div: regions per grid axis (paper default: 4x4 regions).
    cascade_levels > 0 attaches a selective-cascading reduction tree with
    the given per-level region grouping factor.
    """
    cascade = None
    if cascade_levels:
        cascade = CascadeConfig(levels=cascade_levels,
                                group_ny=cascade_group,
                                group_nx=cascade_group,
                                selective=selective)
    return ProxyConfig(region_ny=max(grid.ny // region_div, 2),
                       region_nx=max(grid.nx // region_div, 2),
                       slots=slots,
                       write_back=app in WRITE_BACK_APPS,
                       cascade=cascade)


@dataclasses.dataclass
class AppResult:
    values: np.ndarray
    run: RunResult
    teps_edges: float         # Graph500-style edge count for TEPS

    @property
    def gteps(self) -> float:
        return self.teps_edges / max(self.run.time_s, 1e-12) / 1e9


def _mk_cfg(grid: TileGrid, n_src: int, n_dst: int,
            proxy: Optional[ProxyConfig], **kw) -> EngineConfig:
    return EngineConfig(grid=grid, n_src=n_src, n_dst=n_dst, proxy=proxy, **kw)


def _split_backends(backend: str, kw: dict):
    """The apps-level ``backend=`` kw selects either the distributed
    execution backend ('auto' / 'vmap' / 'shard_map') or — when given a
    kernel-backend name ('jnp' / 'pallas') — the
    ``EngineConfig.backend`` hot-spot implementation.  The two value
    sets are disjoint, so one kw serves both."""
    if backend in ("jnp", "pallas"):
        kw = dict(kw, backend=backend)
        backend = "auto"
    return backend, kw


def _build(spec: AppSpec, cfg: EngineConfig, row_lo, row_hi, col_idx,
           weights, chips: int, backend: str):
    """Monolithic engine, or the distributed runtime when ``chips > 1``
    (same init_state/activate_all/run interface either way)."""
    if chips and chips > 1:
        from ..distrib.driver import DistributedEngine
        return DistributedEngine(spec, cfg, row_lo, row_hi, col_idx,
                                 weights, num_chips=chips, backend=backend)
    return DataLocalEngine(spec, cfg, row_lo, row_hi, col_idx, weights)


def _engine(spec: AppSpec, g: CSR, grid: TileGrid,
            proxy: Optional[ProxyConfig], chips: int = 0,
            backend: str = "auto", **kw):
    backend, kw = _split_backends(backend, kw)
    cfg = _mk_cfg(grid, g.n_rows, g.n_cols, proxy, **kw)
    return _build(spec, cfg, g.row_lo, g.row_hi, g.col_idx, g.weights,
                  chips, backend)


def engine_and_state(name: str, g: CSR, grid: TileGrid,
                     proxy: Optional[ProxyConfig] = None, root: int = 0,
                     x: Optional[np.ndarray] = None,
                     histo_values: Optional[np.ndarray] = None,
                     bins: int = 0, **kw):
    """Engine + ready-to-run initial state for app ``name``.

    The same wiring the app functions below use, exposed so analysis
    tooling (``repro.analysis.runner``) can trace the engine's chunk-step
    function — and seed mutation tests — without re-implementing each
    app's setup.  Returns ``(engine, state, seeds)`` where ``seeds`` is
    the number of initial mailbox records (the slack term of the
    consumed-bound conservation check).
    """
    if name == "bfs":
        eng = _engine(BFS_SPEC, g, grid, proxy, **kw)
        return eng, eng.init_state(seed_idx=root, seed_val=0.0), 1
    if name == "sssp":
        eng = _engine(SSSP_SPEC, g, grid, proxy, **kw)
        return eng, eng.init_state(seed_idx=root, seed_val=0.0), 1
    if name == "wcc":
        eng = _engine(WCC_SPEC, g, grid, proxy, **kw)
        n = g.n_rows
        state = eng.init_state(seed_idx=np.arange(n),
                               seed_val=np.arange(n, dtype=np.float32))
        return eng, state, n
    if name == "pagerank":
        eng = _engine(PAGERANK_SPEC, g, grid, proxy, **kw)
        deg = np.maximum(g.out_degree(), 1).astype(np.float32)
        contrib = 0.85 / g.n_rows / deg
        return eng, eng.activate_all(eng.init_state(), contrib), 0
    if name == "spmv":
        at = transpose_csr(g)
        chips = kw.pop("chips", 0)
        backend, kw = _split_backends(kw.pop("backend", "auto"), kw)
        cfg = _mk_cfg(grid, at.n_rows, g.n_rows, proxy, **kw)
        eng = _build(SPMV_SPEC, cfg, at.row_lo, at.row_hi, at.col_idx,
                     at.weights, chips, backend)
        xv = np.ones(g.n_cols, np.float32) if x is None else x
        return eng, eng.activate_all(eng.init_state(), xv), 0
    if name == "histo":
        hv = np.asarray(histo_values, np.int32)
        m = hv.shape[0]
        row_lo = np.arange(m, dtype=np.int32)
        chips = kw.pop("chips", 0)
        backend, kw = _split_backends(kw.pop("backend", "auto"), kw)
        cfg = _mk_cfg(grid, m, bins, proxy, **kw)
        eng = _build(HISTO_SPEC, cfg, row_lo, row_lo + 1, hv, None, chips,
                     backend)
        state = eng.activate_all(eng.init_state(), np.ones(m, np.float32))
        return eng, state, 0
    raise ValueError(name)


# ---------------------------------------------------------------- traversals
def bfs(g: CSR, root: int, grid: TileGrid,
        proxy: Optional[ProxyConfig] = None, observer=None,
        **kw) -> AppResult:
    eng = _engine(BFS_SPEC, g, grid, proxy, **kw)
    state = eng.init_state(seed_idx=root, seed_val=0.0)
    state, run = eng.run(state, observer=observer)
    vals = np.asarray(state["values"])[: g.n_rows]
    reached = np.isfinite(vals)
    teps = float(g.out_degree()[reached].sum())
    return AppResult(values=vals, run=run, teps_edges=teps)


def sssp(g: CSR, root: int, grid: TileGrid,
         proxy: Optional[ProxyConfig] = None, observer=None,
         **kw) -> AppResult:
    eng = _engine(SSSP_SPEC, g, grid, proxy, **kw)
    state = eng.init_state(seed_idx=root, seed_val=0.0)
    state, run = eng.run(state, observer=observer)
    vals = np.asarray(state["values"])[: g.n_rows]
    reached = np.isfinite(vals)
    teps = float(g.out_degree()[reached].sum())
    return AppResult(values=vals, run=run, teps_edges=teps)


def wcc(g: CSR, grid: TileGrid, proxy: Optional[ProxyConfig] = None,
        symmetrize: bool = False, observer=None, **kw) -> AppResult:
    """Min-label propagation (graph colouring per [75]).  The input graph
    must contain both edge directions for weak components; RMAT graphs
    from ``rmat_edges`` already do — pass symmetrize=True otherwise."""
    if symmetrize:
        gt = transpose_csr(g)
        src = np.concatenate([
            np.repeat(np.arange(g.n_rows, dtype=np.int64), g.out_degree()),
            np.repeat(np.arange(gt.n_rows, dtype=np.int64), gt.out_degree())])
        dst = np.concatenate([g.col_idx.astype(np.int64),
                              gt.col_idx.astype(np.int64)])
        from .csr import csr_from_edges
        g = csr_from_edges(src, dst, max(g.n_rows, g.n_cols))
    eng = _engine(WCC_SPEC, g, grid, proxy, **kw)
    n = g.n_rows
    state = eng.init_state(seed_idx=np.arange(n),
                           seed_val=np.arange(n, dtype=np.float32))
    state, run = eng.run(state, observer=observer)
    vals = np.asarray(state["values"])[:n]
    return AppResult(values=vals, run=run, teps_edges=float(g.nnz))


# --------------------------------------------------------------- BSP / algebra
def pagerank(g: CSR, grid: TileGrid, proxy: Optional[ProxyConfig] = None,
             epochs: int = 10, damping: float = 0.85, observer=None,
             **kw) -> AppResult:
    """BSP PageRank: one engine drain per epoch (barrier = paper's epoch
    end, where the write-back proxy flushes).  An ``observer`` sees one
    on_run_start/on_run_end pair per epoch; spans accumulate across
    epochs (each epoch's step_lo restarts at 0)."""
    n = g.n_rows
    deg = np.maximum(g.out_degree(), 1).astype(np.float32)
    ranks = np.full(n, 1.0 / n, np.float32)
    eng = _engine(PAGERANK_SPEC, g, grid, proxy, **kw)
    total = RunResult(counters=_zero_counters(), cycles=0.0, time_s=0.0,
                      supersteps=0)
    for _ in range(epochs):
        contrib = damping * ranks / deg
        state = eng.init_state()
        state = eng.activate_all(state, contrib)
        state, run = eng.run(state, observer=observer)
        acc = np.asarray(state["values"])[:n]
        ranks = (1.0 - damping) / n + acc
        _accumulate(total, run)
    return AppResult(values=ranks, run=total,
                     teps_edges=float(g.nnz) * epochs)


def spmv(a: CSR, x: np.ndarray, grid: TileGrid,
         proxy: Optional[ProxyConfig] = None, observer=None,
         **kw) -> AppResult:
    """y = A @ x.  The engine streams from *columns* (the source items that
    own x[j]) along the column's nonzeros to row owners — i.e. we run on
    A^T's CSR, which is A's CSC.  This is the paper's formulation: the
    reduction onto y rows is the proxied task."""
    at = transpose_csr(a)                      # rows of at = columns of a
    chips = kw.pop("chips", 0)
    backend, kw = _split_backends(kw.pop("backend", "auto"), kw)
    cfg = _mk_cfg(grid, at.n_rows, a.n_rows, proxy, **kw)
    eng = _build(SPMV_SPEC, cfg, at.row_lo, at.row_hi, at.col_idx,
                 at.weights, chips, backend)
    state = eng.init_state()
    state = eng.activate_all(state, np.asarray(x, np.float32))
    state, run = eng.run(state, observer=observer)
    y = np.asarray(state["values"])[: a.n_rows]
    return AppResult(values=y, run=run, teps_edges=float(a.nnz))


def histogram(values: np.ndarray, bins: int, grid: TileGrid,
              proxy: Optional[ProxyConfig] = None, observer=None,
              **kw) -> AppResult:
    """Count values into bins.  Each input element is a source item with a
    single 'edge' to its bin (paper: E elements filtered into V/8 bins)."""
    values = np.asarray(values, np.int32)
    m = values.shape[0]
    row_lo = np.arange(m, dtype=np.int32)
    row_hi = row_lo + 1
    chips = kw.pop("chips", 0)
    backend, kw = _split_backends(kw.pop("backend", "auto"), kw)
    cfg = _mk_cfg(grid, m, bins, proxy, **kw)
    eng = _build(HISTO_SPEC, cfg, row_lo, row_hi, values, None, chips,
                 backend)
    state = eng.init_state()
    state = eng.activate_all(state, np.ones(m, np.float32))
    state, run = eng.run(state, observer=observer)
    counts = np.asarray(state["values"])[:bins]
    return AppResult(values=counts, run=run, teps_edges=float(m))


APPS = dict(bfs=bfs, sssp=sssp, wcc=wcc, pagerank=pagerank, spmv=spmv,
            histo=histogram)

# Apps that honour a ``chips=N`` kw by running on the distributed runtime
# (all six today; the registry exists so callers that *measure* under a
# chip partition — e.g. ``ProductSearch`` — can validate support up front
# instead of silently dropping the kw for a future non-distributed app).
DISTRIBUTED_APPS = frozenset(APPS)


def _zero_counters():
    from ..core.netstats import TrafficCounters
    return TrafficCounters()


def _accumulate(total: RunResult, run: RunResult) -> None:
    total.counters.add(run.counters)
    total.cycles += run.cycles
    total.time_s += run.time_s
    total.supersteps += run.supersteps
    if run.trace is not None:
        if total.trace is None:
            from ..core.netstats import SuperstepTrace
            total.trace = SuperstepTrace()
        total.trace.extend(run.trace)
