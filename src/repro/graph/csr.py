"""CSR graph/matrix container.

The paper reads "the CSR structure from disk" with no preprocessing
(§IV-D); data placement is the engine's equal-chunk scatter of the CSR
arrays themselves.  We keep CSR in plain numpy (host-side dataset) — the
engine converts to device arrays at construction.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSR:
    """Compressed-sparse-row adjacency / matrix.

    row_ptr: (n_rows+1,) int64 offsets into col_idx.
    col_idx: (nnz,) int32 column / neighbor indices.
    weights: (nnz,) float32 edge weights (None => unweighted).
    n_cols:  number of columns (== n_rows for graphs).
    """

    row_ptr: np.ndarray
    col_idx: np.ndarray
    weights: np.ndarray | None
    n_cols: int

    @property
    def n_rows(self) -> int:
        return self.row_ptr.shape[0] - 1

    @property
    def nnz(self) -> int:
        return int(self.col_idx.shape[0])

    @property
    def row_lo(self) -> np.ndarray:
        return self.row_ptr[:-1].astype(np.int32)

    @property
    def row_hi(self) -> np.ndarray:
        return self.row_ptr[1:].astype(np.int32)

    def out_degree(self) -> np.ndarray:
        return (self.row_ptr[1:] - self.row_ptr[:-1]).astype(np.int64)

    def footprint_bytes(self) -> int:
        b = self.row_ptr.nbytes + self.col_idx.nbytes
        if self.weights is not None:
            b += self.weights.nbytes
        return b


def csr_from_edges(src: np.ndarray, dst: np.ndarray, n: int,
                   weights: np.ndarray | None = None,
                   dedup: bool = False) -> CSR:
    """Build CSR from an edge list (sorted by src internally)."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    if dedup:
        key = src * n + dst
        _, keep = np.unique(key, return_index=True)
        src, dst = src[keep], dst[keep]
        if weights is not None:
            weights = weights[keep]
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    if weights is not None:
        weights = np.asarray(weights, np.float32)[order]
    counts = np.bincount(src, minlength=n)
    row_ptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    return CSR(row_ptr=row_ptr, col_idx=dst.astype(np.int32),
               weights=weights, n_cols=n)


def transpose_csr(g: CSR) -> CSR:
    """Transpose (in-edges CSR), preserving weights."""
    n = g.n_cols
    src = np.repeat(np.arange(g.n_rows, dtype=np.int64), g.out_degree())
    return csr_from_edges(g.col_idx.astype(np.int64), src, max(n, g.n_rows),
                          weights=g.weights)
