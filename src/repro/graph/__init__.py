from .csr import CSR, csr_from_edges, transpose_csr
from .rmat import rmat_edges, wikipedia_like
from . import apps, oracles

__all__ = ["CSR", "csr_from_edges", "transpose_csr", "rmat_edges",
           "wikipedia_like", "apps", "oracles"]
