"""Synthetic dataset generators: RMAT (Graph500-style Kronecker) and a
Wikipedia-like heavy-tailed graph.

The paper evaluates RMAT-22..26 (2^s vertices, ~16 edges/vertex before
dedup, a=0.57 b=c=0.19 per Graph500) and the real Wikipedia graph
(V=4.2M, E=101M).  We reproduce RMAT faithfully at reduced scales
(laptop-class) and provide a power-law generator standing in for
Wikipedia; all claims we validate are *relative* (proxy vs no-proxy,
queue ratios), which the paper shows hold across datasets.
"""
from __future__ import annotations

import numpy as np

from .csr import CSR, csr_from_edges


def rmat_edges(scale: int, edge_factor: int = 16, a: float = 0.57,
               b: float = 0.19, c: float = 0.19, seed: int = 42,
               weighted: bool = True) -> CSR:
    """Graph500 Kronecker generator (undirected edges added both ways)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor // 2
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        # standard RMAT quadrant draw: pick row half with P(a+b), then the
        # column half conditioned on the row half.
        q = rng.random(m)
        src_bit = q >= ab
        cond = np.where(src_bit, c / max(c + (1.0 - abc), 1e-12), a / ab)
        dst_bit = rng.random(m) >= cond
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    # permute vertex ids to decorrelate hubs from low ids (Graph500 does this)
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    w = None
    if weighted:
        w = rng.integers(1, 256, size=s2.shape[0]).astype(np.float32)
    return csr_from_edges(s2, d2, n, weights=w)


def wikipedia_like(n: int = 1 << 14, avg_deg: int = 24, alpha: float = 2.1,
                   seed: int = 7) -> CSR:
    """Power-law digraph standing in for the Wikipedia dataset (V=4.2M,
    E=101M, avg degree ~24) at reduced scale."""
    rng = np.random.default_rng(seed)
    m = n * avg_deg
    # heavy-tailed destination popularity (hot vertices = the paper's
    # work-imbalance story)
    pop = (rng.pareto(alpha - 1.0, n) + 1.0)
    pop /= pop.sum()
    dst = rng.choice(n, size=m, p=pop)
    src = rng.integers(0, n, size=m)
    w = rng.integers(1, 256, size=m).astype(np.float32)
    return csr_from_edges(src, dst, n, weights=w)


def histogram_input(g: CSR, bins: int) -> np.ndarray:
    """The paper's Histogram input: 'E elements to be filtered into V/8
    bins (values = edge array index plus its value, modulo #bins)'."""
    idx = np.arange(g.nnz, dtype=np.int64)
    val = g.weights if g.weights is not None else np.ones(g.nnz)
    return ((idx + val.astype(np.int64)) % bins).astype(np.int32)
