"""Pure-numpy oracles for the six applications (used by tests and by the
benchmark harness to verify engine output before timing it)."""
from __future__ import annotations

import heapq

import numpy as np

from .csr import CSR


def bfs_oracle(g: CSR, root: int) -> np.ndarray:
    n = g.n_rows
    dist = np.full(n, np.inf, np.float32)
    dist[root] = 0
    frontier = [root]
    d = 0
    while frontier:
        nxt = []
        d += 1
        for u in frontier:
            for v in g.col_idx[g.row_ptr[u]: g.row_ptr[u + 1]]:
                if dist[v] == np.inf:
                    dist[v] = d
                    nxt.append(int(v))
        frontier = nxt
    return dist


def sssp_oracle(g: CSR, root: int) -> np.ndarray:
    n = g.n_rows
    w = g.weights if g.weights is not None else np.ones(g.nnz, np.float32)
    dist = np.full(n, np.inf, np.float32)
    dist[root] = 0.0
    pq = [(0.0, root)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        lo, hi = g.row_ptr[u], g.row_ptr[u + 1]
        for v, wv in zip(g.col_idx[lo:hi], w[lo:hi]):
            nd = np.float32(d + wv)
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (float(nd), int(v)))
    return dist


def wcc_oracle(g: CSR) -> np.ndarray:
    """Min-label per weak component; input graph must already contain both
    directions (matching apps.wcc)."""
    n = g.n_rows
    label = np.arange(n)
    # union-find over edges
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    src = np.repeat(np.arange(n), g.out_degree())
    for u, v in zip(src, g.col_idx):
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    for i in range(n):
        label[i] = find(i)
    return label.astype(np.float32)


def pagerank_oracle(g: CSR, epochs: int = 10,
                    damping: float = 0.85) -> np.ndarray:
    """Power iteration exactly matching apps.pagerank's epoch semantics
    (dangling mass dropped, same constant term)."""
    n = g.n_rows
    deg = np.maximum(g.out_degree(), 1).astype(np.float32)
    ranks = np.full(n, 1.0 / n, np.float32)
    src = np.repeat(np.arange(n), g.out_degree())
    for _ in range(epochs):
        contrib = damping * ranks / deg
        acc = np.zeros(n, np.float32)
        np.add.at(acc, g.col_idx, contrib[src])
        ranks = (1.0 - damping) / n + acc
    return ranks


def spmv_oracle(a: CSR, x: np.ndarray) -> np.ndarray:
    w = a.weights if a.weights is not None else np.ones(a.nnz, np.float32)
    src = np.repeat(np.arange(a.n_rows), a.out_degree())
    y = np.zeros(a.n_rows, np.float32)
    np.add.at(y, src, w * np.asarray(x, np.float32)[a.col_idx])
    return y


def histogram_oracle(values: np.ndarray, bins: int) -> np.ndarray:
    return np.bincount(np.asarray(values), minlength=bins).astype(np.float32)
