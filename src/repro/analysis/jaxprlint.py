"""Jaxpr linter: static hazards of the device-resident superstep loop.

The chunk-step functions (``core/engine.py::_scan_steps`` bodies and the
distributed driver's scan) are traced to ClosedJaxprs — no device
execution — and walked recursively (into ``scan``/``cond``/``while``
bodies, ``pjit`` calls, custom-derivative wrappers and Pallas kernel
jaxprs).  Rules:

``host-sync``
    Callback / infeed primitives inside the traced step.  The whole
    point of the scanned run loop is O(supersteps/K) host syncs; a
    ``pure_callback`` / ``io_callback`` / ``debug_callback`` (what
    ``jax.debug.print`` lowers to) inside the scan forces a host round
    trip per superstep — or worse, per scan iteration.

``scatter-mode``
    Overwrite scatters (primitive ``scatter``, not the commutative
    ``scatter-add``/``-min``/``-max``/``-mul``) whose mode is not the
    engine's ``mode="drop"`` (FILL_OR_DROP) discipline and whose indices
    are not declared unique.  XLA's result for duplicate indices in an
    overwrite scatter is undefined; the engine's contract is
    at-most-one-live-writer with masked records redirected out of bounds
    and dropped *at the scatter* — which requires FILL_OR_DROP.

``bucket-coverage``
    Compaction cells only: the traced step must contain the capacity
    ladder's ``lax.switch`` (a ``cond`` with one branch per bucket,
    dense rung included) with non-empty branch bodies.  ``iter_eqns``
    recurses into every branch, so the host-sync and scatter rules
    apply to each pre-traced bucket — this rule asserts the branches
    are actually there to be walked (a silently-dense engine would
    pass every other rule while never testing the compacted code).

``int-stat-f32-row``
    Integer-dtype per-superstep stats that ride the packed f32 stat row
    without being covered by ``engine._EXACT_INT_STATS``.  f32 holds
    exact integers only to 2**24; paper-scale counters (message counts,
    pending work, P$ residency at a million PUs) exceed that, which is
    the overflow class PR 4 patched by hand — the int32 side channel.

``backend-dtype-drift``
    Structural (shape/dtype) mismatch between the jnp-oracle and Pallas
    renderings of the same step.  The Pallas path is tested bitwise (min
    apps) against the oracle; a silent dtype promotion on one side turns
    that into a cast comparison.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

import jax
import numpy as np

from .findings import Finding

# Primitives that force a host round trip (or host-dependent execution)
# when they appear inside the scanned superstep.
HOST_SYNC_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "infeed", "outfeed", "debug_print",
})

# Commutative scatter variants: safe under duplicate indices regardless
# of mode (the combine is order-independent).
_COMBINING_SCATTERS = frozenset({
    "scatter-add", "scatter-min", "scatter-max", "scatter-mul",
})


def iter_eqns(jaxpr) -> Iterable[Tuple[object, Tuple[str, ...]]]:
    """Yield (eqn, path) over a (Closed)Jaxpr and every sub-jaxpr reachable
    through eqn params — scan/while/cond bodies, pjit calls, custom-vjp
    wrappers, Pallas kernel jaxprs — without naming each primitive."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)      # ClosedJaxpr -> Jaxpr
    stack = [(jaxpr, ())]
    while stack:
        jx, path = stack.pop()
        for eqn in jx.eqns:
            yield eqn, path
            sub_path = path + (eqn.primitive.name,)
            for sub in _param_jaxprs(eqn.params):
                stack.append((sub, sub_path))


def _param_jaxprs(params) -> List[object]:
    out = []
    for v in params.values():
        out.extend(_as_jaxprs(v))
    return out


def _as_jaxprs(v) -> List[object]:
    inner = getattr(v, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return [inner]                           # ClosedJaxpr
    if hasattr(v, "eqns"):
        return [v]                               # raw Jaxpr
    if isinstance(v, (tuple, list)):
        out = []
        for x in v:
            out.extend(_as_jaxprs(x))
        return out
    return []


def _is_drop_mode(mode) -> bool:
    # GatherScatterMode.FILL_OR_DROP is what the indexed-update
    # ``mode="drop"`` (and the default) lowers to.
    return mode is None or getattr(mode, "name", str(mode)) == "FILL_OR_DROP"


def lint_jaxpr(closed, where: str) -> List[Finding]:
    """Walk one traced step function: host-sync + scatter-mode rules."""
    findings = []
    for eqn, path in iter_eqns(closed):
        name = eqn.primitive.name
        loc = "/".join(path + (name,))
        if name in HOST_SYNC_PRIMITIVES:
            cb = eqn.params.get("callback")
            detail = f" ({cb})" if cb is not None else ""
            findings.append(Finding(
                "jaxprlint", "host-sync", where,
                f"host-sync primitive `{loc}`{detail} inside the traced "
                f"step: forces a host round trip per superstep, defeating "
                f"the device-resident scan"))
        elif name == "scatter" or name in _COMBINING_SCATTERS:
            unique = bool(eqn.params.get("unique_indices", False))
            mode = eqn.params.get("mode")
            if name == "scatter" and not unique and not _is_drop_mode(mode):
                findings.append(Finding(
                    "jaxprlint", "scatter-mode", where,
                    f"overwrite scatter `{loc}` with mode="
                    f"{getattr(mode, 'name', mode)} and non-unique "
                    f"indices: duplicate-index results are undefined; the "
                    f"engine's discipline is mode='drop' with masked "
                    f"records redirected out of bounds"))
    return findings


def lint_step_fn(fn, args, where: str) -> List[Finding]:
    """Trace ``fn(*args)`` (abstractly — no device compute) and lint it.
    ``fn`` may be jitted; the walker recurses through the pjit eqn."""
    closed = jax.make_jaxpr(fn)(*args)
    return lint_jaxpr(closed, where)


def lint_bucket_coverage(closed, n_buckets: int, where: str) -> List[Finding]:
    """Assert the compaction ladder's ``lax.switch`` is present in the
    traced step AND that every pre-traced bucket branch is reachable by
    the lint walk (``iter_eqns`` recurses into ``cond`` branch bodies,
    so host-sync/scatter rules apply per branch exactly when the branch
    jaxprs are where we expect them).

    ``n_buckets`` is ``len(engine._ladder)``: the dense rung plus one
    branch per capacity.  A missing or smaller switch means the engine
    silently fell back to the dense path (ladder not threaded through
    this code path) — the failure mode this rule exists to catch;
    an empty branch body means a bucket the linter cannot see into."""
    jaxpr = getattr(closed, "jaxpr", closed)
    for eqn, path in iter_eqns(jaxpr):
        if eqn.primitive.name != "cond":
            continue
        branches = eqn.params.get("branches", ())
        if len(branches) < n_buckets:
            continue
        empties = sum(1 for b in branches
                      if not getattr(getattr(b, "jaxpr", b), "eqns", ()))
        if empties:
            return [Finding(
                "jaxprlint", "bucket-coverage", where,
                f"compaction switch at `{'/'.join(path + ('cond',))}` has "
                f"{empties} empty branch bodies out of {len(branches)}: "
                f"the lint walk cannot cover those buckets")]
        return []
    return [Finding(
        "jaxprlint", "bucket-coverage", where,
        f"no `cond` with >= {n_buckets} branches in the traced step: "
        f"the compaction ladder's bucket switch is missing — the "
        f"engine is silently running the dense path only")]


# ---------------------------------------------------------------- int stats
def lint_int_stats(stats_shapes: dict, exact_int_stats: Sequence[str],
                   where: str) -> List[Finding]:
    """Integer-dtype stats not covered by the exact-int side channel.

    ``stats_shapes`` maps stat name -> ShapeDtypeStruct (from
    ``jax.eval_shape`` of the step function).  Every integer-dtype stat
    is packed into the f32 row by ``_scan_steps``; unless it also rides
    ``_EXACT_INT_STATS``, values past 2**24 silently lose low bits.
    """
    findings = []
    covered = set(exact_int_stats)
    for k in sorted(stats_shapes):
        dt = np.dtype(stats_shapes[k].dtype)
        if np.issubdtype(dt, np.integer) and k not in covered:
            findings.append(Finding(
                "jaxprlint", "int-stat-f32-row", f"{where}:{k}",
                f"stat '{k}' is {dt.name} on device but rides the packed "
                f"f32 row uncovered by _EXACT_INT_STATS: counts past 2**24 "
                f"(paper-scale supersteps) lose low bits"))
    return findings


def stats_shapes_of(step_one, state, flush) -> dict:
    """Stat name -> ShapeDtypeStruct of one superstep, via an abstract
    trace (mirrors ``engine._stat_keys``'s eval_shape, keeping dtypes)."""
    return dict(jax.eval_shape(step_one, state, flush)[1])


# ------------------------------------------------------------ backend drift
def lint_backend_drift(tree_jnp, tree_pallas, where: str) -> List[Finding]:
    """Compare two abstract (state, stats) pytrees (``jax.eval_shape``
    results) for shape/dtype drift between the jnp oracle and the Pallas
    rendering of the same step."""
    flat_j = _flatten_shapes(tree_jnp)
    flat_p = _flatten_shapes(tree_pallas)
    findings = []
    for k in sorted(set(flat_j) | set(flat_p)):
        a, b = flat_j.get(k), flat_p.get(k)
        if a is None or b is None:
            side = "pallas" if a is None else "jnp"
            findings.append(Finding(
                "jaxprlint", "backend-dtype-drift", f"{where}:{k}",
                f"leaf '{k}' exists only on the {side} path"))
        elif a != b:
            findings.append(Finding(
                "jaxprlint", "backend-dtype-drift", f"{where}:{k}",
                f"jnp path computes {a[0]}{list(a[1])} but pallas path "
                f"computes {b[0]}{list(b[1])}: the oracle comparison "
                f"silently becomes a cast"))
    return findings


def _flatten_shapes(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = (np.dtype(leaf.dtype).name, tuple(leaf.shape))
    return out
