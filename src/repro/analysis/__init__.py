"""Static analysis + runtime sanitation for the engine (`repro.analysis`).

The device-resident run loops (PR 4/5) made correctness rest on
invariants that only hand-written tests enforced after the fact:

  * no host syncs / callbacks inside the scanned superstep;
  * scatter discipline (masked records dropped at the scatter, no
    order-undefined overwrite scatters);
  * exact-int counters riding the int32 side channel past f32's 2^24
    integer range (``engine._EXACT_INT_STATS``);
  * Pallas kernels writing disjoint output windows per grid program (or
    revisiting the same window only with a commutative combine);
  * counter conservation (every emitted record is merged, filtered or
    delivered), hop-level decomposition, and the measure-once /
    price-many contract (re-pricing the measured trace under its own
    ``PackageConfig`` reproduces the run's BSP time exactly).

This package proves those properties on every PR:

  ``jaxprlint``     traces the chunk-step functions to ClosedJaxprs and
                    walks them (host-sync hazards, scatter modes,
                    uncovered int stats, jnp/pallas dtype drift).
  ``pallas_races``  evaluates each kernel's BlockSpec index maps over
                    the grid and proves output-window disjointness.
  ``invariants``    post-run counter/trace conservation checks, plus
                    the ``EngineConfig.sanitize=True`` runtime
                    sanitizer's host-side error type.
  ``deadcode``      import-graph reachability report from the repo's
                    entry points.
  ``runner``        runs every pass over the six apps x {jnp, pallas} x
                    {monolithic, distributed} matrix
                    (``scripts/lint_engine.py`` is the CLI; CI fails on
                    findings not in the committed baseline).
"""
from .findings import Finding, Report, load_baseline  # noqa: F401
from .invariants import SanitizerError, check_run  # noqa: F401
