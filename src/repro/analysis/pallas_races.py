"""Pallas write-race detector: output-window disjointness per grid program.

For every ``pl.pallas_call`` a kernel entry point issues, this pass
evaluates the output ``BlockSpec`` index maps symbolically over the whole
grid (index maps are pure functions of the grid coordinates — calling
them with python ints costs nothing) and computes each program's output
element windows (``index_map(*program) * block_shape``).  Two distinct
grid programs mapping to the same window are *aliased writes*:

  * with a commutative combine ("add"/"min"/"max") and the revisit
    idiom (``@pl.when(first_visit)`` init + in-place accumulation) they
    are the standard Pallas reduction pattern — safe, because the TPU
    grid executes sequentially, so revisits are ordered;
  * with overwrite semantics they are a bug: the last program in grid
    order silently wins (and on a parallel backend the result is
    non-deterministic).  The pass rejects them.

Partially overlapping windows (possible only with element-indexed
maps / misaligned blocking) are rejected unconditionally.

Calls are captured by temporarily wrapping ``pallas.pallas_call`` while
invoking the kernel entry point on tiny inputs (``capture_pallas_calls``)
— the kernel modules need no modification, and the capture also serves
as a smoke execution of the kernel.  Each kernel module exports
``analysis_cases()`` returning (name, thunk, combine) triples so the
suite enumerates itself (``kernel_suite``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
from typing import Dict, List, Tuple

from jax.experimental import pallas as pl

from .findings import Finding

COMMUTATIVE = ("add", "min", "max")


@dataclasses.dataclass
class CapturedCall:
    """One ``pl.pallas_call`` invocation's static geometry."""

    kernel_name: str
    grid: Tuple[int, ...]
    out_specs: List[object]           # normalized to a list of BlockSpec
    out_shapes: List[Tuple[int, ...]]
    n_prefetch: int = 0               # scalar-prefetch args the index maps take


def _kernel_name(kernel) -> str:
    """Stable name for a kernel callable (unwraps functools.partial — a
    repr would embed a memory address and churn baseline keys)."""
    inner = getattr(kernel, "func", kernel)
    return getattr(inner, "__name__", type(kernel).__name__)


@contextlib.contextmanager
def capture_pallas_calls():
    """Capture every ``pl.pallas_call`` issued inside the block (the call
    still executes normally).  Yields the list the captures append to."""
    captured: List[CapturedCall] = []
    real = pl.pallas_call

    def wrapper(kernel, **kw):
        grid_spec = kw.get("grid_spec")
        if grid_spec is not None:     # PrefetchScalarGridSpec form
            grid = grid_spec.grid
            out_specs = grid_spec.out_specs
            n_prefetch = int(getattr(grid_spec, "num_scalar_prefetch", 0))
        else:
            grid = kw.get("grid", ())
            out_specs = kw.get("out_specs")
            n_prefetch = 0
        if isinstance(grid, int):
            grid = (grid,)
        out_shape = kw.get("out_shape")
        specs = list(out_specs) if isinstance(out_specs, (list, tuple)) \
            else [out_specs]
        shapes = out_shape if isinstance(out_shape, (list, tuple)) \
            else [out_shape]
        captured.append(CapturedCall(
            kernel_name=_kernel_name(kernel),
            grid=tuple(int(g) for g in grid),
            out_specs=specs,
            out_shapes=[tuple(s.shape) for s in shapes],
            n_prefetch=n_prefetch))
        return real(kernel, **kw)

    pl.pallas_call = wrapper
    try:
        yield captured
    finally:
        pl.pallas_call = real


class _PrefetchStub:
    """Stands in for a scalar-prefetch ref in index-map evaluation: block
    indices derived from prefetched tables (e.g. the BCSR column table)
    resolve to 0 — which window they select doesn't affect *aliasing*
    between (program, window) pairs driven by the grid coordinates."""

    def __getitem__(self, _):
        return 0


def _program_windows(call: CapturedCall, spec) -> Dict[Tuple, List[Tuple]]:
    """window -> list of grid programs writing it.  A window is a tuple
    of per-dim (start, stop) element ranges: ``index_map`` returns block
    indices, scaled by ``block_shape`` (the installed Pallas convention —
    see e.g. ``kernels/histogram_bin.py``)."""
    block = tuple(int(b) for b in spec.block_shape)
    ranges = [range(max(int(g), 1)) for g in call.grid] or [range(1)]
    stubs = tuple(_PrefetchStub() for _ in range(call.n_prefetch))
    windows: Dict[Tuple, List[Tuple]] = {}
    for program in itertools.product(*ranges):
        idx = spec.index_map(*program, *stubs)
        if not isinstance(idx, tuple):
            idx = (idx,)
        win = tuple((int(i) * b, (int(i) + 1) * b)
                    for i, b in zip(idx, block))
        windows.setdefault(win, []).append(program)
    return windows


def _windows_overlap(a: Tuple, b: Tuple) -> bool:
    return all(lo1 < hi2 and lo2 < hi1
               for (lo1, hi1), (lo2, hi2) in zip(a, b))


def check_call(call: CapturedCall, combine: str, where: str) -> List[Finding]:
    """Race-check one captured call under the declared combine semantics
    (``'add' | 'min' | 'max'`` commutative accumulation, anything else —
    canonically ``'overwrite'`` — order-sensitive)."""
    findings = []
    commutative = combine in COMMUTATIVE
    for out_i, spec in enumerate(call.out_specs):
        windows = _program_windows(call, spec)
        site = f"{where}:{call.kernel_name}[out{out_i}]"
        # aliased writes: >1 program revisits one window
        aliased = {w: ps for w, ps in windows.items() if len(ps) > 1}
        if aliased and not commutative:
            w, ps = next(iter(sorted(aliased.items())))
            findings.append(Finding(
                "pallas_races", "aliased-overwrite", site,
                f"{len(aliased)} output window(s) written by multiple grid "
                f"programs (e.g. window {w} by programs {ps[:4]}) with "
                f"non-commutative combine '{combine}': last program in "
                f"grid order wins silently"))
        # partial overlap between distinct windows: always wrong
        keys = sorted(windows)
        for i, w1 in enumerate(keys):
            for w2 in keys[i + 1:]:
                if _windows_overlap(w1, w2):
                    findings.append(Finding(
                        "pallas_races", "window-overlap", site,
                        f"output windows {w1} (programs "
                        f"{windows[w1][:2]}) and {w2} (programs "
                        f"{windows[w2][:2]}) partially overlap: "
                        f"misaligned blocking races regardless of the "
                        f"combine"))
    return findings


def check_fn(thunk, combine: str, where: str) -> List[Finding]:
    """Run ``thunk`` (a kernel invocation on tiny inputs) under capture
    and race-check every pallas_call it issued."""
    with capture_pallas_calls() as calls:
        thunk()
    findings = []
    if not calls:
        findings.append(Finding(
            "pallas_races", "no-pallas-call", where,
            "kernel thunk issued no pallas_call: the race check is "
            "vacuous (did the entry point hit a cached jit?)"))
    for call in calls:
        findings.extend(check_call(call, combine, where))
    return findings


def kernel_suite() -> List[Tuple[str, object, str]]:
    """(name, thunk, combine) for every analyzable kernel in
    ``repro.kernels`` — collected from each module's ``analysis_cases``."""
    from ..kernels import (deliver_fused, histogram_bin, ops, relax_min,
                           segment_combine)
    cases = []
    for mod in (segment_combine, relax_min, histogram_bin, deliver_fused,
                ops):
        cases.extend(mod.analysis_cases())
    return cases


def check_kernels() -> List[Finding]:
    """Race-check the whole kernel suite (the ops-level entry points'
    underlying pallas_calls)."""
    findings = []
    for name, thunk, combine in kernel_suite():
        findings.extend(check_fn(thunk, combine, f"kernels/{name}"))
    return findings
