"""Import-graph dead-code report.

Walks ``import``/``from ... import`` statements (AST only — nothing is
executed) from the repo's entry points — ``tests/``, ``benchmarks/``,
``scripts/`` — and reports every module under ``src/repro/`` that no
entry point reaches.  Importing a submodule marks its ancestor packages
(their ``__init__`` runs), and package ``__init__`` re-exports propagate
reachability to what they import.

A module may opt out of the report by carrying a ``# seed: unused``
marker near the top of the file: that is the documented quarantine for
seed-time scaffolding that is intentionally kept but not wired up
(deleting it would lose reference value; importing it would hide real
dead code).  Quarantined modules are listed in the report's metadata but
produce no finding; an *unmarked* unreachable module is a
``dead-module`` finding.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Set, Tuple

from .findings import Finding

MARKER = "# seed: unused"
ENTRY_DIRS = ("tests", "benchmarks", "scripts")


def module_map(src_root: Path) -> Dict[str, Path]:
    """Dotted module name -> file for everything under ``src/``."""
    out: Dict[str, Path] = {}
    for p in sorted(src_root.rglob("*.py")):
        rel = p.relative_to(src_root).with_suffix("")
        parts = list(rel.parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        if parts:
            out[".".join(parts)] = p
    return out


def _parents(name: str) -> List[str]:
    parts = name.split(".")
    return [".".join(parts[:i]) for i in range(1, len(parts))]


def imports_of(path: Path, modname: str, known: Set[str]) -> Set[str]:
    """Modules from ``known`` that ``path`` imports (absolute and
    relative forms; ``from X import a`` marks both X and X.a)."""
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError:
        return set()
    pkg_parts = modname.split(".")
    found: Set[str] = set()

    def note(name: str):
        if name in known:
            found.add(name)
        for par in _parents(name):
            if par in known:
                found.add(par)

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                note(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                # relative: level 1 = this package, 2 = parent, ...
                base_parts = pkg_parts[:len(pkg_parts) - node.level + 1] \
                    if path.name == "__init__.py" \
                    else pkg_parts[:len(pkg_parts) - node.level]
                base = ".".join(base_parts + ([node.module]
                                              if node.module else []))
            else:
                base = node.module or ""
            if base:
                note(base)
            for alias in node.names:
                if base and alias.name != "*":
                    note(f"{base}.{alias.name}")
    return found


def reachable_from(roots: List[Path], known: Dict[str, Path]) -> Set[str]:
    """Transitive closure of the import graph from the entry files."""
    names = set(known)
    seen: Set[str] = set()
    frontier: Set[str] = set()
    for root in roots:
        frontier |= imports_of(root, "", names)
    while frontier:
        name = frontier.pop()
        if name in seen:
            continue
        seen.add(name)
        seen.update(p for p in _parents(name) if p in names)
        frontier |= imports_of(known[name], name, names) - seen
    return seen


def is_quarantined(path: Path) -> bool:
    """True if a line near the top of the file IS the ``# seed: unused``
    marker (a whole comment line, so prose *mentioning* the marker — like
    this module's docstring — does not quarantine anything)."""
    try:
        head = path.read_text()[:2048]
    except OSError:
        return False
    return any(line.strip().startswith(MARKER)
               for line in head.splitlines())


def check_repo(repo_root) -> Tuple[List[Finding], Dict[str, List[str]]]:
    """Dead-module findings + {'dead': [...], 'quarantined': [...]}."""
    repo_root = Path(repo_root)
    known = module_map(repo_root / "src")
    roots = [p for d in ENTRY_DIRS
             for p in sorted((repo_root / d).rglob("*.py"))]
    live = reachable_from(roots, known)
    findings: List[Finding] = []
    dead, quarantined = [], []
    for name in sorted(set(known) - live):
        if is_quarantined(known[name]):
            quarantined.append(name)
            continue
        dead.append(name)
        findings.append(Finding(
            "deadcode", "dead-module", name,
            f"module '{name}' ({known[name].relative_to(repo_root)}) is "
            f"unreachable from tests/, benchmarks/ and scripts/: delete "
            f"it or quarantine with '{MARKER}'"))
    return findings, dict(dead=dead, quarantined=quarantined)
