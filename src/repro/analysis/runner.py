"""Matrix runner: every analysis pass over the app/backend/partition grid.

One :func:`run_all` call produces the :class:`~.findings.Report` that
``scripts/lint_engine.py`` serializes and CI gates on.  The matrix is
the six paper apps x {jnp, pallas} x {monolithic, 4-chip distributed,
4-chip double-buffered} x {dense, compaction=2} (the Pallas kernel
backend is monolithic-only, so its distributed cells are skipped by
construction — see ``distrib.driver``; the ``-db`` cell traces and runs
the deferred boundary-exchange chunk path; the ``-c2`` cells trace the
capacity ladder's bucket switch, which ``jaxprlint`` walks per branch
and ``lint_bucket_coverage`` asserts is actually present):

  * **jaxprlint** traces each cell's chunk-step function (the scanned
    superstep body, boundary exchange included for distributed cells) to
    a ClosedJaxpr and walks it: host-sync primitives, unsafe overwrite
    scatters.  Per cell it also checks the abstract stats dtypes against
    ``engine._EXACT_INT_STATS`` (the 2**24 class) and, per app, the
    jnp-vs-pallas shape/dtype drift of the step output.
  * **invariants** executes each cell on a tiny RMAT graph (scale 7) and
    checks the measured run: counter conservation, trace sanity,
    monotone frontier (min apps), reprice ratio == 1.
  * **pallas_races** proves output-window disjointness for the kernel
    suite (grid-independent: runs once, not per cell).
  * **deadcode** reports unreachable modules (repo-wide: runs once).

Everything runs on tiny inputs — the static passes trace abstractly
(no device compute) and the invariant runs take a few supersteps each.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from . import deadcode, invariants, jaxprlint, pallas_races
from .findings import Finding, Report

APP_NAMES = ("bfs", "sssp", "wcc", "pagerank", "spmv", "histo")
# (backend, chips, double_buffer, compaction): pallas cells are
# monolithic-only (driver constraint); the double-buffer cell lints +
# runs the deferred boundary-exchange chunk fn (distrib.driver
# ._make_chunk's db path); the compaction cells trace the capacity
# ladder's bucket switch (jaxprlint.lint_bucket_coverage asserts every
# pre-traced branch is present and walkable) and run it over the
# invariants graph
MATRIX = (("jnp", 0, False, 0), ("pallas", 0, False, 0),
          ("jnp", 4, False, 0), ("jnp", 4, True, 0),
          ("jnp", 0, False, 2), ("jnp", 4, True, 2))
_SCALE = 7          # tiny RMAT: 128 vertices — a few supersteps per app
_CHUNK_LEN = 4      # scan length for the traced chunk step


def _inputs():
    from ..core.tilegrid import square_grid
    from ..graph import rmat
    g = rmat.rmat_edges(_SCALE, edge_factor=4, seed=2)
    grid = square_grid(16)
    root = int(np.argmax(g.out_degree()))
    bins = max(g.n_rows // 8, 1)
    hv = rmat.histogram_input(g, bins)
    return g, grid, root, bins, hv


def _proxy_for(name, grid):
    from ..graph import apps
    if name == "bfs":
        return None                        # direct routing (Table II)
    if name == "spmv":
        return apps.table2_proxy(grid, "spmv", cascade_levels=1)
    return apps.table2_proxy(grid, name)


def _cell_engine(name, backend, chips, g, grid, root, bins, hv,
                 double_buffer=False, compaction=0):
    """(engine, state, seeds) for one matrix cell (no run executed)."""
    from ..graph import apps
    return apps.engine_and_state(
        name, g, grid, proxy=_proxy_for(name, grid), root=root,
        histo_values=hv, bins=bins, backend=backend,
        chips=chips, oq_cap=16, double_buffer=double_buffer,
        compaction=compaction)


def _chunk_args(eng, state):
    zero = jnp.zeros((), jnp.bool_)
    return (state, zero, zero, jnp.int32(64))


def _lint_cell(name, backend, chips, g, grid, root, bins, hv,
               where: str, double_buffer=False,
               compaction=0) -> List[Finding]:
    """Static passes of one cell: trace the chunk step + int-stat check."""
    import jax
    eng, state, _seeds = _cell_engine(name, backend, chips, g, grid, root,
                                      bins, hv, double_buffer, compaction)
    if chips:
        chunk_fn = eng._get_chunk_fn(_CHUNK_LEN)
        raw = eng._raw_vmap_step()
        step_one = functools.partial(raw, eng._row_lo_s, eng._row_hi_s)

        def step(st, fl):
            return step_one(st, eng._chip_ids, fl)
    else:
        chunk_fn = functools.partial(eng._chunk_impl, length=_CHUNK_LEN)
        step = eng._chunk_step_one
    closed = jax.make_jaxpr(chunk_fn)(*_chunk_args(eng, state))
    findings = jaxprlint.lint_jaxpr(closed, where)
    if compaction:
        kernel = eng.kernel if chips else eng
        findings += jaxprlint.lint_bucket_coverage(
            closed, len(kernel._ladder), where)
    from ..core.engine import _EXACT_INT_STATS
    shapes = jaxprlint.stats_shapes_of(step, state,
                                       jnp.zeros((), jnp.bool_))
    findings += jaxprlint.lint_int_stats(shapes, _EXACT_INT_STATS, where)
    return findings


def _drift_cell(name, g, grid, root, bins, hv, where: str) -> List[Finding]:
    """jnp-vs-pallas structural drift of one app's step output."""
    import jax
    trees = {}
    for backend in ("jnp", "pallas"):
        eng, state, _ = _cell_engine(name, backend, 0, g, grid, root,
                                     bins, hv)
        trees[backend] = jax.eval_shape(eng._chunk_step_one, state,
                                        jnp.zeros((), jnp.bool_))
    return jaxprlint.lint_backend_drift(trees["jnp"], trees["pallas"],
                                        where)


def _run_cell(name, backend, chips, g, grid, root, bins, hv,
              where: str, double_buffer=False,
              compaction=0) -> List[Finding]:
    """Execute one cell and check the measured run's invariants."""
    from ..graph import apps
    proxy = _proxy_for(name, grid)
    kw = dict(backend=backend, oq_cap=16, double_buffer=double_buffer,
              compaction=compaction)
    if chips:
        kw["chips"] = chips
    if name == "bfs":
        res = apps.bfs(g, root, grid, **kw)
        seeds = 1
    elif name == "sssp":
        res = apps.sssp(g, root, grid, proxy=proxy, **kw)
        seeds = 1
    elif name == "wcc":
        res = apps.wcc(g, grid, proxy=proxy, **kw)
        seeds = g.n_rows
    elif name == "pagerank":
        res = apps.pagerank(g, grid, proxy=proxy, epochs=2, **kw)
        seeds = 0
    elif name == "spmv":
        x = np.random.default_rng(3).random(g.n_cols).astype(np.float32)
        res = apps.spmv(g, x, grid, proxy=proxy, **kw)
        seeds = 0
    elif name == "histo":
        res = apps.histogram(hv, bins, grid, proxy=proxy, **kw)
        seeds = 0
    else:
        raise ValueError(name)
    write_back = proxy is not None and proxy.write_back
    from ..core.costmodel import DCRA_SRAM
    return invariants.check_run(res.run, pkg=DCRA_SRAM, grid=grid,
                                where=where, write_back=write_back,
                                seeds=seeds)


def run_all(repo_root, app_names: Optional[Sequence[str]] = None,
            passes: Optional[Sequence[str]] = None,
            progress=None) -> Report:
    """Run the selected passes over the whole matrix -> :class:`Report`.

    ``passes`` defaults to all of ``("jaxprlint", "invariants",
    "pallas_races", "deadcode")``; ``progress`` is an optional
    ``callable(str)`` for CLI progress lines.
    """
    apps_sel = tuple(app_names or APP_NAMES)
    passes_sel = tuple(passes or ("jaxprlint", "invariants",
                                  "pallas_races", "deadcode"))
    say = progress or (lambda _msg: None)
    report = Report(passes=list(passes_sel))
    g, grid, root, bins, hv = _inputs()

    for name in apps_sel:
        for backend, chips, db, comp in MATRIX:
            part = f"{chips}chips" if chips else "mono"
            if db:
                part += "-db"
            if comp:
                part += f"-c{comp}"
            where = f"{name}/{backend}/{part}"
            report.matrix.append(where)
            if "jaxprlint" in passes_sel:
                say(f"jaxprlint {where}")
                report.extend(_lint_cell(name, backend, chips, g, grid,
                                         root, bins, hv, where, db, comp))
            if "invariants" in passes_sel:
                say(f"invariants {where}")
                report.extend(_run_cell(name, backend, chips, g, grid,
                                        root, bins, hv, where, db, comp))
        if "jaxprlint" in passes_sel:
            say(f"backend-drift {name}")
            report.extend(_drift_cell(name, g, grid, root, bins, hv,
                                      f"{name}/drift"))

    if "pallas_races" in passes_sel:
        say("pallas_races kernel suite")
        report.extend(pallas_races.check_kernels())
    if "deadcode" in passes_sel:
        say("deadcode import graph")
        dc, _meta = deadcode.check_repo(repo_root)
        report.extend(dc)
    return report
