"""Findings and reports: the common currency of the analysis passes.

A :class:`Finding` is one violated property at one site.  Its identity
for baseline comparison is ``(pass_name, rule, where)`` — deliberately
excluding the human-readable message, so cosmetic message changes (or
counts embedded in them) do not churn the committed baseline.

A :class:`Report` is the JSON document ``scripts/lint_engine.py`` emits:
the full finding list plus the matrix that produced it.  CI compares the
report against the committed baseline (``analysis_baseline.json``) and
fails on findings whose key is not baselined.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated property at one site."""

    pass_name: str        # 'jaxprlint' | 'pallas_races' | 'invariants' | 'deadcode'
    rule: str             # e.g. 'host-sync', 'scatter-mode', 'reprice-ratio'
    where: str            # site: 'bfs/jnp/mono', 'segment_combine:add', module
    message: str          # human-readable detail (not part of the key)
    severity: str = "error"

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}")

    @property
    def key(self) -> str:
        """Baseline identity: pass:rule:where (message excluded)."""
        return f"{self.pass_name}:{self.rule}:{self.where}"

    def as_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d) -> "Finding":
        return cls(pass_name=d["pass_name"], rule=d["rule"],
                   where=d["where"], message=d.get("message", ""),
                   severity=d.get("severity", "error"))


@dataclasses.dataclass
class Report:
    """A lint run's full output: findings + what was analyzed."""

    findings: List[Finding] = dataclasses.field(default_factory=list)
    matrix: List[str] = dataclasses.field(default_factory=list)
    passes: List[str] = dataclasses.field(default_factory=list)

    def extend(self, findings: Sequence[Finding]) -> "Report":
        self.findings.extend(findings)
        return self

    def keys(self) -> List[str]:
        return [f.key for f in self.findings]

    def new_vs_baseline(self, baseline_keys) -> List[Finding]:
        """Findings not covered by the baseline (what fails CI)."""
        base = set(baseline_keys)
        return [f for f in self.findings if f.key not in base]

    def to_json(self) -> str:
        return json.dumps(
            dict(findings=[f.as_dict() for f in self.findings],
                 matrix=list(self.matrix), passes=list(self.passes)),
            indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "Report":
        d = json.loads(text)
        return cls(findings=[Finding.from_dict(f) for f in d["findings"]],
                   matrix=list(d.get("matrix", ())),
                   passes=list(d.get("passes", ())))

    def baseline_json(self) -> str:
        """The committed-baseline form: sorted finding keys only."""
        return json.dumps(dict(keys=sorted(set(self.keys()))),
                          indent=2) + "\n"


def load_baseline(path) -> List[str]:
    """Read a committed baseline file -> finding keys.  A missing file is
    an empty baseline (every finding fails CI)."""
    try:
        with open(path) as fh:
            d = json.load(fh)
    except FileNotFoundError:
        return []
    return list(d.get("keys", ()))


def summarize(findings: Sequence[Finding],
              baseline_keys: Optional[Sequence[str]] = None) -> str:
    """One human-readable block per finding, baseline-annotated."""
    base = set(baseline_keys or ())
    if not findings:
        return "no findings"
    lines = []
    for f in sorted(findings, key=lambda f: f.key):
        mark = " [baselined]" if f.key in base else ""
        lines.append(f"{f.severity.upper():7s} {f.pass_name}:{f.rule} "
                     f"@ {f.where}{mark}\n        {f.message}")
    return "\n".join(lines)
