"""Counter-conservation checker + the runtime sanitizer's error type.

Every message the engine emits is conserved: an emitted edge record is
either merged with a sibling (batch coalescing, P$ combine, cascade-tree
merge), absorbed (P$ filter), or delivered to its owner — and every
network hop it takes decomposes into exactly one level (intra-die,
inter-die, off-package).  These are the properties that make the traffic
counters a *measurement* rather than an estimate, and the measured
:class:`~repro.core.netstats.SuperstepTrace` re-priceable
(measure-once / price-many).  The checks:

``counter-negative`` / ``counter-nonint``
    Every :class:`TrafficCounters` field is a count (or a hop-weighted
    sum of counts): nonnegative and integer-valued.  f32 device sums
    keep integer values exactly below 2**24 and round to *integers*
    above it, so a fractional counter is a model bug, not rounding.

``hop-decomposition``
    ``hop_msgs == intra_die_hops + inter_die_crossings +
    inter_pkg_crossings`` — every on-silicon hop is charged at exactly
    one network level (the board-level legs are counted separately in
    ``off_chip_hop_msgs``).

``owner-conservation``
    Write-through / no-proxy: ``owner_msgs == edges_processed -
    filtered_at_proxy - coalesced_at_proxy - cascade_combined`` exactly
    (batch leaders = emitted - coalesced; survivors = leaders -
    filtered; tree merges subtract one message each).  Write-back P$
    absorbs improving hits without a counter, so only ``<=`` holds
    there (with equality impossible to restore without counting
    ``upd_hit`` — which is P$-internal, not traffic).

``consumed-bound``
    ``records_consumed <= owner_msgs + seeds``: mailbox slots combine on
    arrival, so each drain needs at least one owner-leg delivery (or an
    initial seed) behind it.

``owner-subset``
    ``owner_msgs <= messages`` and ``owner_hop_msgs <= hop_msgs``: the
    owner-bound leg is a subset of all charged legs.

``trace-*``
    The per-superstep trace: equal-length vectors, nonnegative entries,
    wire-bit vectors quantized to ``MSG_BITS``, and a drained final
    superstep (``pending[-1] == 0`` — the run loop only stops early on
    an explicit budget).

``monotone-frontier``
    Min-combine apps only relax: no value may increase between
    snapshots (:func:`check_values`).  ``EngineConfig.sanitize=True``
    additionally proves this per superstep on device.

``reprice-ratio``
    ``costmodel.trace_time_s`` under the run's own
    :class:`PackageConfig` must reproduce ``RunResult.time_s`` (ratio
    == 1 up to f64 summation order) — the measure-once / price-many
    contract.

:func:`check_run` composes all of the above on a
:class:`~repro.core.engine.RunResult`; ``assert_clean`` turns findings
into a :class:`SanitizerError` (what ``EngineConfig.sanitize=True``
raises).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np

from ..core import costmodel
from ..core.netstats import MSG_BITS, SuperstepTrace, TrafficCounters
from .findings import Finding, summarize

# f32 device accumulation: integer counts stay exact below 2**24 and
# integral above; equality checks allow relative f32 slack.
_RTOL = 1e-6


class SanitizerError(AssertionError):
    """A conservation/sanity invariant failed at runtime."""


def _isint(v: float) -> bool:
    return math.isfinite(v) and abs(v - round(v)) <= _RTOL * max(1.0, abs(v))


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= _RTOL * max(1.0, abs(a), abs(b))


# ------------------------------------------------------------------ counters
def check_counters(c: TrafficCounters, *, where: str,
                   write_back: bool = False,
                   seeds: int = 0) -> List[Finding]:
    """Conservation + sanity of a run's accumulated traffic counters."""
    findings = []

    def bad(rule, msg):
        findings.append(Finding("invariants", rule, where, msg))

    for f in dataclasses.fields(c):
        v = float(getattr(c, f.name))
        if not math.isfinite(v) or v < 0:
            bad("counter-negative",
                f"counter '{f.name}' = {v!r}: counts cannot go negative "
                f"or non-finite")
        elif not _isint(v):
            bad("counter-nonint",
                f"counter '{f.name}' = {v!r} is fractional: every field "
                f"is a message/hop count")

    lvl = c.intra_die_hops + c.inter_die_crossings + c.inter_pkg_crossings
    if not _close(c.hop_msgs, lvl):
        bad("hop-decomposition",
            f"hop_msgs={c.hop_msgs} != intra+die+pkg={lvl}: some hop was "
            f"charged at zero or two network levels")

    rhs = (c.edges_processed - c.filtered_at_proxy - c.coalesced_at_proxy
           - c.cascade_combined)
    if write_back:
        # improving P$ hits absorb records without a counter: only <=
        if c.owner_msgs > rhs * (1 + _RTOL) + _RTOL:
            bad("owner-conservation",
                f"owner_msgs={c.owner_msgs} > emitted-merged-filtered="
                f"{rhs}: the owner leg delivered records that were never "
                f"emitted")
    elif not _close(c.owner_msgs, rhs):
        bad("owner-conservation",
            f"owner_msgs={c.owner_msgs} != edges_processed - filtered - "
            f"coalesced - cascade_combined = {rhs}: an emitted record "
            f"was neither merged, filtered nor delivered")

    if c.records_consumed > c.owner_msgs + seeds + _RTOL * c.owner_msgs:
        bad("consumed-bound",
            f"records_consumed={c.records_consumed} > owner_msgs+seeds="
            f"{c.owner_msgs + seeds}: mailbox drains outnumber "
            f"deliveries")

    if c.owner_msgs > c.messages * (1 + _RTOL):
        bad("owner-subset",
            f"owner_msgs={c.owner_msgs} > messages={c.messages}")
    if c.owner_hop_msgs > c.hop_msgs * (1 + _RTOL):
        bad("owner-subset",
            f"owner_hop_msgs={c.owner_hop_msgs} > hop_msgs={c.hop_msgs}")
    return findings


# --------------------------------------------------------------------- trace
def check_trace(trace: SuperstepTrace, *, where: str,
                drained: bool = True) -> List[Finding]:
    """Structural sanity of the per-superstep level-traffic record."""
    findings = []

    def bad(rule, msg):
        findings.append(Finding("invariants", rule, where, msg))

    n = len(trace)
    for f in trace._VECTOR_FIELDS:
        vec = np.asarray(getattr(trace, f), dtype=np.float64)
        if vec.shape[0] != n:
            bad("trace-length",
                f"trace field '{f}' has {vec.shape[0]} entries but "
                f"compute_ops has {n}: a superstep was dropped from one "
                f"vector")
            continue
        if vec.size and (not np.all(np.isfinite(vec)) or vec.min() < 0):
            bad("trace-negative",
                f"trace field '{f}' has negative/non-finite entries "
                f"(min={vec.min() if np.all(np.isfinite(vec)) else 'nan'})")
        if f.endswith("_bits") and f != "touched_bits" and vec.size:
            q = vec / MSG_BITS
            if not np.allclose(q, np.round(q), rtol=_RTOL, atol=_RTOL):
                bad("trace-bit-quantum",
                    f"trace field '{f}' is not a multiple of MSG_BITS="
                    f"{MSG_BITS}: level traffic is charged per message")
    if drained and n and trace.pending[-1] != 0:
        bad("trace-not-drained",
            f"final superstep left pending={trace.pending[-1]}: the run "
            f"stopped before draining (budget hit without being declared)")
    return findings


# -------------------------------------------------------------------- values
def check_values(before, after, combine: str, *, where: str) -> List[Finding]:
    """Monotone frontier for min-combine apps: relaxation never regresses."""
    if combine != "min":
        return []
    b = np.asarray(before, dtype=np.float64)
    a = np.asarray(after, dtype=np.float64)
    worse = int(np.sum(a > b))
    if worse:
        return [Finding(
            "invariants", "monotone-frontier", where,
            f"{worse} value(s) increased across the run of a min-combine "
            f"app: relaxation must be monotone")]
    return []


# ------------------------------------------------------------------- reprice
def check_reprice(result, pkg, grid, *, where: str,
                  mem_bits_hbm: float = 0.0,
                  rtol: float = 1e-9) -> List[Finding]:
    """Measure-once / price-many: re-pricing the measured trace under the
    run's own package must reproduce the run's BSP time.  ``rtol`` covers
    f64 summation-order drift only (np.sum pairwise vs the run loop's
    sequential accumulation), not model slack."""
    trace = getattr(result, "trace", None)
    if trace is None or len(trace) == 0:
        return []
    repriced = costmodel.trace_time_s(pkg, grid, trace,
                                      mem_bits_hbm=mem_bits_hbm)
    t = float(result.time_s)
    if t == 0.0 and repriced == 0.0:
        return []
    if t == 0.0 or abs(repriced - t) > rtol * max(abs(t), abs(repriced)):
        ratio = repriced / t if t else float("inf")
        return [Finding(
            "invariants", "reprice-ratio", where,
            f"trace_time_s={repriced!r} vs run time_s={t!r} "
            f"(ratio {ratio!r}): the measured trace no longer reproduces "
            f"the run's BSP time under its own PackageConfig")]
    return []


# ----------------------------------------------------------------- composite
def check_run(result, *, pkg, grid, where: str = "run",
              write_back: bool = False, seeds: int = 0,
              combine: Optional[str] = None,
              values_before=None, values_after=None,
              drained: bool = True,
              mem_bits_hbm: float = 0.0) -> List[Finding]:
    """All post-run invariants of one ``RunResult``.

    ``pkg``/``grid`` are the run's own :class:`PackageConfig` /
    :class:`TileGrid` (the reprice contract is against the measured
    config, not an arbitrary one).  ``values_before``/``values_after``
    enable the monotone-frontier check when ``combine == 'min'``.
    Returns findings; use :func:`assert_clean` to raise instead.
    """
    findings = []
    findings += check_counters(result.counters, where=where,
                               write_back=write_back, seeds=seeds)
    if result.trace is not None:
        findings += check_trace(result.trace, where=where, drained=drained)
        findings += check_reprice(result, pkg, grid, where=where,
                                  mem_bits_hbm=mem_bits_hbm)
    if combine is not None and values_before is not None \
            and values_after is not None:
        findings += check_values(values_before, values_after, combine,
                                 where=where)
    return findings


def assert_clean(findings: Sequence[Finding], context: str = "") -> None:
    """Raise :class:`SanitizerError` if any invariant failed."""
    if findings:
        head = f"sanitizer: {len(findings)} invariant violation(s)"
        if context:
            head += f" in {context}"
        raise SanitizerError(head + "\n" + summarize(findings))
