"""The data-local execution engine (paper §II-B, §III), TPU-adapted.

Execution model
---------------
The dataset is scattered across tiles as equal chunks.  Work proceeds in
*supersteps* (the TPU-idiomatic, bulk-synchronous rendering of the
paper's asynchronous task pipeline — see DESIGN.md §2):

  1. **IQ drain**: each tile consumes up to ``iq_cap`` pending records
     from its *mailbox* (a dense, per-owned-index combining input queue —
     incoming records with the same index are combined on arrival, which
     is exactly what the paper's combining queues/P$ exploit: all
     evaluated apps have commutative updates).  Unconsumed records remain
     pending — measurable backpressure.
  2. **Task execution / OQ emit**: consuming an improving record
     re-activates the per-item edge cursor; each tile then streams up to
     ``oq_cap`` edges from its active cursors (the paper's PU executing
     tasks, with the OQ bounding per-superstep emission), producing
     (dst_index, value) records.
  3. **Proxy stage** (if configured): records are routed to the proxy
     tile in the sender's region, batch-coalesced, filtered/combined
     through a direct-mapped P$ with write-through or write-back policy,
     and only surviving records are forwarded to the true owners.
  3b. **Cascaded drain** (if the proxy config carries a ``CascadeConfig``):
     instead of travelling straight to the owner, every record the proxy
     stage forwards — write-through survivors, write-back evictions and
     whole-P$ flushes alike — climbs a *region reduction tree*: the
     record hops from its region proxy to the proxy for the same index
     in the enclosing super-region (base regions grouped
     ``group_ny x group_nx`` per level), where records from sibling
     regions bound for the same index are combined into one, then
     onward level-by-level until the tree root forwards a single record
     to the true owner.  Under the *selective* criterion a record whose
     owner already lies inside its current super-region exits the tree
     early and goes straight to the owner, and apps whose combine is not
     profitable to merge (``AppSpec.cascade_profitable=False``) skip the
     tree entirely.  This is the paper's scaling mechanism: owner-bound
     updates are combined hierarchically instead of all converging on
     one tile, so cross-chip traffic shrinks as the grid grows.
  4. **Delivery**: surviving records are combined into owner mailboxes.

Every message is charged exact XY-torus hops at each leg (including every
cascade-tree leg); the BSP time model takes the per-superstep max over
(tile compute, per-level network serialization, endpoint contention —
including contention at intermediate cascade proxies) — reproducing the
paper's observable effects without per-cycle router simulation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import netstats
from .costmodel import (CLOCK_GHZ, HBM_CHANNEL_GBS, HBM_CHANNELS,
                        PU_OPS_PER_EDGE, PU_OPS_PER_RECORD, DCRA_SRAM,
                        PackageConfig, link_provisioning, step_cycles)
from .netstats import MSG_BITS, SuperstepTrace, TrafficCounters
from .proxy import (ProxyConfig, cascade_proxy_tile, make_pcache,
                    pcache_slot, proxy_tile)
from .tilegrid import ChipPartition, TileGrid

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass(frozen=True)
class AppSpec:
    """How an application maps onto the engine."""

    name: str
    combine: str             # 'min' | 'add'
    edge_value: str          # 'add_w' | 'add_one' | 'mul_w' | 'carry' | 'one'
    reactivate: bool = True  # mailbox improvements re-activate edge cursors
    count_teps_on: str = "edges"   # what Graph500-style TEPS counts
    # Whether merging two in-flight updates to the same index into one
    # record is profitable for this app (true for commutative reductions
    # like min/add).  The selective-cascading criterion consults this:
    # with CascadeConfig(selective=True), unprofitable apps bypass the
    # reduction tree and forward proxy output straight to the owners.
    cascade_profitable: bool = True

    @property
    def identity(self) -> float:
        return float("inf") if self.combine == "min" else 0.0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    grid: TileGrid
    n_src: int                       # items with edge cursors (vertices/cols/elems)
    n_dst: int                       # items receiving updates (vertices/rows/bins)
    oq_cap: int = 64                 # edge emissions per tile per superstep
    iq_ratio: int = 8                # iq_cap = iq_ratio * oq_cap
    proxy: Optional[ProxyConfig] = None
    pkg: PackageConfig = DCRA_SRAM
    max_supersteps: int = 200_000
    element_bits: int = 64           # index+value footprint per dataset element

    @property
    def iq_cap(self) -> int:
        return self.iq_ratio * self.oq_cap

    @property
    def chunk_src(self) -> int:
        return self.grid.chunk_size(self.n_src)

    @property
    def chunk_dst(self) -> int:
        return self.grid.chunk_size(self.n_dst)


class DataLocalEngine:
    """Vectorised single-host engine: simulates the whole tile grid, with
    exact traffic accounting.  (The sharded multi-device rendering of the
    same schedule lives in ``core/collectives.py`` + ``launch/dryrun.py``.)

    The superstep kernel is *window-parametric*: with the default
    ``part=None`` the window is the whole grid (the monolithic engine);
    with a ``ChipPartition`` the same kernel executes one chip's subgrid
    at a time — local state, global tile ids and data indices — which is
    how ``distrib.driver`` runs one engine superstep per chip (vmapped or
    under ``shard_map``) and exchanges the off-window records between
    supersteps.
    """

    def __init__(self, app: AppSpec, cfg: EngineConfig,
                 row_lo: np.ndarray, row_hi: np.ndarray,
                 col_idx: np.ndarray, weights: Optional[np.ndarray] = None,
                 part: Optional[ChipPartition] = None):
        self.app = app
        self.cfg = cfg
        grid = cfg.grid
        self.part = part if part is not None else ChipPartition(grid, 1, 1)
        self.n_chips = self.part.num_chips
        T = self.part.tiles_per_chip      # tiles per execution window
        self.T = T
        self.Tg = grid.num_tiles          # tiles in the whole grid
        self.Cs = cfg.chunk_src
        self.Cd = cfg.chunk_dst
        self.Ns = T * self.Cs             # window lengths (mono: == global)
        self.Nd = T * self.Cd
        self.Ngs = self.Tg * self.Cs      # global lengths / index sentinels
        self.Ngd = self.Tg * self.Cd
        self._cascade_levels = 0
        if cfg.proxy is not None:
            if T * cfg.proxy.slots >= 2**31:
                raise ValueError("T*slots must fit int32 for P$ sort keys")
            cfg.proxy.validate_window(self.part.sub_ny, self.part.sub_nx)
            casc = cfg.proxy.cascade
            if casc is not None and (not casc.selective
                                     or app.cascade_profitable):
                self._cascade_levels = casc.levels
        # per-source arrays padded to the *global* length; in chip mode the
        # driver partitions these into per-window slices before stepping.
        self.row_lo = jnp.asarray(_pad(row_lo, self.Ngs, 0), jnp.int32)
        self.row_hi = jnp.asarray(_pad(row_hi, self.Ngs, 0), jnp.int32)
        self.col_idx = jnp.asarray(col_idx, jnp.int32)
        if weights is None:
            weights = np.ones_like(col_idx, dtype=np.float32)
        self.weights = jnp.asarray(weights, jnp.float32)
        self._superstep = jax.jit(self._superstep_impl)

    def chip_superstep(self, row_lo, row_hi, state, chip_id, flush):
        """One superstep of window ``chip_id``: pure in its array args so
        the distributed driver can vmap / shard_map it across chips.
        Returns (new_state, stats, off) where ``off`` is the dict of
        off-chip records (dst, val, mask) to exchange — ``None`` for a
        monolithic window."""
        return self._step(row_lo, row_hi, state, chip_id, flush)

    def _require_mono(self, what: str):
        """init_state/activate_all/run build whole-grid state; with a
        multi-chip partition the per-window shapes differ and state
        handling lives in the driver."""
        if self.n_chips > 1:
            raise ValueError(
                f"{what} is monolithic-only; with a {self.n_chips}-chip "
                f"partition use distrib.DistributedEngine, which wraps "
                f"this engine's chip_superstep")

    # ---------------------------------------------------------------- state
    def init_state(self, seed_idx=None, seed_val=None,
                   values: Optional[np.ndarray] = None):
        self._require_mono("init_state")
        ident = jnp.float32(self.app.identity)
        st = dict(
            values=jnp.full((self.Nd,), ident) if values is None
            else jnp.asarray(_pad(values, self.Nd, self.app.identity), jnp.float32),
            mail_val=jnp.full((self.Nd,), ident),
            mail_flag=jnp.zeros((self.Nd,), jnp.bool_),
            cur_lo=jnp.zeros((self.Ns,), jnp.int32),
            cur_hi=jnp.zeros((self.Ns,), jnp.int32),
            cur_val=jnp.zeros((self.Ns,), jnp.float32),
        )
        if self.cfg.proxy is not None:
            tags, vals = make_pcache(self.cfg.grid, self.cfg.proxy,
                                     self.app.identity)
            st["p_tag"], st["p_val"] = tags, vals
        if seed_idx is not None:
            si = jnp.asarray(np.atleast_1d(seed_idx), jnp.int32)
            sv = jnp.asarray(np.atleast_1d(seed_val), jnp.float32)
            st["mail_val"] = st["mail_val"].at[si].set(sv)
            st["mail_flag"] = st["mail_flag"].at[si].set(True)
        return st

    def activate_all(self, state, cur_val):
        """Epoch-style activation (PageRank/SPMV/Histogram): every source
        item starts with its full edge range and a carried value."""
        self._require_mono("activate_all")
        state = dict(state)
        state["cur_lo"] = self.row_lo
        state["cur_hi"] = self.row_hi
        state["cur_val"] = jnp.asarray(_pad(cur_val, self.Ns, 0.0), jnp.float32)
        return state

    # ------------------------------------------------------------ superstep
    def _superstep_impl(self, state, flush: jnp.ndarray):
        """Monolithic superstep: the whole grid as one window."""
        new_state, stats, _ = self._step(self.row_lo, self.row_hi, state,
                                         jnp.int32(0), flush)
        return new_state, stats

    def _step(self, row_lo, row_hi, state, chip_id, flush):
        app, cfg, grid = self.app, self.cfg, self.cfg.grid
        T, Cs, Cd = self.T, self.Cs, self.Cd
        is_min = app.combine == "min"
        ident = jnp.float32(app.identity)
        tile_gids = self.part.global_tile(
            chip_id, jnp.arange(T, dtype=jnp.int32))

        # ---- 1. IQ drain (budgeted mailbox consumption) -------------------
        flag2d = state["mail_flag"].reshape(T, Cd)
        csum = jnp.cumsum(flag2d.astype(jnp.int32), axis=1)
        take2d = flag2d & (csum <= cfg.iq_cap)
        take = take2d.reshape(-1)
        mval, vals = state["mail_val"], state["values"]
        if is_min:
            improved = take & (mval < vals)
            new_vals = jnp.where(improved, mval, vals)
        else:
            improved = take
            new_vals = jnp.where(take, vals + mval, vals)
        mail_flag = state["mail_flag"] & ~take
        mail_val = jnp.where(take, ident, mval)
        consumed_per_tile = jnp.sum(take2d, axis=1)

        cur_lo, cur_hi, cur_val = state["cur_lo"], state["cur_hi"], state["cur_val"]
        if app.reactivate:
            # an improving record restarts the item's edge cursor with the
            # new value (re-expansion of an already-visited item is the
            # engine's rendering of data staleness: measurable wasted work).
            re = improved[: self.Ns] if self.Nd == self.Ns else jnp.zeros(
                (self.Ns,), jnp.bool_)
            cur_lo = jnp.where(re, row_lo, cur_lo)
            cur_hi = jnp.where(re, row_hi, cur_hi)
            cur_val = jnp.where(re, new_vals[: self.Ns], cur_val)

        # ---- 2. OQ emit (budgeted edge streaming) -------------------------
        B = cfg.oq_cap
        rem2d = (cur_hi - cur_lo).reshape(T, Cs)
        prefix = jnp.cumsum(rem2d, axis=1)                    # inclusive
        capped = jnp.minimum(prefix, B)
        take_v2d = capped - jnp.concatenate(
            [jnp.zeros((T, 1), jnp.int32), capped[:, :-1]], axis=1)
        total_take = capped[:, -1]                            # (T,)
        b_idx = jnp.arange(B, dtype=jnp.int32)
        vslot = jax.vmap(
            functools.partial(jnp.searchsorted, side="right"),
            in_axes=(0, None))(capped, b_idx)
        vslot = jnp.minimum(vslot, Cs - 1)                    # (T, B)
        capped_prev = capped - take_v2d
        offset = b_idx[None, :] - jnp.take_along_axis(capped_prev, vslot, axis=1)
        vglob = vslot + jnp.arange(T, dtype=jnp.int32)[:, None] * Cs
        pos = cur_lo[vglob] + offset
        emit_mask = b_idx[None, :] < total_take[:, None]
        pos = jnp.clip(pos, 0, self.col_idx.shape[0] - 1)
        dst = self.col_idx[pos]
        cval = cur_val[vglob]
        if app.edge_value == "add_w":
            cand = cval + self.weights[pos]
        elif app.edge_value == "add_one":
            cand = cval + 1.0
        elif app.edge_value == "mul_w":
            cand = cval * self.weights[pos]
        elif app.edge_value == "carry":
            cand = cval
        elif app.edge_value == "one":
            cand = jnp.ones_like(cval)
        else:
            raise ValueError(app.edge_value)
        cur_lo = cur_lo + (take_v2d.reshape(-1))
        edges_per_tile = total_take

        # flatten records (tile ids are global; dst indices are global)
        R = T * B
        dst = dst.reshape(R)
        cand = cand.reshape(R)
        emit_mask = emit_mask.reshape(R)
        src_tile = jnp.repeat(tile_gids, B)
        owner = jnp.minimum(dst // Cd, self.Tg - 1)

        stats = dict(edges_processed=jnp.sum(edges_per_tile),
                     records_consumed=jnp.sum(consumed_per_tile),
                     compute_per_tile_max=jnp.max(
                         consumed_per_tile * PU_OPS_PER_RECORD
                         + edges_per_tile * PU_OPS_PER_EDGE),
                     filtered_at_proxy=jnp.float32(0.0),
                     coalesced_at_proxy=jnp.float32(0.0),
                     cascade_combined=jnp.float32(0.0))

        p_tag = state.get("p_tag")
        p_val = state.get("p_val")

        if cfg.proxy is None:
            (mail_val, mail_flag, owner_leg, off_ch, dmax,
             off) = self._drain_to_owners(
                mail_val, mail_flag, dst, cand, emit_mask, src_tile,
                chip_id, None, is_min)
            charges = dict(netstats.merge_charges(owner_leg, off_ch),
                           owner_msgs=owner_leg["messages"],
                           owner_hop_msgs=owner_leg["hop_msgs"])
        else:
            (mail_val, mail_flag, p_tag, p_val, charges, pstats, dmax,
             off) = self._proxy_stage(
                mail_val, mail_flag, p_tag, p_val, dst, cand, emit_mask,
                src_tile, owner, flush, is_min, ident, chip_id, tile_gids)
            stats.update(pstats)

        # ---- P$ flush (write-back): emit all resident entries to owners --
        new_state = dict(values=new_vals, mail_val=mail_val,
                         mail_flag=mail_flag, cur_lo=cur_lo, cur_hi=cur_hi,
                         cur_val=cur_val)
        if p_tag is not None:
            new_state["p_tag"], new_state["p_val"] = p_tag, p_val

        pending = (jnp.sum(new_state["mail_flag"])
                   + jnp.sum(new_state["cur_hi"] > new_state["cur_lo"]))
        stats["pending"] = pending
        # write-back P$ residency is *deferred* work: it does not keep the
        # engine busy, but must be flushed before the result is final.
        if p_tag is not None and self.cfg.proxy.write_back:
            stats["p_resident"] = jnp.sum(new_state["p_tag"] >= 0)
        else:
            stats["p_resident"] = jnp.int32(0)
        stats["delivered_max_per_tile"] = dmax
        stats.update({k: jnp.asarray(v, jnp.float32) for k, v in charges.items()})
        return new_state, stats, off

    # ------------------------------------------------------- owner delivery
    def _drain_to_owners(self, mail_val, mail_flag, dst, val, mask, src,
                         chip_id, region_dims, is_min):
        """Charge the owner-bound leg, deliver on-window records into the
        local mailboxes, and split off-window records for the exchange.

        ``dst``/``src`` are global; the local mailbox index of an
        on-window record is recovered from the owner's in-chip position.
        Returns (mail_val, mail_flag, owner_leg_charge, off_chip_charge,
        delivered_max_per_tile, off_records) — ``off_records`` is None
        for a monolithic window (nothing can leave it).
        """
        part, Cd = self.part, self.Cd
        owner = jnp.minimum(dst // Cd, self.Tg - 1)
        owner_leg = netstats.charge(self.cfg.grid, src, owner, mask,
                                    region_dims=region_dims)
        if self.n_chips == 1:
            mail_val, mail_flag, dmax = _deliver(
                mail_val, mail_flag, dst, val, mask, owner, self.T,
                self.Nd, is_min)
            return mail_val, mail_flag, owner_leg, {}, dmax, None
        on_chip = part.chip_of_tile(owner) == chip_id
        on = mask & on_chip
        off_mask = mask & ~on_chip
        lowner = part.local_tile(owner)
        ldst = lowner * Cd + dst % Cd
        mail_val, mail_flag, dmax = _deliver(
            mail_val, mail_flag, ldst, val, on, lowner, self.T, self.Nd,
            is_min)
        off_ch = netstats.charge_off_chip(part, src, owner, off_mask)
        off = dict(dst=jnp.where(off_mask, dst, self.Ngd), val=val,
                   mask=off_mask)
        return mail_val, mail_flag, owner_leg, off_ch, dmax, off

    # --------------------------------------------------------- proxy stage
    def _proxy_stage(self, mail_val, mail_flag, p_tag, p_val, dst, cand,
                     emit_mask, src_tile, owner, flush, is_min, ident,
                     chip_id, tile_gids):
        cfg, grid = self.cfg, self.cfg.grid
        pcfg = cfg.proxy
        T = self.T
        S = pcfg.slots
        R = dst.shape[0]

        ptile = proxy_tile(grid, pcfg, owner, src_tile)
        leg1 = netstats.charge(grid, src_tile, ptile, emit_mask)
        # the sender's region is window-local by construction, so the
        # proxy tile always lies on this chip — index P$ by local tile.
        ptile_l = self.part.local_tile(ptile)

        slot = pcache_slot(pcfg, dst)
        key = jnp.where(emit_mask, ptile_l * S + slot, T * S)  # sentinel at end
        dkey = jnp.where(emit_mask, dst, self.Ngd)
        # lexicographic (key, dst) via two stable argsorts
        perm1 = jnp.argsort(dkey, stable=True)
        key1, dst1 = key[perm1], dst[perm1]
        cand1, mask1 = cand[perm1], emit_mask[perm1]
        perm2 = jnp.argsort(key1, stable=True)
        skey, sdst = key1[perm2], dst1[perm2]
        scand, smask = cand1[perm2], mask1[perm2]

        first = jnp.arange(R) == 0
        new_slot = smask & (first | (skey != jnp.roll(skey, 1)))
        new_dst = smask & (new_slot | (sdst != jnp.roll(sdst, 1)))
        gid = jnp.cumsum(new_dst.astype(jnp.int32)) - 1
        gid = jnp.where(smask, gid, R - 1)
        if is_min:
            gagg = jax.ops.segment_min(jnp.where(smask, scand, INF), gid,
                                       num_segments=R, indices_are_sorted=True)
        else:
            gagg = jax.ops.segment_sum(jnp.where(smask, scand, 0.0), gid,
                                       num_segments=R, indices_are_sorted=True)
        combined = gagg[gid]                                   # per-record view
        n_leaders = jnp.sum(new_dst)
        coalesced = jnp.sum(smask) - n_leaders

        winner = new_slot                                      # first dst-group per slot
        bypass = new_dst & ~new_slot                           # batch slot conflicts

        wtile = jnp.minimum(skey // S, T - 1)
        wslot = skey % S
        cur_tag = p_tag[wtile, wslot]
        cur_pv = p_val[wtile, wslot]
        tag_hit = winner & (cur_tag == sdst)
        if is_min:
            improves = combined < cur_pv
        else:
            improves = jnp.ones_like(cur_pv, dtype=bool)
        filtered = tag_hit & ~improves                         # absorbed
        upd_hit = tag_hit & improves
        miss = winner & ~tag_hit
        evict = miss & (cur_tag >= 0) & pcfg.write_back        # flush resident

        if is_min:
            new_pv_hit = jnp.minimum(cur_pv, combined)
        else:
            new_pv_hit = cur_pv + combined
        inst_val = jnp.where(upd_hit, new_pv_hit, combined)
        do_write = upd_hit | miss
        # Scatter P$ updates.  Only winner records write, and there is at
        # most one winner per (tile, slot) per superstep; non-writers are
        # redirected to a padding row so no duplicate index can clobber a
        # winner's write (XLA scatter order with dupes is undefined).
        wtile_safe = jnp.where(do_write, wtile, T)
        p_tag = jnp.concatenate([p_tag, jnp.zeros((1, S), p_tag.dtype)]) \
            .at[wtile_safe, wslot].set(sdst)[:T]
        p_val = jnp.concatenate([p_val, jnp.zeros((1, S), p_val.dtype)]) \
            .at[wtile_safe, wslot].set(inst_val)[:T]

        # forwarding set
        if pcfg.write_back:
            fwd_now = bypass                                   # only conflicts bypass
        else:
            fwd_now = upd_hit | miss | bypass                  # write-through
        fdst = jnp.where(fwd_now, sdst, self.Ngd)
        fval = jnp.where(fwd_now, combined, ident)
        # evicted residents (write-back) also forward
        edst = jnp.where(evict, cur_tag, self.Ngd)
        eval_ = jnp.where(evict, cur_pv, ident)

        # write-back flush: when the engine signals idle, spill whole P$
        def flushed(args):
            p_tag_, p_val_ = args
            ft = p_tag_.reshape(-1)
            fv = p_val_.reshape(-1)
            return ft, fv, jnp.full_like(ft, -1), jnp.full(fv.shape, ident)

        def not_flushed(args):
            p_tag_, p_val_ = args
            z = jnp.full((T * S,), -1, jnp.int32)
            return z, jnp.full((T * S,), ident), p_tag_.reshape(-1), p_val_.reshape(-1)

        if pcfg.write_back:
            ftags, fvals, keep_t, keep_v = jax.lax.cond(
                flush, flushed, not_flushed, (p_tag, p_val))
            p_tag = keep_t.reshape(T, S)
            p_val = keep_v.reshape(T, S)
            flush_dst = jnp.where(ftags >= 0, ftags, self.Ngd)
            flush_val = jnp.where(ftags >= 0, fvals, ident)
            flush_src = jnp.repeat(tile_gids, S)
        else:
            flush_dst = flush_val = flush_src = None

        # drain all forwarded legs: write-through survivors, slot-conflict
        # bypasses, write-back evictions and whole-P$ flushes
        # (sources are global tile ids — the forwarding proxy tile)
        all_dst = [fdst, edst]
        all_val = [fval, eval_]
        all_src = [self.part.global_tile(
            chip_id, jnp.minimum(skey // S, T - 1))] * 2
        if flush_dst is not None:
            all_dst.append(flush_dst)
            all_val.append(flush_val)
            all_src.append(flush_src)
        cat_dst = jnp.concatenate(all_dst)
        cat_val = jnp.concatenate(all_val)
        cat_src = jnp.concatenate(all_src)
        cat_mask = cat_dst < self.Ngd
        rdims = (pcfg.region_ny, pcfg.region_nx)
        ncomb = jnp.float32(0.0)
        if self._cascade_levels:
            # Cascaded drain: level-by-level through the region reduction
            # tree instead of straight to the owners.  Under the selective
            # criterion, write-back apps cascade only the dense whole-P$
            # flush wave — sporadic slot-conflict bypasses and evictions
            # carry too few same-index duplicates to merge profitably and
            # go direct; write-through apps cascade their full forward set.
            if pcfg.write_back and pcfg.cascade.selective:
                n_direct = all_dst[0].shape[0] + all_dst[1].shape[0]
                eligible = jnp.arange(cat_dst.shape[0]) >= n_direct
            else:
                eligible = jnp.ones(cat_dst.shape[0], bool)
            (mail_val, mail_flag, leg2, owner_leg, dmax, ncomb,
             off) = self._cascade_drain(
                mail_val, mail_flag, cat_dst, cat_val, cat_src, cat_mask,
                eligible, is_min, chip_id)
        else:
            (mail_val, mail_flag, owner_leg, off_ch, dmax,
             off) = self._drain_to_owners(
                mail_val, mail_flag, cat_dst, cat_val, cat_mask, cat_src,
                chip_id, rdims, is_min)
            leg2 = netstats.merge_charges(owner_leg, off_ch)
        charges = dict(netstats.merge_charges(leg1, leg2),
                       owner_msgs=owner_leg["messages"],
                       owner_hop_msgs=owner_leg["hop_msgs"])
        pstats = dict(filtered_at_proxy=jnp.sum(filtered).astype(jnp.float32),
                      coalesced_at_proxy=coalesced.astype(jnp.float32),
                      cascade_combined=ncomb)
        return mail_val, mail_flag, p_tag, p_val, charges, pstats, dmax, off

    # ------------------------------------------------------- cascaded drain
    def _cascade_drain(self, mail_val, mail_flag, dst, val, src, mask,
                       eligible, is_min, chip_id):
        """Drain proxy-stage output through the region reduction tree.

        Records climb from their region proxy to the same-index proxy of
        the enclosing super-region at each level, merging with records
        from sibling regions bound for the same destination; only tree
        roots (or selective early exits) forward to the true owner.  Each
        leg is charged exact XY hops; endpoint contention at intermediate
        proxies feeds the BSP time model.  Records with ``eligible=False``
        skip the tree and go straight to their owner.

        Returns (mail_val, mail_flag, merged_charges, owner_leg_charge,
        delivered_max_per_tile, n_combined, off_records).
        """
        cfg, grid = self.cfg, self.cfg.grid
        pcfg = cfg.proxy
        casc = pcfg.cascade
        T = self.T
        rdims = (pcfg.region_ny, pcfg.region_nx)

        cur = jnp.minimum(src, self.Tg - 1)
        alive = mask & eligible
        owner = jnp.minimum(dst // self.Cd, self.Tg - 1)
        legs = []
        out_dst = [dst]
        out_val = [val]
        out_src = [cur]
        out_mask = [mask & ~eligible]
        ncomb = jnp.float32(0.0)
        dmax = jnp.float32(0.0)

        for level in range(1, self._cascade_levels + 1):
            rny, rnx = casc.level_dims(pcfg.region_ny, pcfg.region_nx, level)
            if casc.selective:
                # selective exit: once the owner lies inside the record's
                # level-`level` super-region, climbing further cannot merge
                # it with updates from other subtrees on a shorter path —
                # it leaves the tree and goes straight to the owner.
                near = alive & (grid.region_id(cur, rny, rnx)
                                == grid.region_id(owner, rny, rnx))
                out_dst.append(dst)
                out_val.append(val)
                out_src.append(cur)
                out_mask.append(near)
                alive = alive & ~near
            ptile = cascade_proxy_tile(grid, rny, rnx, owner, cur)
            ptile_l = self.part.local_tile(ptile)
            legs.append(netstats.charge(grid, cur, ptile, alive,
                                        region_dims=rdims))
            recv = jax.ops.segment_sum(alive.astype(jnp.float32),
                                       jnp.where(alive, ptile_l, T),
                                       num_segments=T + 1)[:T]
            dmax = jnp.maximum(dmax, jnp.max(recv))
            cur, dst, val, owner, alive, merged = self._combine_level(
                ptile_l, dst, val, alive, is_min, chip_id)
            ncomb = ncomb + merged

        out_dst.append(dst)
        out_val.append(val)
        out_src.append(cur)
        out_mask.append(alive)
        cat_dst = jnp.concatenate(out_dst)
        cat_val = jnp.concatenate(out_val)
        cat_src = jnp.concatenate(out_src)
        cat_mask = jnp.concatenate(out_mask)
        (mail_val, mail_flag, owner_leg, off_ch, del_max,
         off) = self._drain_to_owners(
            mail_val, mail_flag, cat_dst, cat_val, cat_mask, cat_src,
            chip_id, rdims, is_min)
        legs.append(owner_leg)
        legs.append(off_ch)
        return (mail_val, mail_flag, netstats.merge_charges(*legs),
                owner_leg, jnp.maximum(dmax, del_max), ncomb, off)

    def _combine_level(self, ptile_l, dst, val, alive, is_min, chip_id):
        """Merge records that meet at the same (proxy tile, dst) of one
        cascade level into a single combined record (leaders survive).

        Same lexicographic two-argsort grouping as the P$ batch coalesce;
        masked records carry sentinel keys and sort to the end.  Grouping
        keys use the window-local proxy tile; the surviving records'
        source tiles are returned as global ids.  Returns the level's
        outputs in sorted order plus the merge count.
        """
        T = self.T
        R = dst.shape[0]
        tkey = jnp.where(alive, ptile_l, T)
        dkey = jnp.where(alive, dst, self.Ngd)
        perm1 = jnp.argsort(dkey, stable=True)
        t1, d1, v1, a1 = tkey[perm1], dkey[perm1], val[perm1], alive[perm1]
        perm2 = jnp.argsort(t1, stable=True)
        stile, sdst = t1[perm2], d1[perm2]
        sval, salive = v1[perm2], a1[perm2]
        first = jnp.arange(R) == 0
        leader = salive & (first | (stile != jnp.roll(stile, 1))
                           | (sdst != jnp.roll(sdst, 1)))
        gid = jnp.cumsum(leader.astype(jnp.int32)) - 1
        gid = jnp.where(salive, gid, R - 1)
        if is_min:
            agg = jax.ops.segment_min(jnp.where(salive, sval, INF), gid,
                                      num_segments=R,
                                      indices_are_sorted=True)
        else:
            agg = jax.ops.segment_sum(jnp.where(salive, sval, 0.0), gid,
                                      num_segments=R,
                                      indices_are_sorted=True)
        nval = agg[gid]
        merged = (jnp.sum(salive) - jnp.sum(leader)).astype(jnp.float32)
        cur = self.part.global_tile(chip_id, jnp.minimum(stile, T - 1))
        owner = jnp.minimum(sdst // self.Cd, self.Tg - 1)
        return cur, sdst, nval, owner, leader, merged

    # ----------------------------------------------------------------- run
    def run(self, state, max_supersteps: Optional[int] = None,
            progress_every: int = 0):
        """Run supersteps until drained; returns (state, RunResult)."""
        self._require_mono("run")
        cfg = self.cfg
        maxs = max_supersteps or cfg.max_supersteps
        counters = TrafficCounters()
        trace = SuperstepTrace()
        cycles = 0.0
        write_back = cfg.proxy is not None and cfg.proxy.write_back
        steps = 0
        pkg = cfg.pkg
        links = link_provisioning(cfg.grid, pkg)

        flush_flag = jnp.asarray(False)
        while steps < maxs:
            state, stats = self._superstep(state, flush_flag)
            stats = jax.device_get(stats)
            steps += 1
            counters.add(superstep_counters(stats))
            trace.append_step(stats, element_bits=cfg.element_bits)
            # ---- BSP time model for this superstep ------------------------
            step_cycles = superstep_cycles(stats, pkg, links)
            if step_cycles > 0 or stats["pending"] > 0:
                cycles += step_cycles + links["diameter"] * 0.5  # pipeline fill
            if flush_flag:
                flush_flag = jnp.asarray(False)
            if stats["pending"] == 0:
                # live work drained; spill any write-back P$ residue (the
                # paper's TSU heuristic: flush when queues/buffers go idle).
                # Repeated flushes terminate: a spilled value that does not
                # improve its owner generates no new work.
                if write_back and stats["p_resident"] > 0:
                    flush_flag = jnp.asarray(True)
                    continue
                break
            if progress_every and steps % progress_every == 0:
                print(f"  [{self.app.name}] step {steps} pending={stats['pending']:.0f}")
        counters.supersteps = steps
        time_s = cycles / (CLOCK_GHZ * 1e9)
        return state, RunResult(counters=counters, cycles=cycles, time_s=time_s,
                                supersteps=steps, trace=trace)


@dataclasses.dataclass
class RunResult:
    counters: TrafficCounters
    cycles: float
    time_s: float
    supersteps: int
    # per-superstep level-traffic record: what makes the run re-priceable
    # under other package configs (costmodel.price(per_superstep_peak=...))
    trace: Optional[SuperstepTrace] = None


def superstep_counters(stats) -> TrafficCounters:
    """One superstep's measured traffic as a TrafficCounters delta.
    Shared by the monolithic and distributed run loops so the two paths
    cannot drift in which fields they accumulate."""
    return TrafficCounters(
        messages=stats["messages"], hop_msgs=stats["hop_msgs"],
        owner_msgs=stats["owner_msgs"],
        owner_hop_msgs=stats["owner_hop_msgs"],
        intra_die_hops=stats["intra_die_hops"],
        inter_die_crossings=stats["inter_die_crossings"],
        inter_pkg_crossings=stats["inter_pkg_crossings"],
        filtered_at_proxy=stats["filtered_at_proxy"],
        coalesced_at_proxy=stats["coalesced_at_proxy"],
        cascade_combined=stats.get("cascade_combined", 0.0),
        cross_region_msgs=stats.get("cross_region_msgs", 0.0),
        off_chip_msgs=stats.get("off_chip_msgs", 0.0),
        off_chip_hop_msgs=stats.get("off_chip_hop_msgs", 0.0),
        edges_processed=stats["edges_processed"],
        records_consumed=stats["records_consumed"], supersteps=1)


def superstep_cycles(stats, pkg, links: dict) -> float:
    """BSP cycles of one superstep: max over (tile compute, per-level
    network serialization, endpoint contention).  The distributed runtime
    maxes the board-level leg on top of this.  (Thin wrapper around
    ``costmodel.step_cycles`` so the run loops and analytic re-pricing
    cannot drift; ``link_provisioning`` also lives in costmodel now.)"""
    bits = MSG_BITS
    return float(step_cycles(
        pkg, links,
        compute_ops=float(stats["compute_per_tile_max"]),
        intra_bits=float(stats["intra_die_hops"]) * bits,
        die_bits=float(stats["inter_die_crossings"]) * bits,
        pkg_bits=float(stats["inter_pkg_crossings"]) * bits,
        endpoint_bits=float(stats["delivered_max_per_tile"]) * bits))


def _deliver(mail_val, mail_flag, dst, val, mask, owner, T, Nd, is_min):
    """Combine records into owner mailboxes; returns endpoint-contention max."""
    safe_dst = jnp.where(mask, dst, Nd)
    mv = jnp.concatenate([mail_val, jnp.zeros((1,), mail_val.dtype)])
    mf = jnp.concatenate([mail_flag, jnp.zeros((1,), jnp.bool_)])
    if is_min:
        mv = mv.at[safe_dst].min(jnp.where(mask, val, INF))
    else:
        mv = mv.at[safe_dst].add(jnp.where(mask, val, 0.0))
    mf = mf.at[safe_dst].max(mask)
    per_tile = jax.ops.segment_sum(mask.astype(jnp.float32),
                                   jnp.where(mask, owner, T),
                                   num_segments=T + 1)[:T]
    return mv[:Nd], mf[:Nd], jnp.max(per_tile)


def _pad(a: np.ndarray, n: int, fill) -> np.ndarray:
    a = np.asarray(a)
    if a.shape[0] == n:
        return a
    out = np.full((n,), fill, dtype=a.dtype)
    out[: a.shape[0]] = a
    return out
