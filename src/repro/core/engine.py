"""The data-local execution engine (paper §II-B, §III), TPU-adapted.

Execution model
---------------
The dataset is scattered across tiles as equal chunks.  Work proceeds in
*supersteps* (the TPU-idiomatic, bulk-synchronous rendering of the
paper's asynchronous task pipeline — see DESIGN.md §2):

  1. **IQ drain**: each tile consumes up to ``iq_cap`` pending records
     from its *mailbox* (a dense, per-owned-index combining input queue —
     incoming records with the same index are combined on arrival, which
     is exactly what the paper's combining queues/P$ exploit: all
     evaluated apps have commutative updates).  Unconsumed records remain
     pending — measurable backpressure.
  2. **Task execution / OQ emit**: consuming an improving record
     re-activates the per-item edge cursor; each tile then streams up to
     ``oq_cap`` edges from its active cursors (the paper's PU executing
     tasks, with the OQ bounding per-superstep emission), producing
     (dst_index, value) records.
  3. **Proxy stage** (if configured): records are routed to the proxy
     tile in the sender's region, batch-coalesced, filtered/combined
     through a direct-mapped P$ with write-through or write-back policy,
     and only surviving records are forwarded to the true owners.
  3b. **Cascaded drain** (if the proxy config carries a ``CascadeConfig``):
     instead of travelling straight to the owner, every record the proxy
     stage forwards — write-through survivors, write-back evictions and
     whole-P$ flushes alike — climbs a *region reduction tree*: the
     record hops from its region proxy to the proxy for the same index
     in the enclosing super-region (base regions grouped
     ``group_ny x group_nx`` per level), where records from sibling
     regions bound for the same index are combined into one, then
     onward level-by-level until the tree root forwards a single record
     to the true owner.  Under the *selective* criterion a record whose
     owner already lies inside its current super-region exits the tree
     early and goes straight to the owner, and apps whose combine is not
     profitable to merge (``AppSpec.cascade_profitable=False``) skip the
     tree entirely.  This is the paper's scaling mechanism: owner-bound
     updates are combined hierarchically instead of all converging on
     one tile, so cross-chip traffic shrinks as the grid grows.
  4. **Delivery**: surviving records are combined into owner mailboxes.

Every message is charged exact XY-torus hops at each leg (including every
cascade-tree leg); the BSP time model takes the per-superstep max over
(tile compute, per-level network serialization, endpoint contention —
including contention at intermediate cascade proxies) — reproducing the
paper's observable effects without per-cycle router simulation.

Device-resident run loop
------------------------
The paper's runs take hundreds of thousands of supersteps, so the run
loop must not pay a host round-trip per superstep.  ``run`` therefore
executes ``EngineConfig.run_chunk`` supersteps per device dispatch with
``jax.lax.scan``: the engine state, the write-back flush flag and the
drained/budget flags ride the scan carry entirely on device, each
superstep's fixed-shape stats are stacked into a ``(K, ...)`` trace
buffer, and the host fetches that buffer — and checks ``pending`` /
``p_resident`` — once per chunk instead of once per step.  Flush
triggering and termination are decided *inside* the scan body (the same
rules the legacy loop applied between dispatches), and supersteps past
the stop point are masked no-ops, so counters and traces are
bit-identical to the per-step loop while host syncs drop from
O(supersteps) to O(supersteps / K).  ``run(chunk=0)`` keeps the legacy
per-step loop (the benchmark baseline); larger ``run_chunk`` amortizes
dispatch further at the cost of up to K-1 wasted (masked) supersteps in
the final chunk — ``benchmarks/engine_throughput.py`` measures the
tradeoff.  Per-superstep traces are reassembled on the host from the
stacked chunk stats (``SuperstepTrace.append_chunk``), in execution
order, exactly as the per-step loop appended them.

Hot-spot kernels: with ``EngineConfig.backend="pallas"`` the engine's
combine/drain hot spots — the IQ-drain relax, the P$ / cascade segment
min/add, and the owner-mailbox delivery — run through the Pallas kernels
in ``kernels/`` (``relax_min``, ``segment_combine``, ``histogram_bin``);
the default ``"jnp"`` path is the numerical oracle the Pallas path is
tested against (bitwise for min-combine apps, up to f32 re-association
for add).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import netstats
from ..obs.metrics import default_registry
from ..obs.timeline import ChunkSpan, RunMeta
from .costmodel import (CLOCK_GHZ, PU_OPS_PER_EDGE, PU_OPS_PER_RECORD, DCRA_SRAM,
                        PackageConfig, link_provisioning, step_cycles)
from .netstats import MSG_BITS, SuperstepTrace, TrafficCounters
from .proxy import (ProxyConfig, cascade_proxy_tile, make_pcache,
                    pcache_slot, proxy_tile)
from .tilegrid import ChipPartition, TileGrid

INF = jnp.float32(jnp.inf)


@dataclasses.dataclass(frozen=True)
class AppSpec:
    """How an application maps onto the engine."""

    name: str
    combine: str             # 'min' | 'add'
    edge_value: str          # 'add_w' | 'add_one' | 'mul_w' | 'carry' | 'one'
    reactivate: bool = True  # mailbox improvements re-activate edge cursors
    count_teps_on: str = "edges"   # what Graph500-style TEPS counts
    # Whether merging two in-flight updates to the same index into one
    # record is profitable for this app (true for commutative reductions
    # like min/add).  The selective-cascading criterion consults this:
    # with CascadeConfig(selective=True), unprofitable apps bypass the
    # reduction tree and forward proxy output straight to the owners.
    cascade_profitable: bool = True

    @property
    def identity(self) -> float:
        return float("inf") if self.combine == "min" else 0.0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    grid: TileGrid
    n_src: int                       # items with edge cursors (vertices/cols/elems)
    n_dst: int                       # items receiving updates (vertices/rows/bins)
    oq_cap: int = 64                 # edge emissions per tile per superstep
    iq_ratio: int = 8                # iq_cap = iq_ratio * oq_cap
    proxy: Optional[ProxyConfig] = None
    pkg: PackageConfig = DCRA_SRAM
    max_supersteps: int = 200_000
    element_bits: int = 64           # index+value footprint per dataset element
    # Supersteps per device dispatch: the run loop scans this many
    # supersteps on device between host syncs (0 = legacy per-step loop).
    run_chunk: int = 16
    # 'jnp' (oracle) or 'pallas': which implementation the combine/drain
    # hot spots (IQ drain, segment min/add, owner delivery) run through.
    backend: str = "jnp"
    # Runtime sanitizer (repro.analysis): every superstep additionally
    # counts invariant violations on device (monotone relaxation for
    # min-combine apps, mailbox flag/value consistency, NaNs) into a
    # ``sanity_violations`` stat the run loop raises on, and the run's
    # counters/trace are conservation-checked after draining
    # (``analysis.invariants.check_run``).  Results are bit-identical to
    # sanitize=False — the checks only observe; failures raise
    # ``analysis.invariants.SanitizerError``.
    sanitize: bool = False
    # Telemetry vectors (repro.obs): every superstep additionally emits
    # per-tile load vectors (``tv_edges`` / ``tv_records`` /
    # ``tv_delivered``; the distributed driver reduces them to per-chip
    # ``pc_*`` vectors) that ride the existing chunk fetch — zero extra
    # host syncs — and feed ``obs.imbalance`` / the Perfetto tracks.
    # Results are bit-identical to telemetry=False: the vectors are
    # extra *outputs*, never inputs, of the superstep.
    telemetry: bool = False
    # Double-buffered boundary exchange (distributed runtime): superstep
    # k's board-level mailbox-value delivery is deferred into a second
    # mailbox bank and folded in at the start of superstep k+1, so the
    # collective exchange overlaps the next superstep's chip-local
    # compute.  Mailbox combining is commutative and nothing touches the
    # mailbox between the two fold points, so counters/trace/values are
    # bit-identical to the synchronous exchange — only the BSP time
    # accumulation changes (exchange cycles hidden under compute; see
    # costmodel._trace_time_s_parsed).  Monolithic runs have no board
    # exchange: the flag only tags their trace, time is unchanged.
    double_buffer: bool = False
    # Active-set compaction (0 = off): depth of the power-of-two window
    # capacity ladder.  With compaction=L the superstep is pre-traced
    # once per capacity in ``capacity_ladder(T, L)`` (T, T/4, ..., down
    # L rungs); each superstep counts the active tiles (pending mailbox
    # flags or open edge cursors) *on device* and ``lax.switch``es into
    # the smallest window that fits — zero added host syncs, the IQ/OQ
    # record stream shrinks from T*oq_cap to W*oq_cap rows.  Inactive
    # tiles contribute combine-identity work in the dense path, so every
    # bucket is bit-identical in values, counters and SuperstepTrace to
    # compaction=0 (the oracle; tests/test_compaction.py is the gate).
    compaction: int = 0
    # Fault tolerance (distributed runtime; 0 = off): checkpoint the
    # chunked-scan carry every this-many supersteps, at the chunk
    # host-accounting boundary the run loop already pays (zero extra
    # host syncs), through the atomic ``checkpoint/ckpt.py`` writer.  On
    # an injected chip loss (``runtime.fault.FaultInjector``) the run
    # re-shards the lost device's chip block onto the surviving devices
    # (``ExecMesh`` rebuild + ``runtime.elastic.reshard_checkpoint``),
    # rolls host accounting back to the snapshot and replays — final
    # values/counters/trace/supersteps are bit-identical to an unfailed
    # run, and the checkpoint/rollback/re-shard overhead is priced into
    # ``time_s`` so the reprice contract still holds exactly
    # (``costmodel.checkpoint_leg_cycles`` / ``recovery_waste_cycles``).
    ckpt_every_supersteps: int = 0

    @property
    def iq_cap(self) -> int:
        return self.iq_ratio * self.oq_cap

    @property
    def chunk_src(self) -> int:
        return self.grid.chunk_size(self.n_src)

    @property
    def chunk_dst(self) -> int:
        return self.grid.chunk_size(self.n_dst)


class DataLocalEngine:
    """Vectorised single-host engine: simulates the whole tile grid, with
    exact traffic accounting.  (The sharded multi-device rendering of the
    same schedule lives in ``core/collectives.py`` + ``launch/dryrun.py``.)

    The superstep kernel is *window-parametric*: with the default
    ``part=None`` the window is the whole grid (the monolithic engine);
    with a ``ChipPartition`` the same kernel executes one chip's subgrid
    at a time — local state, global tile ids and data indices — which is
    how ``distrib.driver`` runs one engine superstep per chip (vmapped or
    under ``shard_map``) and exchanges the off-window records between
    supersteps.
    """

    def __init__(self, app: AppSpec, cfg: EngineConfig,
                 row_lo: np.ndarray, row_hi: np.ndarray,
                 col_idx: np.ndarray, weights: Optional[np.ndarray] = None,
                 part: Optional[ChipPartition] = None):
        if cfg.backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown engine backend {cfg.backend!r}")
        self.app = app
        self.cfg = cfg
        grid = cfg.grid
        self.part = part if part is not None else ChipPartition(grid, 1, 1)
        self.n_chips = self.part.num_chips
        T = self.part.tiles_per_chip      # tiles per execution window
        self.T = T
        self.Tg = grid.num_tiles          # tiles in the whole grid
        self.Cs = cfg.chunk_src
        self.Cd = cfg.chunk_dst
        self.Ns = T * self.Cs             # window lengths (mono: == global)
        self.Nd = T * self.Cd
        self.Ngs = self.Tg * self.Cs      # global lengths / index sentinels
        self.Ngd = self.Tg * self.Cd
        self._cascade_levels = 0
        if cfg.proxy is not None:
            if T * cfg.proxy.slots >= 2**31:
                raise ValueError("T*slots must fit int32 for P$ sort keys")
            cfg.proxy.validate_window(self.part.sub_ny, self.part.sub_nx)
            casc = cfg.proxy.cascade
            if casc is not None and (not casc.selective
                                     or app.cascade_profitable):
                self._cascade_levels = casc.levels
        self._ladder = capacity_ladder(T, cfg.compaction)
        # per-source arrays padded to the *global* length; in chip mode the
        # driver partitions these into per-window slices before stepping.
        self.row_lo = jnp.asarray(_pad(row_lo, self.Ngs, 0), jnp.int32)
        self.row_hi = jnp.asarray(_pad(row_hi, self.Ngs, 0), jnp.int32)
        self.col_idx = jnp.asarray(col_idx, jnp.int32)
        if weights is None:
            weights = np.ones_like(col_idx, dtype=np.float32)
        self.weights = jnp.asarray(weights, jnp.float32)
        self._superstep = jax.jit(self._superstep_impl)
        self._chunk = jax.jit(self._chunk_impl, static_argnames=("length",))
        self._stat_names = None        # packed-stat layout, cached per engine
        self._n_seeds = 0              # set by init_state, read by sanitizer

    def chip_superstep(self, row_lo, row_hi, state, chip_id, flush,
                       active=None, window=None, pad_off_to=None):
        """One superstep of window ``chip_id``: pure in its array args so
        the distributed driver can vmap / shard_map it across chips.
        Returns (new_state, stats, off) where ``off`` is the dict of
        off-chip records (dst, val, mask) to exchange — ``None`` for a
        monolithic window.

        ``window=W`` (with ``active``, the (T,) active-tile mask) runs
        the active-set-compacted superstep: the IQ/OQ stages execute on
        a W-row compacted window, bit-identical to the dense path (the
        inactive tiles it skips are combine-identity no-ops).
        ``pad_off_to`` pads the off-chip record buffer with masked
        sentinels to the dense length so every compaction bucket of a
        ``lax.switch`` returns identical shapes (and the double-buffer
        bank size is unchanged)."""
        return self._step(row_lo, row_hi, state, chip_id, flush,
                          active=active, window=window,
                          pad_off_to=pad_off_to)

    def _require_mono(self, what: str):
        """init_state/activate_all/run build whole-grid state; with a
        multi-chip partition the per-window shapes differ and state
        handling lives in the driver."""
        if self.n_chips > 1:
            raise ValueError(
                f"{what} is monolithic-only; with a {self.n_chips}-chip "
                f"partition use distrib.DistributedEngine, which wraps "
                f"this engine's chip_superstep")

    # ---------------------------------------------------------------- state
    def init_state(self, seed_idx=None, seed_val=None,
                   values: Optional[np.ndarray] = None):
        self._require_mono("init_state")
        ident = jnp.float32(self.app.identity)
        st = dict(
            values=jnp.full((self.Nd,), ident) if values is None
            else jnp.asarray(_pad(values, self.Nd, self.app.identity), jnp.float32),
            mail_val=jnp.full((self.Nd,), ident),
            mail_flag=jnp.zeros((self.Nd,), jnp.bool_),
            cur_lo=jnp.zeros((self.Ns,), jnp.int32),
            cur_hi=jnp.zeros((self.Ns,), jnp.int32),
            cur_val=jnp.zeros((self.Ns,), jnp.float32),
        )
        if self.cfg.proxy is not None:
            tags, vals = make_pcache(self.cfg.grid, self.cfg.proxy,
                                     self.app.identity)
            st["p_tag"], st["p_val"] = tags, vals
        self._n_seeds = 0   # mailbox seeds, for the sanitizer's consumed-bound
        if seed_idx is not None:
            si = jnp.asarray(np.atleast_1d(seed_idx), jnp.int32)
            sv = jnp.asarray(np.atleast_1d(seed_val), jnp.float32)
            st["mail_val"] = st["mail_val"].at[si].set(sv)
            st["mail_flag"] = st["mail_flag"].at[si].set(True)
            self._n_seeds = int(si.shape[0])
        return st

    def activate_all(self, state, cur_val):
        """Epoch-style activation (PageRank/SPMV/Histogram): every source
        item starts with its full edge range and a carried value."""
        self._require_mono("activate_all")
        state = dict(state)
        state["cur_lo"] = self.row_lo
        state["cur_hi"] = self.row_hi
        state["cur_val"] = jnp.asarray(_pad(cur_val, self.Ns, 0.0), jnp.float32)
        return state

    # ------------------------------------------------------------ superstep
    def _superstep_impl(self, state, flush: jnp.ndarray):
        """Monolithic superstep: the whole grid as one window."""
        return self._step_mono(state, flush)

    def _step_mono(self, state, flush):
        """One monolithic superstep, dispatched through the compaction
        ladder: with ``compaction=0`` this is exactly the dense
        ``_step``; otherwise the active-tile count (computed on device
        from the carry — no host sync) picks the smallest pre-traced
        window branch via ``lax.switch``.  Every branch is bit-identical
        to the dense path; the extra ``active_tiles`` / ``bucket_cap``
        stats are pure telemetry outputs the fixed-key counter/trace
        accumulators ignore."""
        if len(self._ladder) <= 1:
            new_state, stats, _ = self._step(self.row_lo, self.row_hi,
                                             state, jnp.int32(0), flush)
            return new_state, stats
        active = self._active_tiles(state)
        n_act = jnp.sum(active.astype(jnp.int32))
        idx = bucket_index(n_act, self._ladder)

        def branch(w):
            def run(st, fl, act):
                return self._step(self.row_lo, self.row_hi, st,
                                  jnp.int32(0), fl, active=act, window=w)
            return run

        new_state, stats, _ = jax.lax.switch(
            idx, [branch(None if j == 0 else cap)
                  for j, cap in enumerate(self._ladder)],
            state, flush, active)
        stats = dict(stats, active_tiles=n_act.astype(jnp.float32),
                     bucket_cap=jnp.take(
                         jnp.asarray(self._ladder, jnp.float32), idx))
        return new_state, stats

    def _active_tiles(self, state):
        """(T,) mask of tiles with pending mailbox records or open edge
        cursors — the exact set the dense superstep does non-identity
        work on (reactivation only touches flagged tiles, so post-drain
        emission stays inside this set too)."""
        T = self.T
        mail = jnp.any(state["mail_flag"].reshape(T, self.Cd), axis=1)
        cur = jnp.any((state["cur_hi"] > state["cur_lo"])
                      .reshape(T, self.Cs), axis=1)
        return mail | cur

    def _edge_value(self, cval, pos):
        """Per-edge record value from the source cursor value and the
        edge position (shared by the dense and compacted emit fronts)."""
        app = self.app
        if app.edge_value == "add_w":
            return cval + self.weights[pos]
        if app.edge_value == "add_one":
            return cval + 1.0
        if app.edge_value == "mul_w":
            return cval * self.weights[pos]
        if app.edge_value == "carry":
            return cval
        if app.edge_value == "one":
            return jnp.ones_like(cval)
        raise ValueError(app.edge_value)

    def _front_dense(self, row_lo, row_hi, state, tile_gids):
        """Dense IQ drain + OQ emit over all T tiles (the oracle path).

        Returns (new_vals, mail_val, mail_flag, cur_lo, cur_hi, cur_val,
        consumed_vec, edges_vec, consumed_full, edges_full, dst, cand,
        emit_mask, src_tile): full-length state arrays, per-lane count
        vectors (here lane == tile), their (T,) per-tile renderings, and
        the flattened emission record stream."""
        app, cfg = self.app, self.cfg
        T, Cs, Cd = self.T, self.Cs, self.Cd
        is_min = app.combine == "min"
        ident = jnp.float32(app.identity)

        # ---- 1. IQ drain (budgeted mailbox consumption) -------------------
        flag2d = state["mail_flag"].reshape(T, Cd)
        csum = jnp.cumsum(flag2d.astype(jnp.int32), axis=1)
        take2d = flag2d & (csum <= cfg.iq_cap)
        take = take2d.reshape(-1)
        mval, vals = state["mail_val"], state["values"]
        if cfg.backend == "pallas":
            # fused relax kernel: combine + improvement detection in one
            # VMEM pass (same formulas as the jnp oracle below)
            from ..kernels import ops as kops
            new_vals, imp8 = kops.relax(vals, mval, take, combine=app.combine)
            improved = imp8.astype(bool)
        elif is_min:
            improved = take & (mval < vals)
            new_vals = jnp.where(improved, mval, vals)
        else:
            improved = take
            new_vals = jnp.where(take, vals + mval, vals)
        mail_flag = state["mail_flag"] & ~take
        mail_val = jnp.where(take, ident, mval)
        consumed_per_tile = jnp.sum(take2d, axis=1)

        cur_lo, cur_hi, cur_val = state["cur_lo"], state["cur_hi"], state["cur_val"]
        if app.reactivate:
            # an improving record restarts the item's edge cursor with the
            # new value (re-expansion of an already-visited item is the
            # engine's rendering of data staleness: measurable wasted work).
            re = improved[: self.Ns] if self.Nd == self.Ns else jnp.zeros(
                (self.Ns,), jnp.bool_)
            cur_lo = jnp.where(re, row_lo, cur_lo)
            cur_hi = jnp.where(re, row_hi, cur_hi)
            cur_val = jnp.where(re, new_vals[: self.Ns], cur_val)

        # ---- 2. OQ emit (budgeted edge streaming) -------------------------
        B = cfg.oq_cap
        rem2d = (cur_hi - cur_lo).reshape(T, Cs)
        prefix = jnp.cumsum(rem2d, axis=1)                    # inclusive
        capped = jnp.minimum(prefix, B)
        take_v2d = capped - jnp.concatenate(
            [jnp.zeros((T, 1), jnp.int32), capped[:, :-1]], axis=1)
        total_take = capped[:, -1]                            # (T,)
        b_idx = jnp.arange(B, dtype=jnp.int32)
        vslot = jax.vmap(
            functools.partial(jnp.searchsorted, side="right"),
            in_axes=(0, None))(capped, b_idx)
        vslot = jnp.minimum(vslot, Cs - 1)                    # (T, B)
        capped_prev = capped - take_v2d
        offset = b_idx[None, :] - jnp.take_along_axis(capped_prev, vslot, axis=1)
        vglob = vslot + jnp.arange(T, dtype=jnp.int32)[:, None] * Cs
        pos = cur_lo[vglob] + offset
        emit_mask = b_idx[None, :] < total_take[:, None]
        pos = jnp.clip(pos, 0, self.col_idx.shape[0] - 1)
        dst = self.col_idx[pos]
        cand = self._edge_value(cur_val[vglob], pos)
        cur_lo = cur_lo + (take_v2d.reshape(-1))

        # flatten records (tile ids are global; dst indices are global)
        R = T * B
        dst = dst.reshape(R)
        cand = cand.reshape(R)
        emit_mask = emit_mask.reshape(R)
        src_tile = jnp.repeat(tile_gids, B)
        return (new_vals, mail_val, mail_flag, cur_lo, cur_hi, cur_val,
                consumed_per_tile, total_take, consumed_per_tile,
                total_take, dst, cand, emit_mask, src_tile)

    def _front_compact(self, row_lo, row_hi, state, chip_id, active, W):
        """Compacted IQ drain + OQ emit over a W-tile active window.

        Active tiles are compacted (stably, preserving tile order) into
        the leading rows of a W-row window; every IQ/OQ tensor op then
        runs on (W, .) gathers instead of (T, .) and the emission record
        stream shrinks to W*oq_cap rows.  Invalid window lanes gather
        tile T-1's rows, so their mailbox flags and cursor ranges are
        forced to zero — otherwise an *active* tile T-1 would be drained
        and emitted twice — making them combine-identity no-ops, and the
        scatter-back drops them (sentinel row T, ``mode="drop"``).  Live
        records keep the dense path's tile-major relative order, so the
        downstream sorts, segment reductions and delivery scatters see
        the same live sequence: state, counters and trace stay
        bit-identical to ``_front_dense``.  Same return contract as
        ``_front_dense`` (per-lane count vectors are (W,); the (T,)
        renderings are scattered back only under telemetry)."""
        app, cfg = self.app, self.cfg
        T, Cs, Cd = self.T, self.Cs, self.Cd
        is_min = app.combine == "min"
        ident = jnp.float32(app.identity)
        w_valid, w_rows, rows_drop = _compact_window(active, W, T)

        # ---- 1. IQ drain on the window's mailbox rows ---------------------
        flagW2 = (state["mail_flag"].reshape(T, Cd)[w_rows]
                  & w_valid[:, None])
        csum = jnp.cumsum(flagW2.astype(jnp.int32), axis=1)
        takeW2 = flagW2 & (csum <= cfg.iq_cap)
        takeW = takeW2.reshape(-1)
        mval2 = state["mail_val"].reshape(T, Cd)
        vals2 = state["values"].reshape(T, Cd)
        mvalW = mval2[w_rows].reshape(-1)
        valsW = vals2[w_rows].reshape(-1)
        if cfg.backend == "pallas":
            from ..kernels import ops as kops
            nvW, imp8 = kops.relax(valsW, mvalW, takeW, combine=app.combine)
            improvedW = imp8.astype(bool)
        elif is_min:
            improvedW = takeW & (mvalW < valsW)
            nvW = jnp.where(improvedW, mvalW, valsW)
        else:
            improvedW = takeW
            nvW = jnp.where(takeW, valsW + mvalW, valsW)
        mail_flagW = flagW2.reshape(-1) & ~takeW
        mail_valW = jnp.where(takeW, ident, mvalW)
        consumedW = jnp.sum(takeW2, axis=1)

        # ---- cursors, windowed --------------------------------------------
        cur_lo2 = state["cur_lo"].reshape(T, Cs)
        cur_loW = cur_lo2[w_rows].reshape(-1)
        cur_hiW = state["cur_hi"].reshape(T, Cs)[w_rows].reshape(-1)
        cur_valW = state["cur_val"].reshape(T, Cs)[w_rows].reshape(-1)
        react = app.reactivate and self.Nd == self.Ns
        if react:
            # Cd == Cs here, so ``improvedW`` is laid out exactly like
            # the windowed cursor rows (the dense path's improved[:Ns])
            row_loW = row_lo.reshape(T, Cs)[w_rows].reshape(-1)
            row_hiW = row_hi.reshape(T, Cs)[w_rows].reshape(-1)
            cur_loW = jnp.where(improvedW, row_loW, cur_loW)
            cur_hiW = jnp.where(improvedW, row_hiW, cur_hiW)
            cur_valW = jnp.where(improvedW, nvW, cur_valW)

        # ---- 2. OQ emit from the window -----------------------------------
        B = cfg.oq_cap
        rem2d = jnp.where(w_valid[:, None],
                          (cur_hiW - cur_loW).reshape(W, Cs), 0)
        prefix = jnp.cumsum(rem2d, axis=1)                    # inclusive
        capped = jnp.minimum(prefix, B)
        take_v2d = capped - jnp.concatenate(
            [jnp.zeros((W, 1), jnp.int32), capped[:, :-1]], axis=1)
        total_take = capped[:, -1]                            # (W,)
        b_idx = jnp.arange(B, dtype=jnp.int32)
        vslot = jax.vmap(
            functools.partial(jnp.searchsorted, side="right"),
            in_axes=(0, None))(capped, b_idx)
        vslot = jnp.minimum(vslot, Cs - 1)                    # (W, B)
        capped_prev = capped - take_v2d
        offset = b_idx[None, :] - jnp.take_along_axis(capped_prev, vslot, axis=1)
        vglob = vslot + jnp.arange(W, dtype=jnp.int32)[:, None] * Cs
        pos = cur_loW[vglob] + offset
        emit_mask = b_idx[None, :] < total_take[:, None]
        pos = jnp.clip(pos, 0, self.col_idx.shape[0] - 1)
        dst = self.col_idx[pos]
        cand = self._edge_value(cur_valW[vglob], pos)
        cur_loW = cur_loW + (take_v2d.reshape(-1))

        # ---- ONE fused (W, .) scatter-back for the whole state ------------
        # Scatter cost on XLA CPU is per update ROW, so the six per-array
        # scatter-backs are stacked side by side into a single W-row
        # scatter.  Everything rides as f32 *bits*: the mailbox flag as
        # 0.0/1.0 (the != 0 reconstruction is exact), the int32 cursor
        # bounds bitcast (concat/scatter-set/slice are pure data movement
        # — no arithmetic touches the lanes, so the round-trip is
        # bit-exact for any pattern), values/mail_val/cur_val untouched.
        bc_f = lambda a: jax.lax.bitcast_convert_type(a, jnp.float32)
        bc_i = lambda a: jax.lax.bitcast_convert_type(a, jnp.int32)
        parts_T = [vals2, mval2,
                   state["mail_flag"].reshape(T, Cd).astype(jnp.float32),
                   bc_f(cur_lo2)]
        parts_W = [nvW.reshape(W, Cd), mail_valW.reshape(W, Cd),
                   mail_flagW.reshape(W, Cd).astype(jnp.float32),
                   bc_f(cur_loW.reshape(W, Cs))]
        if react:
            parts_T += [bc_f(state["cur_hi"].reshape(T, Cs)),
                        state["cur_val"].reshape(T, Cs)]
            parts_W += [bc_f(cur_hiW.reshape(W, Cs)),
                        cur_valW.reshape(W, Cs)]
        stacked = jnp.concatenate(parts_T, axis=1).at[rows_drop].set(
            jnp.concatenate(parts_W, axis=1), mode="drop")
        new_vals = stacked[:, :Cd].reshape(-1)
        mail_val = stacked[:, Cd:2 * Cd].reshape(-1)
        mail_flag = (stacked[:, 2 * Cd:3 * Cd] != 0).reshape(-1)
        c0 = 3 * Cd
        cur_lo = bc_i(stacked[:, c0:c0 + Cs]).reshape(-1)
        if react:
            cur_hi = bc_i(stacked[:, c0 + Cs:c0 + 2 * Cs]).reshape(-1)
            cur_val = stacked[:, c0 + 2 * Cs:c0 + 3 * Cs].reshape(-1)
        else:
            cur_hi, cur_val = state["cur_hi"], state["cur_val"]

        # flatten records (tile ids are global; dst indices are global)
        R = W * B
        dst = dst.reshape(R)
        cand = cand.reshape(R)
        emit_mask = emit_mask.reshape(R)
        src_tile = jnp.repeat(self.part.global_tile(chip_id, w_rows), B)
        if cfg.telemetry:    # (T,) per-tile renderings for the tv_* vectors
            consumed_full = jnp.zeros((T,), consumedW.dtype).at[rows_drop] \
                .set(consumedW, mode="drop")
            edges_full = jnp.zeros((T,), total_take.dtype).at[rows_drop] \
                .set(total_take, mode="drop")
        else:
            consumed_full = edges_full = None
        return (new_vals, mail_val, mail_flag, cur_lo, cur_hi, cur_val,
                consumedW, total_take, consumed_full, edges_full, dst,
                cand, emit_mask, src_tile)

    def _step(self, row_lo, row_hi, state, chip_id, flush, active=None,
              window=None, pad_off_to=None):
        app, cfg, grid = self.app, self.cfg, self.cfg.grid
        T, Cs, Cd = self.T, self.Cs, self.Cd
        is_min = app.combine == "min"
        ident = jnp.float32(app.identity)
        tile_gids = self.part.global_tile(
            chip_id, jnp.arange(T, dtype=jnp.int32))

        if window is None:
            (new_vals, mail_val, mail_flag, cur_lo, cur_hi, cur_val,
             consumed_vec, edges_vec, consumed_per_tile, edges_per_tile,
             dst, cand, emit_mask, src_tile) = self._front_dense(
                row_lo, row_hi, state, tile_gids)
        else:
            if active is None:
                active = self._active_tiles(state)
            (new_vals, mail_val, mail_flag, cur_lo, cur_hi, cur_val,
             consumed_vec, edges_vec, consumed_per_tile, edges_per_tile,
             dst, cand, emit_mask, src_tile) = self._front_compact(
                row_lo, row_hi, state, chip_id, active, window)
        vals = state["values"]
        owner = jnp.minimum(dst // Cd, self.Tg - 1)

        # per-lane maxima/sums equal the dense per-tile ones: compacted
        # lanes cover every tile with nonzero work, and the counts the
        # window drops are exact zeros (max over non-negatives, sums)
        stats = dict(edges_processed=jnp.sum(edges_vec),
                     records_consumed=jnp.sum(consumed_vec),
                     compute_per_tile_max=jnp.max(
                         consumed_vec * PU_OPS_PER_RECORD
                         + edges_vec * PU_OPS_PER_EDGE),
                     filtered_at_proxy=jnp.float32(0.0),
                     coalesced_at_proxy=jnp.float32(0.0),
                     cascade_combined=jnp.float32(0.0))

        p_tag = state.get("p_tag")
        p_val = state.get("p_val")

        if cfg.proxy is None:
            (mail_val, mail_flag, owner_leg, off_ch, per_tile,
             off) = self._drain_to_owners(
                mail_val, mail_flag, dst, cand, emit_mask, src_tile,
                chip_id, None, is_min)
            dmax = jnp.max(per_tile)
            charges = dict(netstats.merge_charges(owner_leg, off_ch),
                           owner_msgs=owner_leg["messages"],
                           owner_hop_msgs=owner_leg["hop_msgs"])
        else:
            (mail_val, mail_flag, p_tag, p_val, charges, pstats, dmax,
             off) = self._proxy_stage(
                mail_val, mail_flag, p_tag, p_val, dst, cand, emit_mask,
                src_tile, owner, flush, is_min, ident, chip_id, tile_gids)
            stats.update(pstats)

        # ---- P$ flush (write-back): emit all resident entries to owners --
        new_state = dict(values=new_vals, mail_val=mail_val,
                         mail_flag=mail_flag, cur_lo=cur_lo, cur_hi=cur_hi,
                         cur_val=cur_val)
        if p_tag is not None:
            new_state["p_tag"], new_state["p_val"] = p_tag, p_val

        pending = (jnp.sum(new_state["mail_flag"])
                   + jnp.sum(new_state["cur_hi"] > new_state["cur_lo"]))
        stats["pending"] = pending
        # write-back P$ residency is *deferred* work: it does not keep the
        # engine busy, but must be flushed before the result is final.
        if p_tag is not None and self.cfg.proxy.write_back:
            stats["p_resident"] = jnp.sum(new_state["p_tag"] >= 0)
        else:
            stats["p_resident"] = jnp.int32(0)
        stats["delivered_max_per_tile"] = dmax
        stats.update({k: jnp.asarray(v, jnp.float32) for k, v in charges.items()})
        if cfg.telemetry:
            # per-tile load vectors (window-local), pure extra outputs:
            # they ride the chunk stat fetch (obs.timeline) and feed
            # obs.imbalance; the distributed driver reduces them to
            # per-chip pc_* vectors in _aggregate.  The proxy stage set
            # tv_delivered already (its delivery vector is internal).
            stats["tv_edges"] = edges_per_tile.astype(jnp.float32)
            stats["tv_records"] = consumed_per_tile.astype(jnp.float32)
            if "tv_delivered" not in stats:
                stats["tv_delivered"] = per_tile.astype(jnp.float32)
        if cfg.sanitize:
            # On-device sanitizer: count invariant violations this
            # superstep (checkify-style — observed, not branched on, so
            # the computation is unchanged).  The run loop raises
            # SanitizerError on a nonzero count.  Saturated f32: the
            # stat rides the packed row and only zero/nonzero matters.
            bad = jnp.int32(0)
            if is_min:
                # relaxation is monotone: a value may never increase
                bad += jnp.sum((new_vals > vals).astype(jnp.int32))
            # an unflagged mailbox slot must hold the combine identity
            bad += jnp.sum((~new_state["mail_flag"]
                            & (new_state["mail_val"] != ident))
                           .astype(jnp.int32))
            # edge cursors may never go negative-length
            bad += jnp.sum((new_state["cur_hi"]
                            < new_state["cur_lo"]).astype(jnp.int32))
            bad += jnp.sum(jnp.isnan(new_state["values"])
                           .astype(jnp.int32))
            stats["sanity_violations"] = jnp.minimum(
                bad, 2 ** 20).astype(jnp.float32)
        if off is not None and pad_off_to is not None:
            # pad the off-chip buffer with masked sentinels to the dense
            # length so every compaction bucket returns identical shapes
            # (masked rows are dropped at the exchange scatter; the live
            # records keep their order, so delivery is bit-identical)
            pad = int(pad_off_to) - off["dst"].shape[0]
            if pad > 0:
                off = dict(
                    dst=jnp.concatenate(
                        [off["dst"],
                         jnp.full((pad,), self.Ngd, jnp.int32)]),
                    val=jnp.concatenate(
                        [off["val"], jnp.full((pad,), ident, jnp.float32)]),
                    mask=jnp.concatenate(
                        [off["mask"], jnp.zeros((pad,), jnp.bool_)]))
        return new_state, stats, off

    # ------------------------------------------------------- owner delivery
    def _drain_to_owners(self, mail_val, mail_flag, dst, val, mask, src,
                         chip_id, region_dims, is_min):
        """Charge the owner-bound leg, deliver on-window records into the
        local mailboxes, and split off-window records for the exchange.

        ``dst``/``src`` are global; the local mailbox index of an
        on-window record is recovered from the owner's in-chip position.
        Returns (mail_val, mail_flag, owner_leg_charge, off_chip_charge,
        delivered_per_tile, off_records) — ``delivered_per_tile`` is the
        (T,) count vector (callers max it into endpoint contention, or
        sum it across delivery legs of the same superstep first);
        ``off_records`` is None for a monolithic window (nothing can
        leave it).
        """
        part, Cd = self.part, self.Cd
        owner = jnp.minimum(dst // Cd, self.Tg - 1)
        owner_leg = netstats.charge(self.cfg.grid, src, owner, mask,
                                    region_dims=region_dims)
        if self.n_chips == 1:
            mail_val, mail_flag, per_tile = _deliver(
                mail_val, mail_flag, dst, val, mask, owner, self.T,
                self.Nd, is_min, backend=self.cfg.backend)
            return mail_val, mail_flag, owner_leg, {}, per_tile, None
        on_chip = part.chip_of_tile(owner) == chip_id
        on = mask & on_chip
        off_mask = mask & ~on_chip
        lowner = part.local_tile(owner)
        ldst = lowner * Cd + dst % Cd
        mail_val, mail_flag, per_tile = _deliver(
            mail_val, mail_flag, ldst, val, on, lowner, self.T, self.Nd,
            is_min, backend=self.cfg.backend)
        off_ch = netstats.charge_off_chip(part, src, owner, off_mask)
        off = dict(dst=jnp.where(off_mask, dst, self.Ngd), val=val,
                   mask=off_mask)
        return mail_val, mail_flag, owner_leg, off_ch, per_tile, off

    # --------------------------------------------------------- proxy stage
    def _proxy_stage(self, mail_val, mail_flag, p_tag, p_val, dst, cand,
                     emit_mask, src_tile, owner, flush, is_min, ident,
                     chip_id, tile_gids):
        cfg, grid = self.cfg, self.cfg.grid
        pcfg = cfg.proxy
        T = self.T
        S = pcfg.slots

        ptile = proxy_tile(grid, pcfg, owner, src_tile)
        leg1 = netstats.charge(grid, src_tile, ptile, emit_mask)
        # the sender's region is window-local by construction, so the
        # proxy tile always lies on this chip — index P$ by local tile.
        ptile_l = self.part.local_tile(ptile)

        slot = pcache_slot(pcfg, dst)
        key = jnp.where(emit_mask, ptile_l * S + slot, T * S)  # sentinel at end
        dkey = jnp.where(emit_mask, dst, self.Ngd)
        (skey, sdst, smask, (scand,),
         new_slot, new_dst, gid) = _lex_group(key, dkey, emit_mask, cand)
        gagg = self._segment_reduce(scand, smask, gid, is_min)
        combined = gagg[gid]                                   # per-record view
        n_leaders = jnp.sum(new_dst)
        coalesced = jnp.sum(smask) - n_leaders

        winner = new_slot                                      # first dst-group per slot
        bypass = new_dst & ~new_slot                           # batch slot conflicts

        wtile = jnp.minimum(skey // S, T - 1)
        wslot = skey % S
        cur_tag = p_tag[wtile, wslot]
        cur_pv = p_val[wtile, wslot]
        tag_hit = winner & (cur_tag == sdst)
        if is_min:
            improves = combined < cur_pv
        else:
            improves = jnp.ones_like(cur_pv, dtype=bool)
        filtered = tag_hit & ~improves                         # absorbed
        upd_hit = tag_hit & improves
        miss = winner & ~tag_hit
        evict = miss & (cur_tag >= 0) & pcfg.write_back        # flush resident

        if is_min:
            new_pv_hit = jnp.minimum(cur_pv, combined)
        else:
            new_pv_hit = cur_pv + combined
        inst_val = jnp.where(upd_hit, new_pv_hit, combined)
        do_write = upd_hit | miss
        # Scatter P$ updates.  Only winner records write, and there is at
        # most one winner per (tile, slot) per superstep; non-writers are
        # redirected one row past the end and dropped at the scatter
        # (mode="drop"), so no duplicate index can clobber a winner's
        # write (XLA scatter order with dupes is undefined) and the P$ is
        # never copy-padded.
        wtile_safe = jnp.where(do_write, wtile, T)
        p_tag = p_tag.at[wtile_safe, wslot].set(sdst, mode="drop")
        p_val = p_val.at[wtile_safe, wslot].set(inst_val, mode="drop")

        # forwarding set
        if pcfg.write_back:
            fwd_now = bypass                                   # only conflicts bypass
        else:
            fwd_now = upd_hit | miss | bypass                  # write-through
        fdst = jnp.where(fwd_now, sdst, self.Ngd)
        fval = jnp.where(fwd_now, combined, ident)
        # evicted residents (write-back) also forward
        edst = jnp.where(evict, cur_tag, self.Ngd)
        eval_ = jnp.where(evict, cur_pv, ident)

        rdims = (pcfg.region_ny, pcfg.region_nx)
        ncomb = jnp.float32(0.0)
        proxy_src = self.part.global_tile(chip_id,
                                          jnp.minimum(skey // S, T - 1))
        # The whole-P$ flush wave travels with the direct legs only when
        # a non-selective cascade must merge them in one tree walk; in
        # every other mode the flush drain runs in its own lax.cond leg
        # (_flush_drain) so the frequent non-flush supersteps never touch
        # the (T*S,) flush-shaped arrays — on write-back apps those
        # masked no-op legs dominated the superstep.
        split_flush = pcfg.write_back and (
            self._cascade_levels == 0 or pcfg.cascade.selective)

        all_dst = [fdst, edst]
        all_val = [fval, eval_]
        all_src = [proxy_src] * 2
        if pcfg.write_back and not split_flush:
            # non-selective cascade: flush records climb the reduction
            # tree together with the direct legs (they may merge), so
            # they stay in the shared cat, masked on non-flush steps
            def flushed(args):
                p_tag_, p_val_ = args
                ft = p_tag_.reshape(-1)
                fv = p_val_.reshape(-1)
                return ft, fv, jnp.full_like(ft, -1), jnp.full(fv.shape,
                                                               ident)

            def not_flushed(args):
                p_tag_, p_val_ = args
                z = jnp.full((T * S,), -1, jnp.int32)
                return (z, jnp.full((T * S,), ident), p_tag_.reshape(-1),
                        p_val_.reshape(-1))

            ftags, fvals, keep_t, keep_v = jax.lax.cond(
                flush, flushed, not_flushed, (p_tag, p_val))
            p_tag = keep_t.reshape(T, S)
            p_val = keep_v.reshape(T, S)
            all_dst.append(jnp.where(ftags >= 0, ftags, self.Ngd))
            all_val.append(jnp.where(ftags >= 0, fvals, ident))
            all_src.append(jnp.repeat(tile_gids, S))
        cat_dst = jnp.concatenate(all_dst)
        cat_val = jnp.concatenate(all_val)
        cat_src = jnp.concatenate(all_src)
        cat_mask = cat_dst < self.Ngd

        lvl_max = jnp.float32(0.0)
        if self._cascade_levels and not split_flush:
            # Cascaded drain: level-by-level through the region reduction
            # tree instead of straight to the owners (write-through apps
            # cascade their full forward set; non-selective write-back
            # cascades direct legs + flush wave together).
            eligible = jnp.ones(cat_dst.shape[0], bool)
            (mail_val, mail_flag, leg2, owner_leg, per_tile, lvl_max,
             ncomb, off) = self._cascade_drain(
                mail_val, mail_flag, cat_dst, cat_val, cat_src, cat_mask,
                eligible, is_min, chip_id)
        else:
            (mail_val, mail_flag, owner_leg, off_ch, per_tile,
             off) = self._drain_to_owners(
                mail_val, mail_flag, cat_dst, cat_val, cat_mask, cat_src,
                chip_id, rdims, is_min)
            leg2 = netstats.merge_charges(owner_leg, off_ch)

        if split_flush:
            (p_tag, p_val, mail_val, mail_flag, flush_leg, f_owner_leg,
             f_per_tile, f_lvl_max, f_ncomb, f_off) = self._flush_drain(
                flush, p_tag, p_val, mail_val, mail_flag, tile_gids,
                ident, is_min, chip_id, rdims)
            leg2 = netstats.merge_charges(leg2, flush_leg)
            owner_leg = netstats.merge_charges(owner_leg, f_owner_leg)
            per_tile = per_tile + f_per_tile     # same-phase deliveries sum
            lvl_max = jnp.maximum(lvl_max, f_lvl_max)
            ncomb = ncomb + f_ncomb
            if off is not None:
                off = {k: jnp.concatenate([off[k], f_off[k]]) for k in off}

        dmax = jnp.maximum(jnp.max(per_tile), lvl_max)
        charges = dict(netstats.merge_charges(leg1, leg2),
                       owner_msgs=owner_leg["messages"],
                       owner_hop_msgs=owner_leg["hop_msgs"])
        pstats = dict(filtered_at_proxy=jnp.sum(filtered).astype(jnp.float32),
                      coalesced_at_proxy=coalesced.astype(jnp.float32),
                      cascade_combined=ncomb)
        if cfg.telemetry:
            # owner-delivery counts per tile (direct + flush legs summed)
            pstats["tv_delivered"] = per_tile.astype(jnp.float32)
        return mail_val, mail_flag, p_tag, p_val, charges, pstats, dmax, off

    # --------------------------------------------------------- flush drain
    def _flush_drain(self, flush, p_tag, p_val, mail_val, mail_flag,
                     tile_gids, ident, is_min, chip_id, rdims):
        """Write-back whole-P$ spill as its own ``lax.cond`` leg.

        Only actual flush supersteps execute the (T*S,) record drain
        (charge + cascade/deliver + P$ clear); the common non-flush
        superstep takes the no-op branch.  Counter/trace effects are
        identical to draining masked flush arrays every step — a fully
        masked leg charges zero and delivers nothing — so this is pure
        superstep-time savings on write-back apps.  Returns
        (p_tag, p_val, mail_val, mail_flag, merged_leg, owner_leg,
        per_tile, level_max, n_combined, off_records).
        """
        T, S = self.T, self.cfg.proxy.slots
        multi = self.n_chips > 1
        charge_keys = ("messages", "hop_msgs", "intra_die_hops",
                       "inter_die_crossings", "inter_pkg_crossings",
                       "cross_region_msgs")

        def zero_leg(with_off):
            z = {k: jnp.float32(0.0) for k in charge_keys}
            if with_off and multi:
                z["off_chip_msgs"] = jnp.float32(0.0)
                z["off_chip_hop_msgs"] = jnp.float32(0.0)
            return z

        def do_flush(p_tag, p_val, mail_val, mail_flag):
            ft = p_tag.reshape(-1)
            fv = p_val.reshape(-1)
            fmask = ft >= 0
            fdst = jnp.where(fmask, ft, self.Ngd)
            fval = jnp.where(fmask, fv, ident)
            fsrc = jnp.repeat(tile_gids, S)
            cleared_t = jnp.full_like(p_tag, -1)
            cleared_v = jnp.full_like(p_val, ident)
            if self._cascade_levels:
                # selective write-back: the dense flush wave is exactly
                # the record set that profits from the reduction tree
                (mail_val, mail_flag, leg, owner_leg, per_tile, lvl_max,
                 ncomb, off) = self._cascade_drain(
                    mail_val, mail_flag, fdst, fval, fsrc, fmask,
                    jnp.ones_like(fmask), is_min, chip_id)
            else:
                (mail_val, mail_flag, owner_leg, off_ch, per_tile,
                 off) = self._drain_to_owners(
                    mail_val, mail_flag, fdst, fval, fmask, fsrc,
                    chip_id, rdims, is_min)
                leg = netstats.merge_charges(owner_leg, off_ch)
                lvl_max = jnp.float32(0.0)
                ncomb = jnp.float32(0.0)
            return (cleared_t, cleared_v, mail_val, mail_flag, leg,
                    owner_leg, per_tile.astype(jnp.float32), lvl_max,
                    ncomb, off)

        def no_flush(p_tag, p_val, mail_val, mail_flag):
            off = None if self.n_chips == 1 else dict(
                dst=jnp.full((self._flush_off_len(),), self.Ngd,
                             jnp.int32),
                val=jnp.full((self._flush_off_len(),), ident, jnp.float32),
                mask=jnp.zeros((self._flush_off_len(),), bool))
            return (p_tag, p_val, mail_val, mail_flag,
                    zero_leg(with_off=True), zero_leg(with_off=False),
                    jnp.zeros((T,), jnp.float32), jnp.float32(0.0),
                    jnp.float32(0.0), off)

        out = jax.lax.cond(flush, do_flush, no_flush,
                           p_tag, p_val, mail_val, mail_flag)
        return out

    def _flush_off_len(self) -> int:
        """Length of the flush leg's off-chip record buffer: the T*S
        flush wave, replicated per cascade output leg (the direct copy,
        one selective early-exit copy per level, and the tree-root exit —
        matching _cascade_drain's concatenation)."""
        base = self.T * self.cfg.proxy.slots
        if not self._cascade_levels:
            return base
        return base * (2 + self._cascade_levels)

    # ------------------------------------------------------- cascaded drain
    def _cascade_drain(self, mail_val, mail_flag, dst, val, src, mask,
                       eligible, is_min, chip_id):
        """Drain proxy-stage output through the region reduction tree.

        Records climb from their region proxy to the same-index proxy of
        the enclosing super-region at each level, merging with records
        from sibling regions bound for the same destination; only tree
        roots (or selective early exits) forward to the true owner.  Each
        leg is charged exact XY hops; endpoint contention at intermediate
        proxies feeds the BSP time model.  Records with ``eligible=False``
        skip the tree and go straight to their owner.

        Returns (mail_val, mail_flag, merged_charges, owner_leg_charge,
        delivered_per_tile, level_recv_max, n_combined, off_records) —
        ``delivered_per_tile`` is the final owner-delivery count vector
        (summable with other same-superstep delivery legs before the
        max); ``level_recv_max`` the per-proxy receive contention of the
        tree levels.
        """
        cfg, grid = self.cfg, self.cfg.grid
        pcfg = cfg.proxy
        casc = pcfg.cascade
        T = self.T
        rdims = (pcfg.region_ny, pcfg.region_nx)

        cur = jnp.minimum(src, self.Tg - 1)
        alive = mask & eligible
        owner = jnp.minimum(dst // self.Cd, self.Tg - 1)
        legs = []
        out_dst = [dst]
        out_val = [val]
        out_src = [cur]
        out_mask = [mask & ~eligible]
        ncomb = jnp.float32(0.0)
        lvl_max = jnp.float32(0.0)

        for level in range(1, self._cascade_levels + 1):
            rny, rnx = casc.level_dims(pcfg.region_ny, pcfg.region_nx, level)
            if casc.selective:
                # selective exit: once the owner lies inside the record's
                # level-`level` super-region, climbing further cannot merge
                # it with updates from other subtrees on a shorter path —
                # it leaves the tree and goes straight to the owner.
                near = alive & (grid.region_id(cur, rny, rnx)
                                == grid.region_id(owner, rny, rnx))
                out_dst.append(dst)
                out_val.append(val)
                out_src.append(cur)
                out_mask.append(near)
                alive = alive & ~near
            ptile = cascade_proxy_tile(grid, rny, rnx, owner, cur)
            ptile_l = self.part.local_tile(ptile)
            legs.append(netstats.charge(grid, cur, ptile, alive,
                                        region_dims=rdims))
            recv = jax.ops.segment_sum(alive.astype(jnp.float32),
                                       jnp.where(alive, ptile_l, T),
                                       num_segments=T + 1)[:T]
            lvl_max = jnp.maximum(lvl_max, jnp.max(recv))
            cur, dst, val, owner, alive, merged = self._combine_level(
                ptile_l, dst, val, alive, is_min, chip_id)
            ncomb = ncomb + merged

        out_dst.append(dst)
        out_val.append(val)
        out_src.append(cur)
        out_mask.append(alive)
        cat_dst = jnp.concatenate(out_dst)
        cat_val = jnp.concatenate(out_val)
        cat_src = jnp.concatenate(out_src)
        cat_mask = jnp.concatenate(out_mask)
        (mail_val, mail_flag, owner_leg, off_ch, per_tile,
         off) = self._drain_to_owners(
            mail_val, mail_flag, cat_dst, cat_val, cat_mask, cat_src,
            chip_id, rdims, is_min)
        legs.append(owner_leg)
        legs.append(off_ch)
        return (mail_val, mail_flag, netstats.merge_charges(*legs),
                owner_leg, per_tile, lvl_max, ncomb, off)

    def _combine_level(self, ptile_l, dst, val, alive, is_min, chip_id):
        """Merge records that meet at the same (proxy tile, dst) of one
        cascade level into a single combined record (leaders survive).

        Same single-sort lexicographic grouping (``_lex_group``) as the
        P$ batch coalesce; masked records carry sentinel keys and sort to
        the end.  Grouping keys use the window-local proxy tile; the
        surviving records' source tiles are returned as global ids.
        Returns the level's outputs in sorted order plus the merge count.
        """
        T = self.T
        tkey = jnp.where(alive, ptile_l, T)
        dkey = jnp.where(alive, dst, self.Ngd)
        (stile, sdst, salive, (sval,),
         _, leader, gid) = _lex_group(tkey, dkey, alive, val)
        agg = self._segment_reduce(sval, salive, gid, is_min)
        nval = agg[gid]
        merged = (jnp.sum(salive) - jnp.sum(leader)).astype(jnp.float32)
        cur = self.part.global_tile(chip_id, jnp.minimum(stile, T - 1))
        owner = jnp.minimum(sdst // self.Cd, self.Tg - 1)
        return cur, sdst, nval, owner, leader, merged

    def _segment_reduce(self, sval, smask, gid, is_min):
        """Combine same-group record values (``gid`` sorted ascending,
        from ``_lex_group``) into one value per group.  The jnp path is
        the oracle; ``backend='pallas'`` routes through the dense
        ``segment_combine`` kernel (masked records become padding)."""
        R = gid.shape[0]
        if self.cfg.backend == "pallas":
            from ..kernels import ops as kops
            return kops.segment_combine(jnp.where(smask, gid, -1), sval, R,
                                        combine="min" if is_min else "add")
        if is_min:
            return jax.ops.segment_min(jnp.where(smask, sval, INF), gid,
                                       num_segments=R,
                                       indices_are_sorted=True)
        return jax.ops.segment_sum(jnp.where(smask, sval, 0.0), gid,
                                   num_segments=R, indices_are_sorted=True)

    # ------------------------------------------------------- chunked stepping
    def _chunk_step_one(self, st, fl):
        """One monolithic superstep as a (state, stats) pair — the scan
        body unit of the chunked run loop (compaction-ladder dispatched,
        like the per-step path)."""
        return self._step_mono(st, fl)

    def _chunk_impl(self, state, flush, done, steps_left, *, length: int):
        """Scan ``length`` monolithic supersteps in one device dispatch
        (see :func:`_scan_steps` for the carry/termination contract)."""
        write_back = self.cfg.proxy is not None and self.cfg.proxy.write_back
        return _scan_steps(self._chunk_step_one, state, flush, done,
                           steps_left, length, write_back)

    # ----------------------------------------------------------------- run
    def run(self, state, max_supersteps: Optional[int] = None,
            progress_every: int = 0, chunk: Optional[int] = None,
            observer=None):
        """Run supersteps until drained; returns (state, RunResult).

        ``chunk`` overrides ``EngineConfig.run_chunk``: supersteps per
        device dispatch.  ``chunk=0`` selects the legacy per-step loop
        (one host sync per superstep — the benchmark baseline); any K>=1
        scans K supersteps per dispatch with identical results.
        ``progress_every`` reports at chunk granularity: the first chunk
        boundary at or past each multiple prints the true executed
        superstep count.

        ``observer`` (obs.timeline.Observer) receives ``on_run_start``
        with the run's :class:`~repro.obs.timeline.RunMeta`, one
        ``on_chunk`` span per chunk (per superstep on the legacy loop) at
        the existing host-accounting boundary, and ``on_run_end`` with
        the RunResult.  Attaching one adds no host syncs and leaves
        counters/trace/final state bit-identical."""
        self._require_mono("run")
        cfg = self.cfg
        maxs = max_supersteps or cfg.max_supersteps
        K = cfg.run_chunk if chunk is None else int(chunk)
        counters = TrafficCounters()
        trace = SuperstepTrace(double_buffer=cfg.double_buffer)
        cycles = 0.0
        steps = 0
        pkg = cfg.pkg
        links = link_provisioning(cfg.grid, pkg)
        values_before = state["values"] if cfg.sanitize else None
        if observer is not None:
            observer.on_run_start(RunMeta(
                app=self.app.name, grid_ny=cfg.grid.ny, grid_nx=cfg.grid.nx,
                chunk=K, backend=cfg.backend, sanitize=cfg.sanitize,
                telemetry=cfg.telemetry, pkg=pkg, grid=cfg.grid))

        def account(stats):
            """Legacy-loop per-superstep accounting.  The chunked branch
            uses the vectorized twin (chunk_counters / append_chunk /
            add_chunk_cycles below) — edit BOTH in lockstep; the
            bit-identity tests in tests/test_chunked.py are the gate."""
            nonlocal cycles
            _sanitize_gate(cfg, self.app.name,
                           float(stats.get("sanity_violations", 0.0)))
            counters.add(superstep_counters(stats))
            trace.append_step(stats, element_bits=cfg.element_bits)
            # ---- BSP time model for this superstep ----------------------
            step_cycles = superstep_cycles(stats, pkg, links)
            if step_cycles > 0 or stats["pending"] > 0:
                cycles += step_cycles + links["diameter"] * 0.5  # pipeline fill

        if K <= 0:
            state, steps = self._run_legacy(state, maxs, progress_every,
                                            account, observer=observer)
        else:
            progress = _ProgressReporter(self.app.name, progress_every,
                                         sanitize=cfg.sanitize,
                                         tiles=self.T)
            fill = links["diameter"] * 0.5
            if self._stat_names is None:   # one abstract trace per engine
                self._stat_names = _stat_keys(self._chunk_step_one, state,
                                              jnp.zeros((), jnp.bool_))

            def add_chunk_cycles(stacked, n_act, cycles):
                # vectorized BSP terms, accumulated in execution order —
                # bit-identical to account() per step
                if cfg.sanitize:
                    bad = stacked.get("sanity_violations")
                    if bad is not None:
                        _sanitize_gate(cfg, self.app.name,
                                       float(np.sum(bad[:n_act])))
                sc = chunk_cycles(stacked, n_act, pkg, links)
                pend = np.asarray(stacked["pending"][:n_act])
                for s, p in zip(sc.tolist(), pend.tolist()):
                    if s > 0 or p > 0:
                        cycles += s + fill
                return cycles

            chunk_fn = functools.partial(self._chunk, length=K)
            state, steps, cycles = _drain_chunked(
                chunk_fn, state, maxs, self._stat_names, counters, trace,
                cfg.element_bits, progress, add_chunk_cycles, cycles,
                observer=observer)
        counters.supersteps = steps
        time_s = cycles / (CLOCK_GHZ * 1e9)
        result = RunResult(counters=counters, cycles=cycles, time_s=time_s,
                           supersteps=steps, trace=trace)
        if cfg.sanitize:
            from ..analysis import invariants as _inv
            write_back = cfg.proxy is not None and cfg.proxy.write_back
            findings = _inv.check_run(
                result, pkg=pkg, grid=cfg.grid,
                where=f"sanitize/{self.app.name}", write_back=write_back,
                seeds=self._n_seeds, combine=self.app.combine,
                values_before=values_before, values_after=state["values"],
                drained=steps < maxs)
            _inv.assert_clean(findings, context=f"run({self.app.name})")
        if observer is not None:
            observer.on_run_end(result)
        return state, result

    def _run_legacy(self, state, maxs, progress_every, account,
                    observer=None):
        """The seed per-step loop: one dispatch + one host sync per
        superstep.  Kept as the measured baseline for the chunked loop
        (``benchmarks/engine_throughput.py``) and its bit-identity tests.
        With an ``observer``, each superstep emits one single-step
        :class:`~repro.obs.timeline.ChunkSpan` at the per-step host sync
        this loop already pays."""
        cfg = self.cfg
        write_back = cfg.proxy is not None and cfg.proxy.write_back
        sync_ctr = default_registry().counter("engine.host_syncs")
        steps = 0
        flush_flag = jnp.asarray(False)
        while steps < maxs:
            t0 = time.perf_counter()
            state, stats = self._superstep(state, flush_flag)
            t1 = time.perf_counter()
            stats = jax.device_get(stats)
            sync_ctr.inc()
            t2 = time.perf_counter()
            steps += 1
            account(stats)
            t3 = time.perf_counter()
            if observer is not None:
                observer.on_chunk(_legacy_span(steps, stats, (t0, t1),
                                               (t1, t2), (t2, t3)))
            if flush_flag:
                flush_flag = jnp.asarray(False)
            if stats["pending"] == 0:
                # live work drained; spill any write-back P$ residue (the
                # paper's TSU heuristic: flush when queues/buffers go idle).
                # Repeated flushes terminate: a spilled value that does not
                # improve its owner generates no new work.
                if write_back and stats["p_resident"] > 0:
                    flush_flag = jnp.asarray(True)
                    continue
                break
            if progress_every and steps % progress_every == 0:
                print(f"  [{self.app.name}] step {steps} pending={stats['pending']:.0f}")
        return state, steps


@dataclasses.dataclass
class RunResult:
    counters: TrafficCounters
    cycles: float
    time_s: float
    supersteps: int
    # per-superstep level-traffic record: what makes the run re-priceable
    # under other package configs (costmodel.price(per_superstep_peak=...))
    trace: Optional[SuperstepTrace] = None


def _sanitize_gate(cfg, app_name: str, violations: float) -> None:
    """Raise on a nonzero on-device ``sanity_violations`` count (the
    ``EngineConfig.sanitize`` per-superstep checks computed in ``_step``).
    Shared by the legacy per-step and chunked accounting paths of both
    run loops."""
    if cfg.sanitize and violations > 0:
        from ..analysis.invariants import SanitizerError
        raise SanitizerError(
            f"sanitizer: {violations:.0f} on-device invariant violation(s) "
            f"during {app_name} (monotone relaxation / mailbox consistency "
            f"/ NaN checks in the superstep body)")


def superstep_counters(stats) -> TrafficCounters:
    """One superstep's measured traffic as a TrafficCounters delta.
    Shared by the monolithic and distributed run loops so the two paths
    cannot drift in which fields they accumulate."""
    return TrafficCounters(
        messages=stats["messages"], hop_msgs=stats["hop_msgs"],
        owner_msgs=stats["owner_msgs"],
        owner_hop_msgs=stats["owner_hop_msgs"],
        intra_die_hops=stats["intra_die_hops"],
        inter_die_crossings=stats["inter_die_crossings"],
        inter_pkg_crossings=stats["inter_pkg_crossings"],
        filtered_at_proxy=stats["filtered_at_proxy"],
        coalesced_at_proxy=stats["coalesced_at_proxy"],
        cascade_combined=stats.get("cascade_combined", 0.0),
        cross_region_msgs=stats.get("cross_region_msgs", 0.0),
        off_chip_msgs=stats.get("off_chip_msgs", 0.0),
        off_chip_hop_msgs=stats.get("off_chip_hop_msgs", 0.0),
        edges_processed=stats["edges_processed"],
        records_consumed=stats["records_consumed"], supersteps=1)


def superstep_cycles(stats, pkg, links: dict) -> float:
    """BSP cycles of one superstep: max over (tile compute, per-level
    network serialization, endpoint contention).  The distributed runtime
    maxes the board-level leg on top of this.  (Thin wrapper around
    ``costmodel.step_cycles`` so the run loops and analytic re-pricing
    cannot drift; ``link_provisioning`` also lives in costmodel now.)"""
    bits = MSG_BITS
    return float(step_cycles(
        pkg, links,
        compute_ops=float(stats["compute_per_tile_max"]),
        intra_bits=float(stats["intra_die_hops"]) * bits,
        die_bits=float(stats["inter_die_crossings"]) * bits,
        pkg_bits=float(stats["inter_pkg_crossings"]) * bits,
        endpoint_bits=float(stats["delivered_max_per_tile"]) * bits))


def chunk_counters(stacked, n_active: int) -> TrafficCounters:
    """One chunk's accumulated traffic as a TrafficCounters delta.

    The chunked-loop rendering of :func:`superstep_counters`: one numpy
    reduction per field per chunk instead of a python accumulation per
    superstep (per-step host accounting would eat the chunked loop's
    dispatch savings).  Bit-identical to per-step accumulation because
    every counter is an integer-valued count: float64 sums of integers
    below 2**53 are exact under any association.
    """
    n = int(n_active)

    def tot(key):
        a = stacked.get(key)
        if a is None:
            return 0.0
        return float(np.sum(np.asarray(a[:n], dtype=np.float64)))

    return TrafficCounters(
        messages=tot("messages"), hop_msgs=tot("hop_msgs"),
        owner_msgs=tot("owner_msgs"),
        owner_hop_msgs=tot("owner_hop_msgs"),
        intra_die_hops=tot("intra_die_hops"),
        inter_die_crossings=tot("inter_die_crossings"),
        inter_pkg_crossings=tot("inter_pkg_crossings"),
        filtered_at_proxy=tot("filtered_at_proxy"),
        coalesced_at_proxy=tot("coalesced_at_proxy"),
        cascade_combined=tot("cascade_combined"),
        cross_region_msgs=tot("cross_region_msgs"),
        off_chip_msgs=tot("off_chip_msgs"),
        off_chip_hop_msgs=tot("off_chip_hop_msgs"),
        edges_processed=tot("edges_processed"),
        records_consumed=tot("records_consumed"), supersteps=n)


def chunk_cycles(stacked, n_active: int, pkg, links: dict) -> np.ndarray:
    """Vectorized :func:`superstep_cycles` over a chunk's stacked stats:
    one ``costmodel.step_cycles`` call on ``(n_active,)`` float64 vectors
    (elementwise identical to the per-step scalar calls)."""
    n = int(n_active)
    bits = MSG_BITS

    def vec(key):
        return np.asarray(stacked[key][:n], dtype=np.float64)

    return np.atleast_1d(step_cycles(
        pkg, links,
        compute_ops=vec("compute_per_tile_max"),
        intra_bits=vec("intra_die_hops") * bits,
        die_bits=vec("inter_die_crossings") * bits,
        pkg_bits=vec("inter_pkg_crossings") * bits,
        endpoint_bits=vec("delivered_max_per_tile") * bits))


# int32 per-superstep stats that can exceed f32's exact-integer range at
# paper-scale runs; _scan_steps carries them on an exact int32 side
# channel next to the packed f32 rows (order matters — the scan body's
# drained test reads index 0, so "pending" must stay first; see
# packed_step).  "p_resident" joined after the repro.analysis jaxpr
# linter's int-stat-f32-row rule flagged it: write-back P$ residency is
# bounded by T*slots, which passes 2**24 at the paper's million-PU scale.
_EXACT_INT_STATS = ("pending", "edges_processed", "records_consumed",
                    "p_resident")


def _stat_keys(step_one, state, flush):
    """Scalar stat names of ``step_one``'s stats dict in the packed-vector
    order ``_scan_steps`` emits (sorted, with ``active`` appended), via an
    abstract trace — no device computation.  Telemetry *vector* stats
    (``tv_*`` / ``pc_*``, nonzero ndim) are excluded: they ride the
    scan's separate stacked-dict channel under their own names, so the
    packed f32 row layout is identical with telemetry on or off."""
    stats_shape = jax.eval_shape(step_one, state, flush)[1]
    return sorted(k for k, v in stats_shape.items()
                  if v.ndim == 0) + ["active"]


def _drain_chunked(chunk_fn, state, maxs, keys, counters, trace,
                   element_bits, progress, add_chunk_cycles, cycles,
                   observer=None, *, steps0=0, flush0=None, boundary=None,
                   vec_sums=None):
    """The host side of the chunked run loop, shared verbatim by the
    monolithic and distributed engines (so chunk unpacking, accounting
    and termination cannot drift between them).

    Per chunk: one device dispatch (``chunk_fn``), one host sync, then
    vectorized accounting — ``chunk_counters`` into ``counters``,
    ``SuperstepTrace.append_chunk`` into ``trace``, and the caller's
    ``add_chunk_cycles(stacked, n_act, cycles) -> cycles`` closure for
    the BSP time model (it accumulates sequentially, preserving the
    legacy loop's float-addition order).  Returns (state, steps, cycles).

    ``observer`` (obs.timeline.Observer) is called once per chunk at the
    *existing* host-accounting boundary with the already-fetched arrays
    plus wall-clock span times — attaching one adds zero host syncs and
    cannot perturb the computation (it only reads).  Every chunk's
    device_get increments the ``engine.host_syncs`` metric, observer or
    not, so telemetry-on/off sync counts are directly comparable.

    The keyword-only extensions serve the distributed engine's
    fault-tolerance layer (defaults keep the monolithic call untouched):
    ``steps0`` / ``flush0`` resume the loop from a restored checkpoint
    carry; ``boundary(steps, state, flush, host_done, cycles) -> cycles``
    runs at each chunk host-accounting boundary *after* the chunk's
    accounting (it checkpoints on cadence and may raise the fault
    injector's chip-loss error, which the caller's retry loop turns into
    a rollback); ``vec_sums`` (a dict) accumulates the per-superstep sum
    of every telemetry vector stat (``pc_*``) across the run — the
    straggler-rebalancing load feed, riding the existing fetch.
    """
    sync_ctr = default_registry().counter("engine.host_syncs")
    steps = int(steps0)
    chunk_idx = 0
    flush = jnp.zeros((), jnp.bool_) if flush0 is None else \
        jnp.asarray(flush0, jnp.bool_)
    done = jnp.zeros((), jnp.bool_)
    while steps < maxs:
        t0 = time.perf_counter()
        (state, flush, done, _), (packed, ints, vecs) = chunk_fn(
            state, flush, done, jnp.int32(maxs - steps))
        t1 = time.perf_counter()
        # the single host sync of this chunk:
        host_done, packed, ints, vecs = jax.device_get(
            (done, packed, ints, vecs))
        sync_ctr.inc()
        t2 = time.perf_counter()
        stacked = {k: packed[:, i] for i, k in enumerate(keys)}
        for i, k in enumerate(_EXACT_INT_STATS):
            stacked[k] = ints[:, i]          # exact int32, not the f32 row
        n_act = int(np.sum(stacked["active"]))
        if n_act:
            counters.add(chunk_counters(stacked, n_act))
            trace.append_chunk(stacked, n_act, element_bits=element_bits)
            cycles = add_chunk_cycles(stacked, n_act, cycles)
            if vec_sums is not None:
                for k, v in vecs.items():
                    s = np.sum(np.asarray(v[:n_act], np.float64), axis=0)
                    vec_sums[k] = vec_sums.get(k, 0.0) + s
        t3 = time.perf_counter()
        if observer is not None:
            observer.on_chunk(ChunkSpan(
                index=chunk_idx, step_lo=steps, step_hi=steps + n_act,
                t_dispatch=(t0, t1), t_fetch=(t1, t2), t_account=(t2, t3),
                stats={k: np.asarray(v[:n_act]) for k, v in stacked.items()},
                vecs={k: np.asarray(v[:n_act]) for k, v in vecs.items()}))
        steps += n_act
        chunk_idx += 1
        progress.report(steps, stacked, n_act)
        if boundary is not None:
            cycles = boundary(steps, state, flush, bool(host_done), cycles)
        if host_done or n_act == 0:
            break
    return state, steps, cycles


def _legacy_span(steps, stats, t_dispatch, t_fetch, t_account):
    """One per-step-loop superstep as a single-step ChunkSpan: scalar
    stats become ``(1,)`` arrays and telemetry vectors (``tv_*`` /
    ``pc_*``) become ``(1, W)`` rows — the same shapes the chunked loop
    emits, so observers need not care which loop ran."""
    scal, vecs = {}, {}
    for k, v in stats.items():
        a = np.asarray(v)
        if a.ndim == 0:
            scal[k] = a[None]
        else:
            vecs[k] = a[None]
    scal["active"] = np.ones((1,), np.float32)
    return ChunkSpan(index=steps - 1, step_lo=steps - 1, step_hi=steps,
                     t_dispatch=t_dispatch, t_fetch=t_fetch,
                     t_account=t_account, stats=scal, vecs=vecs)


def _scan_steps(step_one, state, flush, done, steps_left, length: int,
                write_back: bool):
    """Scan ``length`` supersteps in one device dispatch.

    ``step_one(state, flush) -> (new_state, stats)`` is one engine
    superstep (monolithic, or a whole distributed superstep including
    the boundary exchange).  The carry holds the engine state, the
    write-back flush flag, the drained flag and the remaining superstep
    budget — all on device.  Each iteration applies the same post-step
    rules the legacy host loop applied between dispatches: a just-drained
    engine with write-back P$ residue schedules a flush superstep; a
    drained engine without residue stops.  Iterations past the stop point
    (or past the budget) skip the superstep entirely (``lax.cond``) and
    emit a zeroed row with ``active=0``.  Shared by the monolithic and
    distributed chunked run loops so the two cannot drift in
    flush/termination semantics.

    The per-step stats are packed into ONE ``(n_stats,)`` f32 vector (in
    :func:`_stat_keys` order) so the scan stacks a single ``(length,
    n_stats)`` buffer instead of one buffer per stat — a large share of
    the per-iteration overhead at small grid sizes.  The int32 stats
    that can outgrow f32's 2**24 integer range at paper-scale runs
    (see ``_EXACT_INT_STATS``) additionally ride an exact int32 side
    channel; every other stat is f32 on device already or a count far
    below 2**24, so the packing loses nothing.  The flush/termination
    decisions read the exact pre-packing integers.

    Returns ((state, flush, done, steps_left), (stacked, stacked_ints,
    stacked_vecs)) with shapes ``(length, n_stats)`` f32,
    ``(length, len(_EXACT_INT_STATS))`` int32, and — telemetry only — a
    dict of ``(length, W)`` f32 vector stats (empty dict otherwise, so
    the non-telemetry compiled program is unchanged).
    """
    stats_shape = jax.eval_shape(step_one, state, flush)[1]
    keys = sorted(k for k, v in stats_shape.items() if v.ndim == 0)
    vkeys = sorted(k for k, v in stats_shape.items() if v.ndim > 0)

    def packed_step(st, fl):
        new_state, stats = step_one(st, fl)
        vec = jnp.stack([stats[k].astype(jnp.float32) for k in keys])
        ints = jnp.stack([stats[k].astype(jnp.int32)
                          for k in _EXACT_INT_STATS])
        vstats = {k: stats[k].astype(jnp.float32) for k in vkeys}
        return (new_state, vec, ints,
                stats["p_resident"] if write_back else jnp.int32(0),
                vstats)

    def idle_step(st, _fl):
        # pending=1 so a masked idle row can never read as "drained";
        # the row is discarded anyway (active=0)
        vstats = {k: jnp.zeros(stats_shape[k].shape, jnp.float32)
                  for k in vkeys}
        return (st, jnp.zeros((len(keys),), jnp.float32),
                jnp.array([1] + [0] * (len(_EXACT_INT_STATS) - 1),
                          jnp.int32), jnp.int32(0), vstats)

    def body(carry, _):
        state, flush, done, left = carry
        active = jnp.logical_and(~done, left > 0)
        # cond, not select: iterations past the stop point skip the
        # superstep entirely instead of computing and discarding it
        new_state, vec, ints, p_res, vstats = jax.lax.cond(
            active, packed_step, idle_step, state, flush)
        drained = active & (ints[0] == 0)
        if write_back:
            flush_next = drained & (p_res > 0)
        else:
            flush_next = jnp.zeros((), jnp.bool_)
        done_next = done | (drained & ~flush_next)
        row = jnp.concatenate([vec, active.astype(jnp.float32)[None]])
        return (new_state, flush_next, done_next,
                left - active.astype(left.dtype)), (row, ints, vstats)

    return jax.lax.scan(body, (state, flush, done, steps_left), None,
                        length=length)


def _lex_group(key, sub, mask, *vals):
    """Single-sort lexicographic (key, sub) record grouping.

    One fused stable ``lax.sort`` with ``num_keys=2`` orders records by
    the (key, sub) composite — the sort the two-stable-argsort idiom
    (argsort by sub, then by key) and a packed ``(key << k) | sub``
    key both express, but with one sort pass, no gathers, and no int64
    requirement — carrying ``mask`` and ``vals`` along as passengers.
    Masked records must hold sentinel keys that order after all live
    ones.  Ties in (key, sub) keep arrival order (stability), so
    downstream f32 segment sums accumulate in the same order as the
    two-argsort formulation: bit-identical results.

    Returns (skey, ssub, smask, svals, new_key, new_pair, gid):
      new_key:  sorted-order mask of the first live record of each key;
      new_pair: first live record of each (key, sub) group — the group
                leaders; gid numbers the groups (masked rows -> last id).
    """
    R = key.shape[0]
    skey, ssub, smask, *svals = jax.lax.sort(
        (key, sub, mask) + tuple(vals), num_keys=2, is_stable=True)
    first = jnp.arange(R) == 0
    new_key = smask & (first | (skey != jnp.roll(skey, 1)))
    new_pair = smask & (new_key | (ssub != jnp.roll(ssub, 1)))
    gid = jnp.cumsum(new_pair.astype(jnp.int32)) - 1
    gid = jnp.where(smask, gid, R - 1)
    return skey, ssub, smask, tuple(svals), new_key, new_pair, gid


class _ProgressReporter:
    """Chunk-granularity progress for the scanned run loops: reports the
    true executed superstep count at the first chunk boundary at or past
    each ``every`` multiple (the per-step loop's ``steps % every == 0``
    would silently skip multiples that fall inside a chunk).

    Progress flows through the obs metrics registry — gauges
    ``progress.<app>.steps`` / ``.pending`` updated every chunk, counter
    ``progress.<app>.reports`` per printed line — so harnesses read it
    without scraping stdout; when the sanitizer is on, the line also
    carries the cumulative ``sanity_violations`` count.

    Compacted runs (``EngineConfig.compaction > 1``) additionally feed
    the ``engine.active_fraction`` gauge (mean active-tile fraction of
    the latest chunk) and per-capacity ``engine.bucket_occupancy.<cap>``
    counters (supersteps spent in each ladder rung) from the
    ``active_tiles`` / ``bucket_cap`` telemetry stats the bucket switch
    emits — they ride the same chunk stat fetch, zero extra syncs."""

    def __init__(self, name: str, every: int, sanitize: bool = False,
                 tiles: int = 0):
        self.name = name
        self.every = every
        self.sanitize = sanitize
        self.tiles = tiles
        self._next = every
        self._violations = 0.0
        reg = default_registry()
        self._g_steps = reg.gauge(f"progress.{name}.steps")
        self._g_pending = reg.gauge(f"progress.{name}.pending")
        self._c_reports = reg.counter(f"progress.{name}.reports")
        self._g_active = reg.gauge("engine.active_fraction")
        self._bucket_counters: dict = {}

    def report(self, steps: int, stacked, n_act: int) -> None:
        if n_act == 0:
            return
        pending = float(stacked["pending"][n_act - 1])
        self._g_steps.set(steps)
        self._g_pending.set(pending)
        act = stacked.get("active_tiles")
        if act is not None and self.tiles:
            self._g_active.set(
                float(np.mean(act[:n_act])) / self.tiles)
            caps, cnts = np.unique(
                np.asarray(stacked["bucket_cap"][:n_act]),
                return_counts=True)
            for cap, cnt in zip(caps.tolist(), cnts.tolist()):
                c = self._bucket_counters.get(int(cap))
                if c is None:
                    c = default_registry().counter(
                        f"engine.bucket_occupancy.{int(cap)}")
                    self._bucket_counters[int(cap)] = c
                c.inc(float(cnt))
        if self.sanitize and "sanity_violations" in stacked:
            self._violations += float(
                np.sum(stacked["sanity_violations"][:n_act]))
        if not self.every or steps < self._next:
            return
        self._c_reports.inc()
        line = (f"  [{self.name}] step {steps} (chunk of {n_act}) "
                f"pending={pending:.0f}")
        if self.sanitize:
            line += f" sanity_violations={self._violations:.0f}"
        print(line)
        while self._next <= steps:
            self._next += self.every


def _deliver(mail_val, mail_flag, dst, val, mask, owner, T, Nd, is_min,
             backend: str = "jnp"):
    """Combine records into owner mailboxes; returns the (T,) per-tile
    delivered-record counts (endpoint contention before the max).

    Two scatters instead of the seed's three: one combines the arriving
    values per mailbox index, one counts arrivals per index — and the
    count vector then yields both the flag update (``count > 0`` ==
    scatter-max of the mask) and the per-tile endpoint contention
    (mailbox indices of one tile are contiguous, so per-tile delivered
    records are a reshape-sum of the counts).  XLA CPU serializes
    scatters per update row, so every scatter removed is the single
    biggest superstep saving; counts are integers, so the derived values
    are bit-identical to the scatter-max/segment-sum formulation.  min
    combines are order-independent (bitwise identical to the seed); add
    combines apply ``mail + sum(arrivals)`` instead of the seed's
    sequential scatter order — equal up to f32 re-association.
    """
    if backend == "pallas":
        return _deliver_pallas(mail_val, mail_flag, dst, val, mask, owner,
                               T, Nd, is_min)
    # masked records point one past the end; mode="drop" discards them at
    # the scatter itself — no padded copy of the mailbox per superstep
    safe_dst = jnp.where(mask, dst, Nd)
    cnt = jnp.zeros((Nd,), jnp.int32).at[safe_dst].add(
        mask.astype(jnp.int32), mode="drop")
    if is_min:
        inc = jnp.full((Nd,), INF).at[safe_dst].min(
            jnp.where(mask, val, INF), mode="drop")
        mv = jnp.minimum(mail_val, inc)
    else:
        inc = jnp.zeros((Nd,), jnp.float32).at[safe_dst].add(
            jnp.where(mask, val, 0.0), mode="drop")
        mv = mail_val + inc
    mf = mail_flag | (cnt > 0)
    per_tile = jnp.sum(cnt.reshape(T, Nd // T), axis=1)
    return mv, mf, per_tile.astype(jnp.float32)


def _deliver_pallas(mail_val, mail_flag, dst, val, mask, owner, T, Nd,
                    is_min):
    """Pallas rendering of the owner delivery, fused into ONE launch
    (``kernels.deliver_fused``): the kernel reads the record stream once
    and produces both the relaxed mailbox (min: guarded running minimum
    == scatter-min, bitwise; add: accumulate — equal to the jnp oracle
    up to f32 re-association) and the per-index arrival counts.  Flags
    and per-tile endpoint contention derive from the counts exactly like
    the jnp path (counts are integers, mailbox indices of one tile are
    contiguous) — this replaces the former four-launch chain
    (segment_combine + 2x histogram + relax)."""
    from ..kernels import ops as kops
    comb = "min" if is_min else "add"
    seg = jnp.where(mask, dst, -1)                 # negative = padding
    mv, cnt = kops.deliver_fused(seg, val, mail_val, combine=comb)
    mf = mail_flag | (cnt > 0)
    per_tile = jnp.sum(cnt.reshape(T, Nd // T), axis=1)
    return mv, mf, per_tile


def capacity_ladder(T: int, levels: int) -> tuple:
    """Window-capacity ladder for active-set compaction: ``(T, T/4,
    T/16, ...)`` — the dense window plus ``levels`` power-of-two rungs
    (each a quarter of the previous, floored at 1 tile; rungs that no
    longer shrink are dropped).  Descending, so ``bucket_index`` can
    pick the smallest capacity that fits the active count."""
    caps = [int(T)]
    for k in range(1, max(int(levels), 0) + 1):
        c = max(int(T) >> (2 * k), 1)
        if c < caps[-1]:
            caps.append(c)
    return tuple(caps)


def bucket_index(n_act, caps: tuple):
    """Index of the smallest ladder capacity that holds ``n_act`` active
    tiles (0 = the dense window; traced — ``n_act`` may be a device
    scalar, so this is the on-device ``lax.switch`` selector)."""
    idx = jnp.int32(0)
    for j, c in enumerate(caps[1:], start=1):
        idx = jnp.where(n_act <= c, jnp.int32(j), idx)
    return idx


def _compact_window(active, W: int, T: int):
    """Stable compaction of the (T,) active mask into a W-slot window.

    Returns (w_valid, w_rows, rows_drop): per-window-slot validity, the
    source tile row each slot gathers (invalid slots clamp to T-1 — the
    caller must mask their gathered work to zero), and the scatter-back
    row index (invalid slots -> sentinel row T, for ``mode="drop"``).
    The cumsum keeps active tiles in tile order, which is what makes
    the compacted record stream order-identical to the dense one.
    The slot->tile map is a searchsorted over the inclusive cumsum (the
    j-th active tile is the first row where the cumsum reaches j+1), NOT
    a T-row scatter and NOT an argsort: XLA CPU serializes indexed
    scatters and gathers per row, so a T-row scatter here (~70us at
    T=1024) costs more than the whole windowed front saves, and a
    full-length stable sort is worse still.  The W-row scatter-backs the
    callers do are fine — their row count shrinks with the bucket."""
    csum = jnp.cumsum(active.astype(jnp.int32))
    tile_map = jnp.searchsorted(
        csum, jnp.arange(1, W + 1, dtype=jnp.int32),
        side="left").astype(jnp.int32)
    w_valid = tile_map < T
    w_rows = jnp.minimum(tile_map, T - 1)
    rows_drop = jnp.where(w_valid, w_rows, T)
    return w_valid, w_rows, rows_drop


def _pad(a: np.ndarray, n: int, fill) -> np.ndarray:
    a = np.asarray(a)
    if a.shape[0] == n:
        return a
    out = np.full((n,), fill, dtype=a.dtype)
    out[: a.shape[0]] = a
    return out
