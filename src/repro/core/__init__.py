# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

# Importing any core submodule installs the jax version-compat shims
# (jax.shard_map on 0.4.x installs, check_vma -> check_rep translation).
from . import compat  # noqa: F401
