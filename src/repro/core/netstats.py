"""Network traffic accounting for the data-local engine.

Every message the engine emits is charged here: exact XY-torus hop counts
between source and destination tiles, decomposed into intra-die hops,
inter-die (on-package substrate) crossings and off-package crossings.
These feed the Table-III energy model and the BSP time model.

This is the TPU adaptation of the paper's cycle-accurate NoC simulator:
instead of simulating router arbitration per cycle, we measure the exact
traffic each superstep generates (the engine is deterministic) and apply
a bandwidth/latency model per network level.  Relative effects the paper
reports (proxy traffic reduction, link-width scaling, queue backpressure)
are preserved because they are properties of the traffic, not of the
arbiter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from .tilegrid import TileGrid

# A task-invocation message is (index, value): 32-bit index + 32-bit value,
# as in the paper (the first parameter is the routed global array index).
MSG_BITS = 64


@dataclasses.dataclass
class TrafficCounters:
    """Accumulated traffic, all in message units (64 bit each)."""

    messages: float = 0.0            # total messages injected
    hop_msgs: float = 0.0            # sum over msgs of router hops
    owner_msgs: float = 0.0          # messages on the owner-bound leg
    owner_hop_msgs: float = 0.0      # their hop-weighted traffic
    intra_die_hops: float = 0.0
    inter_die_crossings: float = 0.0
    inter_pkg_crossings: float = 0.0
    filtered_at_proxy: float = 0.0   # msgs absorbed by P$ (never forwarded)
    coalesced_at_proxy: float = 0.0  # msgs merged into an existing P$ entry
    cascade_combined: float = 0.0    # msgs merged at cascade tree levels
    cross_region_msgs: float = 0.0   # region-boundary crossings, msg-weighted
    off_chip_msgs: float = 0.0       # records exchanged between chips
    off_chip_hop_msgs: float = 0.0   # their chip-grid (board-level) hops
    dropped_backpressure: float = 0.0
    edges_processed: float = 0.0
    records_consumed: float = 0.0    # mailbox records drained by owners
    supersteps: int = 0

    def add(self, other: "TrafficCounters") -> "TrafficCounters":
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))
        return self

    def as_dict(self) -> Dict[str, float]:
        return {f.name: float(getattr(self, f.name)) for f in dataclasses.fields(self)}

    @property
    def avg_hops(self) -> float:
        return self.hop_msgs / max(self.messages, 1.0)

    @property
    def avg_owner_hops(self) -> float:
        """Average hops of the owner-bound (vertex-update) messages —
        the quantity the paper's Fig. 8 (top) plots."""
        return self.owner_hop_msgs / max(self.owner_msgs, 1.0)


@dataclasses.dataclass
class SuperstepTrace:
    """Per-superstep level-traffic vectors measured by the run loop.

    One entry per superstep, in execution order.  This is the record that
    makes a run *re-priceable*: ``costmodel.price`` recomputes the BSP
    time superstep-wise from these vectors under an arbitrary
    :class:`~repro.core.costmodel.PackageConfig` (different link widths /
    counts, NoC count, HBM channels), so one measured run can be priced
    across a whole package design space (measure-once / price-many).

    Vector fields (floats, one per superstep):
      compute_ops:   max per-tile PU ops (the BSP compute leg).
      intra_bits:    whole-grid intra-die NoC wire bits.
      die_bits:      inter-die (on-package substrate) crossing bits.
      pkg_bits:      off-package crossing bits.
      endpoint_bits: max per-tile delivered bits (endpoint contention).
      off_chip_bits: board-level hop-weighted bits (distributed runtime).
      off_chip_msgs: records that left their chip (IO-die latency events).
      touched_bits:  dataset bits touched (drives the D$ miss -> HBM leg).
      pending:       live work after the superstep (idle steps charge no
                     pipeline fill; flush-only steps still do).

    ``board_links`` is the provisioned board-link count of the partition
    the run executed on (1 for a monolithic run); ``chips_y`` /
    ``chips_x`` record that partition's chip-grid geometry (1x1
    monolithic), which is what lets ``costmodel.price`` re-provision the
    board leg per axis under an arbitrary :class:`PackageConfig` while
    refusing to re-price the trace at a *different* chip count (the
    off-chip traffic is a property of the measured partition).

    ``double_buffer`` records whether the run overlapped each
    superstep's board exchange with the next superstep's compute
    (``EngineConfig.double_buffer``): re-pricing replays the matching
    overlap-aware BSP accumulation, so the priced time reproduces the
    run's own (the reprice contract holds in both modes).

    ``recovery_events`` is the fault-tolerance machinery's
    execution-order log (checkpoint writes, rollbacks, re-shards onto
    survivors), *not* a per-superstep vector: a recovered run's vector
    rows are bit-identical to the unfailed run's (the rollback truncates
    them and the replay re-records them), while the events record the
    overhead timeline — ``costmodel._trace_time_s_parsed`` replays them
    (checkpoint/restore board legs, discarded-work windows) so the
    reprice contract holds on faulted runs too.  Event dicts carry
    ``kind`` ('checkpoint' | 'rollback' | 'reshard') plus kind-specific
    fields (``step`` / ``from_step`` / ``at_step`` / ``bits`` /
    ``chip`` / ``devices``).
    """

    compute_ops: List[float] = dataclasses.field(default_factory=list)
    intra_bits: List[float] = dataclasses.field(default_factory=list)
    die_bits: List[float] = dataclasses.field(default_factory=list)
    pkg_bits: List[float] = dataclasses.field(default_factory=list)
    endpoint_bits: List[float] = dataclasses.field(default_factory=list)
    off_chip_bits: List[float] = dataclasses.field(default_factory=list)
    off_chip_msgs: List[float] = dataclasses.field(default_factory=list)
    touched_bits: List[float] = dataclasses.field(default_factory=list)
    pending: List[float] = dataclasses.field(default_factory=list)
    board_links: int = 1
    chips_y: int = 1
    chips_x: int = 1
    double_buffer: bool = False
    recovery_events: List[dict] = dataclasses.field(default_factory=list)

    _VECTOR_FIELDS = ("compute_ops", "intra_bits", "die_bits", "pkg_bits",
                      "endpoint_bits", "off_chip_bits", "off_chip_msgs",
                      "touched_bits", "pending")

    def __len__(self) -> int:
        return len(self.compute_ops)

    def truncate(self, n: int) -> "SuperstepTrace":
        """Drop every recorded superstep past the first ``n`` (rollback to
        a checkpoint: the replay re-records the discarded rows
        bit-identically).  ``recovery_events`` survive — they log the
        fault-tolerance timeline in execution order, not per-step rows."""
        n = max(int(n), 0)
        for f in self._VECTOR_FIELDS:
            del getattr(self, f)[n:]
        return self

    def append_step(self, stats, element_bits: int = MSG_BITS) -> None:
        """Record one superstep from the run loop's device-fetched stats."""
        self.compute_ops.append(float(stats["compute_per_tile_max"]))
        self.intra_bits.append(float(stats["intra_die_hops"]) * MSG_BITS)
        self.die_bits.append(float(stats["inter_die_crossings"]) * MSG_BITS)
        self.pkg_bits.append(float(stats["inter_pkg_crossings"]) * MSG_BITS)
        self.endpoint_bits.append(
            float(stats["delivered_max_per_tile"]) * MSG_BITS)
        self.off_chip_bits.append(
            float(stats.get("off_chip_hop_msgs", 0.0)) * MSG_BITS)
        self.off_chip_msgs.append(float(stats.get("off_chip_msgs", 0.0)))
        self.touched_bits.append(
            (float(stats["edges_processed"])
             + float(stats["records_consumed"])) * element_bits)
        self.pending.append(float(stats["pending"]))

    def append_chunk(self, stacked, n_active: int,
                     element_bits: int = MSG_BITS) -> None:
        """Append the first ``n_active`` supersteps of a stacked chunk.

        ``stacked`` is the chunked run loop's device-fetched stats dict:
        every value is a ``(K,)`` array whose row ``i`` holds superstep
        ``i`` of the chunk (rows past ``n_active`` are masked no-op
        padding).  Appending is vectorized (one numpy pass per field per
        chunk, not per step — per-step python accounting would eat the
        chunked loop's dispatch savings) yet bit-identical to per-step
        :meth:`append_step` calls: every source stat is an integer-valued
        count, so the float64 convert-and-scale is exact in either
        formulation.
        """
        n = int(n_active)
        if n == 0:
            return

        def vec(key, scale=1.0):
            a = stacked.get(key)
            if a is None:                    # e.g. off-chip legs, monolithic
                return [0.0] * n
            return (np.asarray(a[:n], np.float64) * scale).tolist()

        self.compute_ops.extend(vec("compute_per_tile_max"))
        self.intra_bits.extend(vec("intra_die_hops", MSG_BITS))
        self.die_bits.extend(vec("inter_die_crossings", MSG_BITS))
        self.pkg_bits.extend(vec("inter_pkg_crossings", MSG_BITS))
        self.endpoint_bits.extend(vec("delivered_max_per_tile", MSG_BITS))
        self.off_chip_bits.extend(vec("off_chip_hop_msgs", MSG_BITS))
        self.off_chip_msgs.extend(vec("off_chip_msgs"))
        touched = (np.asarray(stacked["edges_processed"][:n], np.float64)
                   + np.asarray(stacked["records_consumed"][:n], np.float64))
        self.touched_bits.extend((touched * element_bits).tolist())
        self.pending.extend(vec("pending"))

    # recovery-event fields that index trace rows: shifted when traces
    # concatenate so events keep pointing at their supersteps
    _EVENT_STEP_KEYS = ("step", "from_step", "at_step")

    def extend(self, other: "SuperstepTrace") -> "SuperstepTrace":
        """Concatenate another trace (epoch-style apps accumulate runs)."""
        base = len(self)
        for f in self._VECTOR_FIELDS:
            getattr(self, f).extend(getattr(other, f))
        for ev in other.recovery_events:
            ev = dict(ev)
            for k in self._EVENT_STEP_KEYS:
                if k in ev:
                    ev[k] = int(ev[k]) + base
            self.recovery_events.append(ev)
        self.board_links = max(self.board_links, other.board_links)
        self.chips_y = max(self.chips_y, other.chips_y)
        self.chips_x = max(self.chips_x, other.chips_x)
        self.double_buffer = self.double_buffer or other.double_buffer
        return self

    def to_dict(self) -> Dict[str, object]:
        d: Dict[str, object] = {f: list(getattr(self, f))
                                for f in self._VECTOR_FIELDS}
        d["board_links"] = self.board_links
        d["chips_y"] = self.chips_y
        d["chips_x"] = self.chips_x
        d["double_buffer"] = self.double_buffer
        if self.recovery_events:
            d["recovery_events"] = [dict(ev) for ev in self.recovery_events]
        return d

    @classmethod
    def from_dict(cls, d) -> "SuperstepTrace":
        t = cls(board_links=int(d.get("board_links", 1)),
                chips_y=int(d.get("chips_y", 1)),
                chips_x=int(d.get("chips_x", 1)),
                double_buffer=bool(d.get("double_buffer", False)))
        for f in cls._VECTOR_FIELDS:
            getattr(t, f).extend(float(v) for v in d.get(f, ()))
        t.recovery_events.extend(dict(ev)
                                 for ev in d.get("recovery_events", ()))
        return t


def charge(grid: TileGrid, src_tid, dst_tid, mask, region_dims=None):
    """Vectorised traffic charge for a batch of messages.

    Args:
      grid: tile grid geometry.
      src_tid, dst_tid: integer arrays of tile ids (any shape).
      mask: boolean array, True where a real message exists.
      region_dims: optional (region_ny, region_nx) of the base proxy
        regions; when given, each message is additionally charged its
        region-boundary crossings along the route into
        ``cross_region_msgs`` (the traffic class selective cascading
        exists to shrink).

    Returns a dict of scalar jnp totals (messages, hop_msgs, intra, die,
    pkg, cross_region_msgs).
    """
    m = mask.astype(jnp.float32).reshape(-1)
    hops = grid.hops(src_tid, dst_tid).astype(jnp.float32).reshape(-1)
    intra, die, pkg = grid.link_levels(src_tid, dst_tid)
    rows = [m, hops * m, intra.astype(jnp.float32).reshape(-1) * m,
            die.astype(jnp.float32).reshape(-1) * m,
            pkg.astype(jnp.float32).reshape(-1) * m]
    if region_dims is not None:
        rny, rnx = region_dims
        crosses = grid.region_crossings(src_tid, dst_tid, rny, rnx)
        rows.append(crosses.astype(jnp.float32).reshape(-1) * m)
    # one fused reduction over all traffic classes (the run loop executes
    # this once per leg per superstep — separate sums were a measurable
    # share of the device-resident step)
    sums = jnp.sum(jnp.stack(rows), axis=1)
    return dict(
        messages=sums[0],
        hop_msgs=sums[1],
        intra_die_hops=sums[2],
        inter_die_crossings=sums[3],
        inter_pkg_crossings=sums[4],
        cross_region_msgs=(sums[5] if region_dims is not None
                           else jnp.float32(0.0)),
    )


def charge_off_chip(part, src_tid, dst_tid, mask):
    """Charge the off-chip network leg for records leaving their chip.

    In the distributed runtime a record whose owner lives on another chip
    rides the board-level network: out through the source chip's IO die,
    across one board link per chip-grid hop, and in through the
    destination chip's IO die.  The on-silicon route is already charged
    by ``charge`` (with its inter-die / inter-package crossings); this
    counts the *additional* board legs that only exist once the grid is
    physically split into chips — priced at OFF_PKG_PJ_BIT per bit per
    leg and IO-die Rx/Tx latency in the BSP time model.

    Args:
      part: a ``tilegrid.ChipPartition``.
      src_tid, dst_tid: global tile ids of the record's final leg.
      mask: True where a real off-chip record exists (caller pre-masks to
        records whose source and owner chips differ).

    Returns a dict(off_chip_msgs, off_chip_hop_msgs) of scalar totals.
    """
    m = mask.astype(jnp.float32)
    hops = part.chip_hops(src_tid, dst_tid).astype(jnp.float32)
    return dict(off_chip_msgs=jnp.sum(m),
                off_chip_hop_msgs=jnp.sum(hops * m))


def merge_charges(*charges) -> Dict[str, jnp.ndarray]:
    out: Dict[str, jnp.ndarray] = {}
    for c in charges:
        for k, v in c.items():
            out[k] = out.get(k, 0.0) + v
    return out


def to_counters(charge_dict, **extras) -> TrafficCounters:
    c = TrafficCounters()
    for k, v in charge_dict.items():
        setattr(c, k, float(np.asarray(v)))
    for k, v in extras.items():
        setattr(c, k, float(np.asarray(v)))
    return c
