"""Tile-grid geometry for the DCRA data-local execution model.

A DCRA system is a 2D grid of ``ny x nx`` tiles.  Tiles are grouped into
dies (default 16x16 per the paper), dies into packages (default 64x64
tiles per package, i.e. 4x4 dies), packages onto a board.  Every dataset
array of global length N is scattered across tiles as equal-sized chunks
(``chunk = ceil(N / num_tiles)``), and the *owner* of global index ``i``
is ``i // chunk`` — exactly the paper's index-routed placement, which lets
messages be routed by their first parameter with no headers.

All geometry helpers are written with ``jnp``-compatible arithmetic so
they can be traced inside jitted supersteps; they also work with plain
numpy arrays and python ints.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TileGrid:
    """Geometry of a DCRA tile grid.

    Attributes:
      ny, nx: grid dimensions in tiles.
      die_ny, die_nx: tiles per die (tapeout-time; paper uses 16x16).
      pkg_ny, pkg_nx: tiles per package (packaging-time; paper uses 64x64).
      torus: whether the tile network is configured as a (folded) 2D torus
        (compile-time reconfigurable per the paper, Fig. 4).
    """

    ny: int
    nx: int
    die_ny: int = 16
    die_nx: int = 16
    pkg_ny: int = 64
    pkg_nx: int = 64
    torus: bool = True

    def __post_init__(self):
        if self.ny <= 0 or self.nx <= 0:
            raise ValueError("grid dims must be positive")

    # ------------------------------------------------------------------ ids
    @property
    def num_tiles(self) -> int:
        return self.ny * self.nx

    @property
    def dies(self) -> Tuple[int, int]:
        return (max(1, self.ny // self.die_ny), max(1, self.nx // self.die_nx))

    @property
    def packages(self) -> Tuple[int, int]:
        return (max(1, self.ny // self.pkg_ny), max(1, self.nx // self.pkg_nx))

    @property
    def num_packages(self) -> int:
        py, px = self.packages
        return py * px

    def coords(self, tid):
        """tile id -> (y, x). Row-major, matching the paper's logical ids."""
        return tid // self.nx, tid % self.nx

    def tid(self, y, x):
        return y * self.nx + x

    # -------------------------------------------------------------- regions
    def region_id(self, tid, region_ny: int, region_nx: int):
        """Id of the (region_ny x region_nx) region containing ``tid``.

        Regions tile the grid from the origin in row-major order.  With
        cascade-level-scaled dimensions this enumerates the nodes of one
        level of the proxy reduction tree; two tiles share a tree node
        iff their region ids at that level are equal.
        """
        y, x = self.coords(tid)
        cols = -(-self.nx // region_nx)
        return (y // region_ny) * cols + x // region_nx

    def region_crossings(self, src_tid, dst_tid, region_ny: int,
                         region_nx: int):
        """Proxy-region boundary crossings along the XY route src -> dst
        (the region-granular analogue of ``link_levels``' die/package
        crossings).  This is the cross-region traffic unit that selective
        cascading exists to shrink: hierarchical combining sends fewer
        messages over each successive region boundary."""
        sy, sx = self.coords(src_tid)
        dy, dx = self.coords(dst_tid)
        return (self._axis_crossings(sx, dx, self.nx, region_nx)
                + self._axis_crossings(sy, dy, self.ny, region_ny))

    # ------------------------------------------------------------- partition
    def chunk_size(self, n: int) -> int:
        """Equal-chunk size for a global array of length n."""
        return -(-n // self.num_tiles)

    def owner(self, idx, n: int):
        """Owner tile of global array index ``idx`` (array of length n)."""
        return jnp.minimum(idx // self.chunk_size(n), self.num_tiles - 1)

    # -------------------------------------------------------------- routing
    def _axis_hops(self, a, b, period: int):
        """Hops along one axis under XY dimension-ordered routing."""
        d = jnp.abs(a - b)
        if self.torus and period > 1:
            return jnp.minimum(d, period - d)
        return d

    def hops(self, src_tid, dst_tid):
        """Total router-to-router hops for a message src -> dst (XY/DOR)."""
        sy, sx = self.coords(src_tid)
        dy, dx = self.coords(dst_tid)
        return self._axis_hops(sx, dx, self.nx) + self._axis_hops(sy, dy, self.ny)

    def _axis_crossings(self, a, b, period: int, cell: int):
        """Number of ``cell``-boundaries crossed travelling a -> b along one
        axis, taking the shorter torus direction when configured.

        Boundary between coordinate c and c+1 exists iff (c+1) % cell == 0.
        """
        lo = jnp.minimum(a, b)
        hi = jnp.maximum(a, b)
        # boundaries in [lo, hi): floor(hi/cell) - floor(lo/cell)
        direct = hi // cell - lo // cell
        if not (self.torus and period > 1):
            return direct
        # wrap path crosses boundaries in [hi, period) and [0, lo), plus the
        # wrap seam itself iff the seam is a cell boundary — which requires
        # at least two cells along the axis (a torus confined to one
        # die/package wraps on internal links).
        seam = 1 if (period % cell == 0 and period > cell) else 0
        wrap = (period - 1) // cell - hi // cell + lo // cell + seam
        d = hi - lo
        use_wrap = (period - d) < d
        return jnp.where(use_wrap, wrap, direct)

    def link_levels(self, src_tid, dst_tid):
        """Decompose the XY route into (intra_die_hops, die_crossings,
        package_crossings).  die_crossings counts inter-die (on-package
        substrate) link traversals; package_crossings counts off-package
        link traversals; intra_die_hops is the remaining on-silicon hops.
        Used by the energy/latency model (Table III charges each level
        differently)."""
        sy, sx = self.coords(src_tid)
        dy, dx = self.coords(dst_tid)
        die_x = self._axis_crossings(sx, dx, self.nx, self.die_nx)
        die_y = self._axis_crossings(sy, dy, self.ny, self.die_ny)
        pkg_x = self._axis_crossings(sx, dx, self.nx, self.pkg_nx)
        pkg_y = self._axis_crossings(sy, dy, self.ny, self.pkg_ny)
        total = self.hops(src_tid, dst_tid)
        die = die_x + die_y
        pkg = pkg_x + pkg_y
        # package crossings are also die crossings physically; separate them.
        die_only = jnp.maximum(die - pkg, 0)
        intra = jnp.maximum(total - die, 0)
        return intra, die_only, pkg

    # ---------------------------------------------------------------- misc
    def describe(self) -> str:
        dy, dx = self.dies
        py, px = self.packages
        return (f"TileGrid {self.ny}x{self.nx} ({self.num_tiles} tiles), "
                f"{dy}x{dx} dies of {self.die_ny}x{self.die_nx}, "
                f"{py}x{px} packages of {self.pkg_ny}x{self.pkg_nx}, "
                f"{'torus' if self.torus else 'mesh'}")


def square_grid(num_tiles: int, **kw) -> TileGrid:
    """Convenience: the paper always evaluates square grids (16x16 .. 1024x1024)."""
    side = int(round(num_tiles ** 0.5))
    if side * side != num_tiles:
        raise ValueError(f"num_tiles={num_tiles} is not a perfect square")
    return TileGrid(side, side, **kw)


# --------------------------------------------------------------------------
# Chip partitioning (the distributed runtime's unit of execution)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ChipPartition:
    """A (chips_y x chips_x) block partition of a tile grid into chips.

    Each chip is a rectangular subgrid of ``sub_ny x sub_nx`` tiles that
    the distributed runtime executes as one independent engine instance
    (one device under ``shard_map``, one vmap lane under emulation).
    Tile/data placement keeps the *global* row-major ids of the
    monolithic engine, so hop charging and numerics are unchanged; the
    partition only decides which tiles run together and which messages
    must ride the off-chip network leg between supersteps.

    All index maps are closed-form ``jnp``-compatible arithmetic so they
    can be traced inside jitted/vmapped supersteps.  Maps from a global
    tile to its chip (or to its position within whatever chip holds it)
    need no chip id; only ``global_tile`` does.
    """

    grid: TileGrid
    chips_y: int
    chips_x: int

    def __post_init__(self):
        if self.chips_y <= 0 or self.chips_x <= 0:
            raise ValueError("chip grid dims must be positive")
        if self.grid.ny % self.chips_y or self.grid.nx % self.chips_x:
            raise ValueError(
                f"chip grid {self.chips_y}x{self.chips_x} does not divide "
                f"the {self.grid.ny}x{self.grid.nx} tile grid")

    # ------------------------------------------------------------- geometry
    @property
    def num_chips(self) -> int:
        return self.chips_y * self.chips_x

    @property
    def sub_ny(self) -> int:
        return self.grid.ny // self.chips_y

    @property
    def sub_nx(self) -> int:
        return self.grid.nx // self.chips_x

    @property
    def tiles_per_chip(self) -> int:
        return self.sub_ny * self.sub_nx

    # ------------------------------------------------------------ index maps
    def chip_of_tile(self, tid):
        """Chip id (row-major on the chip grid) owning global tile ``tid``."""
        y, x = self.grid.coords(tid)
        return (y // self.sub_ny) * self.chips_x + x // self.sub_nx

    def local_tile(self, tid):
        """Row-major index of global tile ``tid`` within its own chip."""
        y, x = self.grid.coords(tid)
        return (y % self.sub_ny) * self.sub_nx + x % self.sub_nx

    def global_tile(self, chip, ltid):
        """Global tile id of local tile ``ltid`` on chip ``chip``."""
        cy = chip // self.chips_x
        cx = chip % self.chips_x
        ly = ltid // self.sub_nx
        lx = ltid % self.sub_nx
        return self.grid.tid(cy * self.sub_ny + ly, cx * self.sub_nx + lx)

    def chip_hops(self, src_tid, dst_tid):
        """Manhattan hops on the chip grid for a message src -> dst —
        the number of board-level (IO-die to IO-die) legs it traverses.
        Wrap-around follows the tile network's torus configuration."""
        sc = self.chip_of_tile(src_tid)
        dc = self.chip_of_tile(dst_tid)
        sy, sx = sc // self.chips_x, sc % self.chips_x
        dy, dx = dc // self.chips_x, dc % self.chips_x
        hx = jnp.abs(sx - dx)
        hy = jnp.abs(sy - dy)
        if self.grid.torus:
            if self.chips_x > 1:
                hx = jnp.minimum(hx, self.chips_x - hx)
            if self.chips_y > 1:
                hy = jnp.minimum(hy, self.chips_y - hy)
        return hx + hy

    # ------------------------------------------------------------- host side
    def tile_ids(self, chip: int):
        """Global tile ids of chip ``chip`` in local row-major order
        (numpy, host-side; used to partition/reassemble dataset arrays)."""
        import numpy as _np
        return _np.asarray(self.global_tile(chip,
                                            _np.arange(self.tiles_per_chip)))

    def describe(self) -> str:
        return (f"ChipPartition {self.chips_y}x{self.chips_x} chips of "
                f"{self.sub_ny}x{self.sub_nx} tiles over "
                f"{self.grid.ny}x{self.grid.nx}")


def partition_grid(grid: TileGrid, num_chips: int) -> ChipPartition:
    """Factor ``num_chips`` into the most square chip grid that divides
    ``grid`` (the paper's packages-on-a-board arrangement)."""
    best = None
    for cy in range(1, num_chips + 1):
        if num_chips % cy:
            continue
        cx = num_chips // cy
        if grid.ny % cy or grid.nx % cx:
            continue
        score = abs(cy - cx)
        if best is None or score < best[0]:
            best = (score, cy, cx)
    if best is None:
        raise ValueError(
            f"cannot partition {grid.ny}x{grid.nx} into {num_chips} chips")
    return ChipPartition(grid, best[1], best[2])
