"""Pipeline parallelism (GPipe-style) over a mesh axis, via shard_map +
collective_permute.

For >1k-chip jobs the scan-over-layers + FSDP schedule stops scaling
(per-layer weight gathers cross the whole data axis); pipelining layer
*stages* over a mesh axis keeps weight traffic local and overlaps the
stage boundary transfer with compute.  This module gives the minimal
complete form: L layers split into S contiguous stages laid out on a
mesh axis; microbatches stream through; each stage boundary is one
collective_permute (neighbour hop — cheap on a torus, and across pods it
crosses the DCI exactly once per microbatch: the proxy-region discipline
again).

API (used inside shard_map over the stage axis):
    run_pipeline(stage_fn, params_stage, x_mb, axis, n_stages)
where stage_fn(params_stage, x) applies this device's layer block.
The schedule is the standard GPipe fill-drain: T = M + S - 1 ticks for
M microbatches; bubble fraction (S-1)/(M+S-1).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def stage_index(axis: str):
    return jax.lax.axis_index(axis)


def run_pipeline(stage_fn: Callable, params_stage, x_mb, axis: str,
                 n_stages: int):
    """Run microbatches through pipeline stages laid out on ``axis``.

    stage_fn: (params_stage, x) -> x, this device's contiguous layer
        block (same shape in/out — a residual-stream transformer block).
    params_stage: this device's stage parameters (leading stage axis
        already sharded away by shard_map).
    x_mb: (M, mb, S, D) microbatched input; only stage 0 reads it, but
        every device passes the same shape (SPMD).
    Returns (M, mb, S, D): outputs as produced by the LAST stage (other
    devices return garbage slots; the caller selects stage S-1's copy).
    """
    m = x_mb.shape[0]
    sidx = jax.lax.axis_index(axis)
    ticks = m + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def tick(carry, t):
        buf, outs = carry
        # stage 0 injects microbatch t (if in range); others use buf
        inject = jnp.where(t < m, t, m - 1)
        x_in = jnp.where(sidx == 0, x_mb[inject], buf)
        y = stage_fn(params_stage, x_in)
        # last stage banks its result for microbatch (t - S + 1)
        out_slot = t - (n_stages - 1)
        slot = jnp.clip(out_slot, 0, m - 1)
        write = jnp.logical_and(sidx == n_stages - 1, out_slot >= 0)
        outs = jax.lax.cond(
            write,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, y, slot, 0),
            lambda o: o, outs)
        # boundary hop: neighbour permute (stage s -> s+1)
        buf = jax.lax.ppermute(y, axis, perm)
        return (buf, outs), None

    buf0 = jnp.zeros_like(x_mb[0])
    outs0 = jnp.zeros_like(x_mb)
    (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                jnp.arange(ticks))
    return outs


def pipeline_bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
