"""Version-compat shims for the jax API surface this repo targets.

The code and tests are written against the jax >= 0.5 spelling
``jax.shard_map(..., check_vma=...)``.  On older installs (0.4.x)
``shard_map`` still lives in ``jax.experimental.shard_map`` and the
replication-check kwarg is named ``check_rep``.  ``shard_map`` below
resolves whichever implementation exists and translates the kwarg; it is
also installed as ``jax.shard_map`` when missing so call sites (including
subprocess test snippets) can use the modern spelling unconditionally.
"""
from __future__ import annotations

import functools
import inspect

import jax


def _adapt_check_kwarg(fn):
    """Wrap ``fn`` to translate check_vma -> check_rep when ``fn`` only
    accepts the old spelling.  Keyed on the function's signature, not the
    jax version: some releases export the top-level ``jax.shard_map``
    alias while still taking ``check_rep``."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return fn
    if "check_vma" in params or "check_rep" not in params:
        return fn

    @functools.wraps(fn)
    def shard_map(f, *args, **kwargs):
        if "check_vma" in kwargs:
            kwargs.setdefault("check_rep", kwargs.pop("check_vma"))
        return fn(f, *args, **kwargs)

    return shard_map


def _resolve_shard_map():
    native = getattr(jax, "shard_map", None)
    if native is None:
        from jax.experimental.shard_map import shard_map as native
    return _adapt_check_kwarg(native)


shard_map = _resolve_shard_map()

if getattr(jax, "shard_map", None) is not shard_map:
    jax.shard_map = shard_map


def axis_size(name):
    """``jax.lax.axis_size`` fallback: on 0.4.x, psum of the constant 1
    over a named axis constant-folds to the (static) axis size."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)
