"""Proxy-region collective schedules (the paper's technique, TPU-native).

The paper's core insight: commutative updates should be combined
*hierarchically* — filter/reduce inside the sender's region, then forward
one combined record to the owner.  On a multi-pod TPU mesh the regions
are pods (cheap, wide intra-pod ICI) and the owners are shards:

  proxy_psum            hierarchical gradient sync:
                          reduce-scatter inside the pod  (regional combine)
                          -> all-reduce across pods on 1/N-size shards
                          -> all-gather inside the pod
                        vs a flat all-reduce over all devices.  Same
                        result (psum is associative+commutative = the
                        paper's proxy-coherence requirement); the
                        cross-pod (expensive-link) bytes drop by the
                        region size.

  two_hop_all_to_all    MoE dispatch factorized per mesh axis: tokens
                        cross the pod boundary once, pre-grouped by
                        destination — DeepSeek-V3's node-limited routing
                        is exactly proxy regions for tokens.

  proxy_embedding_grad  vocab-sharded embedding-gradient scatter with
                        regional segment-combine before the cross-region
                        reduce — literally the paper's Histogram proxy.

All are written with shard_map + jax.lax collectives and are
equivalence-tested against their flat counterparts.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from .compat import axis_size, shard_map


# --------------------------------------------------------------------------
# hierarchical (proxy) psum — building block, usable INSIDE shard_map
# --------------------------------------------------------------------------
def proxy_psum(x, region_axis: str, cross_axis: str | None):
    """Hierarchical psum of a per-device partial value.

    region_axis: intra-region mesh axis (e.g. 'data' inside a pod).
    cross_axis:  cross-region axis (e.g. 'pod'); None => flat psum.

    Uses RS -> AR -> AG when the leading dim divides the region size,
    else falls back to a flat psum (correctness first; the schedule is an
    optimization, not a semantic change).
    """
    if cross_axis is None:
        return jax.lax.psum(x, region_axis)
    region = axis_size(region_axis)
    if x.ndim == 0 or x.shape[0] % region != 0:
        return jax.lax.psum(x, (region_axis, cross_axis))
    # 1. regional combine: each region member ends up owning 1/region of
    #    the fully-combined regional value (the proxy tile's P$ content).
    shard = jax.lax.psum_scatter(x, region_axis, scatter_dimension=0,
                                 tiled=True)
    # 2. one cross-region record per shard (write-through to the owner).
    shard = jax.lax.psum(shard, cross_axis)
    # 3. redistribute inside the region.
    return jax.lax.all_gather(shard, region_axis, axis=0, tiled=True)


def flat_psum(x, axes):
    return jax.lax.psum(x, tuple(axes))


def proxy_psum_tree(tree, region_axis: str, cross_axis: str | None):
    return jax.tree.map(
        lambda g: proxy_psum(g, region_axis, cross_axis), tree)


def hierarchical_psum(x, mesh: Mesh, region_axis: str = "data",
                      cross_axis: str | None = "pod",
                      batch_axes: tuple = ("pod", "data")):
    """Standalone wrapper (for tests / benchmarks): x carries a leading
    per-device partial axis laid out over ``batch_axes``; returns the
    replicated hierarchical sum."""
    spec = P(batch_axes)

    def f(xl):
        return proxy_psum(xl[0], region_axis, cross_axis)

    return jax.jit(shard_map(f, mesh=mesh, in_specs=(spec,), out_specs=P(),
                             check_vma=False))(x)


# --------------------------------------------------------------------------
# two-hop all-to-all (MoE dispatch across pods)
# --------------------------------------------------------------------------
def two_hop_all_to_all(x, region_axis: str, cross_axis: str | None):
    """All-to-all over the product (cross x region) device grid, factored
    into one intra-region hop followed by one cross-region hop (use
    INSIDE shard_map).

    x: (n_cross, n_region, m, d) per-device send buffer — slot
    [c, r, ...] goes to device (c, r) of the flattened grid.
    Returns the same-shaped receive buffer.

    The factorization sends each payload once over cheap intra-region
    links and exactly once over the expensive cross-region hop, already
    grouped by destination region — the proxy-region routing rule.
    """
    if cross_axis is None:
        shp = x.shape
        xx = x.reshape((shp[0] * shp[1],) + shp[2:])
        out = jax.lax.all_to_all(xx, region_axis, split_axis=0,
                                 concat_axis=0, tiled=True)
        return out.reshape(shp)
    # hop 1 (regional): exchange along region_axis; payload keeps its
    # cross-region slot so each device accumulates everything its region
    # must forward to each remote region.
    x = jax.lax.all_to_all(x, region_axis, split_axis=1, concat_axis=1,
                           tiled=True)
    # hop 2 (cross): one boundary crossing, pre-grouped.
    x = jax.lax.all_to_all(x, cross_axis, split_axis=0, concat_axis=0,
                           tiled=True)
    return x


def one_hop_all_to_all(x, region_axis: str, cross_axis: str | None):
    """Flat reference: a2a over the combined grid done as a single
    monolithic exchange (cross first, then region — same result, but every
    payload crosses the pod boundary ungrouped)."""
    if cross_axis is None:
        return two_hop_all_to_all(x, region_axis, None)
    x = jax.lax.all_to_all(x, cross_axis, split_axis=0, concat_axis=0,
                           tiled=True)
    x = jax.lax.all_to_all(x, region_axis, split_axis=1, concat_axis=1,
                           tiled=True)
    return x


# --------------------------------------------------------------------------
# proxy embedding-gradient scatter (the Histogram proxy)
# --------------------------------------------------------------------------
def proxy_embedding_grad(ids, gvals, vocab_pad: int, region_axis: str,
                         cross_axis: str | None):
    """Vocab-dense embedding gradient from sparse (token-id, grad) pairs,
    with the paper's proxy schedule (use INSIDE shard_map).

    ids: (n,) int32 local token ids; gvals: (n, d) local grads.
    Returns this device's (vocab_pad / region, d) owner shard.

    Regional combine first (segment-sum = P$ coalescing), then the
    cross-region reduce touches only combined records.
    """
    d = gvals.shape[-1]
    dense = jnp.zeros((vocab_pad, d), gvals.dtype).at[ids].add(gvals)
    shard = jax.lax.psum_scatter(dense, region_axis, scatter_dimension=0,
                                 tiled=True)
    if cross_axis is not None:
        shard = jax.lax.psum(shard, cross_axis)
    return shard


# --------------------------------------------------------------------------
# compressed cross-region sync (gradient compression on the expensive link)
# --------------------------------------------------------------------------
def _quantize_int8(x, block: int = 256):
    """Blockwise-scaled symmetric int8 quantization.  Returns (q, scales)."""
    n = x.size
    flat = x.reshape(-1)
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale[:, 0]


def _dequantize_int8(q, scale, shape):
    out = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    return out[: int(np.prod(shape))].reshape(shape)


def compressed_proxy_psum(x, region_axis: str, cross_axis: str | None,
                          block: int = 256):
    """proxy_psum with the *cross-region* hop int8-compressed.

    The regional combine runs at full precision (cheap links); only the
    combined shard crosses the expensive boundary quantized — 4x fewer
    DCI bytes on top of proxy_psum's 1/region reduction.  The intra-pod
    stages stay exact, so error is bounded by one int8 rounding of the
    regional sums (<= 0.4% of the per-block max, tested).
    """
    if cross_axis is None:
        return jax.lax.psum(x, region_axis)
    region = axis_size(region_axis)
    if x.ndim == 0 or x.shape[0] % region != 0:
        return jax.lax.psum(x, (region_axis, cross_axis))
    shard = jax.lax.psum_scatter(x, region_axis, scatter_dimension=0,
                                 tiled=True)
    # share one scale per block across pods (tiny f32 pmax first) so the
    # int32 sum of int8 payloads dequantizes exactly by that scale.
    _, scale_local = _quantize_int8(shard, block)
    scale = jax.lax.pmax(scale_local, cross_axis)
    flat = shard.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, block)
    q = jnp.round(blocks / jnp.maximum(scale[:, None], 1e-12)) \
        .astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), cross_axis)
    deq = _dequantize_int8(qsum, scale, shard.shape).astype(shard.dtype)
    return jax.lax.all_gather(deq, region_axis, axis=0, tiled=True)


# --------------------------------------------------------------------------
# off-chip record exchange (the distributed tile-grid runtime's boundary leg)
# --------------------------------------------------------------------------
def gather_records(parts, axis: str):
    """Exchange compact off-chip record buffers across the ``chips`` mesh
    axis (use INSIDE shard_map).

    ``parts`` is a tuple of same-length per-device record arrays (e.g.
    dst, val, mask).  Every chip all-gathers the full record stream and
    filters the records it owns on the receive side — an all-to-all
    without per-destination packing, which cannot overflow a send buffer
    no matter how skewed the destination distribution is (RMAT hubs make
    that skew the common case, not the corner case).  Returns the
    flattened (num_chips * R, ...) arrays in chip order.
    """
    return tuple(jax.lax.all_gather(p, axis, axis=0, tiled=True)
                 for p in parts)


# --------------------------------------------------------------------------
# analytic byte accounting (for the roofline deltas in EXPERIMENTS.md)
# --------------------------------------------------------------------------
def allreduce_bytes(n_bytes: float, n_dev: int) -> float:
    """Ring all-reduce wire bytes per device: 2 (N-1)/N * payload."""
    return 2.0 * (n_dev - 1) / n_dev * n_bytes


def proxy_sync_bytes(n_bytes: float, region: int, cross: int):
    """Per-device (intra, cross) wire bytes of RS+AR+AG vs flat AR over
    region*cross devices."""
    intra = 2.0 * (region - 1) / region * n_bytes          # RS + AG
    crossb = 2.0 * (cross - 1) / cross * (n_bytes / region)  # AR on shards
    flat = allreduce_bytes(n_bytes, region * cross)
    return dict(proxy_intra=intra, proxy_cross=crossb, flat=flat,
                cross_reduction=(flat / max(crossb, 1e-12)))
