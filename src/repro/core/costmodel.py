"""Cost / energy / time model for DCRA packages (paper Table III + §IV-B).

Everything here is analytic and deterministic: given the traffic counters
measured by the engine (exact message/hop/crossing counts) and a package
configuration, we price time, energy and dollars exactly the way the
paper does — Murphy-model die yield on a $6,047 7nm wafer, interposer /
substrate / bonding overheads, $7.5/GB HBM, and the per-level pJ/bit and
latency constants of Table III.

The BSP time model: each superstep costs
    max(compute_time, network_time_per_level..., memory_time)
where compute is PU-ops at 1 GHz, network time is level traffic divided by
provisioned level bandwidth (link width x links at that level), and memory
time covers D$ miss traffic to HBM.  This reproduces the paper's
observable effects (Fig. 6 link-width scaling, Fig. 9/10/11 tradeoffs)
from measured traffic rather than per-cycle router simulation.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Union

import numpy as np

from .netstats import MSG_BITS, SuperstepTrace, TrafficCounters
from .tilegrid import TileGrid

# --------------------------------------------------------------------------
# Table III constants
# --------------------------------------------------------------------------
SRAM_DENSITY_MIB_MM2 = 3.5
SRAM_RW_LAT_NS = 0.82
SRAM_READ_PJ_BIT = 0.18
SRAM_WRITE_PJ_BIT = 0.28
CACHE_TAG_PJ = 6.3

HBM_DENSITY_GIB_MM2 = 8.0 / 110.0
HBM_CHANNELS = 8
HBM_CHANNEL_GBS = 64.0
HBM_RW_LAT_NS = 50.0
HBM_RW_PJ_BIT = 3.7
HBM_REFRESH_PJ_BIT = 0.22
HBM_REFRESH_PERIOD_MS = 32.0

MCM_PHY_AREAL_GBIT_MM2 = 690.0
MCM_PHY_BEACH_GBIT_MM = 880.0
INTERPOSER_PHY_AREAL_GBIT_MM2 = 1070.0
INTERPOSER_PHY_BEACH_GBIT_MM = 1780.0
D2D_LINK_LAT_NS = 4.0
D2D_LINK_PJ_BIT = 0.55
NOC_WIRE_LAT_PS_MM = 50.0
NOC_WIRE_PJ_BIT_MM = 0.15
NOC_ROUTER_LAT_PS = 500.0
NOC_ROUTER_PJ_BIT = 0.10
IO_DIE_RXTX_LAT_NS = 20.0
OFF_PKG_PJ_BIT = 1.17

CLOCK_GHZ = 1.0
TILE_WIRE_MM = 0.8          # wire length of one tile-to-tile NoC hop

# Modeled D$ hit rate on touched dataset memory (paper: "high enough");
# the canonical value — benchmarks and the product search import it from
# here so the modeled rate cannot drift between figures.
D_CACHE_HIT = 0.85
HBM_LINE_BITS = 512         # D$ line fill per miss


def dcache_memory_bits(cfg: "PackageConfig", touched_bits: float,
                       hit_rate: float = D_CACHE_HIT):
    """Split touched dataset bits into (sram_bits, hbm_bits) under the
    modeled D$: hits are SRAM accesses; on HBM products each missed
    record additionally fetches a full HBM line.  The single memory
    policy every pricing site shares (Fig. 9, Fig. 10, product search).
    """
    if cfg.has_hbm:
        return (touched_bits * hit_rate,
                (1.0 - hit_rate) * touched_bits * (HBM_LINE_BITS / MSG_BITS))
    return touched_bits, 0.0

# Fabrication economics (§IV-B)
WAFER_COST_USD = 6047.0     # 300mm, 7nm
WAFER_DIAMETER_MM = 300.0
WAFER_EDGE_LOSS_MM = 4.0
SCRIBE_MM = 0.2
# Paper text says "0.07 defects per mm^2" — that must be per cm^2 (the
# isine yield calculator it cites uses defects/cm^2; 0.07/mm^2 would give
# ~1% yield on a 130mm^2 die).  We use the physically sane unit.
DEFECT_DENSITY_MM2 = 0.07 / 100.0
HBM_USD_PER_GB = 7.5
INTERPOSER_COST_FRAC_OF_DIE = 0.20   # HBM<->DCRA silicon interposer
SUBSTRATE_COST_FRAC_OF_DIE = 0.10    # organic substrate, per equal area
BONDING_COST_FRAC = 0.05

# Board-level packaging economics (the chip-partitioning axis): a chip
# product built as N separately packaged chips pays per-chip IO dies,
# board sockets/traces per chip site, a per-link SERDES+trace cost, and
# a known-good-assembly yield per bonded die (more dies in one package
# -> lower assembly yield; splitting into more chips trades that against
# extra IO dies and board links).
BOARD_LINK_USD = 3.0                 # SERDES lanes + board traces per link
BOARD_USD_PER_CHIP = 25.0            # socket/site + assembly per chip
CHIP_ASSEMBLY_YIELD_PER_DIE = 0.995  # multi-die package assembly yield/die

# PU model: simple in-order core, ~instructions per task-record / per edge.
PU_PJ_PER_OP = 2.0          # 7nm in-order RISC-V class energy/op (refs [90],[93])
PU_OPS_PER_RECORD = 8.0     # drain+compare+update per mailbox record
PU_OPS_PER_EDGE = 6.0       # stream one CSR edge and emit


# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PackageConfig:
    """Packaging-time design decisions for a DCRA chip product (Table II)."""

    name: str = "dcra-sram"
    sram_per_tile_mib: float = 1.5
    hbm_gb_per_die: float = 0.0            # 0 => SRAM-only product
    hbm_vertical: bool = False             # Fig. 5 3D option vs interposer
    intra_die_link_bits: int = 64          # NoC link width inside a die
    inter_die_link_bits: int = 64          # substrate links between dies
    inter_die_links: int = 2               # paper's option (c): 2x32-bit
    # I/O-die budget per border die.  The BSP time model serializes each
    # off-package/board link at this value in *bits per tile-clock cycle*
    # (at 1 GHz: numerically Gbit/s per link; 512 = 64 GB/s).
    off_pkg_gbs_per_die_edge: float = 512.0
    noc_count: int = 2                     # physical NoCs
    # Chip partitioning as a packaging decision (paper's multi-node
    # regime): ``chips`` is how many separately packaged chips the tile
    # grid is split into at board level (0 = unpartitioned / inherit the
    # measurement's partition), and ``board_links_y`` / ``board_links_x``
    # are the per-axis board-link provisioning — links laid between each
    # adjacent chip pair along that axis of the chip grid (the default 2
    # reproduces the distributed runtime's historical provisioning).
    chips: int = 0
    board_links_y: int = 2
    board_links_x: int = 2

    @property
    def has_hbm(self) -> bool:
        return self.hbm_gb_per_die > 0


# Paper's evaluated configurations.
DCRA_SRAM = PackageConfig(name="dcra-sram")
DCRA_HBM_HORIZ = PackageConfig(name="dcra-hbm-horiz", hbm_gb_per_die=8.0)
DCRA_HBM_VERT = PackageConfig(name="dcra-hbm-vert", hbm_gb_per_die=8.0,
                              hbm_vertical=True)
# Dalorex baseline: same chiplet integration (paper §V-C), no proxies, and
# the network option (a): single shared 32-bit crossing between dies.
DALOREX = PackageConfig(name="dalorex", intra_die_link_bits=32,
                        inter_die_link_bits=32, inter_die_links=1)

NETWORK_OPTIONS = {
    # Fig. 6 characterization: (intra_die_bits, inter_die_bits, inter_die_links)
    "a_2x32_od32": PackageConfig(name="a", intra_die_link_bits=32,
                                 inter_die_link_bits=32, inter_die_links=1),
    "b_32+64_od32": PackageConfig(name="b", intra_die_link_bits=64,
                                  inter_die_link_bits=32, inter_die_links=1),
    "c_32+64_od2x32": PackageConfig(name="c", intra_die_link_bits=64,
                                    inter_die_link_bits=32, inter_die_links=2),
    "d_32+64_od64": PackageConfig(name="d", intra_die_link_bits=64,
                                  inter_die_link_bits=64, inter_die_links=1),
}


# --------------------------------------------------------------------------
# Silicon cost (Murphy yield)
# --------------------------------------------------------------------------
def murphy_yield(area_mm2: float, d0: float = DEFECT_DENSITY_MM2) -> float:
    ad = area_mm2 * d0
    if ad == 0:
        return 1.0
    return ((1.0 - math.exp(-ad)) / ad) ** 2


def dies_per_wafer(area_mm2: float) -> float:
    r = WAFER_DIAMETER_MM / 2.0 - WAFER_EDGE_LOSS_MM
    side = math.sqrt(area_mm2) + SCRIBE_MM
    eff = side * side
    return max(1.0, math.pi * r * r / eff - math.pi * 2 * r / math.sqrt(2 * eff))


def die_cost(area_mm2: float) -> float:
    good = dies_per_wafer(area_mm2) * murphy_yield(area_mm2)
    return WAFER_COST_USD / good


def tile_area_mm2(sram_mib: float) -> float:
    """SRAM area + logic (PU+router+TSU = 1/7th of SRAM area at 1.5MiB, §V-A)."""
    sram = sram_mib / SRAM_DENSITY_MIB_MM2
    logic = (1.5 / SRAM_DENSITY_MIB_MM2) / 7.0
    return sram + logic


def dcra_die_area_mm2(cfg: PackageConfig, grid: TileGrid) -> float:
    tiles = grid.die_ny * grid.die_nx
    base = tiles * tile_area_mm2(cfg.sram_per_tile_mib)
    # PHY beachfront for inter-die links + I/O edge (adds ~4.5% for option c,
    # matching the paper's reported area growth).
    phy_frac = 0.02 + 0.0125 * cfg.inter_die_links
    if cfg.hbm_vertical:
        phy_frac += 0.05   # active-interposer pads/power for the 3D stack
    return base * (1.0 + phy_frac)


# --------------------------------------------------------------------------
@dataclasses.dataclass
class SystemReport:
    """Priced execution: produced by ``price()`` from measured counters."""

    time_s: float
    energy_j: float
    cost_usd: float
    power_w: float
    breakdown: Dict[str, float]

    @property
    def throughput_per_dollar(self) -> float:
        return 1.0 / (self.time_s * self.cost_usd)

    @property
    def efficiency_per_dollar(self) -> float:
        return 1.0 / (self.energy_j * self.cost_usd)


def system_cost_usd(cfg: PackageConfig, grid: TileGrid) -> float:
    """Dollar cost of the grid: DCRA dies + HBM + interposer/substrate/bonding.

    When ``cfg.chips >= 1`` the grid is priced as a *board-level product*
    of that many separately packaged chips: the same silicon, but each
    chip pays its own IO dies and board site, the board pays per-link
    provisioning (``board_link_provisioning``), and package assembly
    yield degrades with the number of dies bonded into one chip
    (``CHIP_ASSEMBLY_YIELD_PER_DIE``).  ``cfg.chips == 0`` keeps the
    legacy monolithic-assembly model (one IO-die pair per package, no
    board terms) so unpartitioned pricing is unchanged.
    """
    die_a = dcra_die_area_mm2(cfg, grid)
    dcra_unit = die_cost(die_a)
    dy, dx = grid.dies
    n_dies = dy * dx
    cost = n_dies * dcra_unit
    if cfg.has_hbm:
        cost += n_dies * cfg.hbm_gb_per_die * HBM_USD_PER_GB
        ip = INTERPOSER_COST_FRAC_OF_DIE * dcra_unit
        if cfg.hbm_vertical:
            ip *= 1.05  # paper: vertical costs ~5% more than horizontal
        cost += n_dies * ip
    # organic substrate (10% of equal-area die cost) + bonding 5%/die
    cost += n_dies * SUBSTRATE_COST_FRAC_OF_DIE * dcra_unit
    cost *= (1.0 + BONDING_COST_FRAC)
    if cfg.chips >= 1:
        cy, cx = chip_partition_dims(cfg, grid)
        n_chips = cy * cx
        # known-good-die assembly: every die bonded into a chip must
        # survive assembly for the chip to ship
        assembly_yield = CHIP_ASSEMBLY_YIELD_PER_DIE ** (n_dies / n_chips)
        cost /= assembly_yield
        # IO dies per chip (board-network ingress/egress) + board terms
        cost += n_chips * 2 * die_cost(30.0)
        cost += n_chips * BOARD_USD_PER_CHIP
        if n_chips > 1:
            cost += board_link_provisioning(cfg, cy, cx) * BOARD_LINK_USD
    else:
        # I/O dies: one per package edge, small 16-tile-edge die, cheap node
        cost += grid.num_packages * 2 * die_cost(30.0)
    return cost


# --------------------------------------------------------------------------
# BSP time model (shared by the engine run loops and analytic re-pricing)
# --------------------------------------------------------------------------
def link_provisioning(grid: TileGrid, pkg: PackageConfig) -> dict:
    """Per-level link counts + grid diameter for the BSP time model.

    Intra-die capacity scales with the number of physical NoCs (the
    paper's dual-NoC tile: ``noc_count=2`` is the default provisioning of
    4 links/tile, so existing configs are unchanged).
    """
    dy, dx = grid.dies
    n_die_links = (dy * (dx - 1) + dx * (dy - 1)) * 2 * pkg.inter_die_links \
        if dy * dx > 1 else 1
    py, px = grid.packages
    n_pkg_links = max(1, (py * (px - 1) + px * (py - 1)) * 2)
    return dict(intra=grid.num_tiles * 2 * pkg.noc_count, die=n_die_links,
                pkg=n_pkg_links,
                diameter=(grid.ny + grid.nx) / (2 if grid.torus else 1))


def board_link_provisioning(cfg: PackageConfig, chips_y: int,
                            chips_x: int) -> int:
    """Total board links provisioned for a (chips_y x chips_x) chip grid
    under ``cfg``'s per-axis knobs: ``board_links_x`` links between each
    horizontally adjacent chip pair, ``board_links_y`` vertically.  The
    single formula the distributed run loop and analytic re-pricing share
    — re-pricing a measured trace under its own config must reproduce the
    run loop's board serialization exactly."""
    return max(1, chips_y * (chips_x - 1) * cfg.board_links_x
               + chips_x * (chips_y - 1) * cfg.board_links_y)


def chip_partition_dims(cfg: PackageConfig, grid: TileGrid):
    """(chips_y, chips_x) of the board partition ``cfg.chips`` selects on
    ``grid`` (the most square dividing chip grid, same rule as
    ``tilegrid.partition_grid``).  Returns (1, 1) for unpartitioned
    products; raises ValueError when the count cannot partition the grid."""
    if cfg.chips <= 1:
        return 1, 1
    from .tilegrid import partition_grid
    part = partition_grid(grid, cfg.chips)
    return part.chips_y, part.chips_x


def _off_pkg_bits_per_cycle(cfg: PackageConfig) -> float:
    # The BSP model serializes off-package/board links at the IO-die
    # budget expressed in bits/cycle at the 1 GHz tile clock (default 512).
    return float(cfg.off_pkg_gbs_per_die_edge)


# BSP level names, in the order step_cycles maxes them (the exporter's
# simulated-time track order)
STEP_CYCLE_LEVELS = ("compute", "intra", "die", "pkg", "endpoint", "board",
                     "hbm")


def step_cycle_terms(cfg: PackageConfig, links: dict, *, compute_ops,
                     intra_bits, die_bits, pkg_bits, endpoint_bits=0.0,
                     hbm_bits=0.0, off_chip_bits=0.0, board_links=1,
                     n_dies=1) -> Dict[str, np.ndarray]:
    """Named per-level BSP cycle terms of superstep(s) — the
    decomposition behind :func:`step_cycles`' max.  Keys are
    :data:`STEP_CYCLE_LEVELS` (``hbm`` only on HBM products with miss
    traffic).  Works elementwise on scalars or per-superstep vectors.
    The telemetry exporter (``obs.export``) renders these as the
    per-level simulated-time tracks; ``step_cycles`` maxes them, so the
    timeline and the priced time cannot drift."""
    terms = dict(
        compute=np.asarray(compute_ops, dtype=np.float64),
        intra=(np.asarray(intra_bits, np.float64)
               / (links["intra"] * cfg.intra_die_link_bits)),
        die=(np.asarray(die_bits, np.float64)
             / (links["die"] * cfg.inter_die_link_bits)),
        pkg=(np.asarray(pkg_bits, np.float64)
             / (links["pkg"] * _off_pkg_bits_per_cycle(cfg))),
        endpoint=(np.asarray(endpoint_bits, np.float64)
                  / cfg.intra_die_link_bits),
        board=(np.asarray(off_chip_bits, np.float64)
               / (max(board_links, 1) * _off_pkg_bits_per_cycle(cfg))),
    )
    # HBM drain: miss traffic served by the package's HBM channels,
    # converted to tile-clock cycles.
    hbm = np.asarray(hbm_bits, np.float64)
    if cfg.has_hbm and np.any(hbm > 0):
        hbm_bytes_per_cycle = (n_dies * HBM_CHANNELS * HBM_CHANNEL_GBS * 1e9
                               / (CLOCK_GHZ * 1e9))
        terms["hbm"] = hbm / 8.0 / hbm_bytes_per_cycle
    return terms


def step_cycles(cfg: PackageConfig, links: dict, *, compute_ops,
                intra_bits, die_bits, pkg_bits, endpoint_bits=0.0,
                hbm_bits=0.0, off_chip_bits=0.0, board_links=1,
                n_dies=1):
    """BSP cycles of superstep(s): max over (tile compute, per-level
    network serialization, endpoint contention, HBM drain, board leg).
    Works elementwise on scalars or per-superstep numpy vectors."""
    terms = step_cycle_terms(
        cfg, links, compute_ops=compute_ops, intra_bits=intra_bits,
        die_bits=die_bits, pkg_bits=pkg_bits, endpoint_bits=endpoint_bits,
        hbm_bits=hbm_bits, off_chip_bits=off_chip_bits,
        board_links=board_links, n_dies=n_dies)
    t = terms["compute"]
    for name in STEP_CYCLE_LEVELS[1:]:
        if name in terms:
            t = np.maximum(t, terms[name])
    return t


# ``per_superstep_peak`` keys understood by :func:`price` (beyond the
# legacy whole-run {'time_s': ...} shortcut).
TRACE_KEYS = ("compute_ops", "intra_bits", "die_bits", "pkg_bits",
              "hbm_bits")


def _trace_from_peak(peak) -> tuple:
    """Normalize price()'s per_superstep_peak argument.

    Returns (trace_dict, hbm_bits_or_None) where trace_dict maps vector
    names to numpy arrays, or (None, None) when the argument is the
    legacy {'time_s': t} form (or None).
    """
    if peak is None:
        return None, None
    if isinstance(peak, SuperstepTrace):
        d = peak.to_dict()
    else:
        d = dict(peak)
        if not any(k in d for k in TRACE_KEYS):
            return None, None       # legacy {'time_s': ...}
    n = max((len(np.atleast_1d(d[k])) for k in d
             if k in SuperstepTrace._VECTOR_FIELDS + ("hbm_bits",)),
            default=0)
    if n == 0:
        return None, None

    def vec(key, default=0.0):
        v = np.atleast_1d(np.asarray(d.get(key, default), np.float64))
        return np.broadcast_to(v, (n,)) if v.shape[0] != n else v

    trace = {k: vec(k) for k in SuperstepTrace._VECTOR_FIELDS}
    trace["board_links"] = int(d.get("board_links", 1))
    trace["chips_y"] = int(d.get("chips_y", 1))
    trace["chips_x"] = int(d.get("chips_x", 1))
    trace["double_buffer"] = bool(d.get("double_buffer", False))
    trace["recovery_events"] = [dict(ev)
                                for ev in d.get("recovery_events", ())]
    hbm = vec("hbm_bits") if "hbm_bits" in d else None
    return trace, hbm


def trace_time_s(cfg: PackageConfig, grid: TileGrid, trace,
                 mem_bits_hbm: float = 0.0) -> float:
    """Recompute BSP time superstep-wise from recorded level traffic.

    ``trace`` is a :class:`~repro.core.netstats.SuperstepTrace` or a dict
    of per-superstep vectors (scalars broadcast).  This replays the run
    loop's time accounting exactly — per-step level maxima, pipeline-fill
    per active step, IO-die latency per off-chip step — but under an
    arbitrary :class:`PackageConfig`, which is what makes a measured run
    re-priceable across a package design space.
    """
    td, hbm_bits = _trace_from_peak(trace)
    if td is None:
        raise ValueError("trace has no per-superstep level-traffic keys")
    return _trace_time_s_parsed(cfg, grid, td, hbm_bits, mem_bits_hbm)


def _board_links_for(cfg: PackageConfig, td) -> int:
    """Board-link count the BSP board leg serializes over.

    A trace that recorded its chip-partition geometry is re-provisioned
    under *this* config's per-axis board-link knobs — the rescaling that
    makes board-link provisioning a packaging axis.  Traces without
    geometry (legacy dicts, monolithic runs) keep their recorded count.
    A config that names a chip count different from the measured
    partition is rejected: the off-chip traffic in the trace is a
    property of the partition it ran on, so cross-chip-count re-pricing
    needs a new measurement, not a rescale (``ProductSearch.sweep``
    re-measures per chip count).
    """
    cy, cx = int(td["chips_y"]), int(td["chips_x"])
    measured = cy * cx
    if cfg.chips >= 1 and cfg.chips != max(measured, 1):
        raise ValueError(
            f"config prices a {cfg.chips}-chip product but the trace was "
            f"measured on a {cy}x{cx} chip partition ({max(measured, 1)} "
            f"chips); re-measure at chips={cfg.chips} instead of "
            f"re-pricing across chip counts")
    if measured > 1:
        return board_link_provisioning(cfg, cy, cx)
    return int(td["board_links"])


def _parsed_terms(cfg: PackageConfig, grid: TileGrid, td, hbm_bits,
                  mem_bits_hbm: float):
    """Per-superstep level terms + accounting constants of a parsed trace
    dict — the shared front half of the full replay and the
    recovery-window replay (so a faulted run's discarded-work pricing
    cannot drift from its base replay)."""
    if hbm_bits is None:
        # Apportion the run's total HBM miss traffic across supersteps
        # proportionally to touched dataset bits.
        hbm_bits = np.zeros(len(td["compute_ops"]))
        if cfg.has_hbm and mem_bits_hbm > 0:
            touched = td["touched_bits"]
            tot = float(np.sum(touched))
            if tot > 0:
                hbm_bits = mem_bits_hbm * touched / tot
            else:
                hbm_bits = np.full_like(touched,
                                        mem_bits_hbm / max(len(touched), 1))
    links = link_provisioning(grid, cfg)
    dy, dx = grid.dies
    blinks = _board_links_for(cfg, td)
    terms = step_cycle_terms(
        cfg, links, compute_ops=td["compute_ops"],
        intra_bits=td["intra_bits"], die_bits=td["die_bits"],
        pkg_bits=td["pkg_bits"], endpoint_bits=td["endpoint_bits"],
        hbm_bits=hbm_bits, off_chip_bits=td["off_chip_bits"],
        board_links=blinks, n_dies=dy * dx)
    io_lat = 2.0 * IO_DIE_RXTX_LAT_NS * CLOCK_GHZ
    fill = links["diameter"] * 0.5
    return terms, io_lat, fill, blinks


def _window_cycles(td, terms, io_lat: float, fill: float,
                   lo: int, hi: int) -> float:
    """Replay cycles of supersteps ``[lo, hi)`` only — the discarded-work
    window of a rollback event.  Uses the exact per-step rule of the full
    replay below (sync or double-buffered), restricted to the window; in
    double-buffer mode the window's first charged step pays the exchange
    of the last charged step *before* the window, which is precisely the
    ``prev_exch`` the run loop restores from its checkpoint snapshot."""
    if td.get("double_buffer"):
        core = terms["compute"]
        for name in STEP_CYCLE_LEVELS[1:]:
            if name != "board" and name in terms:
                core = np.maximum(core, terms[name])
        board = terms["board"]
        exch = board + io_lat * (td["off_chip_msgs"] > 0)
        charged = (core > 0) | (board > 0) | (td["pending"] > 0)
        ce, ee = core[charged], exch[charged]
        prev = np.concatenate(([0.0], ee[:-1]))
        pos = np.flatnonzero(charged)
        sel = (pos >= lo) & (pos < hi)
        return (float(np.sum(np.maximum(ce, prev)[sel]))
                + float(np.sum(sel)) * fill)
    t = terms["compute"]
    for name in STEP_CYCLE_LEVELS[1:]:
        if name in terms:
            t = np.maximum(t, terms[name])
    charged = (t > 0) | (td["pending"] > 0)
    idx = np.arange(t.shape[0])
    w = charged & (idx >= lo) & (idx < hi)
    cycles = float(np.sum(t[w]))
    cycles += float(np.sum(w)) * fill
    cycles += float(np.sum(w & (td["off_chip_msgs"] > 0))) * io_lat
    return cycles


def checkpoint_leg_cycles(cfg: PackageConfig, bits: float,
                          board_links: int) -> float:
    """Cycles to stream a ``bits``-sized checkpoint image over the
    provisioned board links (checkpoint write, restore and
    re-shard-onto-survivors all move the same image; the serialization
    matches the BSP board leg).  The single formula the distributed run
    loop's fault-tolerance accounting and the trace replay share — so
    re-pricing a faulted run under its own config reproduces its
    measured time exactly."""
    return float(bits) / (max(int(board_links), 1)
                          * _off_pkg_bits_per_cycle(cfg))


def recovery_waste_cycles(cfg: PackageConfig, grid: TileGrid, trace,
                          lo: int, hi: int) -> float:
    """Cycles the run loop spent executing supersteps ``[lo, hi)`` — the
    work a rollback to checkpoint ``lo`` after failing at ``hi``
    discards.  The run loop calls this at rollback time (its trace then
    holds rows ``[0, hi)``); the replay recomputes it from the final
    trace, whose ``[lo, hi)`` rows are bit-identical because the resumed
    run re-records them — both sides therefore add the exact same
    float."""
    td, hbm_bits = _trace_from_peak(trace)
    if td is None:
        return 0.0
    terms, io_lat, fill, _ = _parsed_terms(cfg, grid, td, hbm_bits, 0.0)
    return _window_cycles(td, terms, io_lat, fill, int(lo), int(hi))


def _recovery_overhead_cycles(cfg: PackageConfig, td, terms, io_lat: float,
                              fill: float, blinks: int) -> float:
    """Replay the fault-tolerance event log: checkpoint/restore board
    legs plus each rollback's discarded-work window, accumulated in
    execution order (the run loop adds the identical floats in the
    identical order into its separate overhead accumulator)."""
    oh = 0.0
    for ev in td.get("recovery_events") or ():
        kind = ev.get("kind")
        if kind in ("checkpoint", "reshard"):
            oh += checkpoint_leg_cycles(cfg, float(ev.get("bits", 0.0)),
                                        blinks)
        elif kind == "rollback":
            oh += _window_cycles(td, terms, io_lat, fill,
                                 int(ev["from_step"]), int(ev["at_step"]))
    return oh


def _trace_time_s_parsed(cfg: PackageConfig, grid: TileGrid, td, hbm_bits,
                         mem_bits_hbm: float) -> float:
    terms, io_lat, fill, blinks = _parsed_terms(cfg, grid, td, hbm_bits,
                                                mem_bits_hbm)
    if td.get("double_buffer"):
        # Overlap-aware accumulation (double-buffered boundary exchange):
        # superstep k's board leg + IO-die latency overlap superstep
        # k+1's chip-local BSP work, so each charged step pays
        # max(core_k, exchange_{k-1}) and the final exchange drains in
        # the open.  Mirrors the run loop's double_buffer accounting —
        # a trace with no board traffic degenerates to the sync rule.
        core = terms["compute"]
        for name in STEP_CYCLE_LEVELS[1:]:
            if name != "board" and name in terms:
                core = np.maximum(core, terms[name])
        board = terms["board"]
        exch = board + io_lat * (td["off_chip_msgs"] > 0)
        charged = (core > 0) | (board > 0) | (td["pending"] > 0)
        ce, ee = core[charged], exch[charged]
        cycles = float(np.sum(np.maximum(
            ce, np.concatenate(([0.0], ee[:-1])))))
        cycles += ce.shape[0] * fill
        cycles += float(ee[-1]) if ee.shape[0] else 0.0
    else:
        t = terms["compute"]
        for name in STEP_CYCLE_LEVELS[1:]:
            if name in terms:
                t = np.maximum(t, terms[name])
        charged = (t > 0) | (td["pending"] > 0)
        cycles = float(np.sum(t[charged]))
        cycles += float(np.sum(charged)) * fill
        io_steps = charged & (td["off_chip_msgs"] > 0)
        cycles += float(np.sum(io_steps)) * io_lat
    # fault-tolerance overhead: checkpoint legs, rollback waste, re-shard
    # legs — the run loop keeps these in a separate accumulator added
    # once at the end, so one final addition here matches it bit-exactly
    cycles += _recovery_overhead_cycles(cfg, td, terms, io_lat, fill,
                                        blinks)
    return cycles / (CLOCK_GHZ * 1e9)


def price(cfg: PackageConfig, grid: TileGrid, counters: TrafficCounters,
          mem_bits_sram: float = 0.0, mem_bits_hbm: float = 0.0,
          per_superstep_peak: Union[SuperstepTrace, Dict[str, float],
                                    None] = None) -> SystemReport:
    """Convert measured traffic into (time, energy, $) under a package config.

    Args:
      counters: whole-run accumulated counters from the engine.
      mem_bits_sram / mem_bits_hbm: dataset bits read+written locally.
      per_superstep_peak: optional per-superstep level traffic — a
        :class:`~repro.core.netstats.SuperstepTrace` (what
        ``RunResult.trace`` carries) or a dict with vectors/scalars for
        {'compute_ops', 'intra_bits', 'die_bits', 'pkg_bits',
        'hbm_bits'} (plus the optional trace extras: 'endpoint_bits',
        'off_chip_bits', 'off_chip_msgs', 'touched_bits', 'pending',
        'board_links').  When provided, time is recomputed superstep-wise
        under *this* config — the BSP max per superstep with this
        config's link widths/counts, NoC count and HBM channels — so the
        same measured run can be re-priced across package configs.  When
        'hbm_bits' is absent it is derived from ``mem_bits_hbm``
        apportioned over 'touched_bits'.  The legacy ``{'time_s': t}``
        form is still accepted and uses ``t`` unchanged.
    """
    bits = MSG_BITS
    # ------------------------------------------------------------- energy
    e_wire = (counters.intra_die_hops * bits
              * (NOC_WIRE_PJ_BIT_MM * TILE_WIRE_MM + NOC_ROUTER_PJ_BIT))
    e_d2d = counters.inter_die_crossings * bits * (D2D_LINK_PJ_BIT + NOC_ROUTER_PJ_BIT)
    e_pkg = counters.inter_pkg_crossings * bits * OFF_PKG_PJ_BIT
    # board-level legs of the distributed runtime: each chip-grid hop is
    # one IO-die SERDES Tx + board trace + Rx (charged like an off-package
    # link crossing; the IO-die latency enters the BSP time model instead)
    e_off_chip = counters.off_chip_hop_msgs * bits * OFF_PKG_PJ_BIT
    if cfg.has_hbm and cfg.hbm_vertical:
        # 3D stacking saves the interposer wire energy on HBM accesses.
        hbm_pj = HBM_RW_PJ_BIT * 0.72
    else:
        hbm_pj = HBM_RW_PJ_BIT
    e_sram = mem_bits_sram * (SRAM_READ_PJ_BIT + SRAM_WRITE_PJ_BIT) / 2.0
    e_hbm = mem_bits_hbm * hbm_pj
    ops = (counters.records_consumed * PU_OPS_PER_RECORD
           + counters.edges_processed * PU_OPS_PER_EDGE)
    e_pu = ops * PU_PJ_PER_OP
    # P$ tag checks — including the combine events at intermediate proxies
    # of the cascade reduction tree (each merge is one tag check + combine)
    e_tags = (counters.filtered_at_proxy + counters.coalesced_at_proxy
              + counters.cascade_combined) * CACHE_TAG_PJ
    energy_pj = (e_wire + e_d2d + e_pkg + e_off_chip + e_sram + e_hbm
                 + e_pu + e_tags)

    # --------------------------------------------------------------- time
    trace_dict, hbm_vec = _trace_from_peak(per_superstep_peak)
    time_s = None
    if trace_dict is not None:
        # the documented contract: recompute the BSP time superstep-wise
        # from recorded level traffic under *this* package config
        time_s = _trace_time_s_parsed(cfg, grid, trace_dict, hbm_vec,
                                      mem_bits_hbm)
    elif (per_superstep_peak is not None
          and not isinstance(per_superstep_peak, SuperstepTrace)
          and "time_s" in per_superstep_peak):
        time_s = per_superstep_peak["time_s"]
    if time_s is None:
        # fall back: aggregate roofline over the whole run (also the
        # path for an empty trace — a run that recorded no supersteps)
        n_tiles = grid.num_tiles
        compute_s = ops / n_tiles / (CLOCK_GHZ * 1e9)
        dy, dx = grid.dies
        intra_bw = cfg.intra_die_link_bits * CLOCK_GHZ * 1e9  # bit/s per link
        # bisection-style serialization: level traffic / (links at level * bw)
        intra_links = n_tiles * 2
        die_links = (dy * dx) * 2 * cfg.inter_die_links
        die_bw = cfg.inter_die_link_bits * CLOCK_GHZ * 1e9
        pkg_links = max(1, grid.num_packages) * 4
        pkg_bw = _off_pkg_bits_per_cycle(cfg) * CLOCK_GHZ * 1e9  # bit/s
        t_intra = counters.intra_die_hops * bits / (intra_links * intra_bw)
        t_die = counters.inter_die_crossings * bits / (max(die_links, 1) * die_bw)
        t_pkg = counters.inter_pkg_crossings * bits / (max(pkg_links, 1) * pkg_bw)
        t_hbm = 0.0
        if cfg.has_hbm and mem_bits_hbm:
            t_hbm = (mem_bits_hbm / 8.0) / (dy * dx * HBM_CHANNELS * HBM_CHANNEL_GBS * 1e9)
        time_s = max(compute_s, t_intra, t_die, t_pkg, t_hbm)

    # refresh energy for HBM over the runtime
    if cfg.has_hbm:
        dy, dx = grid.dies
        stored_bits = dy * dx * cfg.hbm_gb_per_die * 8e9
        energy_pj += stored_bits * HBM_REFRESH_PJ_BIT * (time_s * 1e3 / HBM_REFRESH_PERIOD_MS)

    energy_j = energy_pj * 1e-12
    cost = system_cost_usd(cfg, grid)
    return SystemReport(
        time_s=time_s, energy_j=energy_j, cost_usd=cost,
        power_w=energy_j / max(time_s, 1e-12),
        breakdown=dict(
            wire_j=(e_wire + e_d2d + e_pkg) * 1e-12,
            off_chip_j=e_off_chip * 1e-12,
            mem_j=(e_sram + e_hbm) * 1e-12,
            pu_j=e_pu * 1e-12,
            tags_j=e_tags * 1e-12,
            ops=ops,
        ),
    )
