"""Proxy regions (paper §III-A): the core technique.

The tile grid is divided into P subgrids ("proxy regions").  Each region
holds proxy ownership of an entire selected data array, distributed across
the region's tiles by taking the owner tile's coordinates modulo the
region dimensions (the paper's P_DIST).  A task message destined for a
far-away owner is first routed to the proxy tile inside the *sender's*
region, where a direct-mapped proxy cache (P$) filters unsuccessful
updates (e.g. non-improving minimisations) and coalesces commutative ones
(additions) before forwarding a single combined record to the true owner.

Policies (paper §III-A "Proxy Coherence"):
  * write-through: forward whenever the proxy value improves (used by
    SSSP/BFS/WCC, which run without epoch barriers and need fast
    propagation);
  * write-back: accumulate locally and flush on eviction / at epoch or
    kernel end (used by PageRank(BSP), SPMV, Histogram, whose updates are
    purely additive).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .tilegrid import TileGrid


@dataclasses.dataclass(frozen=True)
class ProxyConfig:
    """Per-task proxy configuration (one entry of Table II 'Per Task')."""

    region_ny: int
    region_nx: int
    slots: int = 1024          # P$ entries per tile (direct-mapped)
    write_back: bool = False   # False => write-through

    def num_regions(self, grid: TileGrid) -> int:
        return (grid.ny // self.region_ny) * (grid.nx // self.region_nx)


def region_id(grid: TileGrid, cfg: ProxyConfig, tid):
    """Proxy-region id of a tile."""
    y, x = grid.coords(tid)
    rx = grid.nx // cfg.region_nx
    return (y // cfg.region_ny) * rx + (x // cfg.region_nx)


def proxy_tile(grid: TileGrid, cfg: ProxyConfig, owner_tid, src_tid):
    """Proxy tile for a message from ``src_tid`` to owner ``owner_tid``.

    The proxy lives in the sender's region, at the owner's coordinates
    modulo the region dimensions (paper Fig. 2).
    """
    oy, ox = grid.coords(owner_tid)
    sy, sx = grid.coords(src_tid)
    ry0 = (sy // cfg.region_ny) * cfg.region_ny
    rx0 = (sx // cfg.region_nx) * cfg.region_nx
    py = ry0 + oy % cfg.region_ny
    px = rx0 + ox % cfg.region_nx
    return grid.tid(py, px)


def pcache_slot(cfg: ProxyConfig, global_idx):
    """Direct-mapped P$ slot for a global array index.

    Indices that proxy to the same tile are congruent modulo the region
    geometry, so a simple modulo hash distributes them across slots.
    A P$ line holds a single element (paper §III-C) to avoid multi-update
    messages in write-back mode.
    """
    return global_idx % jnp.int32(cfg.slots)


def make_pcache(grid: TileGrid, cfg: ProxyConfig, default_value: float):
    """Allocate per-tile P$ state: (tags, values).  tag == -1 => invalid."""
    shape = (grid.num_tiles, cfg.slots)
    tags = jnp.full(shape, -1, dtype=jnp.int32)
    vals = jnp.full(shape, default_value, dtype=jnp.float32)
    return tags, vals
