"""Proxy regions (paper §III-A) and selective cascading: the core techniques.

The tile grid is divided into P subgrids ("proxy regions").  Each region
holds proxy ownership of an entire selected data array, distributed across
the region's tiles by taking the owner tile's coordinates modulo the
region dimensions (the paper's P_DIST).  A task message destined for a
far-away owner is first routed to the proxy tile inside the *sender's*
region, where a direct-mapped proxy cache (P$) filters unsuccessful
updates (e.g. non-improving minimisations) and coalesces commutative ones
(additions) before forwarding a single combined record to the true owner.

Policies (paper §III-A "Proxy Coherence"):
  * write-through: forward whenever the proxy value improves (used by
    SSSP/BFS/WCC, which run without epoch barriers and need fast
    propagation);
  * write-back: accumulate locally and flush on eviction / at epoch or
    kernel end (used by PageRank(BSP), SPMV, Histogram, whose updates are
    purely additive).

Selective cascading (the paper's scaling mechanism; see also Tascade)
------------------------------------------------------------------------
Without cascading, every record a proxy forwards travels straight to the
true owner — at large grid sizes all those updates converge on one tile
and the owner-bound legs dominate cross-chip traffic.  ``CascadeConfig``
instead drains proxy output through a *region reduction tree*: level-0
regions are grouped ``group_ny x group_nx`` into level-1 super-regions,
those again into level-2, and so on.  A record climbs from its region
proxy to the proxy for the same index in its level-1 super-region, where
records from sibling regions headed to the same index are combined into
one, then to level-2, ..., and only the tree root forwards to the owner.
Updates are thus combined hierarchically instead of all converging on the
true owner.

"Selective" is twofold:
  * per record — a record whose owner already lies inside its current
    super-region exits the tree and goes straight to the owner (climbing
    further could not merge it with records from other subtrees on a
    shorter path);
  * per app — cascading is only applied to apps whose combine makes the
    merge profitable (commutative reductions; ``AppSpec.cascade_profitable``),
    when ``selective=True``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp

from .tilegrid import TileGrid


@dataclasses.dataclass(frozen=True)
class CascadeConfig:
    """Region reduction-tree policy for draining proxy output.

    levels:   number of tree levels above the base proxy regions.
    group_ny, group_nx: how many child regions merge into a parent region
              along each axis per level (the paper's reduction-tree fanin).
    selective: apply the selective criterion (per-record early exit and
              the per-app combine-profitability gate).

    A level whose region dimensions reach the whole grid is the
    degenerate tree root: its proxy for any index *is* the owner tile, so
    it adds no wire traffic (and under ``selective=True`` every record
    early-exits it).  Configs whose top level equals the grid therefore
    have ``levels - 1`` genuinely combining sub-grid levels; size the
    base regions (e.g. ``table2_proxy(region_div=8)``) so the top level
    stays below the grid when deeper trees are wanted.
    """

    levels: int = 2
    group_ny: int = 2
    group_nx: int = 2
    selective: bool = True

    def __post_init__(self):
        if self.levels < 1:
            raise ValueError("cascade levels must be >= 1")
        if self.group_ny < 1 or self.group_nx < 1:
            raise ValueError("cascade grouping factors must be >= 1")
        if self.group_ny * self.group_nx < 2:
            raise ValueError(
                "cascade grouping must merge at least 2 regions per level")

    def level_dims(self, region_ny: int, region_nx: int,
                   level: int) -> Tuple[int, int]:
        """Region dimensions at tree level ``level`` (0 = base regions)."""
        return (region_ny * self.group_ny ** level,
                region_nx * self.group_nx ** level)


@dataclasses.dataclass(frozen=True)
class ProxyConfig:
    """Per-task proxy configuration (one entry of Table II 'Per Task')."""

    region_ny: int
    region_nx: int
    slots: int = 1024          # P$ entries per tile (direct-mapped)
    write_back: bool = False   # False => write-through
    cascade: Optional[CascadeConfig] = None

    def num_regions(self, grid: TileGrid) -> int:
        # ceil division, consistent with TileGrid.region_id's numbering
        # (edge regions of a non-divisible grid count as regions).
        return (-(-grid.ny // self.region_ny)) * (-(-grid.nx // self.region_nx))

    def validate(self, grid: TileGrid) -> None:
        """Check the cascade region grouping tiles the grid exactly.

        Raises ValueError on non-divisible groupings: every tree level's
        region dimensions must divide the grid, otherwise super-regions
        straddle the grid edge and the reduction tree is ill-formed.
        """
        self.validate_window(grid.ny, grid.nx)

    def validate_window(self, ny: int, nx: int) -> None:
        """``validate`` against an arbitrary tile window (the whole grid
        for the monolithic engine, one chip's subgrid for the distributed
        runtime)."""
        if self.cascade is None:
            return
        if ny % self.region_ny or nx % self.region_nx:
            raise ValueError(
                f"proxy regions {self.region_ny}x{self.region_nx} do not "
                f"divide the {ny}x{nx} window (required for cascading)")
        for level in range(1, self.cascade.levels + 1):
            rny, rnx = self.cascade.level_dims(self.region_ny,
                                               self.region_nx, level)
            if ny % rny or nx % rnx:
                raise ValueError(
                    f"cascade level {level} regions {rny}x{rnx} do not "
                    f"divide the {ny}x{nx} window: grouping "
                    f"{self.cascade.group_ny}x{self.cascade.group_nx} is "
                    f"non-divisible at this level")


def max_cascade_levels(ny: int, nx: int, region_ny: int, region_nx: int,
                       group_ny: int = 2, group_nx: int = 2) -> int:
    """Deepest well-formed reduction tree on an ``ny x nx`` window.

    Counts how many levels of ``group_ny x group_nx`` region grouping
    divide the window exactly, stopping before a level's regions would
    cover the whole window (such a level is the degenerate tree root —
    its proxy *is* the owner tile, so it combines nothing).  Cascade
    sweeps (the product search's per-app level/grouping exploration) use
    this to enumerate only the depths ``validate_window`` would accept.
    """
    if ny % region_ny or nx % region_nx:
        return 0
    fit = 0
    for level in range(1, 64):
        rny = region_ny * group_ny ** level
        rnx = region_nx * group_nx ** level
        if ny % rny or nx % rnx or (rny >= ny and rnx >= nx):
            break
        fit = level
    return fit


def chip_local_proxy(cfg: ProxyConfig, sub_ny: int, sub_nx: int) -> ProxyConfig:
    """Adapt a proxy config to one chip's ``sub_ny x sub_nx`` tile window.

    The distributed runtime runs the proxy stage chip-locally: a sender's
    region — and every cascade tree level — must lie entirely on the
    sender's chip, so proxy/cascade roots sit at the chip boundary and
    anything bound further out rides the off-chip leg straight to its
    owner.  Two adaptations follow:

      * region dimensions shrink to their gcd with the chip dims, so the
        (possibly smaller) regions tile each chip exactly;
      * cascade levels that would outgrow the chip are truncated; if no
        combining level fits, the cascade is dropped entirely (its
        reduction tree would be rooted off-chip).

    Both are schedule changes only: proxy filtering/coalescing and
    hierarchical combining never change the fixed point (min) or the
    delivered sum (add), so distributed results still match the
    monolithic engine.
    """
    rny = math.gcd(cfg.region_ny, sub_ny)
    rnx = math.gcd(cfg.region_nx, sub_nx)
    cascade = cfg.cascade
    if cascade is not None:
        fit = 0
        for level in range(1, cascade.levels + 1):
            lny, lnx = cascade.level_dims(rny, rnx, level)
            if sub_ny % lny or sub_nx % lnx:
                break
            fit = level
        cascade = (dataclasses.replace(cascade, levels=fit) if fit
                   else None)
    return dataclasses.replace(cfg, region_ny=rny, region_nx=rnx,
                               cascade=cascade)


def region_id(grid: TileGrid, cfg: ProxyConfig, tid):
    """Proxy-region id of a tile."""
    return grid.region_id(tid, cfg.region_ny, cfg.region_nx)


def proxy_tile(grid: TileGrid, cfg: ProxyConfig, owner_tid, src_tid):
    """Proxy tile for a message from ``src_tid`` to owner ``owner_tid``.

    The proxy lives in the sender's region, at the owner's coordinates
    modulo the region dimensions (paper Fig. 2).
    """
    return cascade_proxy_tile(grid, cfg.region_ny, cfg.region_nx,
                              owner_tid, src_tid)


def cascade_proxy_tile(grid: TileGrid, region_ny: int, region_nx: int,
                       owner_tid, src_tid):
    """Generalized P_DIST for any region dimensions: the proxy for
    ``owner_tid`` inside the (region_ny x region_nx) region containing
    ``src_tid``.  With level-scaled dimensions this yields each record's
    next hop up the reduction tree."""
    oy, ox = grid.coords(owner_tid)
    sy, sx = grid.coords(src_tid)
    ry0 = (sy // region_ny) * region_ny
    rx0 = (sx // region_nx) * region_nx
    py = ry0 + oy % region_ny
    px = rx0 + ox % region_nx
    return grid.tid(py, px)


def pcache_slot(cfg: ProxyConfig, global_idx):
    """Direct-mapped P$ slot for a global array index.

    Indices that proxy to the same tile are congruent modulo the region
    geometry, so a simple modulo hash distributes them across slots.
    A P$ line holds a single element (paper §III-C) to avoid multi-update
    messages in write-back mode.
    """
    return global_idx % jnp.int32(cfg.slots)


def make_pcache(grid: TileGrid, cfg: ProxyConfig, default_value: float):
    """Allocate per-tile P$ state: (tags, values).  tag == -1 => invalid."""
    shape = (grid.num_tiles, cfg.slots)
    tags = jnp.full(shape, -1, dtype=jnp.int32)
    vals = jnp.full(shape, default_value, dtype=jnp.float32)
    return tags, vals
