"""Sharded, elastic checkpointing.

Layout: <dir>/step_<n>/
    manifest.json    tree structure, per-leaf shape/dtype, mesh metadata
    shard_<k>.npz    leaf payloads (flat key -> array), chunked by bytes

Restore is *elastic*: leaves are loaded as host numpy and re-placed with
whatever sharding the (possibly different-shaped) target mesh dictates —
restart on a different device count is a first-class path (the
multi-thousand-node requirement: any pod can die; the job continues on a
reshaped mesh).  Writes are atomic (tmp dir + rename) so a failure during
save never corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Callable, Optional

import jax
import ml_dtypes
import numpy as np

PyTree = Any
_SHARD_BYTES = 512 * 1024 * 1024

# numpy's npz format can't round-trip ml_dtypes; store them as integer
# views and reconstruct from the manifest's logical dtype.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8, "float16": None}


def _to_storable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC and _EXOTIC[name] is not None:
        return arr.view(_EXOTIC[name]), name
    return arr, name


def _from_storable(arr: np.ndarray, logical: str):
    if logical in _EXOTIC and _EXOTIC[logical] is not None:
        return arr.view(getattr(ml_dtypes, logical))
    return arr


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    extra_meta: Optional[dict] = None) -> str:
    """Write tree atomically; returns the checkpoint path."""
    flat, _ = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = dict(step=step, leaves={}, extra=extra_meta or {})
    shard_idx, shard_bytes, shard_payload = 0, 0, {}

    def flush():
        nonlocal shard_idx, shard_bytes, shard_payload
        if shard_payload:
            np.savez(os.path.join(tmp, f"shard_{shard_idx:04d}.npz"),
                     **shard_payload)
            shard_idx += 1
            shard_bytes = 0
            shard_payload = {}

    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        arr, logical = _to_storable(arr)
        manifest["leaves"][key] = dict(
            shape=list(arr.shape), dtype=logical,
            shard=shard_idx)
        # npz keys cannot contain '/', keep keystr as-is (it uses [''])
        shard_payload[key] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= _SHARD_BYTES:
            flush()
    flush()
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Overlap checkpoint writes with training (DESIGN.md §6).

    ``save`` snapshots the tree to host memory synchronously (device_get —
    cheap relative to serialization) and hands the disk write to a
    background thread; ``wait`` joins the in-flight write (call before
    restore/exit).  At most one write is in flight: a new save waits for
    the previous one first, so checkpoints land in order.
    """

    def __init__(self, directory: str):
        import threading
        self.directory = directory
        self._thread: Optional[object] = None
        self._threading = threading
        self.last_path: Optional[str] = None

    def save(self, step: int, tree: PyTree, extra_meta=None):
        self.wait()
        host_tree = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            self.last_path = save_checkpoint(self.directory, step,
                                             host_tree, extra_meta)

        self._thread = self._threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template: PyTree,
                       step: Optional[int] = None,
                       sharding_fn: Optional[Callable] = None) -> PyTree:
    """Restore into the structure of ``template``.

    sharding_fn(path_str, shape) -> jax.sharding.Sharding | None lets the
    caller re-place leaves on a *different* mesh than the one that wrote
    the checkpoint (elastic restart).  Without it, leaves are host numpy
    converted lazily by first use.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    shards: dict = {}

    def load(key, meta):
        sid = meta["shard"]
        if sid not in shards:
            shards[sid] = np.load(os.path.join(path, f"shard_{sid:04d}.npz"))
        return _from_storable(shards[sid][key], meta["dtype"])

    flat_t, treedef = _flatten(template)
    out = {}
    for key, tleaf in flat_t.items():
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {key}")
        meta = manifest["leaves"][key]
        arr = load(key, meta)
        want_shape = tuple(getattr(tleaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: ckpt shape {arr.shape} != template "
                             f"{want_shape}")
        if sharding_fn is not None:
            sh = sharding_fn(key, arr.shape)
            arr = jax.device_put(arr, sh) if sh is not None else arr
        out[key] = arr
    leaves = [out[k] for k in flat_t.keys()]
    return jax.tree_util.tree_unflatten(treedef, leaves)
