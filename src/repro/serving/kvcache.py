"""KV-cache helpers.

Cache *structure* is family-specific and owned by the model modules
(``fam['init_cache']``); this module adds the serving-level concerns:
capacity planning (bytes/device under a mesh) and ring-buffer metadata
for sliding-window archs.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class CachePlan:
    arch: str
    batch: int
    cache_len: int
    bytes_total: int
    bytes_per_device: int
    ring: bool


def pad_cache(cfg, cache, extra: int):
    """Grow a prefill-built cache's time axis by ``extra`` decode slots.

    Attention-family caches carry time on axis 2 of their (L, B, T, ...)
    leaves; recurrent families (xlstm/ssm states) are O(1) and returned
    unchanged.  Ring (sliding-window) caches never grow."""
    import jax.numpy as jnp

    def grow(leaf, time_axis=2):
        if leaf.ndim <= time_axis:
            return leaf
        pad = [(0, 0)] * leaf.ndim
        pad[time_axis] = (0, extra)
        return jnp.pad(leaf, pad)

    if not isinstance(cache, dict):
        return cache                               # recurrent families
    if cfg.swa_window:                             # ring buffers stay put
        return cache
    out = dict(cache)
    for key in ("k", "v", "dc", "dkr", "mc", "mkr"):
        if key in out:
            out[key] = grow(out[key])
    if "shared" in out and isinstance(out["shared"], dict):
        out["shared"] = {k: grow(v) for k, v in out["shared"].items()}
    return out


def plan_cache(cfg, fam, batch: int, cache_len: int,
               n_devices: int = 1) -> CachePlan:
    """Size the decode cache without allocating it (eval_shape)."""
    shapes = jax.eval_shape(lambda: fam["init_cache"](cfg, batch, cache_len))
    total = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                for l in jax.tree.leaves(shapes))
    return CachePlan(arch=cfg.arch, batch=batch, cache_len=cache_len,
                     bytes_total=total,
                     bytes_per_device=total // max(n_devices, 1),
                     ring=cfg.swa_window > 0)
