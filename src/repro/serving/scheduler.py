"""Continuous-batching serve scheduler (host-side control plane).

Slots hold in-flight requests; finished/empty slots are refilled from the
queue each step so the decode batch stays full — the serving analogue of
the paper's TSU keeping PUs busy from the input queues (§II-B): slot
occupancy is the IQ, the admission queue is the OQ, and refill priority
follows queue pressure.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new: int = 16
    out: Optional[List[int]] = None


class ServeScheduler:
    """Fixed-slot continuous batching over a single shared-length cache.

    Simplification vs paged attention: all slots share one cache capacity
    (max_len); per-slot valid lengths mask attention.  Requests longer
    than the remaining capacity are rejected back to the queue.
    """

    def __init__(self, cfg, fam, params, batch_slots: int, max_len: int,
                 temperature: float = 0.0):
        from .decode import make_serve_step, sample_logits
        self.cfg, self.fam, self.params = cfg, fam, params
        self.slots = batch_slots
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.lengths = np.zeros(batch_slots, np.int32)
        self.cache = fam["init_cache"](cfg, batch_slots, max_len)
        self._step = jax.jit(make_serve_step(cfg, fam, temperature))
        self._sample = sample_logits
        self.tokens = np.zeros((batch_slots, 1), np.int32)
        self.key = jax.random.PRNGKey(0)
        self.completed: List[Request] = []

    def submit(self, req: Request):
        req.out = []
        self.queue.append(req)

    def _admit(self):
        """Fill empty slots; prefill the prompt token-by-token through the
        decode path (single shared cache keeps this simple and exercises
        the same serve_step the dry-run lowers)."""
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                if req.prompt.shape[0] >= self.max_len:
                    continue
                self.active[s] = req
                self.lengths[s] = 0
                # feed prompt tokens sequentially into this slot
                for t in req.prompt:
                    self.tokens[s, 0] = t
                    self._advance(only_slot=s)

    def _advance(self, only_slot: Optional[int] = None):
        self.key, sub = jax.random.split(self.key)
        pos = int(self.lengths.max()) if only_slot is None \
            else int(self.lengths[only_slot])
        nxt, logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.int32(min(pos, self.max_len - 1)), sub)
        nxt = np.asarray(nxt)
        if only_slot is not None:
            self.lengths[only_slot] += 1
            return nxt
        for s, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(nxt[s, 0])
            req.out.append(tok)
            self.tokens[s, 0] = tok
            self.lengths[s] += 1
            if (len(req.out) >= req.max_new
                    or self.lengths[s] >= self.max_len - 1):
                self.completed.append(req)
                self.active[s] = None
        return nxt

    def run(self, max_steps: int = 1000):
        steps = 0
        while (self.queue or any(a is not None for a in self.active)) \
                and steps < max_steps:
            self._admit()
            if any(a is not None for a in self.active):
                self._advance()
            steps += 1
        return self.completed
