"""Serving steps: prefill + single-token decode with sampling.

``serve_step`` is what the decode_32k / long_500k dry-run shapes lower:
one new token against a KV cache of seq_len, optimizer-free.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


def sample_logits(logits, key, temperature: float = 0.0, vocab: int = 0):
    """Greedy (T=0) or temperature sampling.  logits: (B, V_pad)."""
    if vocab:
        vids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        logits = jnp.where(vids < vocab, logits, -jnp.inf)
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)


def make_prefill(cfg, fam) -> Callable:
    """prefill(params, batch) -> (logits_last, cache)."""

    def prefill(params, batch):
        return fam["prefill"](params, batch, cfg)

    return prefill


def make_serve_step(cfg, fam, temperature: float = 0.0) -> Callable:
    """serve_step(params, cache, tokens, pos, key)
       -> (next_tokens, logits, cache).

    tokens: (B, 1) current token; pos: scalar absolute position.
    """

    def serve_step(params, cache, tokens, pos, key):
        logits, cache = fam["decode"](params, cache, tokens, pos, cfg)
        nxt = sample_logits(logits, key, temperature, cfg.vocab)
        return nxt[:, None], logits, cache

    return serve_step


def generate(cfg, fam, params, batch, steps: int, temperature: float = 0.0,
             key=None):
    """Host loop: prefill then `steps` decode steps (example/test path)."""
    from .kvcache import pad_cache
    key = key if key is not None else jax.random.PRNGKey(0)
    prefill = jax.jit(make_prefill(cfg, fam))
    step = jax.jit(make_serve_step(cfg, fam, temperature))
    logits, cache = prefill(params, batch)
    cache = pad_cache(cfg, cache, steps)           # decode headroom
    tok = sample_logits(logits[:, -1], key, temperature, cfg.vocab)[:, None]
    if "tokens" in batch:
        pos0 = batch["tokens"].shape[1]
    else:
        pos0 = batch["embeds"].shape[1]
    out = [tok]
    for i in range(steps - 1):
        key, sub = jax.random.split(key)
        tok, _, cache = step(params, cache, tok, jnp.int32(pos0 + i), sub)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
