from .decode import make_prefill, make_serve_step, sample_logits
from .scheduler import Request, ServeScheduler

__all__ = ["make_prefill", "make_serve_step", "sample_logits", "Request",
           "ServeScheduler"]
