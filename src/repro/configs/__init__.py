# seed: unused — serving-stack arch config from the repo seed; nothing in the
# chiplet engine/tests imports it (repro.analysis.deadcode quarantine).
"""Per-architecture config modules (``--arch <id>``).

Each module exports CONFIG (exact published dims), SMOKE (reduced), and
SHAPES (which assigned input shapes apply).  ``get(arch)`` resolves by id.
"""
import importlib

ARCH_IDS = [
    "starcoder2-3b", "starcoder2-15b", "deepseek-7b", "h2o-danube-3-4b",
    "pixtral-12b", "deepseek-v3-671b", "granite-moe-1b-a400m", "xlstm-1.3b",
    "whisper-tiny", "zamba2-1.2b",
]


def get(arch: str):
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    return mod
