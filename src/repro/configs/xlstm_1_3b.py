# seed: unused — serving-stack arch config from the repo seed; nothing in the
# chiplet engine/tests imports it (repro.analysis.deadcode quarantine).
"""sLSTM + mLSTM recurrent LM [arXiv:2405.04517; unverified]

Exact assigned dimensions live in ``repro.models.registry.ARCHS``; this
module is the ``--arch xlstm-1.3b`` entry point exposing the full config, the
reduced smoke config, and the applicable input shapes.
"""
from repro.models import registry

ARCH = "xlstm-1.3b"
CONFIG = registry.ARCHS[ARCH]
SMOKE = registry.reduced(CONFIG)
# (shape -> applies) long_500k needs sub-quadratic attention (DESIGN.md
# §Arch-applicability); decode applies to every assigned arch (all decode).
SHAPES = {
    "train_4k": True,
    "prefill_32k": True,
    "decode_32k": True,
    "long_500k": True,
}
